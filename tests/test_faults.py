"""Seeded chaos suite for ``repro.faults`` (ISSUE-6 tentpole).

Covers: deterministic fault schedules and their JSON round-trip, the
``FaultyFabric`` wrapper (probe timeouts, corrupted samples, link
degradation, membership replay), retry policy + backoff, the session
health state machine and monitor ladder (degraded → halted, identity
pinned, no exception escape, no hot-spin), plan-cache quarantine of
corrupted store files, drift/reranker input validation, the
elastic-restriction consistency set (``Fabric.subset`` /
``ProbeResult.subset`` / ``SparseProbeResult.subset`` /
``HierarchyModel.restrict`` agree), the degradation-ladder invariant at
every rung, and ``Session.on_node_leave`` / ``on_node_join`` churn.

Everything is seeded — the chaos is reproducible by construction.
"""

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.fabric import (
    make_datacenter,
    probe_fabric,
    scramble,
    sparse_probe_fabric,
)
from repro.faults import (
    FAULT_KINDS,
    HEALTH_STATES,
    LADDER_RUNGS,
    FaultEvent,
    FaultSchedule,
    FaultyFabric,
    HealthTracker,
    ProbeTimeout,
    RetryError,
    RetryPolicy,
    call_with_retries,
    identity_fallback,
    recover_entry,
    recover_plan,
    restrict_perm,
)
from repro.plan import (
    CollectiveRequest,
    JobMix,
    PlanCache,
    PlanCompiler,
    SolveBudget,
)
from repro.plan.cache import DriftMonitor
from repro.session import Session, SessionConfig, SessionError

SMALL = {
    "fabric": {"kind": "datacenter", "nodes": 12, "scramble_seed": 1},
    "probe": {"n_probes": 2},
    "solver": {"budget": {"iters": 60, "chains": 2}},
    "payload_bytes": 1e6,
}


def small_config(**over):
    return SessionConfig.from_dict(SMALL).replace(**over)


def small_mix():
    return JobMix((CollectiveRequest("all-reduce", 1 << 20),), name="t")


def compile_small(n=10, seed=0, iters=60):
    fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
    probe = probe_fabric(fab, n_probes=2, seed=seed)
    comp = PlanCompiler(budget=SolveBudget(iters=iters, chains=2), seed=seed)
    return fab, probe, comp.compile(probe, small_mix())


# ---------------------------------------------------------------------------
# FaultSchedule / FaultEvent
# ---------------------------------------------------------------------------

class TestFaultSchedule:
    def test_generate_deterministic(self):
        a = FaultSchedule.generate(16, ticks=32, seed=7)
        b = FaultSchedule.generate(16, ticks=32, seed=7)
        assert a.to_dict() == b.to_dict()
        c = FaultSchedule.generate(16, ticks=32, seed=8)
        assert a.to_dict() != c.to_dict()

    def test_json_round_trip(self):
        s = FaultSchedule.generate(16, ticks=16, seed=3, preempt_frac=0.25)
        blob = json.dumps(s.to_dict())
        back = FaultSchedule.from_dict(json.loads(blob))
        assert back.to_dict() == s.to_dict()
        assert back.events == s.events

    def test_kinds_are_known(self):
        s = FaultSchedule.generate(16, ticks=32, seed=0, preempt_frac=0.25)
        assert {e.kind for e in s.events} <= set(FAULT_KINDS)

    def test_preempt_frac_schedules_leave_and_rejoin(self):
        s = FaultSchedule.generate(16, ticks=32, seed=0, preempt_frac=0.25)
        kinds = [e.kind for e in s.events]
        assert "node_preempt" in kinds and "node_join" in kinds
        pre = next(e for e in s.events if e.kind == "node_preempt")
        join = next(e for e in s.events if e.kind == "node_join")
        assert len(pre.nodes) == 4           # 25% of 16
        assert join.tick > pre.tick
        assert set(join.nodes) == set(pre.nodes)

    def test_event_active_window(self):
        e = FaultEvent("link_degrade", tick=5, duration=3, factor=2.0)
        assert not e.active_at(4)
        assert e.active_at(5) and e.active_at(7)
        assert not e.active_at(8)

    def test_bad_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent("coffee_spill", tick=0)


# ---------------------------------------------------------------------------
# FaultyFabric
# ---------------------------------------------------------------------------

class TestFaultyFabric:
    def _fab(self, n=8):
        return make_datacenter(n, seed=0)

    def test_clean_schedule_is_transparent(self):
        fab = self._fab()
        ff = FaultyFabric(fab, FaultSchedule(events=(), seed=0))
        np.testing.assert_allclose(ff.lat, fab.lat)
        np.testing.assert_allclose(ff.bw, fab.bw)
        assert ff.n == fab.n

    def test_probe_timeout_raises(self):
        fab = self._fab()
        ff = FaultyFabric(fab, FaultSchedule(
            events=(FaultEvent("probe_timeout", tick=0),), seed=0))
        with pytest.raises(ProbeTimeout):
            _ = ff.lat

    def test_corruption_is_seeded_and_marks_entries(self):
        fab = self._fab()
        sched = FaultSchedule(
            events=(FaultEvent("probe_nan", tick=0, frac=0.2),), seed=5)
        a = FaultyFabric(fab, sched).lat
        b = FaultyFabric(fab, sched).lat
        np.testing.assert_array_equal(np.isnan(a), np.isnan(b))
        assert np.isnan(a).any()

    def test_link_degrade_inflates_cost(self):
        fab = self._fab()
        ff = FaultyFabric(fab, FaultSchedule(events=(
            FaultEvent("link_degrade", tick=0, duration=4,
                       nodes=(1,), factor=4.0),), seed=0))
        assert ff.lat[1, 2] > fab.lat[1, 2]
        assert ff.bw[1, 2] < fab.bw[1, 2]
        # untouched pair stays put
        np.testing.assert_allclose(ff.lat[3, 4], fab.lat[3, 4])

    def test_advance_returns_membership_and_alive_replays(self):
        fab = self._fab()
        sched = FaultSchedule(events=(
            FaultEvent("node_preempt", tick=2, nodes=(1, 5)),
            FaultEvent("node_join", tick=4, nodes=(5,)),), seed=0)
        ff = FaultyFabric(fab, sched)
        assert ff.advance() == []                       # tick 1
        evs = ff.advance()                              # tick 2
        assert [e.kind for e in evs] == ["node_preempt"]
        assert sorted(ff.alive()) == [0, 2, 3, 4, 6, 7]
        ff.advance(2)                                   # tick 4
        assert sorted(ff.alive()) == [0, 2, 3, 4, 5, 6, 7]

    def test_subset_delegates_to_base(self):
        fab = self._fab()
        ff = FaultyFabric(fab, FaultSchedule(events=(), seed=0))
        sub = ff.subset([0, 2, 4])
        np.testing.assert_allclose(
            sub.lat, fab.lat[np.ix_([0, 2, 4], [0, 2, 4])])


# ---------------------------------------------------------------------------
# RetryPolicy / call_with_retries
# ---------------------------------------------------------------------------

class TestRetry:
    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RetryPolicy(base_delay_s=-0.1)
        with pytest.raises(ValueError):
            RetryPolicy(multiplier=0.5)
        with pytest.raises(ValueError):
            RetryPolicy(jitter=2.0)
        with pytest.raises(ValueError):
            RetryPolicy(failure_threshold=0)
        with pytest.raises(ValueError):
            RetryPolicy(halt_threshold=2, failure_threshold=3)

    def test_delay_grows_and_caps(self):
        p = RetryPolicy(base_delay_s=0.1, max_delay_s=0.5, multiplier=2.0,
                        jitter=0.0)
        ds = [p.delay(a) for a in range(1, 7)]
        assert ds[0] == pytest.approx(0.1)
        assert ds[1] == pytest.approx(0.2)
        assert all(d <= 0.5 + 1e-12 for d in ds)
        assert ds[-1] == pytest.approx(0.5)

    def test_jitter_is_bounded_and_seeded(self):
        p = RetryPolicy(base_delay_s=0.1, max_delay_s=10.0, jitter=0.5,
                        seed=3)
        rng = np.random.default_rng(3)
        ds = [p.delay(1, rng) for _ in range(50)]
        assert all(0.05 <= d <= 0.15 + 1e-12 for d in ds)
        rng2 = np.random.default_rng(3)
        assert ds[0] == pytest.approx(p.delay(1, rng2))

    def test_call_with_retries_recovers(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise TimeoutError("transient")
            return 42

        slept = []
        out = call_with_retries(
            flaky, RetryPolicy(max_retries=3, base_delay_s=0.01,
                               jitter=0.0),
            sleep=slept.append)
        assert out == 42 and calls["n"] == 3
        assert len(slept) == 2 and all(s > 0 for s in slept)

    def test_call_with_retries_exhausts(self):
        def broken():
            raise ProbeTimeout("probe lost")

        with pytest.raises(RetryError) as ei:
            call_with_retries(
                broken, RetryPolicy(max_retries=2, base_delay_s=0.0),
                sleep=lambda s: None)
        assert ei.value.attempts == 3
        assert isinstance(ei.value.last, ProbeTimeout)


# ---------------------------------------------------------------------------
# HealthTracker
# ---------------------------------------------------------------------------

class TestHealthTracker:
    def test_states_and_ladder(self):
        h = HealthTracker(failure_threshold=2, halt_threshold=4)
        assert h.state == "healthy" and h.state in HEALTH_STATES
        assert h.record_failure("a") is None
        assert h.record_failure("b") == "degraded"
        assert h.record_failure("c") is None
        assert h.record_failure("d") == "halted"
        # halted is sticky
        h.record_success()
        assert h.state == "halted"
        h.reset()
        assert h.state == "healthy" and h.consecutive_failures == 0

    def test_success_heals_degraded(self):
        h = HealthTracker(failure_threshold=1, halt_threshold=10)
        assert h.record_failure("x") == "degraded"
        assert h.record_success() == "healthy"
        assert h.state == "healthy"

    def test_force_degraded(self):
        h = HealthTracker()
        assert h.force_degraded("ladder") == "degraded"
        assert h.force_degraded("again") is None       # already there


# ---------------------------------------------------------------------------
# monitor() under injected faults
# ---------------------------------------------------------------------------

class TestMonitorLadder:
    def test_degraded_then_halted_no_escape(self):
        cfg = small_config(retry={
            "max_retries": 0, "base_delay_s": 0.001, "max_delay_s": 0.005,
            "jitter": 0.0, "failure_threshold": 2, "halt_threshold": 4})
        seen = []
        polls = {"n": 0}

        def poll():
            polls["n"] += 1
            raise ProbeTimeout("injected")

        with Session(cfg) as s:
            s.plan(small_mix())
            s.on("degraded",
                 lambda sess, **i: seen.append(i.get("state")))
            t = s.monitor(poll=poll, interval_s=0.002)
            deadline = time.time() + 5.0
            while t.is_alive() and time.time() < deadline:
                time.sleep(0.01)
            assert not t.is_alive(), "monitor thread should stop at halt"
            assert s.health == "halted"
            assert seen[0] == "degraded" and "halted" in seen
            # halt pinned every entry to identity order in place
            for e in s.planned.entries.values():
                assert e.perm == e.group
            # halt_threshold failures, not a hot spin
            assert polls["n"] == 4

    def test_monitor_recovers_and_fires_hook(self):
        cfg = small_config(retry={
            "max_retries": 0, "base_delay_s": 0.001, "jitter": 0.0,
            "failure_threshold": 1, "halt_threshold": 10})
        events = []
        fail_first = {"n": 2}

        def poll():
            if fail_first["n"] > 0:
                fail_first["n"] -= 1
                raise ProbeTimeout("early wobble")
            return None   # healthy tick, nothing to observe

        with Session(cfg) as s:
            s.plan(small_mix())
            s.on("degraded", lambda sess, **i: events.append("degraded"))
            s.on("recovered", lambda sess, **i: events.append("recovered"))
            s.monitor(poll=poll, interval_s=0.002)
            deadline = time.time() + 5.0
            while "recovered" not in events and time.time() < deadline:
                time.sleep(0.01)
            assert events[:1] == ["degraded"]
            assert "recovered" in events
            assert s.health == "healthy"

    def test_hook_exception_does_not_kill_monitor(self):
        cfg = small_config(retry={
            "max_retries": 0, "base_delay_s": 0.001, "jitter": 0.0,
            "failure_threshold": 1, "halt_threshold": 3})

        def bad_hook(sess, **info):
            raise RuntimeError("hook bug")

        def poll():
            raise ProbeTimeout("injected")

        with Session(cfg) as s:
            s.plan(small_mix())
            s.on("degraded", bad_hook)
            with pytest.warns(RuntimeWarning):
                t = s.monitor(poll=poll, interval_s=0.002)
                deadline = time.time() + 5.0
                while t.is_alive() and time.time() < deadline:
                    time.sleep(0.01)
            assert s.health == "halted"

    def test_probe_retries_through_transient_failure(self):
        # a fabric whose lat property fails twice then heals: attach()
        # must succeed through the retry policy
        fab, _ = scramble(make_datacenter(12, seed=0), seed=1)
        ff = FaultyFabric(fab, FaultSchedule(events=(
            FaultEvent("probe_timeout", tick=1, duration=2),), seed=0))

        class HealingFabric:
            def __getattr__(self, name):
                return getattr(ff, name)

            @property
            def lat(self):
                ff.advance()
                return ff.lat

        cfg = small_config(retry={"max_retries": 3, "base_delay_s": 0.001,
                                  "jitter": 0.0})
        with Session(cfg) as s:
            s.attach(HealingFabric())
            assert s.probe.n == 12


# ---------------------------------------------------------------------------
# PlanCache quarantine
# ---------------------------------------------------------------------------

class TestCacheQuarantine:
    def test_corrupt_store_file_quarantined(self, tmp_path):
        fab, probe, plan = compile_small()
        d = str(tmp_path)
        PlanCache(store_dir=d).put(plan, "k")
        fname = [f for f in os.listdir(d) if f.endswith(".json")][0]
        with open(os.path.join(d, fname), "w") as f:
            f.write("{not json")
        fresh = PlanCache(store_dir=d)
        with pytest.warns(RuntimeWarning, match="quarantin"):
            got = fresh.get(plan.fingerprint, "k")
        assert got is None
        files = os.listdir(d)
        assert fname not in files
        assert fname + ".corrupt" in files

    def test_valid_entry_unaffected_by_corrupt_neighbor(self, tmp_path):
        from repro.plan.cache import _request_tag

        fab, probe, plan = compile_small()
        d = str(tmp_path)
        PlanCache(store_dir=d).put(plan, "k")
        # scanned first (sorted order), same request tag as the real one
        with open(os.path.join(d, f"aaaa__{_request_tag('k')}.json"),
                  "w") as f:
            f.write("][")
        fresh = PlanCache(store_dir=d)
        with pytest.warns(RuntimeWarning):
            got = fresh.get(plan.fingerprint, "k")
        assert got is not None
        assert got.entries.keys() == plan.entries.keys()

    def test_truncated_payload_never_raises(self, tmp_path):
        fab, probe, plan = compile_small()
        d = str(tmp_path)
        PlanCache(store_dir=d).put(plan, "k")
        fname = [f for f in os.listdir(d) if f.endswith(".json")][0]
        path = os.path.join(d, fname)
        with open(path, "w") as f:
            json.dump({"fingerprint": "yes", "entries": "nope"}, f)
        fresh = PlanCache(store_dir=d)
        with pytest.warns(RuntimeWarning):
            assert fresh.get(plan.fingerprint, "k") is None


# ---------------------------------------------------------------------------
# input validation: DriftMonitor.observe / AdaptiveReranker.update
# ---------------------------------------------------------------------------

class TestObserverValidation:
    def _monitor(self):
        fab, probe, plan = compile_small()
        from repro.fabric import cost_matrix
        return plan, DriftMonitor(plan, cost_matrix(probe, 1e6))

    def test_drift_rejects_bad_inputs(self):
        plan, mon = self._monitor()
        n = plan.n
        with pytest.raises(ValueError, match="square"):
            mon.observe(np.zeros((n, n - 1)))
        with pytest.raises(ValueError, match=str(n)):
            mon.observe(np.zeros((n + 2, n + 2)))
        bad = np.ones((n, n))
        bad[0, 1] = np.nan
        with pytest.raises(ValueError, match="NaN"):
            mon.observe(bad)
        neg = np.ones((n, n))
        neg[2, 3] = -1.0
        with pytest.raises(ValueError, match=r"\[2, 3\]"):
            mon.observe(neg)

    def test_reranker_rejects_bad_inputs(self):
        from repro.core import RingCost
        from repro.core.dynamic import AdaptiveReranker

        rr = AdaptiveReranker(
            model_factory=lambda c: RingCost(len(c), 1e6, c),
            perm=np.arange(6))
        with pytest.raises(ValueError, match="square"):
            rr.update(np.zeros((6, 5)))
        with pytest.raises(ValueError, match="6"):
            rr.update(np.zeros((4, 4)))
        bad = np.ones((6, 6))
        bad[1, 2] = np.nan
        with pytest.raises(ValueError, match="NaN"):
            rr.update(bad)
        neg = np.ones((6, 6))
        neg[0, 5] = -3.0
        with pytest.raises(ValueError, match="negative"):
            rr.update(neg)

    def test_reranker_still_reranks_valid_input(self):
        from repro.core import RingCost
        from repro.core.dynamic import AdaptiveReranker

        rng = np.random.default_rng(0)
        c = rng.uniform(1, 2, (8, 8))
        c = (c + c.T) / 2
        np.fill_diagonal(c, 0)
        rr = AdaptiveReranker(
            model_factory=lambda m: RingCost(len(m), 1e6, m),
            perm=np.arange(8), threshold=1.01)
        perm, changed = rr.update(c)
        assert sorted(perm.tolist()) == list(range(8))


# ---------------------------------------------------------------------------
# elastic restriction consistency
# ---------------------------------------------------------------------------

class TestRestrictionConsistency:
    def test_fabric_and_probe_subsets_agree(self):
        fab, _ = scramble(make_datacenter(12, seed=0), seed=1)
        probe = probe_fabric(fab, n_probes=2, seed=0)
        keep = [0, 2, 3, 7, 8, 11]
        sub_fab = fab.subset(keep)
        sub_probe = probe.subset(keep)
        ix = np.ix_(keep, keep)
        np.testing.assert_allclose(sub_fab.lat, fab.lat[ix])
        np.testing.assert_allclose(sub_probe.lat, probe.lat[ix])
        np.testing.assert_allclose(sub_probe.bw, probe.bw[ix])
        assert sub_probe.n == len(keep)

    def test_probe_subset_validation_mirrors_fabric(self):
        fab = make_datacenter(8, seed=0)
        probe = probe_fabric(fab, n_probes=2, seed=0)
        for bad in ([], [0, 0, 1], [0, 99]):
            with pytest.raises(ValueError):
                probe.subset(bad)
            with pytest.raises(ValueError):
                fab.subset(bad)

    def test_sparse_subset_restricts_hierarchy_and_landmarks(self):
        fab, _ = scramble(make_datacenter(16, seed=0), seed=1)
        sp = sparse_probe_fabric(fab, seed=0)
        keep = list(range(0, 16, 2))
        sub = sp.subset(keep)
        ix = np.ix_(keep, keep)
        np.testing.assert_allclose(sub.lat, sp.lat[ix])
        assert sub.n == len(keep)
        # hierarchy restriction agrees with restricting the original
        want = sp.hierarchy.restrict(keep)
        assert sub.hierarchy.labels(0).shape == (len(keep),)
        for tier in range(want.n_tiers):
            np.testing.assert_array_equal(
                sub.hierarchy.labels(tier), want.labels(tier))
        # landmarks remapped into the new numbering
        assert all(0 <= lm < len(keep) for lm in sub.landmarks)

    def test_hierarchy_restrict_preserves_grouping(self):
        fab, _ = scramble(make_datacenter(16, seed=0), seed=1)
        sp = sparse_probe_fabric(fab, seed=0)
        h = sp.hierarchy
        keep = [0, 1, 2, 3, 8, 9, 10, 11]
        sub = h.restrict(keep)
        for tier in range(h.n_tiers):
            lab, slab = h.labels(tier), sub.labels(tier)
            for i, a in enumerate(keep):
                for j, b in enumerate(keep):
                    assert (lab[a] == lab[b]) == (slab[i] == slab[j])


# ---------------------------------------------------------------------------
# the degradation ladder
# ---------------------------------------------------------------------------

class TestLadder:
    def test_restrict_perm(self):
        assert restrict_perm([3, 1, 4, 0, 2], {1, 3, 4}) == [3, 1, 4]
        assert restrict_perm([0, 1, 2], {0, 1, 2}) == [0, 1, 2]
        assert restrict_perm([2, 0, 1], set()) == []

    def _recover(self, monkeypatch=None, drop=(1, 5, 9)):
        fab, probe, plan = compile_small(n=12)
        survivors = [i for i in range(12) if i not in set(drop)]
        o2n = {old: new for new, old in enumerate(survivors)}
        ix = np.ix_(survivors, survivors)
        entry = next(iter(plan.entries.values()))
        return entry, o2n, probe.lat[ix], probe.bw[ix]

    def test_warm_rung_valid_and_never_worse(self):
        entry, o2n, lat, bw = self._recover()
        new, rung = recover_entry(entry, o2n, lat, bw)
        assert rung == "warm_resolve" and rung in LADDER_RUNGS
        assert sorted(new.perm) == list(new.group)
        assert new.expected_time <= new.best_identity_time * (1 + 1e-9)

    def test_hot_patch_rung(self, monkeypatch):
        import repro.faults.ladder as ladder

        monkeypatch.setattr(ladder, "warm_refine",
                            lambda *a, **k: 1 / 0)
        entry, o2n, lat, bw = self._recover()
        new, rung = recover_entry(entry, o2n, lat, bw)
        assert rung in ("hot_patch", "identity")
        assert sorted(new.perm) == list(new.group)
        assert new.expected_time <= new.best_identity_time * (1 + 1e-9)

    def test_stale_rung(self, monkeypatch):
        import repro.faults.ladder as ladder

        monkeypatch.setattr(ladder, "warm_refine", lambda *a, **k: 1 / 0)
        monkeypatch.setattr(ladder, "bottleneck_swap",
                            lambda *a, **k: 1 / 0)
        entry, o2n, lat, bw = self._recover()
        new, rung = recover_entry(entry, o2n, lat, bw)
        assert rung in ("stale", "identity")
        assert sorted(new.perm) == list(new.group)
        assert new.expected_time <= new.best_identity_time * (1 + 1e-9)

    def test_identity_rung_guard(self, monkeypatch):
        import repro.faults.ladder as ladder
        from repro.plan.compiler import PlanEntry

        # refiners are out, so the stale rung would serve the old perm —
        # which on this matrix is priced far above identity.  The final
        # guard must land on the identity rung.
        monkeypatch.setattr(ladder, "warm_refine", lambda *a, **k: 1 / 0)

        def bad_swap(model, perm, **kw):
            raise RuntimeError("no swap either")

        monkeypatch.setattr(ladder, "bottleneck_swap", bad_swap)
        n = 6
        lat = np.full((n, n), 100.0)          # identity-adjacent cheap,
        for i in range(n):                    # everything else expensive
            lat[i, i] = 0.0
            lat[i, (i + 1) % n] = lat[(i + 1) % n, i] = 1.0
        entry = PlanEntry(
            op="all-reduce", bucket=0, size_bytes=1e6,
            group=tuple(range(n)), algo="ring", algo_kwargs={}, chunks=1,
            perm=(0, 3, 1, 4, 2, 5),          # every hop is a 100x edge
            expected_time=0.0, identity_times={}, solver_cost=0.0,
            oracle="", program_fingerprint="")
        o2n = {i: i for i in range(n)}
        new, rung = recover_entry(entry, o2n, lat, None)
        assert rung == "identity"
        assert new.perm == new.group
        assert new.expected_time == pytest.approx(new.best_identity_time)

    def test_dropped_when_too_few_survive(self):
        entry, o2n, lat, bw = self._recover()
        tiny = {k: v for k, v in list(o2n.items())[:1]}
        new, rung = recover_entry(entry, tiny, lat[:1, :1], bw[:1, :1])
        assert new is None and rung == "dropped"

    def test_infeasible_algo_reselected(self):
        # drop to a non-power-of-two size: pow-2-only builders must be
        # replaced by a feasible candidate
        import dataclasses

        fab, probe, plan = compile_small(n=8)
        entry = dataclasses.replace(
            next(iter(plan.entries.values())),
            algo="halving_doubling", algo_kwargs={})
        survivors = [0, 1, 2, 4, 5, 6, 7]
        o2n = {old: new for new, old in enumerate(survivors)}
        ix = np.ix_(survivors, survivors)
        new, rung = recover_entry(entry, o2n, probe.lat[ix], probe.bw[ix])
        assert new.algo != "halving_doubling"
        assert sorted(new.perm) == list(new.group)

    def test_recover_plan_with_joiners(self):
        fab, probe, plan = compile_small(n=10)
        survivors = [0, 1, 2, 3, 4, 5, 6, 7]        # 8 survive
        o2n = {old: new for new, old in enumerate(survivors)}
        # two joiners appended at new-local ids 8, 9
        lat, bw = probe.lat, probe.bw               # same size by luck: 10
        new_plan, rungs = recover_plan(plan, o2n, lat, bw, joiners=(8, 9))
        assert new_plan.n == 10
        for e in new_plan.entries.values():
            assert sorted(e.perm) == list(e.group)
            assert len(e.group) == 10               # absorbed the joiners
        assert set(rungs.values()) <= set(LADDER_RUNGS) | {"dropped"}
        assert new_plan.meta["recovered_from"] == plan.fingerprint.digest

    def test_identity_fallback_pins_in_place(self):
        fab, probe, plan = compile_small(n=10)
        changed = identity_fallback(plan)
        assert changed >= 0
        for e in plan.entries.values():
            assert e.perm == e.group
        assert plan.meta.get("fallback") == "identity"


# ---------------------------------------------------------------------------
# Session elastic membership
# ---------------------------------------------------------------------------

class TestElasticSession:
    def test_leave_then_join_round_trip(self):
        cfg = small_config()
        events = []
        with Session(cfg) as s:
            s.plan(small_mix())
            s.on("node_leave", lambda sess, **i: events.append(
                ("leave", i["survivors"])))
            s.on("node_join", lambda sess, **i: events.append(
                ("join", i["nodes"])))
            plan = s.on_node_leave([1, 5, 9])
            assert plan is not None and plan.n == 9
            assert s.alive == [0, 2, 3, 4, 6, 7, 8, 10, 11]
            assert s.probe.n == 9
            for e in plan.entries.values():
                assert sorted(e.perm) == list(e.group)
            plan2 = s.on_node_join([1, 5])
            assert plan2 is not None and plan2.n == 11
            assert 1 in s.alive and 5 in s.alive
            assert events[0][0] == "leave" and events[1][0] == "join"

    def test_leave_error_paths(self):
        with Session(small_config()) as s:
            s.plan(small_mix())
            with pytest.raises(ValueError, match="at least one"):
                s.on_node_leave([])
            with pytest.raises(ValueError, match="outside"):
                s.on_node_leave([99])
            with pytest.raises(SessionError, match="survivors"):
                s.on_node_leave(list(range(11)))

    def test_join_error_paths(self):
        with Session(small_config()) as s:
            s.plan(small_mix())
            with pytest.raises(SessionError, match="already live"):
                s.on_node_join()
            s.on_node_leave([0])
            with pytest.raises(ValueError, match="not departed"):
                s.on_node_join([3])

    def test_leave_without_plan_is_fine(self):
        with Session(small_config()) as s:
            s.attach()
            assert s.on_node_leave([0, 1]) is None
            assert s.probe.n == 10

    def test_mesh_plan_dropped_on_churn(self):
        cfg = SessionConfig.from_dict({
            "fabric": {"kind": "datacenter", "nodes": 12,
                       "scramble_seed": 1},
            "probe": {"n_probes": 2},
            "solver": {"budget": {"iters": 60, "chains": 2}},
            "mesh": {"shape": (3, 4)},
        })
        with Session(cfg) as s:
            plan = s.plan(small_mix())
            assert plan.mesh_plan is not None
            new = s.on_node_leave([0])
            assert new is not None and new.mesh_plan is None

    def test_churn_under_generated_schedule(self):
        # the acceptance scenario in miniature: 25% preemption
        # mid-session; recovery valid at every event, nothing escapes
        n = 12
        fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
        sched = FaultSchedule.generate(
            n, ticks=8, seed=0, preempt_frac=0.25,
            timeout_rate=0.0, drop_rate=0.0, nan_rate=0.0)
        ff = FaultyFabric(fab, sched)
        with Session(small_config()) as s:
            s.attach(fab)
            s.plan(small_mix())
            handled = 0
            for _ in range(8):
                for ev in ff.advance():
                    if ev.kind == "node_preempt":
                        alive = s.alive
                        plan = s.on_node_leave(
                            [alive.index(b) for b in ev.nodes
                             if b in alive])
                    else:
                        plan = s.on_node_join(
                            [b for b in ev.nodes if b not in s.alive])
                    handled += 1
                    assert plan is not None
                    for e in plan.entries.values():
                        assert sorted(e.perm) == list(e.group)
                        assert e.expected_time <= \
                            e.best_identity_time * (1 + 1e-9)
            assert handled >= 2                      # preempt + rejoin
            assert len(s.alive) == n                 # everyone came back
