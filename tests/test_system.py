"""End-to-end behaviour tests for the paper's system.

The headline invariant (the paper's thesis): starting from the random
order the cloud hands you, the full pipeline — probe -> cost model ->
solve -> reorder — must produce an ordering that is faster *when actually
executed* (simulated with contention), across fabrics and seeds; and the
whole thing must survive training-loop integration (reordered plan +
checkpoint/restart + rerank) without touching model code.
"""

import dataclasses

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.core import (
    CollectiveSimulator,
    cost_matrix,
    make_cost_model,
    make_datacenter,
    make_tpu_fleet,
    optimize_mesh_assignment,
    optimize_rank_order,
    probe_fabric,
    scramble,
    solve_worst,
)


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_pipeline_beats_random_orders_property(seed):
    """Property (hypothesis): on any generated fabric, the solved order's
    simulated time <= the mean of random orders.  This is the system's
    contract; it must hold regardless of topology seed."""
    fab, _ = scramble(make_datacenter(32, seed=seed), seed=seed + 1)
    c = cost_matrix(probe_fabric(fab, seed=seed + 2))
    res = optimize_rank_order(c, "ring", method="paper", iters=400, seed=0)
    sim = CollectiveSimulator(fab, "ring", 50e6)
    rng = np.random.default_rng(seed)
    t_solved = sim.run(res.perm)
    t_rand = sim.run_many([rng.permutation(32) for _ in range(8)])
    assert t_solved <= t_rand.mean() * 1.02


def test_reordered_mesh_is_transparent_to_the_model():
    """The paper's non-intrusiveness claim, JAX edition: the same jitted
    train step runs identically (same loss) on an identity-ordered and a
    reordered mesh — reordering changes only device placement."""
    from repro.configs import get_config
    from repro.data import SyntheticLM, host_batch
    from repro.launch.mesh import make_mesh_for_tests, make_reordered_mesh
    from repro.models import get_model
    from repro.optim import AdamWConfig
    from repro.train import init_state, make_train_step

    cfg = get_config("qwen2-0.5b").smoke()
    model = get_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
    batch = host_batch(ds, 0)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))

    # 1-device process: both meshes are (1, 1); the reordered one goes
    # through the MeshPlan -> device-permutation code path.
    fleet = make_tpu_fleet(n_pods=1, pod_shape=(1, 1), seed=0)
    c = cost_matrix(probe_fabric(fleet, seed=1))
    plan = optimize_mesh_assignment(c, (1, 1), ("data", "model"))
    mesh_r = make_reordered_mesh(plan)
    mesh_i = make_mesh_for_tests((1, 1), ("data", "model"))

    with jax.set_mesh(mesh_i):
        _, m1 = step(state, batch)
    with jax.set_mesh(mesh_r):
        _, m2 = step(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-6)


def test_fleet_reorder_recovers_pod_structure():
    """On a scrambled 2-pod fleet the hierarchical mesh plan should place
    the DCN boundary on the pod axis: chips within one solved pod group
    should overwhelmingly come from one physical pod."""
    fleet = make_tpu_fleet(n_pods=2, pod_shape=(4, 4), seed=3)
    scr, hidden = scramble(fleet, seed=4)
    c = cost_matrix(probe_fabric(scr, seed=5), 4e6)
    plan = optimize_mesh_assignment(c, (2, 4, 4), ("pod", "data", "model"))
    # map solved ids back to true pod ids
    true_pod = hidden[plan.assignment.reshape(2, -1)] // 16
    purity = max(
        (true_pod[0] == 0).mean() + (true_pod[1] == 1).mean(),
        (true_pod[0] == 1).mean() + (true_pod[1] == 0).mean()) / 2
    assert purity > 0.9, f"pod purity {purity}"


def test_dryrun_cell_small_mesh():
    """The dry-run machinery end to end on a 1-device mesh: lower +
    compile + roofline artifact for a smoke config."""
    from repro.configs import SHAPES
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_mesh_for_tests
    from repro.launch.specs import input_specs, step_callable
    from repro.configs import get_config
    from repro.launch import hlo_analysis as ha

    cfg = dataclasses.replace(get_config("qwen2-0.5b").smoke(), use_scan=True)
    shape = ShapeSpec("tiny_train", 16, 4, "train")
    mesh = make_mesh_for_tests((1, 1), ("data", "model"))
    with jax.set_mesh(mesh):
        lowered = jax.jit(step_callable(cfg, shape)).lower(
            *input_specs(cfg, shape, mesh))
        compiled = lowered.compile()
    assert compiled.cost_analysis().get("flops", 0) > 0
    stats = ha.parse_collectives(compiled.as_text())
    assert stats.total_bytes >= 0  # 1-device: no collectives expected
    terms = ha.roofline_terms(1e12, 1e10, 1e8, 256)
    assert terms["dominant"] in ("compute", "memory", "collective")


def test_multidevice_ring_and_pipeline_subprocess():
    """Ring collective + pipeline parallelism on 8 host devices (separate
    process so the main test process keeps its single-device jax)."""
    import os
    import subprocess
    import sys

    prog = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.kernels import ring_reduce_scatter
from repro.kernels.ref import ring_reduce_scatter_ref
from repro.parallel import pipeline_forward

mesh = jax.make_mesh((8,), ("stage",), axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)
out = ring_reduce_scatter(x, mesh, "stage", perm=[0,3,1,7,2,6,4,5], interpret=True)
np.testing.assert_allclose(np.asarray(out), np.asarray(ring_reduce_scatter_ref(x, 8)), atol=1e-4)

# pipeline: 8 stages of y = tanh(x @ w); compare vs sequential
ws = jnp.asarray(np.random.default_rng(1).standard_normal((8, 16, 16)) * 0.3, jnp.float32)
xs = jnp.asarray(np.random.default_rng(2).standard_normal((4, 2, 16)), jnp.float32)
def stage_fn(w, x): return jnp.tanh(x @ w)
with jax.set_mesh(mesh):
    y = pipeline_forward(stage_fn, ws, xs, mesh, axis="stage")
ref = xs
for i in range(8):
    ref = jnp.tanh(ref @ ws[i])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)

# pipeline backward: grads flow through ppermute schedule
def loss(ws):
    return jnp.sum(pipeline_forward(stage_fn, ws, xs, mesh, axis="stage") ** 2)
with jax.set_mesh(mesh):
    g = jax.grad(loss)(ws)
def loss_seq(ws):
    r = xs
    for i in range(8):
        r = jnp.tanh(r @ ws[i])
    return jnp.sum(r ** 2)
g_ref = jax.grad(loss_seq)(ws)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-3)
print("MULTIDEVICE OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MULTIDEVICE OK" in r.stdout
