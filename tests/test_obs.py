"""Tests for ``repro.obs``: tracer, metrics, capture→fold→replay, and
the instrumented pipeline (PR-7 observability tentpole).

Covers the contracts the instrumentation relies on:

* disabled-mode zero-overhead — ``span()`` on a disabled tracer is the
  shared :data:`NULL_SPAN` singleton (identity, not just equality) and
  nothing is buffered; ``timer()`` still measures;
* thread-safety — spans/counters recorded concurrently from a live
  ``Session.monitor()`` thread and the main thread never corrupt the
  ring buffer;
* round-trips — Chrome trace-event export parses back with matched
  span names, and ``WorkloadTrace`` JSON round-trips exactly;
* fold equivalence — a captured stationary workload folds to a JobMix
  whose ``key()`` equals the declared mix it was issued from;
* replay — per-phase-window plans never lose to the stationary
  declared-mix plan on the synthetic bursty trace.
"""

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import (
    NULL_SPAN,
    MetricsRegistry,
    OpRecord,
    Tracer,
    WorkloadRecorder,
    WorkloadTrace,
    declared_mix,
    fold,
    replay,
    synthetic_bursty_trace,
)


class FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_records_and_clock_injection():
    clk = FakeClock()
    tr = Tracer(enabled=True, clock=clk)
    with tr.span("outer", label="a"):
        clk.advance(0.5)
        with tr.span("inner") as sp:
            clk.advance(0.25)
            sp.set(result=7)
    tr.event("mark", x=1)
    recs = tr.records()
    assert [r[1] for r in recs] == ["inner", "outer", "mark"]
    phases = {r[1]: r[0] for r in recs}
    assert phases == {"inner": "X", "outer": "X", "mark": "i"}
    by_name = {r[1]: r for r in recs}
    # durations come from the injected clock, exactly
    assert by_name["inner"][3] == pytest.approx(0.25)
    assert by_name["outer"][3] == pytest.approx(0.75)
    # depth: outer recorded at depth 0, inner at depth 1
    assert by_name["outer"][5] == 0
    assert by_name["inner"][5] == 1
    assert by_name["inner"][6] == {"result": 7}
    assert by_name["mark"][6] == {"x": 1}


def test_disabled_tracer_is_zero_alloc_and_records_nothing():
    clk = FakeClock()
    tr = Tracer(enabled=False, clock=clk)
    s1 = tr.span("a", big="attr")
    s2 = tr.span("b")
    assert s1 is NULL_SPAN and s2 is NULL_SPAN   # shared singleton
    with s1:
        pass
    tr.event("never", x=1)
    assert len(tr) == 0 and tr.emitted == 0
    # the null span carries no state at all
    assert not hasattr(NULL_SPAN, "__dict__")
    assert NULL_SPAN.elapsed == 0.0


def test_timer_measures_even_when_disabled():
    clk = FakeClock()
    tr = Tracer(enabled=False, clock=clk)
    t = tr.timer("work")
    with t:
        clk.advance(1.5)
    assert t.elapsed == pytest.approx(1.5)       # the number is real
    assert len(tr) == 0                          # but nothing was recorded
    tr.set_enabled(True)
    t2 = tr.timer("work")
    with t2:
        clk.advance(0.5)
    assert t2.elapsed == pytest.approx(0.5)
    assert len(tr) == 1                          # enabled: recorded too


def test_span_records_error_attr_and_restores_depth():
    tr = Tracer(enabled=True)
    with pytest.raises(ValueError, match="boom"):
        with tr.span("failing"):
            raise ValueError("boom")
    (rec,) = tr.records()
    assert rec[6] == {"error": "ValueError: boom"}
    with tr.span("after"):
        pass
    assert tr.records()[-1][5] == 0, "depth must not leak after a raise"


def test_ring_buffer_bounded_and_resizable():
    tr = Tracer(enabled=True, buffer=4)
    for i in range(10):
        tr.event(f"e{i}")
    assert len(tr) == 4
    assert tr.emitted == 10                      # monotone, survives wrap
    assert [r[1] for r in tr.records()] == ["e6", "e7", "e8", "e9"]
    tr.set_buffer(2)
    assert [r[1] for r in tr.records()] == ["e8", "e9"]


def test_chrome_export_round_trip(tmp_path):
    clk = FakeClock()
    tr = Tracer(enabled=True, clock=clk)
    with tr.span("compile", mix="train"):
        clk.advance(0.125)
    tr.event("cache.hit", digest="abc")
    path = tmp_path / "trace.json"
    n = tr.export(str(path))
    assert n == 2
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = {e["name"]: e for e in doc["traceEvents"] if e["ph"] != "M"}
    assert evs["compile"]["ph"] == "X"
    assert evs["compile"]["dur"] == pytest.approx(0.125e6)
    assert evs["compile"]["args"] == {"mix": "train"}
    assert evs["cache.hit"]["ph"] == "i"
    assert evs["cache.hit"]["s"] == "t"
    # thread metadata names the lane
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert metas and metas[0]["name"] == "thread_name"


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_snapshot_and_reuse():
    m = MetricsRegistry()
    m.counter("plan.cache.hits").inc()
    m.counter("plan.cache.hits").inc(2)
    m.gauge("drift.score").set(0.25)
    m.histogram("probe.seconds", scale=1e-3).observe(0.004)
    m.histogram("probe.seconds").observe(0.016)
    snap = m.snapshot()
    assert snap["counters"]["plan.cache.hits"] == 3.0
    assert snap["gauges"]["drift.score"] == 0.25
    h = snap["histograms"]["probe.seconds"]
    assert h["count"] == 2 and h["sum"] == pytest.approx(0.02)
    # log2 buckets on the milli scale: 4ms -> 2^2, 16ms -> 2^4
    assert h["buckets"] == {"2": 1, "4": 1}


def test_metrics_prometheus_text():
    m = MetricsRegistry()
    m.counter("plan.cache.hits").inc(5)
    m.gauge("faults.health.state").set(2)
    m.histogram("plan.compile.seconds", scale=1e-3).observe(0.2)
    text = m.to_prometheus()
    assert "# TYPE plan_cache_hits counter\nplan_cache_hits 5" in text
    assert "# TYPE faults_health_state gauge\nfaults_health_state 2" in text
    assert "# TYPE plan_compile_seconds histogram" in text
    assert 'plan_compile_seconds_bucket{le="+Inf"} 1' in text
    assert "plan_compile_seconds_count 1" in text


def test_disabled_registry_hands_out_null_instruments():
    m = MetricsRegistry(enabled=False)
    c = m.counter("x")
    c.inc()
    m.gauge("y").set(3)
    m.histogram("z").observe(1.0)
    assert c is m.counter("x2"), "disabled registry shares one null"
    assert m.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


# ---------------------------------------------------------------------------
# capture -> fold -> replay
# ---------------------------------------------------------------------------

def test_recorder_disabled_is_noop_and_enabled_captures():
    clk = FakeClock()
    rec = WorkloadRecorder(enabled=False, clock=clk)
    rec.record("all-reduce", 1e6)
    assert len(rec) == 0 and rec.captured == 0
    rec.enabled = True
    clk.advance(1.0)
    rec.record("all-reduce", 1e6, group=(0, 1, 2))
    (r,) = rec.trace().records
    assert r.op == "all-reduce" and r.size_bytes == 1e6
    assert r.group == (0, 1, 2)
    assert r.t == pytest.approx(1.0)             # epoch-relative


def test_workload_trace_json_round_trip(tmp_path):
    trace = synthetic_bursty_trace(8, steps=4, seed=3)
    path = tmp_path / "capture.json"
    trace.save(str(path))
    back = WorkloadTrace.load(str(path))
    assert back.name == trace.name
    assert back.meta == trace.meta
    assert back.records == trace.records         # exact dataclass equality


def test_fold_of_stationary_capture_matches_declared_mix():
    from repro.plan import CollectiveRequest, JobMix

    declared = JobMix(requests=(
        CollectiveRequest(op="all-reduce", size_bytes=4e6, count=2),
        CollectiveRequest(op="all-gather", size_bytes=1e6, count=1),
    ), name="declared")
    # a stationary workload issuing exactly the declared mix each step
    clk = FakeClock()
    rec = WorkloadRecorder(enabled=True, clock=clk)
    for _ in range(5):
        rec.record("all-reduce", 4e6)
        rec.record("all-reduce", 4e6)
        rec.record("all-gather", 1e6)
        clk.advance(1.0)
    windows = fold(rec.trace(), steps_per_window=5.0)
    assert len(windows) == 1
    assert windows[0].mix.key() == declared.key()
    counts = {r.op: r.count for r in windows[0].mix.requests}
    assert counts == {"all-reduce": 2.0, "all-gather": 1.0}


def test_fold_windows_split_phases():
    trace = synthetic_bursty_trace(8, steps=4, seed=0)
    windows = fold(trace, window_s=1.0)
    assert len(windows) == 4
    ops = [sorted({r.op for r in w.mix.requests}) for w in windows]
    assert ops == [["all-gather"], ["all-reduce"],
                   ["all-gather"], ["all-reduce"]]
    assert sum(w.n_records for w in windows) == len(trace)


def test_replay_phased_beats_declared_on_bursty_trace():
    from repro.fabric import make_datacenter, probe_fabric, scramble
    from repro.plan import PlanCompiler, SolveBudget

    n = 8
    fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
    probe = probe_fabric(fab, seed=0)
    compiler = PlanCompiler(budget=SolveBudget(iters=60, chains=2))
    trace = synthetic_bursty_trace(n, steps=4, seed=0)
    stationary = compiler.compile(probe, declared_mix(trace))
    windows = fold(trace, window_s=1.0)
    phased = [(w, compiler.compile(probe, w.mix)) for w in windows]
    base = replay(trace, stationary, probe.lat, probe.bw)
    ph = replay(trace, stationary, probe.lat, probe.bw, windows=phased)
    assert base["unplanned"] == 0 and ph["unplanned"] == 0
    assert base["records"] == ph["records"] == len(trace)
    assert ph["total_seconds"] <= base["total_seconds"], \
        "phase-windowed plans lost to the single declared-mix plan"


def test_replay_counts_unplanned_ops():
    from repro.fabric import make_datacenter, probe_fabric
    from repro.plan import CollectiveRequest, JobMix, PlanCompiler, \
        SolveBudget

    probe = probe_fabric(make_datacenter(8, seed=0), seed=0)
    plan = PlanCompiler(budget=SolveBudget(iters=40, chains=1)).compile(
        probe, JobMix(requests=(
            CollectiveRequest(op="all-reduce", size_bytes=1e6, count=1),)))
    trace = WorkloadTrace(records=[
        OpRecord("all-reduce", 1e6, None, 0.0),
        OpRecord("all-to-all", 1e6, None, 0.5),   # no entry for this op
    ])
    out = replay(trace, plan, probe.lat, probe.bw)
    assert out["unplanned"] == 1
    assert out["per_op_seconds"].keys() == {"all-reduce"}


# ---------------------------------------------------------------------------
# pipeline integration
# ---------------------------------------------------------------------------

@pytest.fixture
def fresh_obs():
    """Swap in an enabled tracer + fresh registry/recorder; restore after."""
    prev_t = obs.set_tracer(Tracer(enabled=True))
    prev_m = obs.set_metrics(MetricsRegistry())
    prev_r = obs.set_recorder(WorkloadRecorder(enabled=True))
    try:
        yield obs.tracer(), obs.metrics(), obs.recorder()
    finally:
        obs.set_tracer(prev_t)
        obs.set_metrics(prev_m)
        obs.set_recorder(prev_r)


def test_compile_emits_spans_and_metrics(fresh_obs):
    tr, m, _ = fresh_obs
    from repro.fabric import make_datacenter, probe_fabric
    from repro.plan import PlanCompiler, SolveBudget
    from repro.session import train_mix

    probe = probe_fabric(make_datacenter(8, seed=0), seed=0)
    plan = PlanCompiler(budget=SolveBudget(iters=40, chains=1)).compile(
        probe, train_mix(1e6))
    names = {r[1] for r in tr.records()}
    assert "plan.compile" in names
    assert "plan.compile_entry" in names
    snap = m.snapshot()
    assert snap["counters"]["plan.compiles"] == 1.0
    assert snap["histograms"]["plan.compile.seconds"]["count"] == 1
    # the product number still comes from the obs timer
    assert plan.compile_seconds > 0.0


def test_session_monitor_thread_traces_safely(fresh_obs):
    """Tracer + metrics under a live monitor thread and main thread."""
    tr, m, _ = fresh_obs
    from repro.session import Session, SessionConfig

    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 8, "scramble_seed": 1},
        "solver": {"budget": {"iters": 40, "chains": 1}},
        "drift": {"threshold": 1e9},     # observe, never go stale
    })
    ticked = threading.Event()
    with Session(cfg) as s:
        s.plan()
        ref = s.reference_matrix()

        def poll():
            ticked.set()
            return ref

        s.monitor(poll=poll, interval_s=0.01)
        assert ticked.wait(timeout=10.0)
        # hammer the tracer from the main thread while the monitor runs
        for i in range(200):
            with tr.span("main.work", i=i):
                pass
    recs = tr.records()
    names = {r[1] for r in recs}
    assert "session.monitor.tick" in names
    assert "main.work" in names
    threads = {r[4] for r in recs}
    assert len(threads) >= 2, "expected records from at least two threads"
    for rec in recs:               # well-formed tuples, no corruption
        assert isinstance(rec[0], str) and isinstance(rec[1], str)
        assert isinstance(rec[2], float) and isinstance(rec[3], float)
    assert m.snapshot()["counters"]["session.monitor.ticks"] >= 1


def test_session_obs_config_exports_on_close(tmp_path, fresh_obs):
    from repro.session import Session, SessionConfig

    export = tmp_path / "trace.json"
    capture = tmp_path / "capture.json"
    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 8, "scramble_seed": 1},
        "solver": {"budget": {"iters": 40, "chains": 1}},
        "obs": {"enabled": True, "capture": True,
                "export_path": str(export),
                "capture_path": str(capture)},
    })
    with Session(cfg) as s:
        s.plan()
        obs.recorder().record("all-reduce", 1e6)
    doc = json.loads(export.read_text())
    assert any(e["name"] == "session.plan"
               for e in doc["traceEvents"] if e["ph"] != "M")
    back = WorkloadTrace.load(str(capture))
    assert back.records and back.records[-1].op == "all-reduce"


def test_obs_config_env_round_trip(monkeypatch):
    from repro.session import ObsConfig, SessionConfig

    monkeypatch.setenv("REPRO_OBS_ENABLED", "1")
    monkeypatch.setenv("REPRO_OBS_CAPTURE", "1")
    monkeypatch.setenv("REPRO_OBS_EXPORT_PATH", "/tmp/t.json")
    cfg = SessionConfig.from_env()
    assert cfg.obs.enabled is True
    assert cfg.obs.capture is True
    assert cfg.obs.export_path == "/tmp/t.json"
    back = SessionConfig.from_dict(json.loads(cfg.to_json()))
    assert back.obs == cfg.obs
    assert ObsConfig() != cfg.obs


def test_quarantine_warning_points_at_caller(tmp_path):
    """stacklevel satellite: the cache-quarantine warning names the
    caller's file, not repro internals, and mirrors an obs event."""
    from repro.plan import PlanCache, fabric_fingerprint
    from repro.plan.cache import _request_tag

    prev_t = obs.set_tracer(Tracer(enabled=True))
    try:
        cache = PlanCache(store_dir=str(tmp_path))
        bad = tmp_path / f"deadbeef__{_request_tag('')}.json"
        bad.write_text("{not json")
        fp = fabric_fingerprint(np.ones((4, 4)))
        with pytest.warns(RuntimeWarning, match="quarantined") as rec:
            assert cache.get(fp) is None
        assert rec[0].filename == __file__, \
            "warning must point at the caller via stacklevel"
        assert any(r[1] == "plan.cache.quarantine"
                   for r in obs.tracer().records())
        assert bad.with_suffix(".json.corrupt").exists()
    finally:
        obs.set_tracer(prev_t)
