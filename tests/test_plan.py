"""Tests for the ``repro.plan`` subsystem.

Covers the ISSUE-2 acceptance surface: compiler quality vs fixed
baselines, plan serialization round-trip, fingerprint stability under
probe noise (and order sensitivity under relabeling), cache LRU +
persistent store, drift-based invalidation, and concurrent service
dedup.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core import (
    make_datacenter,
    make_tpu_fleet,
    probe_fabric,
    scramble,
)
from repro.plan import (
    CollectiveRequest,
    DriftMonitor,
    JobMix,
    Plan,
    PlanCache,
    PlanCompiler,
    PlanningService,
    SolveBudget,
    candidate_algorithms,
    fabric_fingerprint,
    size_bucket,
)

BUDGET = SolveBudget(iters=150, chains=4)


@pytest.fixture(scope="module")
def fab():
    fabric, _ = scramble(make_datacenter(16, seed=0), seed=1)
    return fabric


@pytest.fixture(scope="module")
def probe(fab):
    return probe_fabric(fab, seed=0)


@pytest.fixture(scope="module")
def mix():
    return JobMix((
        CollectiveRequest("all-reduce", 32e6),
        CollectiveRequest("all-gather", 4e6, count=2.0),
        CollectiveRequest("all-to-all", 2e6, count=2.0),
        CollectiveRequest("all-reduce", 1e6, count=1.0,
                          group=tuple(range(8))),
    ), name="test")


@pytest.fixture(scope="module")
def plan(fab, probe, mix):
    comp = PlanCompiler(fabric=fab, budget=BUDGET)
    return comp.compile(probe, mix, mesh_shape=(4, 4),
                        axis_names=("data", "model"))


# -- compiler --------------------------------------------------------------

def test_compiler_covers_every_cell(plan, mix):
    assert len(plan.entries) == 4          # distinct (op, bucket, group)
    for r in mix.requests:
        e = plan.lookup(r.op, r.size_bytes, r.group)
        assert e is not None
        assert e.op == r.op
        assert e.algo in dict(candidate_algorithms(r.op, len(e.group)))
        assert sorted(e.perm) == sorted(e.group)
        assert e.expected_time > 0


def test_plan_beats_or_matches_every_identity_baseline(plan):
    """The joint choice can never lose to any single identity-order
    candidate (they are all in the searched candidate set)."""
    for e in plan.entries.values():
        assert e.expected_time <= min(e.identity_times.values()) + 1e-12


def test_plan_strictly_beats_best_fixed_on_scrambled_fabric(plan, mix):
    total = plan.total_time(mix)
    fixed = sum(r.count * plan.lookup(r.op, r.size_bytes, r.group)
                .best_identity_time for r in mix.requests)
    assert total < fixed              # reordering must buy something here


def test_subgroup_entry_uses_group_nodes_only(plan):
    e = plan.lookup("all-reduce", 1e6, group=tuple(range(8)))
    assert e.group == tuple(range(8))
    assert set(e.perm) == set(range(8))
    local = e.local_perm
    assert sorted(local.tolist()) == list(range(8))


def test_lookup_nearest_bucket(plan):
    big = plan.lookup("all-reduce", 32e6)
    assert plan.lookup("all-reduce", 100e6) is big   # nearest is the 32MB cell
    assert plan.lookup("reduce-scatter", 1e6) is None


def test_mesh_plan_improves_identity(plan):
    mp = plan.mesh_plan
    assert mp is not None and mp.assignment.shape == (4, 4)
    assert mp.cost <= mp.baseline_cost


def test_cost_model_oracle_without_fabric(probe, mix):
    comp = PlanCompiler(fabric=None, budget=BUDGET)
    p = comp.compile(probe, mix)
    assert p.meta["oracle"] == "cost_model"
    for e in p.entries.values():
        assert e.oracle == "cost_model"
        assert e.expected_time <= min(e.identity_times.values()) + 1e-12


def test_mix_from_hlo():
    hlo = """
ENTRY main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), to_apply=%add
  %ag = f32[4096]{0} all-gather(%p0), dimensions={0}
  %cp = f32[1024]{0} collective-permute(%p0), source_target_pairs={{0,1}}
}
"""
    m = JobMix.from_hlo(hlo)
    ops = sorted(r.op for r in m.requests)
    assert ops == ["all-gather", "all-reduce"]     # permute has no algo choice
    ar = [r for r in m.requests if r.op == "all-reduce"][0]
    assert ar.size_bytes == 4096                   # 1024 f32
    assert size_bucket(ar.size_bytes) == 12


def test_mix_key_canonical():
    a = JobMix((CollectiveRequest("all-reduce", 1e6),
                CollectiveRequest("all-gather", 2e6)))
    b = JobMix((CollectiveRequest("all-gather", 2.05e6),  # same octave bucket
                CollectiveRequest("all-reduce", 1.02e6)))
    assert a.key() == b.key()
    c = JobMix((CollectiveRequest("all-reduce", 4e6),))   # different bucket
    assert a.key() != c.key()


# -- serialization ---------------------------------------------------------

def test_plan_round_trip_identical(plan):
    p2 = Plan.from_json(plan.to_json())
    assert p2.fingerprint == plan.fingerprint
    assert p2.mix_key == plan.mix_key
    assert set(p2.entries) == set(plan.entries)
    for k, e in plan.entries.items():
        e2 = p2.entries[k]
        assert e2.to_dict() == e.to_dict()
    assert np.array_equal(p2.mesh_plan.assignment, plan.mesh_plan.assignment)
    assert p2.mesh_plan.axis_names == plan.mesh_plan.axis_names
    # and a second round trip is byte-stable
    assert Plan.from_json(p2.to_json()).to_json() == p2.to_json()


# -- fingerprints ----------------------------------------------------------

def _fp(probe_result):
    return fabric_fingerprint(probe_result.lat, probe_result.bw)


def test_fingerprint_stable_under_probe_noise(fab):
    fps = [_fp(probe_fabric(fab, seed=s)) for s in range(6)]
    for f in fps[1:]:
        assert fps[0].matches(f)


def test_fingerprint_distinguishes_fabrics(fab):
    fp = _fp(probe_fabric(fab, seed=0))
    tpu, _ = scramble(make_tpu_fleet(n_pods=1, pod_shape=(4, 4), seed=3),
                      seed=4)
    assert not fp.matches(_fp(probe_fabric(tpu, seed=0)))
    other, _ = scramble(make_datacenter(16, seed=7), seed=8)
    assert not fp.matches(_fp(probe_fabric(other, seed=0)))


def test_fingerprint_is_order_sensitive(fab):
    """A relabeled (re-scrambled) fabric must NOT hit the same plans:
    the plan's permutations refer to concrete node ids."""
    fp = _fp(probe_fabric(fab, seed=0))
    relabeled, _ = scramble(fab, seed=9)
    assert not fp.matches(_fp(probe_fabric(relabeled, seed=0)))


def test_fingerprint_sees_bandwidth_collapse(fab):
    """Bandwidth drops with latency unchanged must break the match —
    cached plans were compiled against the old bw profile."""
    p = probe_fabric(fab, seed=0)
    fp = fabric_fingerprint(p.lat, p.bw)
    collapsed = p.bw.copy()
    collapsed[4, :] /= 16.0
    collapsed[:, 4] /= 16.0
    np.fill_diagonal(collapsed, np.inf)
    assert not fp.matches(fabric_fingerprint(p.lat, collapsed))
    # latency-only fingerprints never mix with bw-aware ones
    assert not fp.matches(fabric_fingerprint(p.lat))


# -- cache -----------------------------------------------------------------

def test_cache_lru_and_fuzzy_hit(fab, plan):
    cache = PlanCache(capacity=2)
    cache.put(plan, "k")
    # a fresh probe of the same fabric fuzzily matches
    fp = _fp(probe_fabric(fab, seed=11))
    assert cache.get(fp, "k") is plan
    assert cache.get(fp, "other-key") is None
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1


def test_cache_persistent_round_trip(tmp_path, fab, plan):
    store = str(tmp_path / "plans")
    cache = PlanCache(store_dir=store)
    cache.put(plan, "k")
    # new process: fresh cache over the same directory
    cache2 = PlanCache(store_dir=store)
    fp = _fp(probe_fabric(fab, seed=12))
    loaded = cache2.get(fp, "k")
    assert loaded is not None
    assert loaded.to_json() == plan.to_json()
    assert cache2.stats["disk_hits"] == 1


def test_cache_capacity_eviction(plan):
    cache = PlanCache(capacity=1)
    cache.put(plan, "a")
    cache.put(plan, "b")
    assert len(cache) == 1
    assert cache.get(plan.fingerprint, "a") is None   # evicted
    assert cache.get(plan.fingerprint, "b") is plan


# -- drift invalidation ----------------------------------------------------

def test_drift_invalidates_cache(tmp_path, fab, probe, plan):
    store = str(tmp_path / "plans")
    cache = PlanCache(store_dir=store)
    cache.put(plan, "k")
    c0 = probe.lat
    mon = DriftMonitor(plan, c0, cache=cache, threshold=1.15)

    # benign re-probe: small noise, nothing degrades
    rep = mon.observe(probe_fabric(fab, seed=21).lat)
    assert not rep.stale and rep.invalidated == 0
    assert cache.get(plan.fingerprint, "k") is plan

    # inject drift: one node's links slow down 12x
    bad = c0.copy()
    bad[3, :] *= 12.0
    bad[:, 3] *= 12.0
    np.fill_diagonal(bad, 0.0)
    rep = mon.observe(np.maximum(bad, bad.T))
    assert rep.stale and rep.degraded
    assert rep.invalidated >= 1
    assert plan.meta.get("stale") is True
    assert cache.get(plan.fingerprint, "k") is None   # mem + disk dropped
    # repaired entries keep valid permutations (hot patch until recompile)
    for key, perm in rep.repaired.items():
        entry = plan.entries[key]
        assert sorted(perm) == sorted(entry.group)
        assert entry.perm == perm


# -- planning service ------------------------------------------------------

def _count_compiles(compiler):
    calls = {"n": 0}
    orig = compiler.compile

    def wrapped(*a, **kw):
        calls["n"] += 1
        time.sleep(0.05)          # widen the dedup window
        return orig(*a, **kw)

    compiler.compile = wrapped
    return calls


def test_service_dedupes_concurrent_requests(fab, mix):
    comp = PlanCompiler(fabric=fab, budget=BUDGET)
    calls = _count_compiles(comp)
    svc = PlanningService(comp, PlanCache(), max_workers=4)
    probes = [probe_fabric(fab, seed=s) for s in range(6)]
    results = [None] * len(probes)

    def worker(i):
        results[i] = svc.request(probes[i], mix)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(probes))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.close()

    assert calls["n"] == 1, "concurrent identical requests must share a compile"
    assert all(r is results[0] for r in results)
    assert svc.stats["requests"] == 6
    assert svc.stats["compiles"] == 1
    assert svc.stats["cache_hits"] + svc.stats["dedup_joins"] == 5


def test_service_cache_hit_is_fast(fab, probe, mix):
    comp = PlanCompiler(fabric=fab, budget=BUDGET)
    svc = PlanningService(comp, PlanCache())
    t0 = time.perf_counter()
    first = svc.request(probe, mix)
    cold = time.perf_counter() - t0
    warm = float("inf")
    for s in range(3):
        t0 = time.perf_counter()
        again = svc.request(probe_fabric(fab, seed=40 + s), mix)
        warm = min(warm, time.perf_counter() - t0)
    svc.close()
    assert again is first
    # the full >=100x bar is enforced by benchmarks/plan_compiler.py on
    # real budgets; under the test's tiny budget 10x leaves headroom
    assert warm < cold / 10.0


def test_service_request_many_batches_same_fabric(fab, mix):
    comp = PlanCompiler(fabric=fab, budget=BUDGET)
    calls = _count_compiles(comp)
    svc = PlanningService(comp, PlanCache())
    mix2 = JobMix((CollectiveRequest("reduce-scatter", 2e6),), name="serve")
    plans = svc.request_many([
        (probe_fabric(fab, seed=50), mix),
        (probe_fabric(fab, seed=51), mix2),
    ])
    svc.close()
    assert calls["n"] == 1, "same-fingerprint mixes union into one compile"
    assert plans[0] is plans[1]
    # the union plan answers both sub-mixes
    assert plans[0].lookup("all-reduce", 32e6) is not None
    assert plans[1].lookup("reduce-scatter", 2e6) is not None


def test_arm_ep_composes_order_with_mesh_assignment(fab, probe, mix, plan):
    """arm_ep must express the solved ring in EP *axis-index* space: on a
    planned mesh axis index i holds node mesh_plan.flat[i], so walking
    the armed order must visit nodes exactly in the entry's perm order."""
    from types import SimpleNamespace

    from repro.parallel.moe_a2a import _EP_STATE, arm_ep, clear_ep

    mesh = SimpleNamespace(axis_names=("data",), devices=np.zeros((16,)))
    arm_ep(mesh, "data", None, plan=plan)
    order = _EP_STATE["a2a_order"]
    entry = plan.lookup("all-to-all", 1.0)
    flat = plan.mesh_plan.flat
    assert order is not None and sorted(order) == list(range(16))
    assert [int(flat[i]) for i in order] == list(entry.perm)
    clear_ep()

    # plan compiled without a mesh: axis index i IS node i -> local perm
    p2 = PlanCompiler(fabric=fab, budget=BUDGET).compile(probe, mix)
    arm_ep(mesh, "data", None, plan=p2)
    e2 = p2.lookup("all-to-all", 1.0)
    assert _EP_STATE["a2a_order"] == tuple(int(i) for i in e2.local_perm)
    clear_ep()

    # without a plan the shift ring stays identity (order None)
    arm_ep(mesh, "data", None)
    assert _EP_STATE["a2a_order"] is None


def test_moe_shift_perms_follow_plan_order():
    from repro.parallel.moe_a2a import _shift_perms

    n = 8
    order = (3, 1, 4, 0, 6, 2, 7, 5)
    rounds = _shift_perms(n, order)
    assert len(rounds) == n - 1
    seen = set()
    for rnd in rounds:
        srcs = [s for s, _ in rnd]
        dsts = [d for _, d in rnd]
        assert sorted(srcs) == list(range(n))     # bijection per round
        assert sorted(dsts) == list(range(n))
        for s, d in rnd:
            assert s != d
            seen.add((s, d))
    assert len(seen) == n * (n - 1)               # every pair exactly once
    # identity order reproduces the classic i -> i+k shift
    classic = _shift_perms(4, None)
    assert classic[0] == [(i, (i + 1) % 4) for i in range(4)]
