"""The typed collective IR: builders, passes, executors (DESIGN.md §7).

Four suites:

* **Program invariants** (property-tested): every registered builder's
  program validates — structural byte conservation (each flow's bytes
  equal its chunk count times the declared chunk size) plus the
  semantic postcondition via abstract interpretation (every rank ends
  holding the full reduced/gathered result, per the builder's declared
  completion contract).
* **Pass semantics**: ``apply_permutation`` reproduces the legacy
  builder-threaded ``perm`` flow-for-flow; ``chunk`` equals k serial
  pieces at 1/k payload; ``fuse_rounds`` only merges participant-
  disjoint rounds and preserves validity.
* **Cross-backend equivalence**: for every registered algorithm,
  ``SimExecutor`` on the compiled program matches the legacy
  ``simulate_collective`` timing, and ``AnalyticExecutor`` matches the
  corresponding ``CostModel``, within tolerance.
* **Lowering + error contracts**: ``JaxExecutor`` reproduces the moe
  shift schedule / ring links; unknown algorithm names raise
  actionable ``ValueError``\\ s listing the registered builders.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.collective import (
    AnalyticExecutor,
    CollectiveOp,
    JaxExecutor,
    ProgramInvariantError,
    SimExecutor,
    apply_permutation,
    candidates,
    chunk,
    compile_op,
    fuse_rounds,
    get_builder,
    registered_builders,
    validate,
)
from repro.core import make_datacenter, make_cost_model, simulate_collective
from repro.core import schedule as legacy
from repro.fabric import probe_fabric

#: (builder, kind, kwargs, valid group sizes) — every registered seed
#: algorithm in every kind it compiles
CASES = [
    ("ring", "allreduce", {}, (2, 3, 5, 8, 12)),
    ("ring_sequential", "allreduce", {}, (2, 3, 5, 8, 12)),
    ("double_binary_tree", "allreduce", {}, (2, 3, 5, 8, 12)),
    ("halving_doubling", "allreduce", {}, (2, 4, 8, 16)),
    ("bcube", "allreduce", {"base": 2}, (4, 8)),
    ("bcube", "allreduce", {"base": 4}, (4, 16)),
    ("ring_all_gather", "all_gather", {}, (2, 3, 5, 8, 12)),
    ("ring_all_gather", "reduce_scatter", {}, (2, 3, 5, 8, 12)),
    ("recursive_doubling", "all_gather", {}, (2, 4, 8, 16)),
    ("recursive_doubling", "reduce_scatter", {}, (2, 4, 8, 16)),
    ("all_to_all", "all_to_all", {}, (2, 3, 5, 8, 12)),
]

SIZE = 1e6


def _build(name, kind, kw, n, group=None):
    group = tuple(range(n)) if group is None else tuple(group)
    return compile_op(CollectiveOp(kind, SIZE, group), name, **kw)


def _flow_key(rounds):
    """Order-insensitive per-round (src, dst, size) multisets."""
    return [sorted((f.src, f.dst, round(f.size, 6)) for f in rnd)
            for rnd in rounds]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_every_schedule_algorithm_is_a_registered_builder():
    assert set(legacy.SCHEDULES) <= set(registered_builders())


def test_get_builder_unknown_name_lists_registered():
    with pytest.raises(ValueError, match="ring.*all_to_all|registered"):
        get_builder("nccl_tree")


def test_make_cost_model_unknown_name_lists_registered():
    with pytest.raises(ValueError, match="registered models"):
        make_cost_model("nccl_tree", cost_matrix=np.zeros((4, 4)))


def test_candidates_match_legacy_gating():
    from repro.plan import candidate_algorithms

    for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
        for n in (1, 3, 4, 8, 12, 16):
            assert candidate_algorithms(op, n) == candidates(op, n)
    assert ("halving_doubling", {}) not in candidates("all-reduce", 12)
    assert ("bcube", {"base": 4}) in candidates("all-reduce", 16)
    assert ("bcube", {"base": 2}) in candidates("all-reduce", 8)


def test_schedules_shim_warns_and_delegates():
    with pytest.warns(DeprecationWarning, match="repro.collective"):
        build = legacy.SCHEDULES["ring"]
    rounds = build(np.arange(4), SIZE)
    assert len(rounds) == 2 * 3 and all(len(r) == 4 for r in rounds)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="registered builders"):
            legacy.SCHEDULES["nope"]


# ---------------------------------------------------------------------------
# Program invariants (satellite: property tests)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kind,kw,ns", CASES,
                         ids=[f"{c[0]}-{c[1]}" for c in CASES])
def test_program_validates_everywhere(name, kind, kw, ns):
    for n in ns:
        prog = _build(name, kind, kw, n)
        validate(prog)                            # structure + semantics
        # byte totals survive permutation (structure is rank-space)
        perm = tuple(int(x) for x in
                     np.random.default_rng(n).permutation(n))
        permuted = apply_permutation(prog, perm)
        validate(permuted)
        assert permuted.total_bytes == pytest.approx(prog.total_bytes)
        assert permuted.n_rounds == prog.n_rounds


@pytest.mark.parametrize("name,kind,kw", [(c[0], c[1], c[2]) for c in CASES],
                         ids=[f"{c[0]}-{c[1]}" for c in CASES])
def test_degenerate_single_rank_program(name, kind, kw):
    if name in ("halving_doubling", "recursive_doubling", "bcube"):
        pytest.skip("power-of-two builders require n >= 2")
    prog = _build(name, kind, kw, 1)
    validate(prog)
    assert prog.rounds == ()


def test_copy_flows_do_not_count_as_reductions():
    """A copy OVERWRITES the destination: a builder that emits 'copy'
    where a reduction is required must not validate complete."""
    prog = _build("ring_sequential", "allreduce", {}, 2)
    Flow = prog.rounds[0][0].__class__
    fake = prog.replace(rounds=(
        (Flow(0, 1, SIZE, "copy", (0,)),),
        (Flow(1, 0, SIZE, "copy", (0,)),),
    ), postcondition="allreduce")
    with pytest.raises(ProgramInvariantError, match="incomplete"):
        validate(fake)
    # the same shape with reduce flows IS a (tiny) allreduce
    validate(fake.replace(rounds=(
        (Flow(0, 1, SIZE, "reduce", (0,)),),
        (Flow(1, 0, SIZE, "reduce", (0,)),),
    )))


def test_validator_catches_broken_programs():
    prog = _build("ring", "allreduce", {}, 4)
    # drop the last round: the all-gather lap can no longer complete
    broken = prog.replace(rounds=prog.rounds[:-1])
    with pytest.raises(ProgramInvariantError, match="incomplete"):
        validate(broken)
    # corrupt a flow's payload: byte conservation trips
    bad_round = (prog.rounds[0][0].__class__(
        src=0, dst=1, size=SIZE, op="reduce", chunks=(0,)),
    ) + prog.rounds[0][1:]
    with pytest.raises(ProgramInvariantError, match="bytes"):
        validate(prog.replace(rounds=(bad_round,) + prog.rounds[1:]))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_permutation_pass_matches_builder_threaded_perm(seed):
    """apply_permutation == threading perm through the legacy builder."""
    rng = np.random.default_rng(seed)
    name, kind, kw, ns = CASES[seed % len(CASES)]
    if kind == "reduce_scatter":
        kind = "all_gather"       # legacy builders emit the AG schedule
    n = ns[seed % len(ns)]
    perm = [int(x) for x in rng.permutation(n)]
    prog = apply_permutation(_build(name, kind, kw, n), perm)
    legacy_fn = getattr(legacy, {
        "ring": "ring_allreduce_chunked",
        "ring_sequential": "ring_allreduce_sequential",
        "halving_doubling": "halving_doubling_allreduce",
        "double_binary_tree": "double_binary_tree_allreduce",
        "bcube": "bcube_allreduce",
        "ring_all_gather": "ring_all_gather",
        "recursive_doubling": "recursive_doubling_all_gather",
        "all_to_all": "all_to_all",
    }[name])
    assert _flow_key(prog.to_flows()) == _flow_key(legacy_fn(perm, SIZE, **kw))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_program_fingerprint_is_stable_and_perm_sensitive(seed):
    rng = np.random.default_rng(seed)
    name, kind, kw, ns = CASES[seed % len(CASES)]
    n = ns[seed % len(ns)]
    prog = _build(name, kind, kw, n)
    assert prog.fingerprint() == _build(name, kind, kw, n).fingerprint()
    perm = tuple(int(x) for x in rng.permutation(n))
    if perm != tuple(range(n)):
        assert apply_permutation(prog, perm).fingerprint() != \
            prog.fingerprint()
    assert chunk(prog, 2).fingerprint() != prog.fingerprint()


# ---------------------------------------------------------------------------
# passes
# ---------------------------------------------------------------------------

def test_apply_permutation_accepts_node_and_local_space():
    group = (10, 20, 30, 40)
    prog = compile_op(CollectiveOp("allreduce", SIZE, group), "ring")
    by_node = apply_permutation(prog, (30, 10, 40, 20))
    by_local = apply_permutation(prog, (2, 0, 3, 1))
    assert by_node.perm == by_local.perm == (30, 10, 40, 20)
    with pytest.raises(ValueError, match="rearrangement"):
        apply_permutation(prog, (1, 2, 3, 5))


def test_chunk_pass_is_serial_pipelining():
    fab = make_datacenter(8, seed=3)
    prog = _build("ring", "allreduce", {}, 8)
    sim = SimExecutor(fab)
    t1 = simulate_collective(fab, "ring", list(range(8)), SIZE / 4)
    assert sim.estimate(chunk(prog, 4)) == pytest.approx(4 * t1, rel=1e-12)
    assert chunk(prog, 1) is prog
    with pytest.raises(ValueError, match=">= 1"):
        chunk(prog, 0)


def test_fuse_rounds_merges_only_disjoint_participants():
    prog = _build("ring", "allreduce", {}, 4)
    fused, n_fused = fuse_rounds(prog)
    assert n_fused == 0 and fused is prog     # every rank is in every round
    # synthetic program with participant-disjoint adjacent rounds
    base = _build("ring_sequential", "allreduce", {}, 8)
    Flow = base.rounds[0][0].__class__
    rounds = ((Flow(0, 1, SIZE, "reduce", (0,)),),
              (Flow(2, 3, SIZE, "reduce", (0,)),),
              (Flow(3, 4, SIZE, "reduce", (0,)),))
    synth = base.replace(rounds=rounds, postcondition="none")
    fused, n_fused = fuse_rounds(synth)
    assert n_fused == 1 and len(fused.rounds) == 2
    assert {(f.src, f.dst) for f in fused.rounds[0]} == {(0, 1), (2, 3)}
    validate(fused, semantics=False)


# ---------------------------------------------------------------------------
# cross-backend equivalence (satellite)
# ---------------------------------------------------------------------------

#: the INDEPENDENT legacy reference implementations (free builders in
#: repro.core.schedule) — NOT simulate_collective, which itself compiles
#: through the registry now and would make the comparison tautological
LEGACY_BUILDERS = {
    "ring": legacy.ring_allreduce_chunked,
    "ring_sequential": legacy.ring_allreduce_sequential,
    "double_binary_tree": legacy.double_binary_tree_allreduce,
    "halving_doubling": legacy.halving_doubling_allreduce,
    "bcube": legacy.bcube_allreduce,
    "ring_all_gather": legacy.ring_all_gather,
    "recursive_doubling": legacy.recursive_doubling_all_gather,
    "all_to_all": legacy.all_to_all,
}

#: the historical schedule→cost-model mapping, spelled out so a builder
#: mis-declaring its ``cost_model`` fails the analytic comparison
SOLVER_MODEL = {
    "ring": "ring", "ring_sequential": "ring",
    "double_binary_tree": "double_binary_tree",
    "halving_doubling": "halving_doubling", "bcube": "bcube",
    "ring_all_gather": "ring", "recursive_doubling": "halving_doubling",
    "all_to_all": "all_to_all",
}


@pytest.mark.parametrize("name,kind,kw,ns", CASES,
                         ids=[f"{c[0]}-{c[1]}" for c in CASES])
def test_sim_executor_matches_legacy_simulator(name, kind, kw, ns):
    from repro.core.simulator import simulate_rounds

    fab = make_datacenter(16, seed=1)
    rng = np.random.default_rng(7)
    for n in [x for x in ns if x <= 16]:
        perm = [int(x) for x in rng.permutation(n)]
        prog = apply_permutation(_build(name, kind, kw, n), perm)
        t_ir = SimExecutor(fab).estimate(prog)
        t_legacy = simulate_rounds(fab, LEGACY_BUILDERS[name](perm, SIZE, **kw))
        assert t_ir == pytest.approx(t_legacy, rel=1e-9), (name, kind, n)
        # the supported oracle API agrees too
        t_api = simulate_collective(fab, name, perm, SIZE, **kw)
        assert t_api == pytest.approx(t_legacy, rel=1e-9), (name, kind, n)


@pytest.mark.parametrize("name,kind,kw,ns", CASES,
                         ids=[f"{c[0]}-{c[1]}" for c in CASES])
def test_analytic_executor_matches_cost_model(name, kind, kw, ns):
    fab = make_datacenter(16, seed=1)
    probe = probe_fabric(fab, seed=0, measure_bw=True)
    rng = np.random.default_rng(11)
    for matrices in ({"cost_matrix": probe.lat},
                     {"lat": probe.lat, "bw": probe.bw}):
        ex = AnalyticExecutor(**matrices)
        for n in [x for x in ns if x <= 16]:
            perm = [int(x) for x in rng.permutation(n)]
            prog = apply_permutation(_build(name, kind, kw, n), perm)
            model = make_cost_model(
                SOLVER_MODEL[name], size_bytes=SIZE,
                **{k: v[:n, :n] for k, v in matrices.items()}, **kw)
            want = float(model.cost(np.asarray(perm)))
            assert ex.estimate(prog) == pytest.approx(want, rel=1e-9), \
                (name, kind, n)


def test_plan_entry_program_reproduces_oracle_time():
    """entry.program() through the session's executor == expected_time."""
    from repro.plan import CollectiveRequest, JobMix, PlanCompiler, SolveBudget

    fab = make_datacenter(8, seed=5)
    probe = probe_fabric(fab, seed=0, measure_bw=True)
    mix = JobMix(requests=(CollectiveRequest("all-reduce", 4e6),
                           CollectiveRequest("all-to-all", 2e6)))
    plan = PlanCompiler(fabric=fab,
                        budget=SolveBudget(iters=80, chains=2)).compile(
        probe, mix)
    sim = SimExecutor(fab)
    for entry in plan.entries.values():
        prog = entry.program()
        assert prog.fingerprint() == entry.program_fingerprint
        assert sim.estimate(prog) == pytest.approx(
            entry.expected_time, rel=1e-12)


# ---------------------------------------------------------------------------
# jax lowering
# ---------------------------------------------------------------------------

def test_jax_lowering_matches_moe_shift_perms():
    from repro.parallel.moe_a2a import _shift_perms

    order = (3, 1, 4, 0, 6, 2, 7, 5)
    prog = apply_permutation(_build("all_to_all", "all_to_all", {}, 8), order)
    low = JaxExecutor().lower(prog)
    assert low.kind == "shift_a2a" and low.order == order
    assert [list(r) for r in low.shift_rounds] == _shift_perms(8, order)
    # every round a bijection; every ordered pair exactly once
    seen = set()
    for rnd in low.shift_rounds:
        assert sorted(s for s, _ in rnd) == list(range(8))
        assert sorted(d for _, d in rnd) == list(range(8))
        seen.update(rnd)
    assert len(seen) == 8 * 7


def test_jax_lowering_ring_links():
    from repro.kernels.ring_collective import _ring_links

    perm = (2, 0, 3, 1)
    prog = apply_permutation(_build("ring", "allreduce", {}, 4), perm)
    low = JaxExecutor().lower(prog)
    assert low.kind == "ring"
    assert list(low.links) == _ring_links(perm)


def test_jax_executor_lowers_general_programs():
    # halving_doubling used to be refused (can_lower False); the
    # generalized lowering now covers every round-based Program.
    ex = JaxExecutor()
    prog = _build("halving_doubling", "allreduce", {}, 8)
    assert ex.can_lower(prog)
    low = ex.lower(prog)
    assert low.kind == "general"
    assert low.schedule is not None
    assert low.schedule.n_steps >= len(prog.rounds)


# ---------------------------------------------------------------------------
# session facade integration
# ---------------------------------------------------------------------------

def test_session_executor_and_lower():
    from repro import Session, SessionConfig

    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 8, "scramble_seed": 2},
        "solver": {"budget": {"iters": 60, "chains": 2}},
        "cache": {"dir": None}, "moe": True,
    })
    with Session(cfg) as s:
        plan = s.plan()
        entry = plan.lookup("all-to-all", cfg.payload_bytes)
        est = s.executor().estimate(entry.program())
        assert est == pytest.approx(entry.expected_time, rel=1e-12)
        low = s.lower("all-to-all")
        assert low.kind == "shift_a2a" and len(low.shift_rounds) == 7
        analytic = s.executor("analytic")
        assert analytic.estimate(entry.program()) > 0
        with pytest.raises(ValueError, match="unknown executor backend"):
            s.executor("tpu")
