"""Tests for the ``repro.session`` facade (ISSUE-3 tentpole).

Covers: SessionConfig round-trips (dict / JSON / env / replace),
the lifecycle state machine and hooks, the non-intrusive ``wrap()``
patch/unpatch, drift-triggered re-planning through ``observe`` and the
background ``monitor()``, equivalence with the manual pipeline, and the
input-validation satellite (Fabric.subset / cost_matrix).
"""

import threading

import numpy as np
import pytest

from repro.core import make_datacenter, probe_fabric, scramble
from repro.fabric import ProbeResult, cost_matrix
from repro.session import (
    AppliedPlan,
    Session,
    SessionConfig,
    SessionError,
    serve_mix,
    train_mix,
)

SMALL = {
    "fabric": {"kind": "datacenter", "nodes": 12, "scramble_seed": 1},
    "solver": {"budget": {"iters": 80, "chains": 2}},
    "payload_bytes": 1e6,
}


def small_config(**over):
    return SessionConfig.from_dict(SMALL).replace(**over)


# ---------------------------------------------------------------------------
# SessionConfig
# ---------------------------------------------------------------------------

def test_config_dict_roundtrip():
    cfg = small_config(mesh={"shape": "3x4", "axis_names": "data,model"})
    assert SessionConfig.from_dict(cfg.to_dict()) == cfg
    assert cfg.mesh.shape == (3, 4)
    assert cfg.mesh.axis_names == ("data", "model")


def test_config_json_roundtrip(tmp_path):
    cfg = small_config(cache={"dir": str(tmp_path / "plans")})
    assert SessionConfig.from_json(cfg.to_json()) == cfg
    path = tmp_path / "session.json"
    cfg.dump(str(path))
    assert SessionConfig.load(str(path)) == cfg


def test_config_env_overlay():
    cfg = SessionConfig.from_env(environ={
        "REPRO_FABRIC_KIND": "tpu-fleet",
        "REPRO_FABRIC_N_PODS": "2",
        "REPRO_FABRIC_POD_SHAPE": "4x4",
        "REPRO_MESH_SHAPE": "2x4x4",
        "REPRO_SOLVER_BUDGET_ITERS": "123",
        "REPRO_CACHE_DIR": "/tmp/somewhere",
        "REPRO_PAYLOAD_BYTES": "2e6",
        "REPRO_MOE": "true",
        "UNRELATED": "ignored",
    })
    assert cfg.fabric.kind == "tpu-fleet"
    assert cfg.fabric.n_pods == 2
    assert cfg.fabric.pod_shape == (4, 4)
    assert cfg.mesh.shape == (2, 4, 4)
    assert cfg.mesh.axis_names == ("pod", "data", "model")
    assert cfg.solver.budget.iters == 123
    assert cfg.cache.dir == "/tmp/somewhere"
    assert cfg.payload_bytes == 2e6
    assert cfg.moe is True


def test_config_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown session config keys"):
        SessionConfig.from_dict({"fabrik": {}})
    with pytest.raises(ValueError, match="unknown fabric config keys"):
        SessionConfig.from_dict({"fabric": {"knid": "datacenter"}})
    with pytest.raises(ValueError, match="kind"):
        SessionConfig.from_dict({"fabric": {"kind": "quantum"}})
    with pytest.raises(ValueError, match="workload"):
        SessionConfig.from_dict({"workload": "mine-bitcoin"})
    with pytest.raises(ValueError, match="axis name"):
        SessionConfig.from_dict({"mesh": {"shape": "4x4",
                                          "axis_names": "data"}})


def test_config_replace_merges_sections():
    cfg = small_config()
    cfg2 = cfg.replace(fabric={"nodes": 24})
    assert cfg2.fabric.nodes == 24
    assert cfg2.fabric.scramble_seed == 1         # untouched sibling key
    assert cfg.fabric.nodes == 12                 # original is frozen


def test_config_replace_deep_merges_budget():
    cfg = small_config(solver={"budget": {"engine": "reference",
                                          "iters": 999}})
    cfg2 = cfg.replace(solver={"budget": {"iters": 200, "chains": 4}})
    assert cfg2.solver.budget.iters == 200
    assert cfg2.solver.budget.chains == 4
    assert cfg2.solver.budget.engine == "reference"   # nested key survives


# ---------------------------------------------------------------------------
# lifecycle state machine
# ---------------------------------------------------------------------------

def test_lifecycle_states_progress():
    s = Session(small_config())
    assert s.state == "created"
    s.attach()
    assert s.state == "attached"
    plan = s.plan()
    assert s.state == "planned" and plan is s.planned
    applied = s.apply()
    assert s.state == "applied"
    assert isinstance(applied, AppliedPlan)
    assert applied.plan is plan
    s.close()
    assert s.state == "closed"
    s.close()                                    # idempotent


def test_apply_is_lazy_one_call_chain():
    with Session(small_config(mesh={"shape": "3x4"})) as s:
        applied = s.apply()                      # attach + plan implied
    assert s.state == "closed"
    assert applied.plan.mesh_plan is not None
    assert sorted(applied.order.tolist()) == list(range(12))
    assert applied.hints                         # per-op summaries present
    for h in applied.hints.values():
        assert h["speedup_vs_identity"] >= 1.0 - 1e-9


def test_closed_session_refuses_work():
    s = Session(small_config())
    s.close()
    for call in (s.attach, s.plan, s.apply,
                 lambda: s.observe(np.zeros((2, 2))), s.wrap, s.monitor):
        with pytest.raises(SessionError, match="closed"):
            call()


def test_observe_before_plan_raises():
    with Session(small_config()) as s:
        s.attach()
        with pytest.raises(SessionError, match="plan"):
            s.observe(np.zeros((12, 12)))


def test_reattach_resets_plan():
    with Session(small_config()) as s:
        s.plan()
        assert s.planned is not None
        s.attach(fabric=make_datacenter(8, seed=5))
        assert s.planned is None
        assert s.state == "attached"
        assert s.plan().n == 8


def test_hooks_fire_in_lifecycle_order():
    seen = []
    s = Session(small_config())
    for event in ("attach", "plan", "apply", "close"):
        s.on(event, lambda sess, _e=event, **kw: seen.append(_e))
    with pytest.raises(ValueError, match="unknown session event"):
        s.on("reticulate", lambda *a, **k: None)
    with s:
        s.apply()
    assert seen == ["attach", "plan", "apply", "close"]


def test_attach_accepts_raw_cost_matrix():
    rng = np.random.default_rng(0)
    c = rng.uniform(1e-5, 1e-3, size=(6, 6))
    c = np.maximum(c, c.T)
    np.fill_diagonal(c, 0.0)
    with Session(small_config()) as s:
        s.attach(probe=c)
        plan = s.plan(mix=train_mix(1e6))
        assert plan.n == 6
        # no fabric oracle -> analytic cost-model scoring
        assert plan.meta["oracle"] == "cost_model"


# ---------------------------------------------------------------------------
# equivalence with the manual pipeline
# ---------------------------------------------------------------------------

def test_session_plan_matches_manual_pipeline():
    """The facade must be sugar, not a different planner."""
    from repro.plan import PlanCache, PlanCompiler, PlanningService

    cfg = small_config(mesh={"shape": "3x4"})
    with Session(cfg) as s:
        via_session = s.plan()

    fabric, _ = scramble(make_datacenter(12, seed=0), seed=1)
    probed = probe_fabric(fabric, seed=0)
    service = PlanningService(
        PlanCompiler(fabric=fabric, budget=cfg.solver.budget, seed=0),
        PlanCache())
    manual = service.request(probed, train_mix(1e6),
                             mesh_shape=(3, 4),
                             axis_names=("data", "model"))
    service.close()

    assert via_session.fingerprint.digest == manual.fingerprint.digest
    assert set(via_session.entries) == set(manual.entries)
    for key, e in manual.entries.items():
        se = via_session.entries[key]
        assert (se.algo, se.chunks, se.perm) == (e.algo, e.chunks, e.perm)
    assert np.array_equal(via_session.mesh_plan.assignment,
                          manual.mesh_plan.assignment)


def test_session_cache_hits_across_sessions(tmp_path):
    cfg = small_config(cache={"dir": str(tmp_path / "plans")})
    with Session(cfg) as s1:
        p1 = s1.plan()
        assert s1.service.stats["cache_hits"] == 0
    with Session(cfg) as s2:
        p2 = s2.plan()
        stats = s2.service.cache.stats
        assert stats["disk_hits"] + stats["hits"] >= 1
    assert p2.fingerprint.digest == p1.fingerprint.digest


# ---------------------------------------------------------------------------
# wrap(): the non-intrusive patch
# ---------------------------------------------------------------------------

def test_wrap_patches_and_restores_launch_surface():
    from repro.launch import mesh as mesh_mod
    from repro.parallel import moe_a2a

    orig_make = mesh_mod.make_production_mesh
    orig_arm = moe_a2a.arm_ep
    s = Session(small_config())
    with s.wrap():
        assert s.wrapped
        assert mesh_mod.make_production_mesh is not orig_make
        assert moe_a2a.arm_ep is not orig_arm
    assert not s.wrapped
    assert mesh_mod.make_production_mesh is orig_make
    assert moe_a2a.arm_ep is orig_arm
    with pytest.raises(SessionError, match="closed"):
        s.close() or s.wrap()


def test_wrap_injects_plan_into_arm_ep():
    """Existing arm_ep call sites (no plan kwarg) pick up the session's
    solved all-to-all ring with zero call-site edits."""
    from types import SimpleNamespace

    from repro.parallel import moe_a2a

    cfg = small_config(moe=True)
    with Session(cfg) as s:
        s.plan()
        entry = s.planned.lookup("all-to-all", 1.0)
        assert entry is not None
        mesh = SimpleNamespace(axis_names=("data",), devices=np.zeros((12,)))
        with s.wrap():
            moe_a2a.arm_ep(mesh, "data", None)   # unmodified call site
            armed = moe_a2a._EP_STATE["a2a_order"]
        moe_a2a.clear_ep()
    assert armed == tuple(int(i) for i in entry.local_perm)


def test_wrap_twice_raises():
    with Session(small_config()) as s:
        guard = s.wrap()
        try:
            with pytest.raises(SessionError, match="already wrapped"):
                s.wrap()
        finally:
            guard.__exit__(None, None, None)


def test_close_unwraps():
    from repro.parallel import moe_a2a

    orig_arm = moe_a2a.arm_ep
    s = Session(small_config())
    s.wrap()
    assert moe_a2a.arm_ep is not orig_arm
    s.close()
    assert moe_a2a.arm_ep is orig_arm


# ---------------------------------------------------------------------------
# drift: observe + monitor re-plans
# ---------------------------------------------------------------------------

def _degraded(c: np.ndarray, factor: float = 60.0) -> np.ndarray:
    bad = c.copy()
    bad *= 1.0 + np.linspace(0.0, factor, c.shape[0])[:, None]
    bad = np.maximum(bad, bad.T)
    np.fill_diagonal(bad, 0.0)
    return bad


def test_observe_drift_triggers_replan():
    events = []
    with Session(small_config(drift={"threshold": 1.10,
                                     "auto_replan": True})) as s:
        s.on("drift", lambda sess, report: events.append("drift"))
        s.on("replan", lambda sess, plan, previous: events.append("replan"))
        p1 = s.plan()
        ref = s.reference_matrix()
        report = s.observe(_degraded(ref))
        assert report.stale and report.degraded
        assert events == ["drift", "replan"]
        p2 = s.planned
        assert p2 is not p1
        # the re-plan was compiled against the degraded costs, and the
        # stale pre-drift fabric simulator is no longer the oracle
        assert p2.fingerprint.digest != p1.fingerprint.digest
        assert p2.meta["oracle"] == "cost_model"
        # quiet observation after the re-plan: no further events
        report2 = s.observe(s.reference_matrix())
        assert not report2.stale
        assert events == ["drift", "replan"]


def test_observe_without_auto_replan_keeps_plan():
    with Session(small_config(drift={"threshold": 1.10,
                                     "auto_replan": False})) as s:
        p1 = s.plan()
        report = s.observe(_degraded(s.reference_matrix()))
        assert report.stale
        assert s.planned is p1                   # hot-patched, not replaced
        assert report.repaired                   # but entries were repaired


def test_monitor_background_replan():
    fired = threading.Event()
    ticks = {"n": 0}
    with Session(small_config(drift={"threshold": 1.10,
                                     "auto_replan": True})) as s:
        s.plan()
        ref = s.reference_matrix()

        def poll():
            ticks["n"] += 1
            return _degraded(ref) if ticks["n"] == 2 else None

        s.on("replan", lambda sess, **kw: fired.set())
        t = s.monitor(poll=poll, interval_s=0.02)
        assert fired.wait(timeout=10.0), "monitor never triggered a re-plan"
        with pytest.raises(SessionError, match="already running"):
            s.monitor(poll=poll, interval_s=0.02)
    assert not t.is_alive(), "close() must stop the monitor thread"


# ---------------------------------------------------------------------------
# validation satellite: actionable errors instead of numpy index noise
# ---------------------------------------------------------------------------

def test_fabric_subset_validates_nodes():
    fabric = make_datacenter(8, seed=0)
    with pytest.raises(ValueError, match="at least one node"):
        fabric.subset([])
    with pytest.raises(ValueError, match="out of range"):
        fabric.subset([0, 8])
    with pytest.raises(ValueError, match="out of range"):
        fabric.subset([-1, 2])
    with pytest.raises(ValueError, match="duplicates: \\[3\\]"):
        fabric.subset([1, 3, 3])
    sub = fabric.subset([5, 1, 2])               # valid subset still works
    assert sub.n == 3


def test_cost_matrix_validates_probe():
    with pytest.raises(ValueError, match="empty ProbeResult"):
        cost_matrix(ProbeResult(lat=np.zeros((0, 0))))
    with pytest.raises(ValueError, match="square"):
        cost_matrix(ProbeResult(lat=np.zeros((3, 4))))
    c = cost_matrix(ProbeResult(lat=np.ones((2, 2)) - np.eye(2)))
    assert c.shape == (2, 2)


def test_plan_rejects_mesh_fabric_size_mismatch():
    with Session(small_config(mesh={"shape": "4x4"})) as s:   # 16 != 12
        with pytest.raises(ValueError, match="attached fabric has 12"):
            s.plan()


def test_reattach_keeps_plan_cache():
    """An elastic restart on an unchanged fabric must hit the cached
    plan: re-attach rebuilds the fabric-bound service, not the cache."""
    fabric = make_datacenter(10, seed=2)
    with Session(small_config()) as s:
        s.attach(fabric=fabric)
        p1 = s.plan()
        s.attach(fabric=fabric)                  # same fabric, re-probe
        p2 = s.plan()
        assert s.cache.stats["hits"] >= 1
        assert p2.fingerprint.digest == p1.fingerprint.digest


def test_set_drift_threshold_applies_to_live_monitor():
    with Session(small_config(drift={"threshold": 1.05})) as s:
        s.plan()
        s.set_drift_threshold(1e9)               # effectively: never drift
        assert s.config.drift.threshold == 1e9
        assert s._drift.threshold == 1e9
        report = s.observe(_degraded(s.reference_matrix()))
        assert not report.stale


def test_cluster_view_consumes_session():
    """Trainer-side integration: solve_plan attaches the survivor fabric
    to the session and adopts the compiled plan's mesh assignment."""
    from repro.train import ClusterView

    fabric = make_datacenter(12, seed=0)
    with Session(small_config()) as s:
        cluster = ClusterView(fabric=fabric, mesh_shape=(2, 4),
                              axis_names=("data", "model"), session=s)
        mesh_plan = cluster.solve_plan()
        assert mesh_plan is s.planned.mesh_plan
        assert mesh_plan.assignment.shape == (2, 4)
        # 12 alive > 8 mesh slots: the most central 8 were selected
        assert len(cluster.active) == 8
        assert s.planned.n == 8
        # elastic shrink after failures re-plans through the same session
        cluster.fail([0, 5, 7, 9])
        cluster.shrink_mesh()
        mp2 = cluster.solve_plan()
        assert mp2.assignment.size == int(np.prod(cluster.mesh_shape))
        assert s.planned.n == mp2.assignment.size


def test_mixes_shapes():
    t = train_mix(4e6, moe=True)
    assert {r.op for r in t.requests} == {
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all"}
    v = serve_mix(1e6)
    assert {r.op for r in v.requests} == {
        "all-reduce", "all-gather", "reduce-scatter"}
