"""Property tests: the vectorized solver engine vs the seed implementation.

Covers the acceptance contract of the vectorized-engine PR:

* ``_propose`` emits valid permutations for every move kind and size;
* the O(K) changed-edge delta equals a full re-evaluation exactly;
* ``solve`` with the vectorized engine returns costs equal to (or better
  than) the seed engine on small N, for every registered cost model;
* the vectorized mesh assignment matches the seed implementation's cost;
* ``percentile_orders`` regression: no ZeroDivisionError for pool < 4.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.core import COST_MODELS, make_cost_model, percentile_orders, solve, solve_sa
from repro.core.reorder import (
    _group_greedy,
    _group_greedy_reference,
    optimize_mesh_assignment,
)
from repro.core.solver import _edge_delta, _propose, two_opt, or_opt


def _rand_cost(n, seed=0, symmetric=True):
    rng = np.random.default_rng(seed)
    c = rng.uniform(1.0, 10.0, (n, n))
    if symmetric:
        c = np.maximum(c, c.T)
    np.fill_diagonal(c, 0.0)
    return c


# ---------------------------------------------------------------------------
# proposal kernel
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(2, 40))
@settings(max_examples=20, deadline=None)
def test_propose_emits_valid_permutations(seed, n):
    rng = np.random.default_rng(seed)
    perms = np.stack([rng.permutation(n) for _ in range(16)])
    for _ in range(8):
        perms = _propose(perms, rng)
        assert (np.sort(perms, axis=1) == np.arange(n)).all()


def test_propose_valid_near_int16_boundary():
    """Regression: the int16 move tensors must not overflow in the
    wrap-around window arithmetic for n within wmax of 2**15."""
    n = (1 << 15) - 2
    rng = np.random.default_rng(0)
    perms = np.stack([rng.permutation(n) for _ in range(4)])
    for _ in range(8):
        perms = _propose(perms, rng)
        assert (np.sort(perms, axis=1) == np.arange(n)).all()


def test_or_opt_respects_explicit_sweep_cap():
    """An explicit max_sweeps is a hard cap: from a cold start at this
    size, 2 sweeps must stop short of the fixpoint the default reaches."""
    n = 200
    c = _rand_cost(n, 29)
    m = make_cost_model("ring", c, 0.0)
    p0 = np.random.default_rng(6).permutation(n)
    capped = or_opt(c, p0, max_sweeps=2)
    assert sorted(capped.tolist()) == list(range(n))
    assert m.cost(capped) <= m.cost(p0) + 1e-12
    # resuming from the capped result still finds improvements — the cap
    # genuinely stopped early rather than being treated as a floor
    resumed = or_opt(c, capped)
    assert m.cost(resumed) < m.cost(capped) - 1e-9


@given(st.integers(0, 2**31 - 1), st.integers(2, 40))
@settings(max_examples=20, deadline=None)
def test_edge_delta_matches_full_reevaluation(seed, n):
    """The O(K) changed-edge delta must equal cost(new) - cost(old)."""
    rng = np.random.default_rng(seed)
    c = _rand_cost(n, seed % 997)
    model = make_cost_model("ring", c, 0.0)
    perms = np.stack([rng.permutation(n) for _ in range(8)])
    for _ in range(6):
        prop, e_new, e_old = _propose(perms, rng, return_edges=True)
        delta = _edge_delta(c, perms, prop, e_new, e_old)
        true = model.cost_batch(prop) - model.cost_batch(perms)
        np.testing.assert_allclose(delta, true, atol=1e-9)
        perms = prop


# ---------------------------------------------------------------------------
# engine equivalence (same seed => equal-or-better final cost)
# ---------------------------------------------------------------------------

def _model_for(algo, n, seed):
    c = _rand_cost(n, seed)
    kwargs = {"base": 2} if algo == "bcube" else {}
    return make_cost_model(algo, c, 1e6, **kwargs)


@pytest.mark.parametrize("algo", sorted(COST_MODELS))
def test_vectorized_solve_matches_or_beats_reference(algo):
    """On small N the vectorized pipeline must never lose to the seed.

    N=8 with a full iteration budget: both engines reliably reach the
    global optimum there (verified against ``exhaustive`` in the solver
    suite), which is the regime where same-seed equal-or-better is a
    meaningful deterministic contract for two independent stochastic
    streams.
    """
    n = 8
    for seed in (0, 1, 2, 3):
        m = _model_for(algo, n, seed)
        vec = solve(m, method="paper", iters=4000, chains=24, seed=seed)
        ref = solve(m, method="paper", iters=4000, chains=24, seed=seed,
                    engine="reference")
        assert sorted(vec.perm.tolist()) == list(range(n))
        assert vec.cost <= ref.cost * (1 + 1e-9), (
            f"{algo} seed={seed}: vectorized {vec.cost} > reference {ref.cost}")


@pytest.mark.parametrize("algo", sorted(COST_MODELS))
def test_vectorized_sa_valid_and_reported_cost_exact(algo):
    n = 16
    m = _model_for(algo, n, 3)
    res = solve_sa(m, iters=400, chains=8, seed=0)
    assert sorted(res.perm.tolist()) == list(range(n))
    assert res.cost == pytest.approx(m.cost(res.perm))


def test_delta_path_gated_off_for_asymmetric_ring():
    """Asymmetric matrices must fall back to full evaluation and still
    produce exact reported costs."""
    n = 24
    c = _rand_cost(n, 7, symmetric=False)
    m = make_cost_model("ring", c, 0.0)
    res = solve_sa(m, iters=500, chains=8, seed=0)
    assert res.cost == pytest.approx(m.cost(res.perm))


def test_refiners_never_worsen_and_stay_permutations():
    n = 48
    c = _rand_cost(n, 11)
    m = make_cost_model("ring", c, 0.0)
    rng = np.random.default_rng(1)
    for _ in range(4):
        p0 = rng.permutation(n)
        p1 = two_opt(c, p0)
        p2 = or_opt(c, p1)
        assert sorted(p2.tolist()) == list(range(n))
        assert m.cost(p1) <= m.cost(p0) + 1e-12
        assert m.cost(p2) <= m.cost(p1) + 1e-12


def test_knn_two_opt_reaches_full_2opt_local_optimum():
    """n >= 128 takes the knn-candidate branch; the fixpoint must still be
    a *dense* 2-opt local optimum (no improving reversal anywhere)."""
    n = 150
    c = _rand_cost(n, 21)
    p = two_opt(c, np.random.default_rng(2).permutation(n))
    assert sorted(p.tolist()) == list(range(n))
    nxt = np.roll(p, -1)
    d_cur = c[p, nxt]
    delta = (c[np.ix_(p, p)] + c[np.ix_(nxt, nxt)]
             - d_cur[:, None] - d_cur[None, :])
    np.fill_diagonal(delta, np.inf)
    iu = np.triu_indices(n, k=1)
    vals = delta[iu]
    vals[(iu[1] - iu[0] == 1) | ((iu[0] == 0) & (iu[1] == n - 1))] = np.inf
    assert vals.min() >= -1e-9, "knn two_opt left an improving dense move"


def test_or_opt_converges_to_fixpoint_at_larger_n():
    """Regression: the move budget must not truncate before the fixpoint
    (re-running or_opt on its own output must not find improvements)."""
    n = 300
    c = _rand_cost(n, 23)
    m = make_cost_model("ring", c, 0.0)
    p1 = or_opt(c, np.random.default_rng(3).permutation(n))
    p2 = or_opt(c, p1)
    assert m.cost(p2) >= m.cost(p1) - 1e-9 * max(m.cost(p1), 1.0)
    assert m.cost(p2) == pytest.approx(m.cost(p1), rel=1e-9)


def test_cost_batch_slab_path_matches_single_shot(monkeypatch):
    """Force the round-boundary slab split and compare to one-shot eval."""
    import repro.core.cost_models as cm

    n = 32
    c = _rand_cost(n, 17)
    model = make_cost_model("all_to_all", c, 1e6)
    rng = np.random.default_rng(4)
    perms = np.stack([rng.permutation(n) for _ in range(8)])
    full = model.cost_batch(perms).copy()
    monkeypatch.setattr(cm, "_BATCH_SLAB_ELEMS", 512)
    slabbed = model.cost_batch(perms)
    np.testing.assert_allclose(slabbed, full, rtol=1e-12)


def test_structure_cache_shared_across_message_sizes():
    """The cache is keyed size-independently: every message size reuses
    the same pairs tensors, with payloads scaled per instance."""
    n = 16
    c = _rand_cost(n, 19)
    m1 = make_cost_model("halving_doubling", c, 1e6)
    m2 = make_cost_model("halving_doubling", c, 4e6)
    assert m1.rounds[0].pairs is m2.rounds[0].pairs
    assert m2.rounds[0].payload == pytest.approx(4 * m1.rounds[0].payload)
    perm = np.random.default_rng(5).permutation(n)
    # 4x the bytes with a pure c-matrix parameterization scales linearly
    assert m2.cost(perm) == pytest.approx(m1.cost(perm), rel=1e-12)


# ---------------------------------------------------------------------------
# mesh assignment equivalence
# ---------------------------------------------------------------------------

def test_group_greedy_matches_reference_partition_cost():
    for m_units, k, seed in [(16, 4, 0), (24, 8, 1), (32, 4, 2)]:
        c = _rand_cost(m_units, seed)
        vec = _group_greedy(c, list(range(m_units)), k)
        ref = _group_greedy_reference(c, list(range(m_units)), k)
        assert sorted(x for g in vec for x in g) == list(range(m_units))
        intra = lambda gs: sum(c[np.ix_(g, g)].sum() for g in gs)
        assert intra(vec) <= intra(ref) + 1e-9


@pytest.mark.parametrize("shape,names", [
    ((2, 4), ("data", "model")),
    ((4, 4, 4), ("pod", "data", "model")),
])
def test_vectorized_mesh_assignment_matches_reference(shape, names):
    n = int(np.prod(shape))
    c = _rand_cost(n, 5)
    vec = optimize_mesh_assignment(c, shape, names)
    ref = optimize_mesh_assignment(c, shape, names, engine="reference")
    assert sorted(vec.flat.tolist()) == list(range(n))
    assert vec.cost <= ref.cost * (1 + 1e-9)
    # both must beat (or tie) the identity baseline they report
    assert vec.cost <= vec.baseline_cost * (1 + 1e-9)


# ---------------------------------------------------------------------------
# percentile_orders regression (pool < 4 used to ZeroDivisionError)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pool", [1, 2, 3, 4, 5])
def test_percentile_orders_small_pool_regression(pool):
    n = 12
    c = _rand_cost(n, 13)
    m = make_cost_model("ring", c, 0.0)
    best = solve(m, method="paper", iters=200, chains=4, seed=0)
    worst = np.asarray(best.perm)[::-1].copy()
    orders = percentile_orders(m, best.perm, worst, k=3, pool=pool, seed=0)
    assert len(orders) == 3
    for o in orders:
        assert sorted(np.asarray(o).tolist()) == list(range(n))
