"""Per-arch smoke tests (required): reduced config, one forward/train
step on CPU, output shapes + no NaNs; plus prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.train import init_state, make_train_step

B, S = 2, 16


def _batch(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["frontend_embeds"] = jnp.ones(
            (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frontend_embeds"] = jnp.ones(
            (B, cfg.n_audio_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(model.forward)(
        params, batch["tokens"], batch.get("frontend_embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    state = init_state(model, rng)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(state.params)[0]
    assert l0.shape == l1.shape


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the teacher-forced logits.

    MoE archs run with a generous capacity factor: capacity *drops* are
    computed per dispatch group, which legitimately differs between the
    teacher-forced pass (groups of S tokens) and decode (one token per
    step) — with no drops the two paths must agree exactly.
    """
    cfg = get_config(arch).smoke()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]
    # vlm: decode_step sees only tokens; compare the pure-text backbone
    # (the frontend path is covered by test_vlm_frontend_changes_output)
    fe = None if cfg.family == "vlm" else batch.get("frontend_embeds")

    full_logits, _ = jax.jit(model.forward)(params, tokens, fe)

    cache = model.init_cache(B, S)
    if cfg.family == "encdec":
        # whisper decode cache needs cross-attn K/V: take them via prefill
        # on the first token, then compare positions 1..S-1.
        _, cache_p = jax.jit(model.prefill)(params, tokens[:, :1], fe)
        from repro.serve.engine import _grow_cache

        cache = _grow_cache(cache_p, 1, S)
    decode = jax.jit(model.decode_step)
    start = 1 if cfg.family == "encdec" else 0
    logits_steps = []
    for t in range(start, S):
        lg, cache = decode(params, tokens[:, t], cache)
        logits_steps.append(lg)
    dec = np.stack([np.asarray(l, np.float32) for l in logits_steps], axis=1)
    ref = np.asarray(full_logits, np.float32)[:, start:]
    tol = 2e-3 if cfg.family != "hybrid" else 5e-3
    np.testing.assert_allclose(dec, ref, atol=tol, rtol=tol)


def test_vlm_frontend_changes_output():
    cfg = get_config("llava-next-mistral-7b").smoke()
    model = get_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    fe1 = jnp.ones((B, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    fe2 = 2.0 * fe1
    l1, _ = model.forward(params, tokens, fe1)
    l2, _ = model.forward(params, tokens, fe2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_moe_scatter_matches_dense_when_no_drop():
    """With generous capacity both dispatch impls route identically."""
    cfg = dataclasses.replace(
        get_config("dbrx-132b").smoke(), capacity_factor=8.0)
    rng = jax.random.PRNGKey(3)
    model_d = get_model(dataclasses.replace(cfg, moe_impl="dense"))
    params = model_d.init(rng)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    ld, _ = model_d.forward(params, tokens)
    model_s = get_model(dataclasses.replace(cfg, moe_impl="scatter"))
    ls, _ = model_s.forward(params, tokens)
    np.testing.assert_allclose(
        np.asarray(ld, np.float32), np.asarray(ls, np.float32),
        atol=2e-4, rtol=2e-3)


def test_long_context_flag():
    assert get_config("rwkv6-1.6b").supports_long_context
    assert get_config("recurrentgemma-9b").supports_long_context
    assert not get_config("glm4-9b").supports_long_context
    from repro.configs import SHAPES, shape_applicable

    ok, why = shape_applicable(get_config("glm4-9b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    ok, _ = shape_applicable(get_config("rwkv6-1.6b"), SHAPES["long_500k"])
    assert ok
