"""Translation validation: every lowering is bisimilar to its IR.

The matrix half parametrizes all registered builders across group
sizes and rewrite variants and demands a zero-mismatch bisimulation;
the adversarial half hand-corrupts schedules (and runs the seeded
mutant batch) to prove the validator actually rejects broken
lowerings.  The e2e half runs a certified schedule through real
``ppermute`` on a host-local mesh in a subprocess.
"""

import dataclasses
import json
import os
import random
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (
    VerificationError,
    bisimulate,
    certify_stages,
    lowering_kill_rate,
    lowering_mutants,
    require_certified,
)
from repro.analysis.lint import lint_file
from repro.collective import (
    CollectiveOp,
    JaxExecutor,
    compile_op,
    get_builder,
    registered_builders,
)
from repro.collective.builders import candidates
from repro.collective.passes import apply_permutation, chunk, fuse_rounds

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(algo, kind, akw, n):
    op = CollectiveOp(kind=kind, size_bytes=1 << 16, group=tuple(range(n)))
    return compile_op(op, algo, **dict(akw))


def _matrix(n_list=(4, 8, 16)):
    cases = []
    for algo in sorted(registered_builders()):
        b = get_builder(algo)
        for kind in b.kinds:
            for n in n_list:
                for a, akw in candidates(kind, n):
                    if a == algo:
                        cases.append((algo, kind, n,
                                      tuple(sorted(akw.items()))))
    return cases


MATRIX = _matrix()


def test_matrix_covers_every_registered_algorithm():
    assert {algo for algo, *_ in MATRIX} == set(registered_builders())
    assert {n for _, _, n, _ in MATRIX} == {4, 8, 16}


@pytest.mark.parametrize("variant", ["identity", "permuted", "chunked"])
@pytest.mark.parametrize("algo,kind,n,akw", MATRIX,
                         ids=[f"{a}-{k}-n{n}" for a, k, n, _ in MATRIX])
def test_lower_and_bisimulate_zero_mismatches(algo, kind, n, akw, variant):
    prog = _build(algo, kind, akw, n)
    if variant == "permuted":
        perm = list(range(n))
        random.Random(n).shuffle(perm)
        prog = apply_permutation(prog, perm)
    elif variant == "chunked":
        prog = chunk(prog, 2)
    findings, stats = bisimulate(prog)
    assert [f for f in findings if f.severity == "error"] == []
    assert stats["bisimilar"]
    assert stats["n_mismatched_entries"] == 0


@pytest.mark.parametrize("algo", sorted(registered_builders()))
def test_certify_stages_all_ok(algo):
    kind = get_builder(algo).kinds[0]
    akw = next(a for b, a in candidates(kind, 8) if b == algo)
    prog = _build(algo, kind, akw, 8)
    perm = list(range(8))
    random.Random(7).shuffle(perm)
    stages = certify_stages(prog, perm=perm, chunk_k=2)
    assert [s["stage"] for s in stages] == \
        ["base", "apply_permutation", "chunk", "fuse_rounds"]
    assert all(s["ok"] for s in stages), stages


# ---------------------------------------------------------------------------
# adversarial: the validator must reject hand-broken schedules
# ---------------------------------------------------------------------------

def _lowered():
    prog = _build("halving_doubling", "allreduce", (), 8)
    return prog, JaxExecutor().lower_schedule(prog)


def _codes(prog, sched):
    findings, stats = bisimulate(prog, sched)
    assert not stats["bisimilar"]
    return {f.code for f in findings if f.severity == "error"}


def test_dropped_step_is_lost_reduction():
    prog, sched = _lowered()
    rnds = list(sched.rounds)
    rnds[0] = rnds[0][:-1]
    codes = _codes(prog, dataclasses.replace(sched, rounds=tuple(rnds)))
    assert "LOST_REDUCTION" in codes


def test_swapped_tag_is_extra_transfer_and_lost_reduction():
    prog, sched = _lowered()
    rnds = list(sched.rounds)
    step = rnds[0][0]
    assert step.op == "reduce"
    rnds[0] = (dataclasses.replace(step, op="copy"),) + rnds[0][1:]
    codes = _codes(prog, dataclasses.replace(sched, rounds=tuple(rnds)))
    assert {"EXTRA_TRANSFER", "LOST_REDUCTION"} <= codes


def test_missing_round_is_schedule_shape():
    prog, sched = _lowered()
    broken = dataclasses.replace(sched, rounds=sched.rounds[:-1])
    codes = _codes(prog, broken)
    assert codes == {"SCHEDULE_SHAPE"}


def test_flipped_recv_mask_drops_the_transfer():
    prog, sched = _lowered()
    rnds = list(sched.rounds)
    step = rnds[0][0]
    dst = step.links[0][1]
    recv = list(step.recv_mask)
    recv[dst] = False
    rnds[0] = (dataclasses.replace(step, recv_mask=tuple(recv)),) \
        + rnds[0][1:]
    codes = _codes(prog, dataclasses.replace(sched, rounds=tuple(rnds)))
    assert "LOST_REDUCTION" in codes


def test_duplicated_step_is_extra_transfer():
    prog, sched = _lowered()
    rnds = list(sched.rounds)
    rnds[0] = rnds[0] + (rnds[0][0],)
    codes = _codes(prog, dataclasses.replace(sched, rounds=tuple(rnds)))
    assert codes == {"EXTRA_TRANSFER"}


def test_require_certified_raises_on_broken_schedule():
    prog, sched = _lowered()
    require_certified(prog, sched)  # the genuine artifact passes
    broken = dataclasses.replace(sched, rounds=sched.rounds[:-1])
    with pytest.raises(VerificationError):
        require_certified(prog, broken)


def test_lowering_mutants_are_distinct_and_broken():
    prog, _ = _lowered()
    muts = lowering_mutants(prog, seed=3)
    assert len(muts) >= 6
    fps = [s.fingerprint() for _, s in muts]
    assert len(set(fps)) == len(fps)
    assert {k for k, _ in muts} == {"drop_step", "flip_mask", "swap_tag"}


def test_lowering_mutant_kill_rate_at_least_95_percent():
    progs = [_build(a, k, akw, n) for a, k, n, akw in _matrix(n_list=(8,))]
    rate, survivors = lowering_kill_rate(progs, seed=0)
    assert rate >= 0.95, survivors


# ---------------------------------------------------------------------------
# plan-compiler integration: cache key + candidate filtering
# ---------------------------------------------------------------------------

def test_verify_cache_key_distinguishes_rewrites():
    # PR-8 regression: the old key (algo, kwargs, kind, n) replayed a
    # base program's verdict for its chunked/fused rewrites.
    from repro.plan.compiler import PlanCompiler

    base = _build("ring_sequential", "allreduce", (), 8)
    chunked = chunk(base, 4)
    fused, n_fused = fuse_rounds(base)
    assert n_fused > 0  # fusion actually changed the round structure
    keys = {PlanCompiler._verify_key(p) for p in (base, chunked, fused)}
    assert len(keys) == 3


def test_candidate_algorithms_lowerable_filter():
    from repro.plan.compiler import candidate_algorithms

    allc = candidate_algorithms("all-reduce", 8)
    low = candidate_algorithms("all-reduce", 8, lowerable_only=True)
    assert low  # generalized lowering: nothing is filtered out today
    assert set(a for a, _ in low) <= set(a for a, _ in allc)
    assert set(a for a, _ in low) <= set(JaxExecutor().lowerable_algorithms())


def test_session_lower_certifies_every_algorithm():
    ex = JaxExecutor()
    assert set(ex.lowerable_algorithms()) == set(registered_builders())
    prog = _build("bcube", "allreduce", (("base", 2),), 8)
    low = ex.lower(prog)
    assert low.schedule is not None
    require_certified(prog, low.schedule)


# ---------------------------------------------------------------------------
# lint rules
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint_file(str(path), str(tmp_path))


def test_lint_lowered_construction(tmp_path):
    bad = _lint_src(tmp_path, "src/repro/plan/mod.py", """\
        from repro.collective import LoweredSchedule
        s = LoweredSchedule(algorithm="x", kind="allreduce", n=2,
                            order=(0, 1), n_chunks=2, chunk_bytes=8,
                            init="replicated", postcondition="allreduce",
                            rounds=())
        """)
    assert [f.rule for f in bad] == ["lowered-construction"]
    # the lowering layer itself is exempt
    ok = _lint_src(tmp_path, "src/repro/collective/executors.py", """\
        from repro.collective import PermuteStep
        s = PermuteStep(links=(), op="copy", chunks=(),
                        send_mask=(), recv_mask=(), round_index=0)
        """)
    assert ok == []
    ok = _lint_src(tmp_path, "src/repro/analysis/mod.py", """\
        from repro.collective import PermuteStep
        s = PermuteStep(links=(), op="copy", chunks=(),
                        send_mask=(), recv_mask=(), round_index=0)
        """)
    assert ok == []


def test_lint_module_level_np_random(tmp_path):
    bad = _lint_src(tmp_path, "src/repro/mod.py", """\
        import numpy as np
        NOISE = np.random.rand(8)
        """)
    assert [f.rule for f in bad] == ["module-level-np-random"]
    ok = _lint_src(tmp_path, "src/repro/mod2.py", """\
        import numpy as np

        RNG = np.random.default_rng(0)

        def noise():
            return np.random.rand(8)
        """)
    assert ok == []


# ---------------------------------------------------------------------------
# e2e: a certified general schedule runs through real ppermute
# ---------------------------------------------------------------------------

_E2E_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import random

import jax
import numpy as np
from jax.sharding import Mesh

from repro.analysis import require_certified
from repro.collective import CollectiveOp, JaxExecutor, compile_op
from repro.collective.passes import apply_permutation
from repro.kernels.schedule_runner import check_postcondition, run_schedule

n = 8
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
ex = JaxExecutor()
for algo in ("halving_doubling", "double_binary_tree"):
    op = CollectiveOp(kind="allreduce", size_bytes=n * 8 * 4,
                      group=tuple(range(n)))
    perm = list(range(n))
    random.Random(5).shuffle(perm)
    prog = apply_permutation(compile_op(op, algo), perm)
    sched = ex.lower_schedule(prog)
    require_certified(prog, sched)
    x = np.arange(n * n * 8, dtype=np.float32).reshape(n, n * 8)
    out = run_schedule(x, mesh, "x", sched, use_pallas_add=False)
    bad = check_postcondition(sched, x, np.asarray(out))
    assert not bad, (algo, bad)
print("E2E LOWERING OK")
"""


def test_e2e_certified_schedule_runs_on_host_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    script = tmp_path / "e2e_lowering.py"
    script.write_text(_E2E_SCRIPT)
    proc = subprocess.run([sys.executable, str(script)], cwd=REPO,
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "E2E LOWERING OK" in proc.stdout
