"""input_specs / step_callable coverage: every applicable (arch x shape)
cell must produce well-formed, sharding-annotated specs on a tiny mesh,
and the smoke-scale train cell must actually lower on it."""

import dataclasses

import jax
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_mesh_for_tests, production_shape
from repro.launch.specs import configure_sp, input_specs, step_callable


def test_production_shape_contract():
    shape, axes = production_shape(False)
    assert shape == (16, 16) and axes == ("data", "model")
    shape, axes = production_shape(True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_build_for_every_cell(arch, shape_name):
    """Spec construction (eval_shape only, no compile) for all 40 cells."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip(why)
    mesh = make_mesh_for_tests((1, 1), ("data", "model"))
    specs = input_specs(cfg, shape, mesh)
    assert len(specs) >= 2
    leaves = jax.tree.leaves(specs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert all(l.sharding is not None for l in leaves)
    # step callable exists and is callable
    fn = step_callable(cfg, shape)
    assert callable(fn)


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_smoke_cell_lowers_and_compiles(kind):
    cfg = get_config("qwen2-0.5b").smoke()
    shape = ShapeSpec(f"tiny_{kind}", 16, 4, kind)
    mesh = make_mesh_for_tests((1, 1), ("data", "model"))
    configure_sp(cfg, mesh)
    fn = step_callable(cfg, shape)
    specs = input_specs(cfg, shape, mesh)
    with jax.set_mesh(mesh):
        compiled = jax.jit(fn).lower(*specs).compile()
    assert compiled.cost_analysis().get("flops", 0) > 0
