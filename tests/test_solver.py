"""Solver tests: SA, refinement, exactness, and paper §IV-C properties."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.core import (
    exhaustive,
    greedy_ring,
    held_karp,
    make_cost_model,
    or_opt,
    percentile_orders,
    solve,
    solve_sa,
    solve_worst,
    swap_hill_climb,
    two_opt,
)


def _rand_cost(n, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.uniform(1.0, 10.0, (n, n))
    c = np.maximum(c, c.T)
    np.fill_diagonal(c, 0.0)
    return c


def test_exhaustive_matches_held_karp():
    c = _rand_cost(7, seed=5)
    m = make_cost_model("ring", c, 0.0)
    _, best_exh = exhaustive(m)
    _, best_hk = held_karp(c)
    assert best_exh == pytest.approx(best_hk)


def test_two_opt_never_worsens():
    c = _rand_cost(20, seed=1)
    m = make_cost_model("ring", c, 0.0)
    rng = np.random.default_rng(2)
    for _ in range(5):
        p0 = rng.permutation(20)
        p1 = two_opt(c, p0)
        assert m.cost(p1) <= m.cost(p0) + 1e-12
        p2 = or_opt(c, p1)
        assert m.cost(p2) <= m.cost(p1) + 1e-12


def test_sa_improves_over_random_mean():
    c = _rand_cost(32, seed=3)
    m = make_cost_model("ring", c, 0.0)
    rng = np.random.default_rng(4)
    rand_costs = m.cost_batch(np.stack([rng.permutation(32) for _ in range(64)]))
    res = solve_sa(m, iters=800, chains=8, seed=0)
    assert res.cost < rand_costs.mean()


def test_full_pipeline_beats_sa_alone_or_ties():
    c = _rand_cost(24, seed=7)
    m = make_cost_model("ring", c, 0.0)
    sa = solve_sa(m, iters=500, chains=8, seed=1)
    full = solve(m, method="auto", iters=500, chains=8, seed=1)
    assert full.cost <= sa.cost + 1e-12


def test_solve_small_n_exact():
    c = _rand_cost(6, seed=8)
    m = make_cost_model("ring", c, 0.0)
    res = solve(m, method="auto")
    _, best = exhaustive(m)
    assert res.cost == pytest.approx(best)


def test_worst_exceeds_best():
    c = _rand_cost(16, seed=9)
    m = make_cost_model("halving_doubling", c, 1e6)
    best = solve(m, method="paper", iters=600, seed=0)
    worst = solve_worst(m, iters=600, seed=0)
    assert worst.cost > best.cost


def test_swap_hill_climb_monotone():
    c = _rand_cost(12, seed=10)
    m = make_cost_model("double_binary_tree", c, 1e6)
    p0 = np.random.default_rng(0).permutation(12)
    p1 = swap_hill_climb(m, p0)
    assert m.cost(p1) <= m.cost(p0) + 1e-12


def test_percentile_orders_span_cost_range():
    c = _rand_cost(24, seed=11)
    m = make_cost_model("ring", c, 0.0)
    best = solve(m, iters=400, seed=0)
    worst = solve_worst(m, iters=400, seed=0)
    orders = percentile_orders(m, best.perm, worst.perm, k=10, seed=0)
    costs = m.cost_batch(np.stack(orders))
    assert len(orders) == 10
    # spans at least half the best->worst range, monotone-ish coverage
    assert costs.max() - costs.min() > 0.5 * (worst.cost - best.cost)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_solver_output_is_permutation(seed):
    c = _rand_cost(16, seed % 1000)
    m = make_cost_model("ring", c, 0.0)
    res = solve(m, iters=200, chains=4, seed=seed)
    assert sorted(res.perm.tolist()) == list(range(16))


def test_greedy_ring_valid_and_reasonable():
    c = _rand_cost(30, seed=12)
    p = greedy_ring(c)
    assert sorted(p.tolist()) == list(range(30))
    m = make_cost_model("ring", c, 0.0)
    rng = np.random.default_rng(13)
    rand_mean = m.cost_batch(
        np.stack([rng.permutation(30) for _ in range(32)])).mean()
    assert m.cost(p) < rand_mean
