"""Pallas kernel validation: interpret-mode allclose vs pure-jnp oracles,
swept over shapes and dtypes (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused_add
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import wkv_scan

RNG = np.random.default_rng(0)


def _mk(shape, dt):
    return jnp.asarray(RNG.standard_normal(shape), dt)


FLASH_CASES = [
    # (B, H, KV, S, hd, bq, bk, causal, window, dtype)
    (2, 4, 2, 64, 16, 16, 16, True, 0, jnp.float32),
    (1, 8, 8, 128, 32, 32, 64, True, 0, jnp.float32),
    (2, 4, 1, 64, 16, 32, 16, False, 0, jnp.float32),   # MQA, full attn
    (1, 4, 2, 128, 16, 32, 32, True, 32, jnp.float32),  # sliding window
    (1, 2, 2, 64, 16, 64, 64, True, 0, jnp.float32),    # single block
    (1, 2, 2, 64, 16, 16, 16, True, 0, jnp.bfloat16),
    (2, 6, 3, 96, 8, 32, 32, True, 0, jnp.float32),     # non-pow2 heads
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, H, KV, S, hd, bq, bk, causal, window, dt = case
    q, k, v = _mk((B, H, S, hd), dt), _mk((B, KV, S, hd), dt), _mk((B, KV, S, hd), dt)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol, rtol=tol)


def test_flash_matches_model_sdpa():
    """Kernel vs the model's XLA attention path (same math, two impls)."""
    from repro.models.layers import _sdpa

    q, k, v = _mk((2, 4, 64, 16), jnp.float32), _mk((2, 2, 64, 16), jnp.float32), \
        _mk((2, 2, 64, 16), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                        interpret=True)
    b = _sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


WKV_CASES = [
    (2, 32, 2, 8, 8, 8, jnp.float32),
    (1, 64, 4, 16, 16, 16, jnp.float32),
    (2, 16, 1, 8, 16, 16, jnp.float32),   # K != V
    (1, 32, 2, 8, 8, 32, jnp.float32),    # chunk == S
    (1, 32, 2, 8, 8, 8, jnp.bfloat16),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv_scan_matches_recurrence(case):
    B, S, H, K, V, chunk, dt = case
    r = _mk((B, S, H, K), dt) * 0.5
    k = _mk((B, S, H, K), dt) * 0.5
    v = _mk((B, S, H, V), dt) * 0.5
    w = jnp.asarray(
        1 / (1 + np.exp(-RNG.standard_normal((B, S, H, K)))) * 0.5 + 0.45, dt)
    u = _mk((H, K), dt) * 0.1
    out = wkv_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    expect, _ = ref.wkv_chunk_ref(r, k, v, w, u)
    tol = 5e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol, rtol=tol)


def test_wkv_kernel_agrees_with_model_layer():
    """The kernel is a drop-in for the model's scan recurrence."""
    from repro.models.rwkv6 import wkv_recurrence

    B, S, H, K = 1, 32, 2, 8
    r = _mk((B, S, H, K), jnp.float32)
    k = _mk((B, S, H, K), jnp.float32)
    v = _mk((B, S, H, K), jnp.float32)
    w = jnp.asarray(0.9 * np.ones((B, S, H, K)), jnp.float32)
    u = _mk((H, K), jnp.float32)
    a = wkv_scan(r, k, v, w, u, chunk=8, interpret=True)
    b, _ = wkv_recurrence(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("n,block", [(64, 16), (100, 32), (1024, 1024)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_fused_add(n, block, dt):
    a = _mk((n,), dt)
    b = _mk((n,), dt)
    out = fused_add(a, b, block=block, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(a + b, np.float32),
        atol=1e-2 if dt == jnp.bfloat16 else 1e-6)


def test_ring_reduce_scatter_ref_semantics():
    x = _mk((4, 32), jnp.float32)
    out = ref.ring_reduce_scatter_ref(x, 4)
    total = np.asarray(x).sum(0)
    for d in range(4):
        np.testing.assert_allclose(np.asarray(out[d]), total[d * 8:(d + 1) * 8])


CHUNKED_CASES = [
    # (B, S, H, K, V, chunk, w_lo, w_hi)
    (2, 64, 2, 8, 8, 16, 0.5, 0.999),
    (1, 128, 4, 16, 16, 16, 0.3, 0.99),
    (2, 32, 1, 8, 16, 8, 0.7, 0.95),
    (1, 64, 2, 8, 8, 32, 0.9, 0.999),
    (1, 64, 2, 8, 8, 16, 0.05, 0.5),   # strong decay (range bound check)
]


@pytest.mark.parametrize("case", CHUNKED_CASES)
def test_wkv_chunked_matmul_matches_recurrence(case):
    """The MXU matmul-form chunk kernel == the exact token recurrence."""
    from repro.kernels.rwkv6_chunked import wkv_chunked_matmul

    B, S, H, K, V, chunk, wlo, whi = case
    r = _mk((B, S, H, K), jnp.float32) * 0.5
    k = _mk((B, S, H, K), jnp.float32) * 0.5
    v = _mk((B, S, H, V), jnp.float32) * 0.5
    w = jnp.asarray(RNG.uniform(wlo, whi, (B, S, H, K)), jnp.float32)
    u = _mk((H, K), jnp.float32) * 0.1
    out = wkv_chunked_matmul(r, k, v, w, u, chunk=chunk, interpret=True)
    expect, _ = ref.wkv_chunk_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=5e-4, rtol=5e-3)


def test_wkv_chunked_matmul_agrees_with_loop_kernel():
    from repro.kernels.rwkv6_chunked import wkv_chunked_matmul
    from repro.kernels.rwkv6_scan import wkv_scan

    B, S, H, K = 1, 64, 2, 8
    r, k, v = (_mk((B, S, H, K), jnp.float32) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.6, 0.99, (B, S, H, K)), jnp.float32)
    u = _mk((H, K), jnp.float32) * 0.1
    a = wkv_chunked_matmul(r, k, v, w, u, chunk=16, interpret=True)
    b = wkv_scan(r, k, v, w, u, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-4, rtol=5e-3)
