"""Minimal fallback for ``hypothesis`` so test collection never hard-fails.

The real library is preferred (see requirements-dev.txt); when it is not
installed, this shim provides just enough of the ``given``/``settings``/
``strategies`` surface for our property tests: each ``@given`` test runs
a fixed number of pseudo-random examples drawn from the declared
strategies with a deterministic seed, so the tests stay meaningful and
reproducible — they simply lose hypothesis's shrinking and example
database.

Usage (in test modules)::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:          # pragma: no cover - exercised without dev deps
        from _hypothesis_shim import given, settings, strategies as st
"""

from __future__ import annotations

from typing import Any

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    """A draw()-able value source; mirrors the tiny subset we use."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the hypothesis module name
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(len(options)))])

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(size)]
        return _Strategy(draw)


st = strategies


def given(*strats: _Strategy):
    """Run the test once per generated example (deterministic seed)."""

    def decorator(fn):
        # NOTE: deliberately not functools.wraps — pytest must see a
        # zero-argument signature (the strategy parameters are filled by
        # the shim, not by fixtures).
        def wrapper():
            # @settings may sit above or below @given; check both targets
            max_examples = getattr(
                wrapper, "_shim_max_examples",
                getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES))
            rng = np.random.default_rng(0xC0FFEE)
            for _ in range(max_examples):
                values = [s.draw(rng) for s in strats]
                fn(*values)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # mimic hypothesis's marker: plugins (e.g. anyio) introspect
        # `fn.hypothesis.inner_test`
        marker = type("HypothesisShimMarker", (), {})()
        marker.inner_test = fn
        wrapper.hypothesis = marker
        return wrapper

    return decorator


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Record max_examples for ``given``; other options are no-ops."""

    def decorator(fn):
        fn._shim_max_examples = max_examples
        return fn

    return decorator
