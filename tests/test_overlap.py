"""Tests for the overlap layer (ISSUE-10 tentpole).

Covers: the memoised schedule-table cache (no rebuild across calls),
``LoweredSchedule.slice_rounds`` windows, grad-tree bucketing, the
planned ``PlanEntry.bucket_bytes`` dimension, ``OverlapConfig``
round-trips, the ``direct-schedule-run`` lint rule, and — on an
8-device host mesh in subprocesses — bitwise equality of the
double-buffered overlap runner against ``run_schedule``, numeric
equivalence of the overlapped train step against the baseline,
per-bucket postconditions, ``Session.overlap_step``, and the serve
engine's armed decode/prefill overlap.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis.lint import lint_file
from repro.kernels import schedule_runner
from repro.plan.compiler import PlanEntry
from repro.session.config import OverlapConfig, SessionConfig
from repro.train.overlap_grads import certified_allreduce, partition_tree

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(prog: str, sentinel: str, timeout: int = 900) -> None:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, timeout=timeout, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert sentinel in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# schedule-table cache
# ---------------------------------------------------------------------------

def test_schedule_tables_no_rebuild(monkeypatch):
    """Tables are built once per schedule value, never per call."""
    sched = certified_allreduce(4, 1 << 12, algo="ring")
    calls = {"n": 0}
    real = schedule_runner._step_tables

    def counting(step, n, n_chunks):
        calls["n"] += 1
        return real(step, n, n_chunks)

    monkeypatch.setattr(schedule_runner, "_step_tables", counting)
    schedule_runner.schedule_tables.cache_clear()
    t1 = schedule_runner.schedule_tables(sched)
    n_steps = sum(len(r) for r in sched.rounds)
    assert calls["n"] == n_steps
    t2 = schedule_runner.schedule_tables(sched)
    assert calls["n"] == n_steps          # second call: pure cache hit
    assert t1 is t2
    # frozen dataclasses hash by content: an equal re-lowering of the
    # same program shares the entry instead of rebuilding
    again = certified_allreduce(4, 1 << 12, algo="ring")
    schedule_runner.schedule_tables(again)
    assert calls["n"] == n_steps
    schedule_runner.schedule_tables.cache_clear()


# ---------------------------------------------------------------------------
# round slicing
# ---------------------------------------------------------------------------

def test_slice_rounds_windows():
    sched = certified_allreduce(4, 1 << 12, algo="ring")
    nr = len(sched.rounds)
    assert sched.slice_rounds(0, nr) is sched   # full window keeps the proof
    head = sched.slice_rounds(0, 2)
    tail = sched.slice_rounds(2, nr)
    assert len(head.rounds) == 2
    assert len(tail.rounds) == nr - 2
    # a partial window makes no end-state claim
    assert head.postcondition == "none"
    assert tail.postcondition == "none"
    parts = sched.split_rounds()
    assert len(parts) == nr
    assert all(len(p.rounds) == 1 for p in parts)
    with pytest.raises(ValueError):
        sched.slice_rounds(3, 2)
    with pytest.raises(ValueError):
        sched.slice_rounds(0, nr + 1)


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_partition_tree_buckets():
    tree = {"a": np.zeros((100,), np.float32),
            "b": np.zeros((300,), np.float32),
            "c": np.zeros((50,), np.float32),
            "d": np.zeros((500,), np.float32)}
    # <= 0 bytes: everything in one bucket
    whole = partition_tree(tree, 0)
    assert len(whole) == 1
    assert whole[0].n_elems == 950
    buckets = partition_tree(tree, 1200)        # 300 float32 elements
    ids = [i for b in buckets for i in b.leaf_ids]
    assert ids == sorted(set(ids))              # every leaf exactly once
    assert sum(b.n_elems for b in buckets) == 950
    assert len(buckets) > 1
    # an oversized leaf still lands alone rather than being dropped
    assert any(b.leaf_ids == (3,) for b in buckets)


def test_partition_tree_leading_axis():
    tree = {"a": np.zeros((8, 100), np.float32)}
    b = partition_tree(tree, 0, leading_axis=True)[0]
    assert b.n_elems == 100                     # stacked axis not counted
    assert b.n_bytes == 400


# ---------------------------------------------------------------------------
# planned bucket_bytes dimension
# ---------------------------------------------------------------------------

def _entry(**over) -> PlanEntry:
    base = dict(op="all-reduce", bucket=22, size_bytes=4e6,
                group=(0, 1, 2, 3), algo="ring", algo_kwargs={},
                chunks=2, perm=(2, 0, 3, 1), expected_time=1e-3,
                identity_times={"ring": 2e-3}, solver_cost=1.0,
                oracle="simulator", bucket_bytes=1 << 20)
    base.update(over)
    return PlanEntry(**base)


def test_plan_entry_bucket_bytes_roundtrip():
    e = _entry()
    assert PlanEntry.from_dict(e.to_dict()) == e
    # plans serialized before the field existed default to "not planned"
    d = e.to_dict()
    del d["bucket_bytes"]
    assert PlanEntry.from_dict(d).bucket_bytes == 0.0


# ---------------------------------------------------------------------------
# OverlapConfig
# ---------------------------------------------------------------------------

def test_overlap_config_roundtrip():
    cfg = SessionConfig.from_dict(
        {"overlap": {"mode": "bucketed", "bucket_bytes": 1e6}})
    assert cfg.overlap.mode == "bucketed"
    assert cfg.overlap.bucket_bytes == 1e6
    assert SessionConfig.from_dict(cfg.to_dict()) == cfg
    # defaults: overlap off, bucket size delegated to the plan
    assert SessionConfig().overlap == OverlapConfig()


def test_overlap_config_validation_and_env():
    with pytest.raises(ValueError):
        OverlapConfig(mode="nope")
    cfg = SessionConfig.from_env(environ={
        "REPRO_OVERLAP_MODE": "fused",
        "REPRO_OVERLAP_BUCKET_BYTES": "2e6",
    })
    assert cfg.overlap.mode == "fused"
    assert cfg.overlap.bucket_bytes == 2e6


# ---------------------------------------------------------------------------
# lint rule: no raw run_schedule in workload layers
# ---------------------------------------------------------------------------

def test_lint_direct_schedule_run(tmp_path):
    body = ("def f(x, mesh, axis, sched):\n"
            "    return run_schedule(x, mesh, axis, sched)\n")
    train = tmp_path / "src" / "repro" / "train"
    train.mkdir(parents=True)
    (train / "bad.py").write_text(body)
    rules = [f.rule for f in lint_file(str(train / "bad.py"), str(tmp_path))]
    assert rules == ["direct-schedule-run"]
    # waiver comment is honored
    (train / "ok.py").write_text(
        "def f(x, mesh, axis, sched):\n"
        "    return run_schedule(x, mesh, axis, sched)"
        "  # lint: allow(direct-schedule-run)\n")
    assert lint_file(str(train / "ok.py"), str(tmp_path)) == []
    # the kernels layer itself is allowed to call the runner
    kern = tmp_path / "src" / "repro" / "kernels"
    kern.mkdir()
    (kern / "fine.py").write_text(body)
    assert lint_file(str(kern / "fine.py"), str(tmp_path)) == []


# ---------------------------------------------------------------------------
# 8-device host mesh: overlap runner == run_schedule, bitwise
# ---------------------------------------------------------------------------

def test_overlapped_matches_run_schedule_8dev():
    prog = """
import numpy as np
import jax
from jax.sharding import Mesh
from repro.collective import CollectiveOp, compile_op, JaxExecutor
from repro.collective.passes import apply_permutation, chunk
from repro.analysis import require_certified
from repro.kernels.schedule_runner import (
    run_schedule, check_postcondition, schedule_tables)
from repro.kernels.overlap import (
    build_overlap_plan, run_overlapped, seed_state, finish_state)

n = 8
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
ex = JaxExecutor()
perm = [3, 1, 4, 7, 5, 0, 2, 6]
for algo, k in [("ring", 2), ("halving_doubling", 1)]:
    op = CollectiveOp(kind="allreduce", size_bytes=1 << 12,
                      group=tuple(range(n)))
    prog = apply_permutation(compile_op(op, algo), perm)
    if k > 1:
        prog = chunk(prog, k)
    sched = ex.lower_schedule(prog)
    require_certified(prog, sched)
    d = (1 << 12) // 4
    x = np.arange(n * d, dtype=np.float32).reshape(n, d) / (n * d)
    ref = np.asarray(run_schedule(x, mesh, "x", sched, use_pallas_add=False))
    # no-compute overlap: bitwise identical to the plain runner
    out, _ = run_overlapped(x, mesh, "x", sched, use_pallas_add=False)
    assert np.array_equal(ref, np.asarray(out)), (algo, k)
    assert not check_postcondition(sched, x, np.asarray(out))
    # with compute shards interleaved: same result, shards all ran
    comp = [lambda i=i: jax.numpy.sum(jax.numpy.ones((16, 16)) * i)
            for i in range(5)]
    plan = build_overlap_plan(sched, 5)
    out2, res = run_overlapped(x, mesh, "x", plan, compute=comp,
                               use_pallas_add=False)
    assert np.array_equal(ref, np.asarray(out2)), (algo, k)
    assert [float(r) for r in res] == [256.0 * i for i in range(5)]
    # sliced composition: window [0, m) then [m, end) == one shot
    m = max(1, len(sched.rounds) // 2)
    st = seed_state(sched, x)
    st, _ = run_overlapped(None, mesh, "x", sched, state=st, rounds=(0, m),
                           return_state=True, use_pallas_add=False)
    st, _ = run_overlapped(None, mesh, "x", sched, state=st,
                           rounds=(m, None), return_state=True,
                           use_pallas_add=False)
    assert np.array_equal(ref, np.asarray(finish_state(sched, st))), (algo, k)
    print(algo, k, "OK")
assert schedule_tables.cache_info().hits > 0
print("OVERLAP RUNNER OK")
"""
    _run_sub(prog, "OVERLAP RUNNER OK")


# ---------------------------------------------------------------------------
# 8-device host mesh: overlapped train step == baseline
# ---------------------------------------------------------------------------

def test_overlap_train_step_equivalence_8dev():
    prog = """
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.data import SyntheticLM, host_batch
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.train import init_state, make_train_step, jit_train_step
from repro.train.overlap_grads import (
    OverlapGradReducer, certified_allreduce, partition_tree)
from repro.kernels.overlap import run_overlapped
from repro.kernels.schedule_runner import check_postcondition

n = 8
mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
cfg = get_config("qwen2-0.5b").smoke()
model = get_model(cfg)
opt = AdamWConfig(lr=1e-3)
state = init_state(model, jax.random.PRNGKey(0))
ds = SyntheticLM(cfg.vocab_size, 16, n, seed=0)
batch = host_batch(ds, 0)

base_step = jax.jit(make_train_step(model, opt))
base_state, base_metrics = base_step(state, batch)
base_grads = jax.jit(jax.grad(model.loss))(state.params, batch)

# per-shard grads, stacked [n, ...] — what the shard_map hands the reducer
shard = lambda l, i: l[i * (l.shape[0] // n):(i + 1) * (l.shape[0] // n)]
gstack = jax.tree.map(
    lambda *ls: jnp.stack(ls),
    *[jax.jit(jax.grad(model.loss))(
        state.params, jax.tree.map(lambda l, i=i: shard(l, i), batch))
      for i in range(n)])

pb = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(state.params))
bb = pb / 3.5
sched = certified_allreduce(n, bb, algo="ring",
                            perm=[3, 1, 4, 7, 5, 0, 2, 6], chunk_factor=2)

for mode in ("bucketed", "fused"):
    red = OverlapGradReducer(mesh, "data", sched, bucket_bytes=bb, mode=mode)
    # reducer alone: mean of per-shard grads == baseline grads (fp tol)
    mean_tree = jax.jit(lambda g: red(g)[0])(gstack)
    for a, b in zip(jax.tree.leaves(mean_tree), jax.tree.leaves(base_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
    # full jitted step: loss / grad-norm metrics match the baseline
    step = jit_train_step(model, opt, cfg, mesh, None, None, donate=False,
                          overlap=mode, reducer=red, axis="data")
    new_state, metrics = step(state, batch)
    np.testing.assert_allclose(float(metrics["loss"]),
                               float(base_metrics["loss"]),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(float(metrics["grad_norm"]),
                               float(base_metrics["grad_norm"]),
                               rtol=2e-4, atol=1e-5)
    # params: absolute bound only (Adam's 1st step is sign-like where
    # grads ~ 0, so relative comparison there is ill-conditioned)
    for a, b in zip(jax.tree.leaves(new_state.params),
                    jax.tree.leaves(base_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-4)
    print(mode, "OK", float(metrics["loss"]))

# per-bucket payloads satisfy the schedule's declared postcondition
leaves = [np.asarray(l, np.float32).reshape(n, -1)
          for l in jax.tree.leaves(gstack)]
q = sched.n_chunks * max(1, sched.chunk_factor)
for b in partition_tree(state.params, bb)[:2]:
    flat = np.concatenate([leaves[i] for i in b.leaf_ids], axis=1)
    payload = np.pad(flat, ((0, 0), (0, (-flat.shape[1]) % q)))
    out, _ = run_overlapped(payload, mesh, "data", sched,
                            use_pallas_add=False)
    bad = check_postcondition(sched, payload, np.asarray(out), atol=1e-4)
    assert not bad, bad

# Session facade: a planned, certified reducer end to end
from repro.session import Session, SessionConfig
scfg = SessionConfig.from_dict({
    "fabric": {"kind": "datacenter", "nodes": n, "scramble_seed": 1},
    "solver": {"budget": {"iters": 60, "chains": 2}},
    "payload_bytes": float(pb),
    "workload": "train",
    "overlap": {"mode": "bucketed"},
})
with Session(scfg) as s:
    red2 = s.overlap_step(mesh, "data")
mean2 = jax.jit(lambda g: red2(g)[0])(gstack)
for a, b in zip(jax.tree.leaves(mean2), jax.tree.leaves(base_grads)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=1e-6)
print("TRAIN EQUIV DONE")
"""
    _run_sub(prog, "TRAIN EQUIV DONE")


# ---------------------------------------------------------------------------
# 8-device host mesh: serve engine armed overlap
# ---------------------------------------------------------------------------

def test_serve_overlap_8dev():
    prog = """
import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core import make_datacenter, probe_fabric, scramble
from repro.models import get_model
from repro.plan import CollectiveRequest, JobMix, PlanCompiler, SolveBudget
from repro.serve import GenerationConfig, GenerationEngine
from repro import obs

fab, _ = scramble(make_datacenter(8, seed=0), seed=1)
probe = probe_fabric(fab, seed=0)
mix = JobMix((CollectiveRequest("all-gather", 1e6),
              CollectiveRequest("all-reduce", 4e6)), name="serve")
plan = PlanCompiler(fabric=fab,
                    budget=SolveBudget(iters=60, chains=2)).compile(probe, mix)
assert plan.lookup("all-reduce", 4e6).bucket_bytes > 0  # planned dimension

cfg = get_config("qwen2-0.5b").smoke()
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
prompts = [[1, 2, 3, 4], [4, 3, 2, 1]]

base = GenerationEngine(
    model, params, GenerationConfig(max_new_tokens=5, eos_token=-1),
    plan=plan).generate(prompts)

eng = GenerationEngine(
    model, params, GenerationConfig(max_new_tokens=5, eos_token=-1),
    plan=plan)
sched = eng.arm_overlap(mesh, "data", payload_bytes=1e6)
assert sched.postcondition == "all_gather"
outs = eng.generate(prompts)
assert outs == base, (outs, base)
assert obs.metrics().counter("serve.overlap.postcondition_ok").value >= 1
print("SERVE OVERLAP DONE")
"""
    _run_sub(prog, "SERVE OVERLAP DONE")
