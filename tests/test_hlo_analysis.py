"""Unit tests for the HLO introspection layer (roofline instrumentation)."""

import pytest

from repro.launch import hlo_analysis as ha

SAMPLE_HLO = """\
HloModule jit_step, entry_computation_layout={()->f32[]}

%wide.body_2 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ag = bf16[64,512]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
  %ar = f32[128]{0} all-reduce(%y), to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %z)
}

%wide.cond_2 (p: (s32[], f32[128,256])) -> pred[] {
  %c40 = s32[] constant(40)
  ROOT %lt = pred[] compare(%i, %c40), direction=LT
}

ENTRY %main.1 (a: f32[4]) -> f32[] {
  %w = (s32[], f32[128,256]) while(%init), condition=%wide.cond_2, body=%wide.body_2
  %cp = f32[1024]{0} collective-permute(%a), source_target_pairs={{0,1}}
  %rs = bf16[32,32]{1,0} reduce-scatter(%b), replica_groups=[4,4]<=[16]
  ROOT %r = f32[] constant(0)
}
"""


def test_parse_collectives_counts_and_scales():
    st = ha.parse_collectives(SAMPLE_HLO)
    # all-gather inside the while body scales by trip=40
    assert st.count_by_type["all-gather"] == 40
    assert st.bytes_by_type["all-gather"] == pytest.approx(64 * 512 * 2 * 40)
    assert st.bytes_by_type["all-reduce"] == pytest.approx(128 * 4 * 40)
    # entry-level ops scale by 1
    assert st.count_by_type["collective-permute"] == 1
    assert st.bytes_by_type["collective-permute"] == pytest.approx(1024 * 4)
    assert st.bytes_by_type["reduce-scatter"] == pytest.approx(32 * 32 * 2)


def test_parse_collectives_no_scaling_mode():
    st = ha.parse_collectives(SAMPLE_HLO, scale_loops=False)
    assert st.count_by_type["all-gather"] == 1
    assert st.bytes_by_type["all-gather"] == pytest.approx(64 * 512 * 2)


def test_roofline_terms_dominance():
    hw = ha.HW()
    # compute-bound: lots of flops, tiny bytes
    t = ha.roofline_terms(1e20, 1e10, 1e8, 256, hw)
    assert t["dominant"] == "compute"
    # collective-bound with DCN share
    t = ha.roofline_terms(1e12, 1e10, 1e13, 256, hw, dcn_collective_bytes=5e12)
    assert t["dominant"] == "collective"
    # DCN bytes cost more than ICI bytes
    t_ici = ha.roofline_terms(0, 0, 1e12, 256, hw)
    t_dcn = ha.roofline_terms(0, 0, 1e12, 256, hw, dcn_collective_bytes=1e12)
    assert t_dcn["collective_s"] > t_ici["collective_s"]


def test_result_bytes_tuple_results():
    line = ("  %aa = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) "
            "all-to-all(%p0, %p1), replica_groups={}")
    assert ha._result_bytes(line) == pytest.approx(2 * 8 * 128 * 2)
