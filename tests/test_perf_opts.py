"""Correctness tests for the §Perf hillclimb optimizations:

1. matrix-absorbed MLA decode == naive MLA decode;
2. shard_map all-to-all MoE == dense einsum MoE (multi-device subprocess);
3. SP K/V-gather hoist changes layout only, not values.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import get_model


def test_mla_absorbed_matches_naive_decode():
    cfg = dataclasses.replace(get_config("deepseek-v2-236b").smoke(),
                              capacity_factor=8.0)
    model_naive = get_model(cfg)
    params = model_naive.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2,), 0, cfg.vocab_size)
    cache = model_naive.init_cache(2, 8)

    l1, c1 = jax.jit(model_naive.decode_step)(params, toks, cache)
    model_abs = get_model(dataclasses.replace(cfg, mla_absorb=True))
    l2, c2 = jax.jit(model_abs.decode_step)(params, toks, cache)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(
        np.asarray(c1["scan"]["ckv"]), np.asarray(c2["scan"]["ckv"]),
        atol=1e-5)

    # a second step on the updated cache still agrees
    l1b, _ = jax.jit(model_naive.decode_step)(params, toks, c1)
    l2b, _ = jax.jit(model_abs.decode_step)(params, toks, c2)
    np.testing.assert_allclose(np.asarray(l1b), np.asarray(l2b),
                               atol=2e-4, rtol=2e-3)


def test_hoist_kv_gather_is_value_neutral():
    cfg = dataclasses.replace(get_config("glm4-9b").smoke(), attn_q_chunk=4)
    m1 = get_model(cfg)
    params = m1.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    m2 = get_model(dataclasses.replace(cfg, hoist_kv_gather=False))
    l1, _ = jax.jit(m1.forward)(params, toks)
    l2, _ = jax.jit(m2.forward)(params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_moe_a2a_matches_dense_multidevice():
    """a2a MoE vs dense on an (data=4, model=2) 8-device mesh."""
    prog = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import get_model
from repro.launch.specs import configure_sp
from repro.launch.mesh import make_mesh_for_tests

cfg = dataclasses.replace(
    get_config("dbrx-132b").smoke(),
    n_experts=8, moe_top_k=2, capacity_factor=8.0, d_model=64,
    sequence_parallel=True)
mesh = make_mesh_for_tests((4, 2), ("data", "model"))

model_d = get_model(dataclasses.replace(cfg, moe_impl="dense"))
params = model_d.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)

with jax.set_mesh(mesh):
    configure_sp(cfg, mesh)
    ld, _ = jax.jit(model_d.forward)(params, toks)
    model_a = get_model(dataclasses.replace(cfg, moe_impl="a2a"))
    la, _ = jax.jit(model_a.forward)(params, toks)
np.testing.assert_allclose(np.asarray(ld, np.float32),
                           np.asarray(la, np.float32), atol=2e-3, rtol=2e-2)

# gradients agree too
def loss_fn(m):
    def f(p):
        lg, _ = m.forward(p, toks)
        return jnp.mean(lg.astype(jnp.float32) ** 2)
    return f
with jax.set_mesh(mesh):
    gd = jax.jit(jax.grad(loss_fn(model_d)))(params)
    ga = jax.jit(jax.grad(loss_fn(model_a)))(params)
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path(gd),
        jax.tree_util.tree_leaves_with_path(ga)):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=5e-3, rtol=5e-2, err_msg=str(pa))
print("MOE A2A OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]
    assert "MOE A2A OK" in r.stdout


def test_rwkv_kernel_path_matches_xla_path():
    """wkv_impl='kernel' (Pallas chunked matmul) == 'xla' (scan) in the
    full model forward."""
    cfg = dataclasses.replace(get_config("rwkv6-1.6b").smoke(), wkv_impl="xla")
    m_xla = get_model(cfg)
    params = m_xla.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l_xla, _ = m_xla.forward(params, toks)
    m_k = get_model(dataclasses.replace(cfg, wkv_impl="kernel"))
    l_k, _ = m_k.forward(params, toks)
    np.testing.assert_allclose(np.asarray(l_xla), np.asarray(l_k),
                               atol=2e-3, rtol=2e-2)
