"""Schedule-builder invariants for every entry in ``SCHEDULES``.

For any valid (algo, n, perm, size):

* flows stay in-bounds: every endpoint is a node named by ``perm``;
* no self-flows for n >= 2;
* every node participates (appears as a src and as a dst);
* total bytes are conserved under reordering: the multiset structure of
  a schedule is permutation-independent, so its total wire bytes (and
  round count) must equal the identity order's;
* builders with validity constraints raise ValueError with a clear
  message on bad n instead of asserting (regression for the seed's bare
  asserts).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.core.schedule import (
    SCHEDULES,
    bcube_allreduce,
    halving_doubling_allreduce,
    recursive_doubling_all_gather,
)

#: valid world sizes per algo (powers of two / of the bcube base where
#: required); kept small so the exhaustive flow checks stay fast.
_VALID_NS = {
    "ring": (2, 3, 5, 8, 12),
    "ring_sequential": (2, 3, 5, 8, 12),
    "halving_doubling": (2, 4, 8, 16),
    "double_binary_tree": (2, 3, 5, 8, 12),
    "bcube": (4, 16),
    "ring_all_gather": (2, 3, 5, 8, 12),
    "recursive_doubling": (2, 4, 8, 16),
    "all_to_all": (2, 3, 5, 8, 12),
}

SIZE = 1e6


def _flat(rounds):
    return [f for rnd in rounds for f in rnd]


def _check_invariants(algo, n, seed):
    build = SCHEDULES[algo]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    nodes = set(int(x) for x in perm)

    rounds = build(perm, SIZE)
    flows = _flat(rounds)
    assert flows, f"{algo} produced an empty schedule at n={n}"

    # in-bounds + no self-flows + positive finite payloads
    for f in flows:
        assert f.src in nodes and f.dst in nodes, (algo, n, f)
        assert f.src != f.dst, (algo, n, f)
        assert np.isfinite(f.size) and f.size > 0, (algo, n, f)

    # every node participates; for all but the naive sequential ring
    # (where the full buffer circulates 0 -> n-1, so the tail never
    # sends and the head never receives) on BOTH sides
    assert {f.src for f in flows} | {f.dst for f in flows} == nodes, (algo, n)
    if algo != "ring_sequential":
        assert {f.src for f in flows} == nodes, (algo, n)
        assert {f.dst for f in flows} == nodes, (algo, n)

    # conservation under reordering: total bytes and round count match
    # the identity order (the structure is permutation-independent)
    ident_rounds = build(np.arange(n), SIZE)
    ident = _flat(ident_rounds)
    total = sum(f.size for f in flows)
    total_ident = sum(f.size for f in ident)
    assert total == pytest.approx(total_ident, rel=1e-12), (algo, n)
    # per-round flow counts also survive the permutation
    assert [len(r) for r in rounds] == [len(r) for r in ident_rounds], (algo, n)


@given(st.sampled_from(sorted(SCHEDULES)), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_schedule_invariants(algo, seed):
    ns = _VALID_NS[algo]
    n = ns[seed % len(ns)]
    _check_invariants(algo, n, seed)


@pytest.mark.parametrize("algo", sorted(SCHEDULES))
def test_schedule_invariants_exhaustive_small(algo):
    for n in _VALID_NS[algo]:
        _check_invariants(algo, n, seed=n)


# -- validation regressions (satellite: no bare asserts on bad n) ----------

@pytest.mark.parametrize("n", [3, 6, 12])
def test_halving_doubling_rejects_non_power_of_two(n):
    with pytest.raises(ValueError, match="power-of-two"):
        halving_doubling_allreduce(np.arange(n), SIZE)


@pytest.mark.parametrize("n", [3, 6, 12])
def test_recursive_doubling_rejects_non_power_of_two(n):
    with pytest.raises(ValueError, match="power-of-two"):
        recursive_doubling_all_gather(np.arange(n), SIZE)


@pytest.mark.parametrize("n,base", [(6, 4), (12, 4), (10, 2)])
def test_bcube_rejects_non_power_of_base(n, base):
    with pytest.raises(ValueError, match="power"):
        bcube_allreduce(np.arange(n), SIZE, base=base)


def test_bcube_rejects_degenerate_base():
    with pytest.raises(ValueError, match="base"):
        bcube_allreduce(np.arange(4), SIZE, base=1)


def test_valid_sizes_still_build():
    assert halving_doubling_allreduce(np.arange(8), SIZE)
    assert bcube_allreduce(np.arange(16), SIZE, base=4)
    assert bcube_allreduce(np.arange(8), SIZE, base=2)
    assert recursive_doubling_all_gather(np.arange(8), SIZE)
