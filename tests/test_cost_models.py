"""Unit + property tests for the paper's cost models (§IV-A)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.core import COST_MODELS, make_cost_model
from repro.core.cost_models import RingCost


def _rand_cost(n, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.uniform(1.0, 10.0, (n, n))
    c = np.maximum(c, c.T)
    np.fill_diagonal(c, 0.0)
    return c


ALGOS = ["ring", "halving_doubling", "double_binary_tree", "all_to_all"]


@pytest.mark.parametrize("algo", ALGOS)
def test_cost_positive_and_batch_consistent(algo):
    c = _rand_cost(16)
    m = make_cost_model(algo, c, 100e6)
    rng = np.random.default_rng(1)
    perms = np.stack([rng.permutation(16) for _ in range(8)])
    batch = m.cost_batch(perms)
    for i, p in enumerate(perms):
        assert batch[i] == pytest.approx(m.cost(p))
        assert batch[i] > 0


def test_bcube_requires_power_of_base():
    c = _rand_cost(16)
    m = make_cost_model("bcube", c, 1e6, base=4)
    assert m.cost(np.arange(16)) > 0
    with pytest.raises(ValueError):
        make_cost_model("bcube", _rand_cost(12), 1e6, base=4)


def test_ring_cost_is_tour_length():
    """C_r must equal the sum of successive-pair costs (paper formula)."""
    c = _rand_cost(10)
    m = make_cost_model("ring", c, 0.0)
    perm = np.random.default_rng(2).permutation(10)
    expect = sum(c[perm[i], perm[i - 1]] for i in range(10))
    assert m.cost(perm) == pytest.approx(expect)


def test_hd_cost_is_sum_of_round_maxima():
    c = _rand_cost(8)
    m = make_cost_model("halving_doubling", c, 8e6)
    perm = np.arange(8)
    total = 0.0
    for i in range(3):
        pairs = {(j, j ^ (1 << i)) for j in range(8)}
        scale = (8e6 / 2 ** (i + 1)) / 8e6
        total += max(c[a, b] * scale for a, b in pairs)
    assert m.cost(perm) == pytest.approx(total)


@given(st.integers(0, 2**31 - 1), st.sampled_from(ALGOS))
@settings(max_examples=25, deadline=None)
def test_permutation_invariance_of_node_relabeling(seed, algo):
    """Relabeling nodes and permuting identically must not change cost.

    cost(perm, c) == cost(sigma(perm), c relabeled by sigma^-1) — the
    objective depends only on which physical pairs communicate.
    """
    rng = np.random.default_rng(seed)
    n = 8
    c = _rand_cost(n, seed)
    perm = rng.permutation(n)
    sigma = rng.permutation(n)
    c2 = c[np.ix_(sigma, sigma)]          # c2[i,j] = c[sigma_i, sigma_j]
    inv = np.argsort(sigma)
    m1 = make_cost_model(algo, c, 1e6)
    m2 = make_cost_model(algo, c2, 1e6)
    assert m1.cost(perm) == pytest.approx(m2.cost(inv[perm]), rel=1e-9)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_uniform_cost_makes_order_irrelevant(seed):
    """On a uniform fabric every rank order costs the same (no locality
    -> nothing to exploit; the paper's premise in reverse)."""
    rng = np.random.default_rng(seed)
    n = 16
    c = np.full((n, n), 3.0)
    np.fill_diagonal(c, 0.0)
    for algo in ALGOS:
        m = make_cost_model(algo, c, 1e6)
        a = m.cost(np.arange(n))
        b = m.cost(rng.permutation(n))
        assert a == pytest.approx(b)


def test_critical_edges_identify_max_cost_pair():
    c = _rand_cost(8)
    c[2, 5] = c[5, 2] = 1000.0
    m = make_cost_model("halving_doubling", c, 1e6)
    # place 2 and 5 as XOR-1 partners so round 0 uses the bad edge
    perm = np.array([2, 5, 0, 1, 3, 4, 6, 7])
    edges = m.critical_edges(perm)
    assert any({a, b} == {2, 5} for a, b, _ in edges)


def test_exact_lat_bw_parameterization():
    n = 8
    rng = np.random.default_rng(3)
    lat = _rand_cost(n, 1) * 1e-6
    bw = np.full((n, n), 1e9)
    m = make_cost_model("halving_doubling", size_bytes=1e6, lat=lat, bw=bw)
    # round i payload = S / 2^{i+1}: exact alpha-beta, not linear rescale
    perm = np.arange(n)
    total = 0.0
    for i in range(3):
        pairs = {(j, j ^ (1 << i)) for j in range(n)}
        payload = 1e6 / 2 ** (i + 1)
        total += max(lat[a, b] + payload / 1e9 for a, b in pairs)
    assert m.cost(perm) == pytest.approx(total)
