"""Property tests (hypothesis) for MoE dispatch invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - fallback when dev deps absent
    from _hypothesis_shim import given, settings, st

from repro.configs import get_config
from repro.models import layers as L


def _moe_cfg(E=4, K=2, cf=8.0, impl="dense", group=1024):
    return dataclasses.replace(
        get_config("dbrx-132b").smoke(), n_experts=E, moe_top_k=K,
        capacity_factor=cf, moe_impl=impl, moe_group_size=group)


def _params(cfg, seed=0):
    rng = jax.random.PRNGKey(seed)
    return L.init_moe(rng, cfg, jnp.float32)


@given(st.integers(0, 2**31 - 1), st.sampled_from(["dense", "scatter"]))
@settings(max_examples=10, deadline=None)
def test_moe_output_finite_and_shaped(seed, impl):
    cfg = _moe_cfg(impl=impl)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model))
    y, aux = L.moe_layer(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_moe_dense_matches_scatter_without_drops(seed):
    """With ample capacity the two dispatch structures are the same math."""
    cfg = _moe_cfg(cf=16.0)
    p = _params(cfg, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model)) * 0.5
    yd, _ = L.moe_dense(p, x, cfg)
    ys, _ = L.moe_scatter(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               atol=5e-5, rtol=5e-4)


def test_moe_capacity_drops_reduce_output_norm():
    """Tight capacity must drop tokens (outputs shrink toward zero),
    never corrupt them (outputs stay finite)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 64)) * 0.5
    cfg_hi = _moe_cfg(cf=8.0)
    cfg_lo = dataclasses.replace(cfg_hi, capacity_factor=0.25)
    p = _params(cfg_hi)
    y_hi, _ = L.moe_dense(p, x, cfg_hi)
    y_lo, _ = L.moe_dense(p, x, cfg_lo)
    n_hi = float(jnp.linalg.norm(y_hi))
    n_lo = float(jnp.linalg.norm(y_lo))
    assert np.isfinite(n_lo)
    assert n_lo < n_hi


def test_router_weights_normalized():
    cfg = _moe_cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    idx, w, aux = L._router_probs(p, x, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(idx.min()) >= 0 and int(idx.max()) < cfg.n_experts
