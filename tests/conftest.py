"""Shared test fixtures.

The SP/EP contexts are module-level trace-time state armed by launchers
(`configure_sp`); reset them around every test so a test that arms them
(e.g. the launch-spec tests) cannot leak sharding constraints into
mesh-less tests.
"""

import pytest


@pytest.fixture(autouse=True)
def _reset_parallel_contexts():
    yield
    from repro.models.layers import clear_sequence_parallel
    from repro.parallel.moe_a2a import clear_ep

    clear_sequence_parallel()
    clear_ep()
