"""Tests for :mod:`repro.analysis` — static verification + lint (ISSUE-8).

Acceptance criteria exercised here:

* every registered builder verifies **clean** (no errors, no warnings,
  zero dead transfers) at n in {4, 8, 16, 64}, including after the
  ``apply_permutation`` and ``chunk`` rewrite passes;
* the seeded program mutator is caught by the gate passes at >= 95%;
* the static contention report agrees with the flow-level simulator
  about the bottleneck on a planted 2-tier fabric: speeding up the
  reported bottleneck link speeds up the simulated collective, speeding
  up any other link does not;
* ``fuse_rounds`` stays safe when instructions share only a chunk id
  across participant-disjoint rounds (the regression this PR pins);
* each verdict code is reachable from a hand-built program;
* the lint rules fire on violations, honor waivers, and the repo
  itself lints clean.
"""

import dataclasses
import random
import textwrap

import numpy as np
import pytest

from repro.analysis import (
    GATE_PASSES,
    VerificationError,
    kill_rate,
    mutants,
    require_valid,
    verify_program,
)
from repro.analysis.lint import lint_file, lint_repo
from repro.collective import (
    CollectiveOp,
    FlowInstr,
    Program,
    apply_permutation,
    chunk,
    compile_op,
    fuse_rounds,
    get_builder,
    registered_builders,
)
from repro.collective.builders import candidates
from repro.core.simulator import simulate_rounds
from repro.fabric import Fabric, HierarchyModel

SIZES = (4, 8, 16, 64)


def catalogue(ns=SIZES):
    """(label, program) for every feasible (builder, kind, n)."""
    out = []
    for n in ns:
        for algo in sorted(registered_builders()):
            b = get_builder(algo)
            for kind in b.kinds:
                akws = [akw for a, akw in candidates(kind, n) if a == algo]
                if not akws:
                    continue
                op = CollectiveOp(kind=kind, size_bytes=1e6,
                                  group=tuple(range(n)))
                out.append((f"{algo}/{kind}/n={n}",
                            compile_op(op, algo, **dict(akws[0]))))
    return out


def hand_program(rounds, *, n=2, n_chunks=1, init="replicated",
                 post="none", kind="allreduce"):
    """A minimal hand-built Program for verdict tests."""
    return Program(
        op=CollectiveOp(kind=kind, size_bytes=8.0 * n_chunks,
                        group=tuple(range(n))),
        algorithm="hand", algo_kwargs=(),
        rounds=tuple(tuple(r) for r in rounds),
        perm=tuple(range(n)), n_chunks=n_chunks, chunk_bytes=8.0,
        init=init, postcondition=post, cost_model="alpha_beta")


def codes(report):
    return {f.code for f in report.findings}


# ---------------------------------------------------------------------------
# catalogue sweep: every builder, every size, every rewrite variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("label,prog", catalogue(), ids=lambda x: x
                         if isinstance(x, str) else "")
def test_catalogue_verifies_clean(label, prog):
    for variant, p in [
        ("identity", prog),
        ("permuted", apply_permutation(prog, list(range(prog.n))[::-1])),
        ("chunked", chunk(prog, 4)),
    ]:
        rep = verify_program(p, passes=GATE_PASSES)
        assert rep.clean, (
            f"{label} [{variant}]: {[str(f) for f in rep.findings]}")
        assert rep.stats["liveness"]["n_dead"] == 0, f"{label} [{variant}]"
        assert rep.stats["deps"]["acyclic"], f"{label} [{variant}]"


def test_require_valid_returns_report_on_clean_program():
    prog = catalogue(ns=(8,))[0][1]
    rep = require_valid(prog, passes=GATE_PASSES)
    assert rep.ok and rep.program_fingerprint == prog.fingerprint()


# ---------------------------------------------------------------------------
# mutant screen
# ---------------------------------------------------------------------------

def test_mutant_kill_rate_at_least_95_percent():
    programs = [p for _, p in catalogue(ns=(4, 8, 16))]
    rate, survivors = kill_rate(programs, seed=0)
    assert rate >= 0.95, f"kill rate {rate:.3f}; survivors: {survivors}"
    # the only tolerated survivors are the naive sequential ring's
    # src/dst swaps — its second lap re-delivers what the swap broke
    assert all(algo == "ring_sequential" for algo, _, _ in survivors), \
        survivors


def test_mutants_are_deterministic_and_distinct():
    prog = catalogue(ns=(8,))[0][1]
    a = [(kind, m.fingerprint()) for kind, m in mutants(prog, seed=7)]
    b = [(kind, m.fingerprint()) for kind, m in mutants(prog, seed=7)]
    assert a == b
    fps = [fp for _, fp in a]
    assert len(set(fps)) == len(fps)
    assert prog.fingerprint() not in fps


# ---------------------------------------------------------------------------
# verdict codes, each reachable from a hand-built program
# ---------------------------------------------------------------------------

def test_self_send_is_an_error():
    prog = hand_program([[FlowInstr(0, 0, 8.0, "copy", (0,))]])
    rep = verify_program(prog, passes=("deps",))
    assert "SELF_SEND" in codes(rep) and not rep.ok


def test_missing_data_is_an_error():
    # sharded: rank 0 holds only chunk 0, yet sends chunk 1
    prog = hand_program([[FlowInstr(0, 1, 8.0, "copy", (1,))]],
                        n_chunks=2, init="sharded")
    rep = verify_program(prog, passes=("deps",))
    assert "MISSING_DATA" in codes(rep) and not rep.ok


def test_intra_round_race_is_an_error():
    # rank 1 forwards chunk 0 in the same round it first receives it
    prog = hand_program(
        [[FlowInstr(0, 1, 8.0, "copy", (0,)),
          FlowInstr(1, 2, 8.0, "copy", (0,))]],
        n=3, n_chunks=3, init="sharded")
    rep = verify_program(prog, passes=("deps",))
    assert "INTRA_ROUND_RACE" in codes(rep)
    assert rep.stats["deps"]["acyclic"] is False


def test_deadlock_cycle_detected():
    # mutual same-round needs: each rank forwards the chunk the other
    # delivers in this very round — a rendezvous deadlock
    prog = hand_program(
        [[FlowInstr(0, 1, 8.0, "copy", (0, 1)),
          FlowInstr(1, 0, 8.0, "copy", (0, 1))]],
        n=2, n_chunks=2, init="sharded")
    rep = verify_program(prog, passes=("deps",))
    assert "DEADLOCK_CYCLE" in codes(rep) and not rep.ok


def test_empty_round_is_a_warning():
    prog = hand_program([[], [FlowInstr(0, 1, 8.0, "copy", (0,))]])
    rep = verify_program(prog, passes=("deps",))
    assert "EMPTY_ROUND" in codes(rep)
    assert rep.ok and not rep.clean      # warning: gate passes, screen trips


def test_duplicate_round_is_a_warning():
    rnd = [FlowInstr(0, 1, 8.0, "copy", (0,))]
    prog = hand_program([rnd, rnd])
    rep = verify_program(prog, passes=("liveness",))
    assert "DUPLICATE_ROUND" in codes(rep)


def test_dead_transfer_is_a_warning():
    # sharded init already satisfies reduce_scatter, so any transfer is
    # outside the postcondition's backward slice
    prog = hand_program([[FlowInstr(0, 1, 8.0, "copy", (0,))]],
                        init="sharded", post="reduce_scatter",
                        kind="reduce_scatter")
    rep = verify_program(prog, passes=("liveness",))
    assert "DEAD_TRANSFER" in codes(rep)
    assert rep.stats["liveness"]["n_dead"] == 1


def test_validate_pass_reports_invariant_violations_as_findings():
    # claims allreduce but moves nothing: postcondition fails
    prog = hand_program([[FlowInstr(0, 1, 8.0, "copy", (0,))]],
                        post="allreduce")
    rep = verify_program(prog, passes=("validate",))
    assert "INVARIANT_VIOLATION" in codes(rep) and not rep.ok


# ---------------------------------------------------------------------------
# bounds
# ---------------------------------------------------------------------------

def bounds_stats(algo, n, kind="allreduce", **akw):
    op = CollectiveOp(kind=kind, size_bytes=1e6, group=tuple(range(n)))
    rep = verify_program(compile_op(op, algo, **akw), passes=("bounds",))
    return rep.stats["bounds"]

def test_ring_is_bandwidth_optimal():
    s = bounds_stats("ring", 8)
    assert s["bandwidth_efficiency"] == pytest.approx(1.0)
    assert s["bound_kind"] == "allreduce"


def test_ring_sequential_efficiency_is_one_over_2n():
    for n in (8, 16):
        s = bounds_stats("ring_sequential", n)
        assert s["bandwidth_efficiency"] == pytest.approx(1.0 / (2 * n))
        assert s["bound_kind"] == "reduce"     # keyed off the postcondition


def test_bcube_bound_keyed_off_postcondition():
    # bcube registers under allreduce but only builds the RS phase; the
    # bound must follow the postcondition or efficiency would read 2.0
    s = bounds_stats("bcube", 16, base=2)
    assert s["bound_kind"] == "reduce_scatter"
    assert s["bandwidth_efficiency"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# contention vs the simulator on a planted 2-tier fabric
# ---------------------------------------------------------------------------

def planted_two_tier(nodes_per_rack=4, n_racks=2,
                     nic=100e9, uplink=10e9, slow_uplink=5e9):
    """2 racks, dedicated NICs, rack 0's *up* link planted 2x slower.

    Only the up direction is slow so the bottleneck is a single link —
    the test needs "fix the reported link, watch the sim speed up".
    """
    n = nodes_per_rack * n_racks
    base = 2 * n
    link_bw = [nic] * base + [slow_uplink, uplink, uplink, uplink]
    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf)
    paths = [[() for _ in range(n)] for _ in range(n)]
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            ri, rj = i // nodes_per_rack, j // nodes_per_rack
            if ri == rj:
                path = (2 * i, 2 * j + 1)
            else:
                path = (2 * i, base + 2 * ri, base + 2 * rj + 1, 2 * j + 1)
            paths[i][j] = path
            lat[i, j] = 1e-6 * len(path)
            bw[i, j] = min(link_bw[l] for l in path)
    return Fabric(n=n, lat=lat, bw=bw, paths=paths,
                  link_bw=np.asarray(link_bw, dtype=np.float64),
                  meta={"kind": "planted"})


def with_link_bw(fab, link, factor):
    link_bw = fab.link_bw.copy()
    link_bw[link] *= factor
    return dataclasses.replace(fab, link_bw=link_bw)


def test_contention_bottleneck_agrees_with_simulator():
    fab = planted_two_tier()
    op = CollectiveOp(kind="allreduce", size_bytes=4e6,
                      group=tuple(range(fab.n)))
    prog = compile_op(op, "ring")

    rep = verify_program(prog, passes=("contention",), fabric=fab)
    stats = rep.stats["contention"]
    assert stats["mode"] == "fabric"
    bottleneck = stats["bottleneck_link"]
    assert bottleneck == 2 * fab.n, \
        "the planted slow uplink (rack 0, up direction) must be reported"
    assert stats["static_bound_s"] > 0

    flows = prog.to_flows()
    t_base = simulate_rounds(fab, flows)
    # the static bound is a true lower bound on the simulated time
    assert stats["static_bound_s"] <= t_base * (1 + 1e-9)
    # speeding up the reported bottleneck speeds up the collective...
    t_fixed = simulate_rounds(with_link_bw(fab, bottleneck, 2.0), flows)
    assert t_fixed < t_base * 0.75
    # ...while speeding up an uncongested NIC changes nothing
    t_other = simulate_rounds(with_link_bw(fab, 2, 2.0), flows)
    assert t_other == pytest.approx(t_base, rel=1e-9)


def test_contention_flags_oversubscribed_uplink():
    fab = planted_two_tier()
    op = CollectiveOp(kind="all_to_all", size_bytes=4e6,
                      group=tuple(range(fab.n)))
    algo, akw = candidates("all_to_all", fab.n)[0]
    rep = verify_program(compile_op(op, algo, **dict(akw)),
                         passes=("contention",), fabric=fab)
    over = [f for f in rep.findings if f.code == "OVERSUBSCRIBED_LINK"]
    assert over, "4 concurrent cross-rack flows share one uplink"
    assert all(f.severity == "info" for f in over)


def test_contention_hierarchy_and_pairwise_modes():
    fab = planted_two_tier()
    op = CollectiveOp(kind="allreduce", size_bytes=4e6,
                      group=tuple(range(fab.n)))
    prog = compile_op(op, "ring")
    hier = HierarchyModel(
        n=fab.n, tiers=(((0, 1, 2, 3), (4, 5, 6, 7)),), heights=(1.0,))
    rep = verify_program(prog, passes=("contention",), hierarchy=hier)
    assert rep.stats["contention"]["mode"] == "hierarchy"
    rep = verify_program(prog, passes=("contention",),
                         lat=fab.lat, bw=fab.bw)
    assert rep.stats["contention"]["mode"] == "pairwise"
    assert rep.stats["contention"]["static_bound_s"] > 0
    rep = verify_program(prog, passes=("contention",))
    assert rep.stats["contention"]["mode"] == "none"


# ---------------------------------------------------------------------------
# fuse_rounds: chunk-id overlap across participant-disjoint rounds
# ---------------------------------------------------------------------------

def test_fuse_rounds_chunk_id_overlap():
    # both instructions carry chunk id 0, but for different rank pairs:
    # per-rank state entries are unrelated, so the fusion is safe
    prog = hand_program(
        [[FlowInstr(0, 1, 8.0, "copy", (0,))],
         [FlowInstr(2, 3, 8.0, "copy", (0,))]],
        n=4)
    fused, n_fused = fuse_rounds(prog)
    assert n_fused == 1 and fused.n_rounds == 1
    assert require_valid(fused, passes=("deps",)).clean


def test_fuse_rounds_respects_participant_overlap():
    prog = hand_program(
        [[FlowInstr(0, 1, 8.0, "copy", (0,))],
         [FlowInstr(1, 2, 8.0, "copy", (0,))]],
        n=3)
    fused, n_fused = fuse_rounds(prog)
    assert n_fused == 0 and fused.n_rounds == 2


# ---------------------------------------------------------------------------
# the compiler gate is live
# ---------------------------------------------------------------------------

def test_plan_compiler_gate_rejects_corrupt_program(monkeypatch):
    from repro.fabric import probe_fabric
    import repro.plan.compiler as compiler_mod
    from repro.plan import (CollectiveRequest, JobMix, PlanCompiler,
                            SolveBudget)

    real_compile_op = compiler_mod.compile_op

    def corrupt_compile_op(op, algo, **kw):
        prog = real_compile_op(op, algo, **kw)
        first = prog.rounds[0][0]
        bad = dataclasses.replace(first, dst=first.src)   # self-send
        return prog.replace(
            rounds=((bad,) + prog.rounds[0][1:],) + prog.rounds[1:])

    monkeypatch.setattr(compiler_mod, "compile_op", corrupt_compile_op)
    fab = planted_two_tier()
    compiler = PlanCompiler(fabric=fab,
                            budget=SolveBudget(iters=30, chains=1), seed=0)
    mix = JobMix(name="t", requests=(
        CollectiveRequest(op="all-reduce", size_bytes=1e6, count=1),))
    with pytest.raises(VerificationError) as ei:
        compiler.compile(probe_fabric(fab, seed=0), mix)
    assert any(f.code == "SELF_SEND" for f in ei.value.report.findings)


def test_session_lower_gate_is_wired():
    import inspect

    from repro.session.session import Session
    src = inspect.getsource(Session.lower)
    assert "require_valid" in src


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, rel, source):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint_file(str(path), str(tmp_path))


def test_lint_raw_perf_counter(tmp_path):
    bad = _lint_src(tmp_path, "src/repro/mod.py", """\
        import time
        t0 = time.perf_counter()
        """)
    assert [f.rule for f in bad] == ["raw-perf-counter"]
    waived = _lint_src(tmp_path, "src/repro/mod2.py", """\
        import time
        t0 = time.perf_counter()  # lint: allow(raw-perf-counter)
        """)
    assert waived == []
    # repro.obs implements the timers: exempt
    obs = _lint_src(tmp_path, "src/repro/obs/timers.py", """\
        import time
        t0 = time.perf_counter()
        """)
    assert obs == []


def test_lint_warn_stacklevel(tmp_path):
    bad = _lint_src(tmp_path, "src/repro/mod.py", """\
        import warnings
        warnings.warn("boom")
        """)
    assert [f.rule for f in bad] == ["warn-stacklevel"]
    ok = _lint_src(tmp_path, "src/repro/mod2.py", """\
        import warnings
        warnings.warn("boom", stacklevel=2)
        """)
    assert ok == []


def test_lint_deprecation_category(tmp_path):
    bad = _lint_src(tmp_path, "src/repro/mod.py", """\
        import warnings
        warnings.warn("mod is deprecated; use other", stacklevel=2)
        """)
    assert [f.rule for f in bad] == ["deprecation-warning-category"]
    ok = _lint_src(tmp_path, "src/repro/mod2.py", """\
        import warnings
        warnings.warn("mod is deprecated; use other",
                      DeprecationWarning, stacklevel=2)
        """)
    assert ok == []


def test_lint_toplevel_jax_import(tmp_path):
    bad = _lint_src(tmp_path, "src/repro/mod.py", "import jax\n")
    assert [f.rule for f in bad] == ["toplevel-jax-import"]
    guarded = _lint_src(tmp_path, "src/repro/mod2.py", """\
        try:
            import jax
        except ImportError:
            jax = None
        """)
    assert guarded == []
    native = _lint_src(tmp_path, "src/repro/kernels/mod.py", "import jax\n")
    assert native == []


def test_repo_lints_clean():
    import repro
    import os

    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(repro.__file__))))
    findings, n_files = lint_repo(root)
    assert findings == [], "\n".join(str(f) for f in findings)
    assert n_files > 50


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_analyze_program(capsys):
    from repro.cli import main

    assert main(["analyze", "--program", "ring", "--nodes", "8"]) == 0
    assert "OK" in capsys.readouterr().out


def test_cli_analyze_sweep_small(capsys):
    from repro.cli import main

    assert main(["analyze", "--n-list", "4", "--fabric-nodes", "4"]) == 0
    out = capsys.readouterr().out
    assert "0 with findings" in out or "programs verified" in out
