"""repro.fabric subsystem: hierarchy inference, sparse probing, shims.

Covers the PR-5 acceptance surface: planted-tier recovery on the
synthetic fabrics (exact under zero probe noise, rank-correlated under
multi-tenant noise), sparse-vs-dense budget and plan-quality
properties, the deprecation shims at ``repro.core.topology`` /
``repro.core.probe``, the shared cost helper, and probe-parameter
validation.
"""

import importlib
import sys
import warnings

import numpy as np
import pytest

from repro.core import (
    mesh_axis_cost,
    optimize_mesh_assignment,
    optimize_rank_order,
    optimize_rank_order_hierarchical,
)
from repro.fabric import (
    HierarchyModel,
    combine_cost,
    cost_matrix,
    infer_hierarchy,
    make_datacenter,
    make_tpu_fleet,
    probe_fabric,
    refresh_sparse,
    scramble,
    sparse_probe_fabric,
)


def _block_sets(blocks):
    return sorted(tuple(sorted(b)) for b in blocks)


# ---------------------------------------------------------------------------
# hierarchy inference
# ---------------------------------------------------------------------------

def test_planted_racks_recovered_exactly():
    """Zero probe noise: the finest tier must be exactly the racks."""
    fab = make_datacenter(64, nodes_per_rack=8, seed=0)
    h = infer_hierarchy(fab.cost_matrix(0.0))
    racks = [tuple(range(r * 8, (r + 1) * 8)) for r in range(8)]
    assert not h.flat
    assert _block_sets(h.blocks(0)) == _block_sets(racks)


def test_planted_pods_recovered_after_scramble():
    """The tenant's scrambled labels must not hide the pod boundary."""
    fleet = make_tpu_fleet(n_pods=2, pod_shape=(4, 4), seed=0)
    scrambled, hidden = scramble(fleet, seed=3)
    h = infer_hierarchy(scrambled.cost_matrix(0.0))
    true_pods = _block_sets(
        [np.nonzero(hidden < 16)[0].tolist(),
         np.nonzero(hidden >= 16)[0].tolist()])
    assert any(_block_sets(h.blocks(t)) == true_pods
               for t in range(h.n_tiers))


def test_hierarchy_rank_correlated_under_noise():
    """Multi-tenant probe noise: recovered tier distance must rank-
    correlate with the true physical tier distance."""
    fab = make_datacenter(64, nodes_per_rack=8, seed=1)
    probed = probe_fabric(fab, noise_scale=0.3, seed=2)
    h = infer_hierarchy(cost_matrix(probed, 0.0))
    assert not h.flat
    rec = h.distance_ranks()
    node = np.arange(64)
    rack = node // 8
    agg = rack // 4
    true = (rack[:, None] != rack[None, :]).astype(int) + \
           (agg[:, None] != agg[None, :]).astype(int)
    off = ~np.eye(64, dtype=bool)
    rx = np.argsort(np.argsort(rec[off]))
    ry = np.argsort(np.argsort(true[off]))
    rho = np.corrcoef(rx, ry)[0, 1]
    assert rho > 0.6, rho


def test_flat_hierarchy_on_uniform_matrix():
    c = np.full((16, 16), 5e-6)
    np.fill_diagonal(c, 0.0)
    h = infer_hierarchy(c)
    assert h.flat
    assert h.blocks(0) == [[i] for i in range(16)]
    assert (h.distance_ranks() == 0).all()


def test_hierarchy_restrict_and_roundtrip():
    fab = make_datacenter(32, nodes_per_rack=8, seed=0)
    h = infer_hierarchy(fab.cost_matrix(0.0))
    # JSON round-trip
    h2 = HierarchyModel.from_dict(h.to_dict())
    assert h2 == h
    # restriction to two racks re-indexes to local ids
    nodes = list(range(8)) + list(range(16, 24))
    sub = h.restrict(nodes)
    assert sub.n == 16
    assert _block_sets(sub.blocks(0)) == _block_sets(
        [tuple(range(8)), tuple(range(8, 16))])
    with pytest.raises(ValueError):
        h.restrict([0, 0, 1])


def test_distance_ranks_ultrametric():
    fab = make_datacenter(32, seed=4)
    h = infer_hierarchy(fab.cost_matrix(0.0))
    r = h.distance_ranks()
    assert (r == r.T).all() and (np.diag(r) == 0).all()
    # ultrametric: r[i,k] <= max(r[i,j], r[j,k])
    assert (r[:, None, :] <= np.maximum(r[:, :, None],
                                        r[None, :, :])).all()


# ---------------------------------------------------------------------------
# sparse probing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [0.25, 0.15])
def test_sparse_budget_respected(budget):
    fab, _ = scramble(make_datacenter(64, seed=0), seed=1)
    sp = sparse_probe_fabric(fab, budget=budget, seed=0)
    assert sp.probes_used <= budget * 64 * 63
    assert sp.probe_fraction <= budget
    assert sp.hierarchy is not None and not sp.hierarchy.flat
    assert sp.observed is not None and sp.observed.any()


def test_sparse_matrix_close_to_dense():
    fab, _ = scramble(make_datacenter(64, seed=0), seed=1)
    dn = probe_fabric(fab, seed=0)
    sp = sparse_probe_fabric(fab, budget=0.25, seed=0)
    off = ~np.eye(64, dtype=bool)
    err = np.abs(np.log2(np.maximum(sp.lat[off], 1e-12) /
                         np.maximum(dn.lat[off], 1e-12)))
    assert np.median(err) < 0.5, np.median(err)
    # bandwidth completed too, with the right symmetrization
    assert sp.bw is not None
    assert (sp.bw == sp.bw.T).all()
    assert np.isinf(np.diag(sp.bw)).all()


def test_sparse_plan_quality_close_to_dense():
    """Property behind the BENCH_fabric acceptance bar: a sparse-probed
    plan must stay within 5% of the dense-probed plan when both are
    refereed by the contention-aware simulator (the \"real cloud\")."""
    from repro.collective import (CollectiveOp, SimExecutor,
                                  apply_permutation, chunk, compile_op,
                                  kind_from_op)
    from repro.plan import (CollectiveRequest, JobMix, PlanCompiler,
                            SolveBudget)

    mix = JobMix((
        CollectiveRequest("all-reduce", 16e6),
        CollectiveRequest("all-gather", 2e6, count=2.0),
    ), name="t")

    def sim_total(fab, plan):
        ex = SimExecutor(fab)
        total = 0.0
        for r in mix.requests:
            e = plan.lookup(r.op, r.size_bytes, r.group)
            prog = chunk(apply_permutation(
                compile_op(CollectiveOp(kind_from_op(e.op), e.size_bytes,
                                        e.group),
                           e.algo, **e.algo_kwargs), e.perm), e.chunks)
            total += r.count * ex.estimate(prog)
        return total

    for fab in (make_datacenter(64, seed=0),
                make_tpu_fleet(n_pods=2, pod_shape=(4, 8), seed=0)):
        fab, _ = scramble(fab, seed=1)
        comp = PlanCompiler(budget=SolveBudget(iters=200, chains=4), seed=0)
        dense_plan = comp.compile(probe_fabric(fab, seed=0), mix)
        sparse_plan = comp.compile(
            sparse_probe_fabric(fab, budget=0.25, seed=0), mix)
        td = sim_total(fab, dense_plan)
        ts = sim_total(fab, sparse_plan)
        assert ts <= 1.05 * td, (fab.meta["kind"], ts / td)


def test_refresh_sparse_flags_only_moved_clusters():
    fab = make_datacenter(64, nodes_per_rack=8, seed=0)
    sp = sparse_probe_fabric(fab, budget=0.25, seed=0, noise_scale=0.05)
    # quiet fabric: nothing moves, probes stay O(K * L)
    quiet, moved = refresh_sparse(fab, sp, seed=1, noise_scale=0.05)
    assert moved == []
    assert quiet.probes_used < sp.probes_used
    # congest one rack's uplink: x8 latency on every pair touching it
    drifted = make_datacenter(64, nodes_per_rack=8, seed=0)
    lat = drifted.lat.copy()
    rack = list(range(8))
    lat[rack, :] *= 8.0
    lat[:, rack] *= 8.0
    drifted.lat = lat
    refreshed, moved = refresh_sparse(drifted, sp, seed=1, noise_scale=0.05)
    lab = sp.hierarchy.labels(0)
    moved_nodes = sorted(n for m in moved
                         for n in np.nonzero(lab == m)[0].tolist())
    assert set(rack) <= set(moved_nodes)
    # the refreshed matrix reflects the drift
    assert refreshed.lat[0, 9] > 2.0 * sp.lat[0, 9]


def test_sparse_probe_validation():
    fab = make_datacenter(16, seed=0)
    with pytest.raises(ValueError, match="budget"):
        sparse_probe_fabric(fab, budget=0.0)
    with pytest.raises(ValueError, match="budget"):
        sparse_probe_fabric(fab, budget=1.5)
    with pytest.raises(ValueError, match="percentile"):
        sparse_probe_fabric(fab, percentile=0.0)
    with pytest.raises(ValueError, match="refresh_sparse"):
        refresh_sparse(fab, probe_fabric(fab, seed=0))


def test_sparse_budget_is_a_hard_cap_even_when_tiny():
    """A budget barely above the spanning minimum caps the sweep at one
    landmark and trims refinement (rings/medoid anchors last) — and an
    impossible budget (below n-1 pairs) raises instead of silently
    overshooting."""
    fab = make_datacenter(100, seed=0)
    sp = sparse_probe_fabric(fab, budget=0.025, seed=0)
    assert sp.probes_used <= 0.025 * 100 * 99
    with pytest.raises(ValueError, match="below the 99"):
        sparse_probe_fabric(fab, budget=0.005)


# ---------------------------------------------------------------------------
# probe validation + shared cost helper (satellites)
# ---------------------------------------------------------------------------

def test_probe_fabric_validation():
    fab = make_datacenter(8, seed=0)
    with pytest.raises(ValueError, match="n_probes"):
        probe_fabric(fab, n_probes=0)
    with pytest.raises(ValueError, match="percentile"):
        probe_fabric(fab, percentile=0.0)
    with pytest.raises(ValueError, match="percentile"):
        probe_fabric(fab, percentile=100.5)
    with pytest.raises(ValueError, match="noise_scale"):
        probe_fabric(fab, noise_scale=-0.1)
    # the boundary values stay legal
    probe_fabric(fab, n_probes=1, percentile=100.0, noise_scale=0.0)


def test_cost_matrix_implementations_share_helper():
    fab = make_datacenter(16, seed=2)
    for s in (0.0, 4e6):
        np.testing.assert_allclose(fab.cost_matrix(s),
                                   combine_cost(fab.lat, fab.bw, s))
    pr = probe_fabric(fab, seed=3)
    for s in (0.0, 4e6):
        np.testing.assert_allclose(cost_matrix(pr, s),
                                   combine_cost(pr.lat, pr.bw, s))
    with pytest.raises(ValueError, match="square"):
        combine_cost(np.zeros((3, 4)))


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shim,name", [
    ("repro.core.topology", "make_datacenter"),
    ("repro.core.probe", "probe_fabric"),
])
def test_core_shims_warn_and_delegate(shim, name):
    sys.modules.pop(shim, None)
    with pytest.warns(DeprecationWarning, match="repro.fabric"):
        mod = importlib.import_module(shim)
    fabric_mod = importlib.import_module(
        shim.replace("repro.core", "repro.fabric"))
    assert getattr(mod, name) is getattr(fabric_mod, name)


def test_repro_core_import_is_warning_free():
    """`repro.core` (and the session stack) must not route through the
    shims — CI runs the CLI under -W error::DeprecationWarning."""
    for mod in ("repro.core", "repro.fabric", "repro.session", "repro.plan"):
        sys.modules.pop(mod, None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        importlib.import_module("repro.core")
        importlib.import_module("repro.session")


# ---------------------------------------------------------------------------
# hierarchy-decomposed solving
# ---------------------------------------------------------------------------

def test_hierarchical_solve_matches_flat_quality():
    fab, _ = scramble(make_datacenter(64, seed=5), seed=6)
    c = cost_matrix(probe_fabric(fab, seed=7), 0.0)
    h = infer_hierarchy(c)
    flat = optimize_rank_order(c, "ring", iters=600, seed=0)
    hier = optimize_rank_order_hierarchical(c, h, "ring")
    assert hier.cost <= 1.10 * flat.cost, (hier.cost, flat.cost)
    assert sorted(hier.perm.tolist()) == list(range(64))


def test_hierarchical_solve_flat_fallback():
    c = np.full((16, 16), 5e-6)
    np.fill_diagonal(c, 0.0)
    h = infer_hierarchy(c)
    res = optimize_rank_order_hierarchical(c, h, "ring")
    assert sorted(res.perm.tolist()) == list(range(16))


def test_mesh_axis_cost_accepts_hierarchy_model():
    fab = make_datacenter(16, nodes_per_rack=8, seed=0)
    h = infer_hierarchy(fab.cost_matrix(0.0))
    local = np.arange(16).reshape(2, 8)       # rows = racks
    rng = np.random.default_rng(0)
    shuffled = rng.permutation(16).reshape(2, 8)
    c_local = mesh_axis_cost(local, h, axis=1)
    c_shuf = mesh_axis_cost(shuffled, h, axis=1)
    assert c_local <= c_shuf
    assert c_local == 0.0                      # rack rings cross no tier


def test_optimize_mesh_assignment_with_hierarchy():
    fab, _ = scramble(make_datacenter(64, seed=8), seed=9)
    c = cost_matrix(probe_fabric(fab, seed=10), 0.0)
    h = infer_hierarchy(c)
    plain = optimize_mesh_assignment(c, (8, 8), ("data", "model"), seed=0)
    hier = optimize_mesh_assignment(c, (8, 8), ("data", "model"), seed=0,
                                    hierarchy=h)
    assert sorted(hier.assignment.reshape(-1).tolist()) == list(range(64))
    assert hier.cost <= 1.10 * plain.cost
    assert hier.cost <= hier.baseline_cost * 1.001


# ---------------------------------------------------------------------------
# tree fingerprints
# ---------------------------------------------------------------------------

def test_tree_fingerprint_stable_and_order_sensitive():
    """Stability contract: a re-probe over the SAME probe structure
    (what deterministic configs and the refresh_sparse drift path do)
    must fuzzily match; a relabeled fabric must not."""
    from repro.plan.cache import fabric_fingerprint

    fab, _ = scramble(make_datacenter(32, seed=0), seed=1)
    sp1 = sparse_probe_fabric(fab, budget=0.3, seed=0)
    refreshed, _moved = refresh_sparse(fab, sp1, seed=5)
    fp1 = fabric_fingerprint(sp1.lat, sp1.bw, hierarchy=sp1.hierarchy)
    fp2 = fabric_fingerprint(refreshed.lat, refreshed.bw,
                             hierarchy=refreshed.hierarchy)
    assert fp1.digest.startswith("hfab")
    assert fp1.matches(fp2)
    # a relabeled fabric must NOT match (order sensitivity)
    relabeled, _ = scramble(fab, seed=7)
    sp3 = sparse_probe_fabric(relabeled, budget=0.3, seed=0)
    fp3 = fabric_fingerprint(sp3.lat, sp3.bw, hierarchy=sp3.hierarchy)
    assert not fp1.matches(fp3)
    # tree and dense sketches live in different namespaces
    dense_fp = fabric_fingerprint(sp1.lat, sp1.bw)
    assert not fp1.matches(dense_fp)


def test_session_sparse_mode_end_to_end():
    from repro.session import Session, SessionConfig

    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 32, "scramble_seed": 1},
        "probe": {"mode": "sparse", "budget": 0.25},
        "solver": {"budget": {"iters": 150, "chains": 4,
                              "hierarchy_min_n": 16}},
    })
    with Session(cfg) as s:
        plan = s.plan()
        assert s.hierarchy is not None and not s.hierarchy.flat
        assert s.probe.probe_fraction <= 0.25
        assert plan.meta.get("hierarchy")
        assert plan.fingerprint.digest.startswith("hfab")


def test_sparse_drift_replan_keeps_hierarchy():
    """A drift re-plan triggered by the sparse poll must recompile from
    the refreshed SparseProbeResult — keeping the hierarchy (and the
    tree fingerprint) instead of falling back to flat solving."""
    from repro.session import Session, SessionConfig

    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 24, "scramble_seed": 1},
        "probe": {"mode": "sparse", "budget": 0.3, "noise_scale": 0.05},
        "solver": {"budget": {"iters": 100, "chains": 2,
                              "hierarchy_min_n": 16}},
    })
    with Session(cfg) as s:
        plan1 = s.plan()
        assert plan1.fingerprint.digest.startswith("hfab")
        # global congestion: every cluster moves, the poll reports it
        s._fabric.lat = s._fabric.lat * 6.0
        poll = s._default_poll()
        c = poll()
        assert c is not None
        s.observe(c)                      # auto_replan recompiles
        plan2 = s.planned
        assert plan2 is not plan1
        assert s.hierarchy is not None and not s.hierarchy.flat
        assert plan2.fingerprint.digest.startswith("hfab")


def test_probe_config_validates_mode():
    from repro.session import SessionConfig

    with pytest.raises(ValueError, match="mode"):
        SessionConfig.from_dict({"probe": {"mode": "turbo"}})
    cfg = SessionConfig.from_dict({"probe": {"mode": "sparse",
                                             "budget": "0.2"}})
    assert cfg.probe.budget == pytest.approx(0.2)
    assert SessionConfig.from_json(cfg.to_json()).probe.mode == "sparse"
