"""Tests for the unified ``python -m repro`` CLI (ISSUE-3).

Each subcommand smoke-runs on a synthetic fabric, ``repro plan``
reproduces the manual PlanningService pipeline exactly (acceptance
criterion), the resolved config round-trips through --dump-config, the
new session/cli modules leak no DeprecationWarning, and the old entry
points survive as importable, delegating shims.
"""

import json
import subprocess
import sys
import warnings

import pytest

from repro.cli import main, session_config_from_args

PLAN_ARGS = ["--fabric", "datacenter", "--nodes", "12",
             "--scramble-seed", "1", "--iters", "80", "--chains", "2",
             "--payload-bytes", "1e6"]


def run_cli(argv):
    with warnings.catch_warnings():
        # the acceptance bar: the new CLI paths never route through the
        # deprecated shims, so repro-originated DeprecationWarnings are
        # hard errors here
        warnings.filterwarnings(
            "error", category=DeprecationWarning, module=r"repro\..*")
        return main(argv)


# ---------------------------------------------------------------------------
# subcommand smoke runs
# ---------------------------------------------------------------------------

def test_probe_smoke(tmp_path, capsys):
    out = tmp_path / "probe.json"
    assert run_cli(["probe", *PLAN_ARGS, "--out", str(out)]) == 0
    assert "[probe]" in capsys.readouterr().out
    payload = json.loads(out.read_text())
    assert payload["n"] == 12
    assert len(payload["lat"]) == 12


def test_plan_dry_run_smoke(tmp_path, capsys):
    cache = tmp_path / "plans"
    out = tmp_path / "report.json"
    assert run_cli(["plan", *PLAN_ARGS, "--dry-run", "--out", str(out),
                    "--plan-cache-dir", str(cache)]) == 0
    text = capsys.readouterr().out
    assert "[plan] dry-run:" in text
    assert "all-reduce" in text
    assert not cache.exists() or not list(cache.iterdir()), \
        "--dry-run must not write the plan store"
    assert out.exists(), "an explicit --out is written even under --dry-run"


def test_plan_writes_plan_json(tmp_path, capsys):
    from repro.plan import Plan

    out = tmp_path / "plan.json"
    assert run_cli(["plan", *PLAN_ARGS, "--mesh", "3x4",
                    "--out", str(out)]) == 0
    plan = Plan.from_json(out.read_text())
    assert plan.n == 12
    assert plan.mesh_plan is not None


def test_bench_smoke(tmp_path, capsys):
    out = tmp_path / "bench.json"
    assert run_cli(["bench", "--smoke", "--iters", "60",
                    "--out", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["results"][0]["cache_hits"] >= 1
    assert payload["results"][0]["warm_speedup_x"] > 1


def test_dump_config_round_trips(tmp_path, capsys):
    assert run_cli(["plan", *PLAN_ARGS, "--mesh", "3x4",
                    "--dump-config"]) == 0
    dumped = capsys.readouterr().out
    from repro.session import SessionConfig

    cfg = SessionConfig.from_json(dumped)
    assert cfg.fabric.nodes == 12
    assert cfg.mesh.shape == (3, 4)
    # feeding the dump back through --config resolves identically
    path = tmp_path / "cfg.json"
    path.write_text(dumped)
    assert run_cli(["plan", "--config", str(path), "--dump-config"]) == 0
    assert SessionConfig.from_json(capsys.readouterr().out) == cfg


# ---------------------------------------------------------------------------
# acceptance: CLI plan == manual PlanningService pipeline
# ---------------------------------------------------------------------------

def test_cli_plan_matches_manual_pipeline(tmp_path):
    """`python -m repro plan` and the hand-wired pipeline must agree on
    fingerprint key and the chosen (algo, chunks, perm) per entry."""
    from repro.core import make_datacenter, probe_fabric, scramble
    from repro.plan import Plan, PlanCache, PlanCompiler, PlanningService
    from repro.session import SessionConfig, train_mix

    out = tmp_path / "plan.json"
    assert run_cli(["plan", *PLAN_ARGS, "--out", str(out)]) == 0
    via_cli = Plan.from_json(out.read_text())

    cfg = SessionConfig()                         # the CLI's defaults
    fabric, _ = scramble(make_datacenter(12, seed=0), seed=1)
    probed = probe_fabric(fabric, seed=0)
    budget = cfg.solver.budget.__class__(iters=80, chains=2)
    service = PlanningService(
        PlanCompiler(fabric=fabric, budget=budget, seed=0), PlanCache())
    manual = service.request(probed, train_mix(1e6))
    service.close()

    assert via_cli.fingerprint.digest == manual.fingerprint.digest
    assert via_cli.mix_key == manual.mix_key
    assert set(via_cli.entries) == set(manual.entries)
    for key, e in manual.entries.items():
        ce = via_cli.entries[key]
        assert (ce.algo, ce.chunks, tuple(ce.perm)) == \
            (e.algo, e.chunks, tuple(e.perm))


def test_config_precedence_file_env_flags(tmp_path, monkeypatch):
    from repro.session import SessionConfig

    path = tmp_path / "base.json"
    SessionConfig.from_dict({"fabric": {"nodes": 20},
                             "payload_bytes": 1e5}).dump(str(path))
    monkeypatch.setenv("REPRO_PAYLOAD_BYTES", "2e5")
    ap = __import__("repro.cli", fromlist=["build_parser"]).build_parser()
    args = ap.parse_args(["plan", "--config", str(path)])
    cfg = session_config_from_args(args)
    assert cfg.fabric.nodes == 20                 # from file
    assert cfg.payload_bytes == 2e5               # env beats file
    args = ap.parse_args(["plan", "--config", str(path),
                          "--payload-bytes", "3e5"])
    cfg = session_config_from_args(args)
    assert cfg.payload_bytes == 3e5               # flag beats env


# ---------------------------------------------------------------------------
# launcher subcommands (jax): tiny smoke runs
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_subcommand_smoke(tmp_path, capsys):
    assert run_cli(["train", "--steps", "2", "--batch", "2", "--seq", "16",
                    "--ckpt-dir", str(tmp_path / "ckpt")]) == 0
    assert "[train]" in capsys.readouterr().out


@pytest.mark.slow
def test_serve_subcommand_smoke(capsys):
    assert run_cli(["serve", "--max-new", "2", "--batch", "2",
                    "--prompt-len", "4"]) == 0
    assert "[serve]" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_old_entry_points_importable_and_delegating():
    import repro.launch.serve as old_serve
    import repro.launch.train as old_train

    assert callable(old_train.main) and callable(old_serve.main)
    assert callable(old_train.build_mesh)
    with pytest.warns(DeprecationWarning, match="train_mix"):
        mix = old_train.default_job_mix(4e6, moe=True)
    assert {r.op for r in mix.requests} == {
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all"}
    with pytest.warns(DeprecationWarning, match="serve_mix"):
        mix = old_serve.serve_job_mix(1e6)
    assert mix.name == "serve"


def test_module_main_entrypoint():
    """``python -m repro`` resolves (the single CLI entry point)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "--version"],
        capture_output=True, text=True)
    assert proc.returncode == 0
    assert "repro" in proc.stdout


def test_lazy_top_level_exports():
    import repro

    assert repro.__version__
    assert repro.Session.__name__ == "Session"
    assert repro.JobMix.__name__ == "JobMix"
    assert repro.Fabric.__name__ == "Fabric"
    with pytest.raises(AttributeError, match="no attribute"):
        repro.not_a_thing
