"""N-D mesh reordering + dynamic re-ranking (paper §VI) tests."""

import numpy as np
import pytest

from repro.core import (
    AdaptiveReranker,
    StragglerDetector,
    bottleneck_swap,
    cost_matrix,
    make_cost_model,
    make_tpu_fleet,
    mesh_total_cost,
    optimize_mesh_assignment,
    optimize_rank_order,
    probe_fabric,
    random_assignment,
    scramble,
)


def _fleet_cost(seed=0):
    fleet, _ = scramble(make_tpu_fleet(n_pods=2, pod_shape=(4, 4), seed=seed),
                        seed=seed + 1)
    return cost_matrix(probe_fabric(fleet, seed=seed + 2), 1e6)


def test_mesh_plan_beats_identity_and_random():
    c = _fleet_cost(0)
    plan = optimize_mesh_assignment(c, (2, 4, 4), ("pod", "data", "model"))
    assert plan.cost <= plan.baseline_cost
    rand = random_assignment((2, 4, 4), seed=3)
    rand_cost = mesh_total_cost(rand, c, ("pod", "data", "model"))
    assert plan.cost <= rand_cost
    # is a valid assignment of all 32 devices
    assert sorted(plan.flat.tolist()) == list(range(32))


def test_mesh_plan_hot_axis_gets_locality():
    """The model axis (highest weight) must get lower mean ring cost
    than it would under the identity assignment of a scrambled fleet."""
    c = _fleet_cost(4)
    plan = optimize_mesh_assignment(c, (2, 4, 4), ("pod", "data", "model"))
    from repro.core import mesh_axis_cost

    ident = np.arange(32).reshape(2, 4, 4)
    assert plan.per_axis["model"] <= mesh_axis_cost(ident, c, 2) + 1e-12


def test_flat_reorder_paper_path():
    c = _fleet_cost(8)
    res = optimize_rank_order(c, "ring", 1e6, method="paper", iters=400)
    rng = np.random.default_rng(0)
    m = make_cost_model("ring", c, 1e6)
    rand = m.cost_batch(np.stack([rng.permutation(32) for _ in range(32)]))
    assert res.cost <= rand.min() + 1e-12


def test_bottleneck_swap_repairs_degraded_link():
    c = _fleet_cost(12)
    m = make_cost_model("ring", c, 1e6)
    from repro.core import solve

    best = solve(m, iters=400, seed=0)
    # degrade one link on the solved ring's critical path
    a, b, _ = m.critical_edges(best.perm)[0]
    c2 = c.copy()
    c2[a, :] *= 5.0
    c2[:, a] *= 5.0
    np.fill_diagonal(c2, 0.0)
    m2 = make_cost_model("ring", c2, 1e6)
    repaired, cost, swaps = bottleneck_swap(m2, best.perm)
    assert cost <= m2.cost(best.perm) + 1e-12


def test_adaptive_reranker_triggers_on_degradation():
    c = _fleet_cost(16)
    m = make_cost_model("ring", c, 1e6)
    from repro.core import solve

    best = solve(m, iters=300, seed=0)
    rr = AdaptiveReranker(
        model_factory=lambda cm: make_cost_model("ring", cm, 1e6),
        perm=best.perm, threshold=1.1)
    # stable network: no change
    _, changed = rr.update(c)
    assert not changed
    # degrade one specific ring link heavily (the paper's §VI scenario:
    # a bottleneck transfer between n_i and n_j) — replacement must win
    c2 = c.copy()
    edges = m.critical_edges(best.perm)
    a, b, _ = max(edges, key=lambda t: t[2])
    c2[a, b] = c2[b, a] = c2.max() * 50.0
    _, changed = rr.update(c2)
    assert changed
    assert rr.history[-1][2]


def test_straggler_detector_flags_slow_node():
    det = StragglerDetector(8, ratio_threshold=1.5)
    for step in range(20):
        for n in range(8):
            det.observe(n, 1.0 if n != 3 else 4.0)
    assert 3 in det.stragglers().tolist()
    c = np.ones((8, 8)) - np.eye(8)
    inflated = det.inflate(c)
    assert inflated[3, 0] > c[3, 0] * 2
    assert inflated[0, 1] == pytest.approx(c[0, 1])


def test_elastic_multipod_shrink_plan():
    """2-pod fleet loses a pod's worth of hosts: ClusterView shrinks the
    mesh (pod axis first), selects survivors, re-solves the plan."""
    from repro.core import make_tpu_fleet
    from repro.train import ClusterView

    fleet = make_tpu_fleet(n_pods=2, pod_shape=(4, 4), seed=7)
    cv = ClusterView(fabric=fleet, mesh_shape=(2, 4, 4),
                     axis_names=("pod", "data", "model"))
    cv.solve_plan()
    assert sorted(cv.plan.flat.tolist()) == list(range(32))
    # 20 of 32 chips die (most of pod 1)
    cv.fail(list(range(12, 32)))
    cv.shrink_mesh()
    assert int(np.prod(cv.mesh_shape)) <= len(cv.alive)
    plan = cv.solve_plan()
    n = int(np.prod(cv.mesh_shape))
    assert sorted(plan.flat.tolist()) == list(range(n))
    assert len(cv.active) == n
    assert set(cv.active) <= set(cv.alive)
