"""Topology generator, probing, and flow-level simulator tests."""

import numpy as np
import pytest

from repro.core import (
    CollectiveSimulator,
    Fabric,
    cost_matrix,
    make_cost_model,
    make_datacenter,
    make_tpu_fleet,
    probe_fabric,
    scramble,
    simulate_collective,
    solve,
    solve_worst,
)
from repro.core.schedule import SCHEDULES


def test_datacenter_latency_hierarchy():
    """Intra-rack must beat cross-agg latency (paper Fig. 2 structure)."""
    fab = make_datacenter(32, nodes_per_rack=8, seed=0)
    intra = fab.lat[0, 1]          # same rack
    cross = fab.lat[0, 31]         # different agg
    assert intra < cross
    assert fab.lat.max() > 10 * fab.lat[fab.lat > 0].min()  # wide spread


def test_tpu_fleet_ici_vs_dcn():
    fleet = make_tpu_fleet(n_pods=2, pod_shape=(4, 4), seed=0)
    intra = fleet.lat[0, 1]        # 1 ICI hop
    cross = fleet.lat[0, 16]       # cross-pod DCN
    assert cross > 10 * intra
    assert np.isfinite(fleet.bw[fleet.bw < np.inf]).all()


def test_scramble_preserves_multiset_of_costs():
    fab = make_datacenter(16, seed=1)
    scr, hidden = scramble(fab, seed=2)
    assert sorted(fab.lat.ravel()) == pytest.approx(sorted(scr.lat.ravel()))
    # hidden mapping actually recovers the original
    inv = np.argsort(hidden)
    np.testing.assert_allclose(scr.lat[np.ix_(inv, inv)], fab.lat)


def test_probe_symmetric_and_positive():
    fab = make_datacenter(16, seed=3)
    pr = probe_fabric(fab, seed=4)
    assert (pr.lat == pr.lat.T).all()
    assert (pr.lat[~np.eye(16, dtype=bool)] > 0).all()
    c = cost_matrix(pr, 1e6)
    assert (c == c.T).all()


def test_subset_elastic_restart_fabric():
    fab = make_datacenter(16, seed=5)
    sub = fab.subset([0, 1, 2, 3, 8, 9, 10, 11])
    assert sub.n == 8
    np.testing.assert_allclose(sub.lat[0, 1], fab.lat[0, 1])
    np.testing.assert_allclose(sub.lat[4, 5], fab.lat[8, 9])


@pytest.mark.parametrize("algo", ["ring", "ring_sequential", "halving_doubling",
                                  "double_binary_tree", "all_to_all"])
def test_simulator_runs_all_schedules(algo):
    fab = make_datacenter(16, seed=6)
    t = simulate_collective(fab, algo, np.arange(16), 1e7)
    assert t > 0 and np.isfinite(t)


def test_simulator_bcube():
    fab = make_datacenter(16, seed=6)
    t = simulate_collective(fab, "bcube", np.arange(16), 1e7, base=4)
    assert t > 0


def test_schedules_conserve_flow_counts():
    """Chunked ring: 2(N-1) rounds x N flows of S/N bytes each."""
    perm = np.arange(8)
    rounds = SCHEDULES["ring"](perm, 8e6)
    assert len(rounds) == 14
    assert all(len(r) == 8 for r in rounds)
    assert all(f.size == pytest.approx(1e6) for r in rounds for f in r)


def test_contention_slows_shared_links():
    """Two flows sharing one uplink must take longer than one alone."""
    fab = make_datacenter(16, nodes_per_rack=8, oversub=8.0, seed=7)
    from repro.core.schedule import Flow
    from repro.core.simulator import simulate_rounds

    # cross-rack flows share the ToR uplink
    one = simulate_rounds(fab, [[Flow(0, 8, 50e6)]])
    two = simulate_rounds(fab, [[Flow(0, 8, 50e6), Flow(1, 9, 50e6)]])
    assert two > one * 1.2


def test_optimized_order_beats_worst_in_simulator():
    """End-to-end §V: solver's order must beat the worst order when
    *simulated* (not just under its own cost model)."""
    fab, _ = scramble(make_datacenter(32, seed=8), seed=9)
    c = cost_matrix(probe_fabric(fab, seed=10), 0.0)
    m = make_cost_model("ring", c, 0.0)
    best = solve(m, iters=500, chains=8, seed=0)
    worst = solve_worst(m, iters=500, chains=8, seed=0)
    sim = CollectiveSimulator(fab, "ring", 50e6)
    t_best, t_worst = sim.run(best.perm), sim.run(worst.perm)
    assert t_best < t_worst


def test_spearman_cost_model_vs_simulator():
    """Table I reproduction: strong rank correlation on percentile orders."""
    from repro.core import percentile_orders

    fab, _ = scramble(make_datacenter(32, seed=11), seed=12)
    c = cost_matrix(probe_fabric(fab, seed=13), 0.0)
    m = make_cost_model("ring", c, 0.0)
    best = solve(m, iters=400, seed=0)
    worst = solve_worst(m, iters=400, seed=0)
    orders = percentile_orders(m, best.perm, worst.perm, k=10, seed=0)
    pred = m.cost_batch(np.stack(orders))
    sim = CollectiveSimulator(fab, "ring", 50e6)
    act = sim.run_many(orders)
    rx = np.argsort(np.argsort(pred))
    ry = np.argsort(np.argsort(act))
    rho = np.corrcoef(rx, ry)[0, 1]
    assert rho > 0.55, rho  # paper Table I: 0.58-0.94
