"""Substrate tests: optimizer, data pipeline, checkpointing, compression,
serving engine, trainer fault tolerance + straggler rerank."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.data import SyntheticLM, host_batch
from repro.models import get_model
from repro.optim import (
    AdamWConfig,
    compress_grads,
    cosine_schedule,
    decompress_grads,
    error_feedback_update,
    global_norm,
)
from repro.serve import GenerationConfig, GenerationEngine
from repro.train import (
    ClusterView,
    Trainer,
    TrainerConfig,
    init_state,
    make_train_step,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    from repro.optim import apply_opt, init_opt

    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_opt(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping_bounds_update():
    from repro.optim import apply_opt, init_opt

    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt(params)
    _, _, metrics = apply_opt(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert metrics["grad_norm"] > 1e5  # raw norm reported


def test_cosine_schedule_shape():
    fn = cosine_schedule(1.0, warmup=10, total=100)
    assert float(fn(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_converges():
    """With error feedback, repeated compression of a constant gradient
    must deliver the full magnitude on average (residual stays bounded)."""
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(256) * 1e-3)}
    residual = error_feedback_update(g)
    acc = jnp.zeros(256)
    for _ in range(50):
        q, s, residual = compress_grads(g, residual)
        acc = acc + decompress_grads(q, s)["w"]
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g["w"]),
                               atol=1e-4)
    assert float(jnp.abs(residual["w"]).max()) < 1e-3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_restart_safe():
    ds = SyntheticLM(1000, 32, 4, seed=7)
    b1 = host_batch(ds, 5)
    b2 = host_batch(ds, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different steps differ
    b3 = host_batch(ds, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_synthetic_learnable_structure():
    ds = SyntheticLM(256, 16, 2, seed=0)
    b = host_batch(ds, 0)
    # deterministic Markov structure: label mostly = 31*t+7 mod V
    t, l = b["tokens"], b["labels"]
    frac = np.mean((31 * t + 7) % 256 == l)
    assert frac > 0.8


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3)) * 2}}
    save(str(tmp_path), 42, tree, extras={"note": "x"})
    assert latest_step(str(tmp_path)) == 42
    restored, step, extras = restore(str(tmp_path), tree)
    assert step == 42 and extras["note"] == "x"
    np.testing.assert_array_equal(restored["a"], np.arange(10))
    np.testing.assert_array_equal(restored["b"]["c"], np.ones((3, 3)) * 2)


def test_checkpoint_latest_pointer_survives_multiple_saves(tmp_path):
    tree = {"a": jnp.zeros(2)}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 2, {"a": jnp.ones(2)})
    restored, step, _ = restore(str(tmp_path), tree)
    assert step == 2
    np.testing.assert_array_equal(restored["a"], np.ones(2))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(7, {"w": jnp.full(4, 3.0)})
    ck.wait()
    restored, step, _ = restore(str(tmp_path), {"w": jnp.zeros(4)})
    assert step == 7
    np.testing.assert_array_equal(restored["w"], np.full(4, 3.0))


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_greedy_matches_manual_decode():
    cfg = get_config("qwen2-0.5b").smoke()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = GenerationEngine(model, params,
                           GenerationConfig(max_new_tokens=5, eos_token=-1))
    prompts = [[1, 2, 3, 4], [4, 3, 2, 1]]
    outs = eng.generate(prompts)
    assert len(outs) == 2 and all(len(o) == 5 for o in outs)
    # manual: prefill + argmax chain must match engine output
    logits, cache = jax.jit(model.prefill)(params, jnp.asarray(prompts))
    from repro.serve.engine import _grow_cache

    cache = _grow_cache(cache, 4, 9)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    manual = [np.asarray(cur)]
    for _ in range(4):
        logits, cache = jax.jit(model.decode_step)(params, cur, cache)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        manual.append(np.asarray(cur))
    manual = np.stack(manual, 1)
    np.testing.assert_array_equal(np.asarray(outs), manual)


# ---------------------------------------------------------------------------
# trainer: fault tolerance + elastic restart + straggler rerank
# ---------------------------------------------------------------------------

def _mini_trainer(tmp_path, failure_injector=None, total=12):
    from repro.core import make_datacenter

    cfg = get_config("qwen2-0.5b").smoke()
    model = get_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    ds = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)

    def batches():
        i = 0
        while True:
            yield host_batch(ds, i)
            i += 1

    cluster = ClusterView(
        fabric=make_datacenter(16, seed=0),
        mesh_shape=(4, 4), axis_names=("data", "model"))
    return Trainer(
        step_fn=step_fn, state=state, batches=batches(),
        cfg=TrainerConfig(total_steps=total, ckpt_every=4,
                          ckpt_dir=str(tmp_path), log_every=2),
        cluster=cluster, failure_injector=failure_injector)


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _mini_trainer(tmp_path)
    report = tr.run()
    assert report["final_step"] == 12
    assert latest_step(str(tmp_path)) == 12
    assert report["restarts"] == 0


def test_trainer_elastic_restart_on_failure(tmp_path):
    fired = {"done": False}

    def injector(step):
        if step == 6 and not fired["done"]:
            fired["done"] = True
            return [3, 7]          # two nodes die
        return None

    tr = _mini_trainer(tmp_path, failure_injector=injector)
    report = tr.run()
    assert report["restarts"] == 1
    assert report["final_step"] == 12
    # cluster shrank and re-planned: mesh fits survivors, active nodes
    # are a survivor subset, plan covers every mesh slot
    assert len(tr.cluster.alive) == 14
    mesh_n = int(np.prod(tr.cluster.mesh_shape))
    assert mesh_n <= 14
    assert set(tr.cluster.active) <= set(tr.cluster.alive)
    assert len(tr.cluster.active) == mesh_n
    assert sorted(tr.cluster.plan.flat.tolist()) == list(range(mesh_n))


def test_trainer_resumes_from_checkpoint_not_zero(tmp_path):
    """After a failure at step 6 with ckpt_every=4, training resumes from
    step 4 (the last durable checkpoint), not from scratch."""
    seen_steps = []

    def injector(step):
        seen_steps.append(step)
        if step == 6 and seen_steps.count(6) == 1:
            return [0]
        return None

    tr = _mini_trainer(tmp_path, failure_injector=injector)
    report = tr.run()
    assert report["final_step"] == 12
    # step 6 encountered twice: once pre-failure, once after restore to 4
    assert seen_steps.count(6) == 2
