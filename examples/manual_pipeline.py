"""The paper's pipeline, step by step (the manual API).

This is the explicit mapping to the paper's sections — probe (§IV-B),
solve (§IV-C), validate on the contention-aware simulator, N-D mesh
assignment — using the low-level `repro.core` functions directly.
Applications should normally use the Session facade instead (see
examples/quickstart.py); this file exists so every paper stage stays
visible as a separate call.

Run:  python examples/manual_pipeline.py
"""

from repro.core import (
    CollectiveSimulator,
    cost_matrix,
    make_cost_model,
    make_datacenter,
    optimize_mesh_assignment,
    optimize_rank_order,
    probe_fabric,
    scramble,
    solve_worst,
)


def main() -> None:
    # 1. the cloud hands you 64 VMs in random order
    fabric, _ = scramble(make_datacenter(64, seed=0), seed=1)

    # 2. probe pairwise latency (paper §IV-B)
    probed = probe_fabric(fabric, seed=2)
    c = cost_matrix(probed)  # latency-centric c_{i,j}

    # 3. solve for the rank order (paper §IV-C: SA + refinement)
    best = optimize_rank_order(c, "ring", method="auto", iters=1500)
    worst = solve_worst(make_cost_model("ring", c, 0.0), iters=1500)
    print(f"cost model: best={best.cost * 1e3:.2f} ms "
          f"worst={worst.cost * 1e3:.2f} ms "
          f"({worst.cost / best.cost:.1f}x apart)")

    # 4. validate on the contention-aware simulator (the 'real' cloud)
    sim = CollectiveSimulator(fabric, "ring", 100e6)
    t_best, t_worst = sim.run(best.perm), sim.run(worst.perm)
    print(f"simulated 100MB ring allreduce: best={t_best * 1e3:.1f} ms "
          f"worst={t_worst * 1e3:.1f} ms -> {t_worst / t_best:.2f}x speedup")

    # 5. N-D mesh plan (the JAX integration): device order for (data, model)
    plan = optimize_mesh_assignment(c, (8, 8), ("data", "model"))
    print(f"mesh plan: weighted cost {plan.baseline_cost:.5f} -> "
          f"{plan.cost:.5f} ({plan.baseline_cost / plan.cost:.2f}x better "
          f"than identity order)")
    print(f"device order for Mesh(): {plan.flat[:16].tolist()} ...")


if __name__ == "__main__":
    main()
