"""Serving example: batched generation with a Session-compiled plan.

Loads a smoke-scale model per --arch (any of the 10 assigned, including
the SSM/hybrid state-cache families), compiles the decode-path
collective plan through a Session, runs a prefill wave + greedy decode,
and reports tokens/s plus the plan's per-op hints.

Run:  python examples/serve_lm.py --arch rwkv6-1.6b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import Session, SessionConfig
from repro.configs import get_config
from repro.models import get_model
from repro.serve import GenerationConfig, GenerationEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    fe = None
    if cfg.family == "vlm":
        fe = jnp.ones((args.batch, cfg.n_img_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        fe = jnp.ones((args.batch, cfg.n_audio_ctx, cfg.d_model), jnp.float32)

    session = Session(SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 16, "scramble_seed": 1},
        "solver": {"budget": {"iters": 200, "chains": 4}},
        "workload": "serve",
        "payload_bytes": 1e6,
        "moe": bool(cfg.n_experts),
    }))
    with session:
        eng = GenerationEngine(
            model, params, GenerationConfig(max_new_tokens=args.max_new,
                                            eos_token=-1, temperature=0.0),
            session=session)
        print(f"plan hints: {eng.collective_hints(1e6)}")
        prompts = [
            [(7 * i + j) % cfg.vocab_size for j in range(args.prompt_len)]
            for i in range(args.batch)
        ]
        t0 = time.perf_counter()
        outs = eng.generate(prompts, frontend_embeds=fe)
        dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    print(f"arch={cfg.name} ({cfg.family}) batch={args.batch}")
    for i, o in enumerate(outs[:2]):
        print(f"  prompt[{i}] -> {o[:12]}{'...' if len(o) > 12 else ''}")
    print(f"{total_new} tokens in {dt:.2f}s = {total_new / dt:.1f} tok/s "
          f"(prefill {int(eng.stats['prefill_tokens'])} tok, "
          f"{int(eng.stats['decode_steps'])} decode steps)")


if __name__ == "__main__":
    main()
