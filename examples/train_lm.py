"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on synthetic data with the full production stack —
Session-planned cloud-aware mesh, AdamW + ZeRO specs, async checkpoints,
straggler-fed drift observations flowing back into the Session, and
(injectable) failure recovery that re-plans through the same Session.

Run:  python examples/train_lm.py [--steps 300] [--arch qwen2-0.5b]

On this CPU container the model is width-reduced to ~waist size so a few
hundred steps finish in minutes; on a TPU fleet drop --reduce.
"""

import argparse
import dataclasses

import jax

from repro import Session, SessionConfig
from repro.configs import get_config
from repro.core import make_datacenter
from repro.data import SyntheticLM, host_batch
from repro.models import get_model
from repro.optim import AdamWConfig, cosine_schedule
from repro.train import (
    ClusterView,
    Trainer,
    TrainerConfig,
    init_state,
    make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="simulate node failures at this step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        # small-but-real reduction that keeps the architecture family;
        # vocab is shrunk so a few hundred CPU steps visibly learn the
        # synthetic stream's Markov structure
        cfg = dataclasses.replace(
            cfg, n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
            head_dim=32, d_ff=1024, vocab_size=2048, dtype="float32",
            loss_chunk_size=0, attn_q_chunk=0)
    model = get_model(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count() / 1e6:.0f}M")

    state = init_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(schedule=cosine_schedule(1e-3, 10, args.steps))
    step_fn = jax.jit(make_train_step(model, opt))

    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    def batches():
        i = 0
        while True:
            yield host_batch(ds, i)
            i += 1

    # One Session owns probing, plan compilation + caching, and drift
    # re-plans for the cluster; the ClusterView consumes it.
    session = Session(SessionConfig.from_dict({
        "solver": {"budget": {"iters": 400, "chains": 4}},
        "payload_bytes": 4e6,
    }))
    cluster = ClusterView(
        fabric=make_datacenter(64, seed=0),
        mesh_shape=(8, 8), axis_names=("data", "model"),
        session=session)

    injector = None
    if args.inject_failure:
        fired = {}

        def injector(step):
            if step == args.inject_failure and not fired:
                fired["x"] = True
                return [5, 9]
            return None

    with session:
        trainer = Trainer(
            step_fn=step_fn, state=state, batches=batches(),
            cfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                              ckpt_dir=args.ckpt_dir, log_every=20),
            cluster=cluster, failure_injector=injector)
        report = trainer.run()

    first = report["history"][0]["loss"]
    last = report["history"][-1]["loss"]
    print(f"steps={report['final_step']} restarts={report['restarts']} "
          f"rerank_events={report['rerank_events']}")
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "training must make progress"


if __name__ == "__main__":
    main()
