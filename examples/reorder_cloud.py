"""Dynamic cloud adaptation demo (paper §VI) through the Session.

Simulates a long-running job on a multi-tenant fabric whose link costs
drift over time (noisy neighbors come and go).  The Session owns the
whole loop:

1. initial attach + plan (the static paper pipeline);
2. online monitoring via ``session.observe``: each refreshed cost
   matrix feeds the per-entry AdaptiveRerankers; when an entry on the
   plan's critical path degrades past the drift threshold it is
   hot-patched (bottleneck replacement) and the session re-plans;
3. straggler detection feeding the same machinery;
4. lifecycle hooks logging every drift/replan event.

Run:  python examples/reorder_cloud.py
"""

import numpy as np

from repro import Session, SessionConfig
from repro.core import StragglerDetector

N = 48


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": N, "seed": 3,
                   "scramble_seed": 4},
        "probe": {"seed": 5},
        "drift": {"threshold": 1.15, "auto_replan": True},
        "payload_bytes": 0,          # latency-centric, like the paper
    })

    events = []
    with Session(cfg) as s:
        s.on("drift", lambda sess, report:
             events.append(("drift", len(report.degraded))))
        s.on("replan", lambda sess, plan, previous:
             events.append(("replan", plan.fingerprint.digest)))
        plan = s.plan()
        print(f"initial plan {plan.fingerprint.digest}: "
              f"{len(plan.entries)} entries")

        c0 = s.reference_matrix()
        detector = StragglerDetector(N, ratio_threshold=1.6)
        stale_epochs = []
        for epoch in range(30):
            # drifting multi-tenant load: random links degrade / recover
            c = c0 * (1.0 + 0.05 * rng.standard_normal((N, N)))
            c = np.maximum(c, c.T)
            np.fill_diagonal(c, 0.0)
            if epoch == 10:
                # a noisy neighbor lands on a link of the current a-r ring
                entry = next(iter(s.planned.entries.values()))
                a, b = entry.perm[0], entry.perm[1]
                c[a, b] = c[b, a] = c.max() * 20
                print(f"epoch {epoch}: injected congestion on link ({a},{b})")
            if epoch == 20:
                # a straggling host: slow at the *compute* level
                for _ in range(5):
                    detector.observe(7, 4.0)
                for n in range(N):
                    if n != 7:
                        detector.observe(n, 1.0)
                c = detector.inflate(c)
                print(f"epoch {epoch}: straggler detected at nodes "
                      f"{detector.stragglers().tolist()}")

            report = s.observe(c)
            if report.stale:
                stale_epochs.append(epoch)

    print(f"\ndrift detected at epochs: {stale_epochs}")
    print(f"lifecycle events: {events}")
    assert stale_epochs, "the injected congestion must trigger drift"
    assert any(e[0] == "replan" for e in events), \
        "auto_replan must recompile after drift"


if __name__ == "__main__":
    main()
