"""Dynamic cloud adaptation demo (paper §VI end-to-end).

Simulates a long-running job on a multi-tenant fabric whose link costs
drift over time (noisy neighbors come and go).  Shows:

1. initial probe + solve (the static paper pipeline);
2. online monitoring via the AdaptiveReranker: when a link on the ring's
   critical path degrades, the bottleneck-replacement heuristic repairs
   the order without a full re-solve;
3. straggler detection feeding the same machinery;
4. the cost trajectory with vs without adaptation.

Run:  PYTHONPATH=src python examples/reorder_cloud.py
"""

import numpy as np

from repro.core import (
    AdaptiveReranker,
    StragglerDetector,
    cost_matrix,
    make_cost_model,
    make_datacenter,
    optimize_rank_order,
    probe_fabric,
    scramble,
)


def main() -> None:
    rng = np.random.default_rng(0)
    fabric, _ = scramble(make_datacenter(48, seed=3), seed=4)
    c0 = cost_matrix(probe_fabric(fabric, seed=5))

    res = optimize_rank_order(c0, "ring", method="auto", iters=1200)
    print(f"initial solve: ring cost {res.cost * 1e3:.3f} ms "
          f"(stage trace: {[t[0] for t in res.trace[-3:]]})")

    reranker = AdaptiveReranker(
        model_factory=lambda cm: make_cost_model("ring", cm, 0.0),
        perm=res.perm, threshold=1.15)
    detector = StragglerDetector(48, ratio_threshold=1.6)

    static_costs, adaptive_costs, events = [], [], []
    c = c0.copy()
    model0 = make_cost_model("ring", c0, 0.0)

    for epoch in range(30):
        # drifting multi-tenant load: random links degrade / recover
        c = c0 * (1.0 + 0.05 * rng.standard_normal((48, 48)))
        c = np.maximum(c, c.T)
        np.fill_diagonal(c, 0.0)
        if epoch == 10:
            # a noisy neighbor lands on a link of the *current* ring
            m = make_cost_model("ring", c, 0.0)
            a, b, _ = max(m.critical_edges(reranker.perm), key=lambda t: t[2])
            c[a, b] = c[b, a] = c.max() * 20
            print(f"epoch {epoch}: injected congestion on link ({a},{b})")
        if epoch == 20:
            # a straggling host: slow at the *compute* level
            for _ in range(5):
                detector.observe(7, 4.0)
            for n in range(48):
                if n != 7:
                    detector.observe(n, 1.0)
            c = detector.inflate(c)
            print(f"epoch {epoch}: straggler detected at nodes "
                  f"{detector.stragglers().tolist()}")

        m = make_cost_model("ring", c, 0.0)
        static_costs.append(m.cost(res.perm))          # never adapts
        _, changed = reranker.update(c)
        adaptive_costs.append(m.cost(reranker.perm))
        if changed:
            events.append(epoch)

    static = np.asarray(static_costs) * 1e3
    adapt = np.asarray(adaptive_costs) * 1e3
    print(f"\nre-rank events at epochs: {events}")
    print(f"mean ring cost:  static order {static.mean():.3f} ms | "
          f"adaptive {adapt.mean():.3f} ms "
          f"({static.mean() / adapt.mean():.2f}x better)")
    print(f"worst epoch:     static {static.max():.3f} ms | "
          f"adaptive {adapt.max():.3f} ms "
          f"({static.max() / adapt.max():.2f}x better)")
    assert adapt.mean() <= static.mean() * 1.001


if __name__ == "__main__":
    main()
