"""Quickstart: the whole pipeline through one Session.

Probes a scrambled multi-tenant datacenter, compiles the collective
plan (algorithm + chunking + rank order per op, plus the N-D mesh
assignment), and applies it — one declarative config, one call chain.
For the step-by-step paper mapping see examples/manual_pipeline.py.

Run:  python examples/quickstart.py        (after `pip install -e .`)
"""

from repro import Session, SessionConfig

cfg = SessionConfig.from_dict({
    "fabric": {"kind": "datacenter", "nodes": 64, "scramble_seed": 1},
    "mesh": {"shape": "8x8", "axis_names": "data,model"},
    "payload_bytes": 100e6,
})

with Session(cfg) as s:
    applied = s.apply()                  # probe -> plan -> apply, lazily
    print(applied.summary())
    print(f"device order for Mesh(): {applied.order[:16].tolist()} ...")
