"""Overlapped train step: planned+bucketed vs planned-sequential vs identity.

Two sections, one artifact (``BENCH_overlap.json``):

* **modeled fabric** — on the oversubscribed scrambled 8-node
  datacenter (the fabric every benchmark shares), price the planned
  all-reduce with ``SimExecutor`` at the full payload and at the
  plan-selected bucket payload (``PlanEntry.bucket_bytes``), then roll
  the standard bucket-pipeline recurrence: bucket ``b``'s transfer may
  start once backward slice ``b`` is done and the wire is free.
  Compute is pinned to the sequential comm time (the balanced
  compute:comm boundary — the regime the paper's reordering targets),
  so the reported speedup isolates what pipelining + rank reordering
  hide.  Gate: ``overlap="bucketed"`` must model **>= 1.15x** the
  planned-sequential full-step throughput.
* **host execution** — an 8-device host-mesh subprocess jits the real
  thing (smoke LM, ``jit_train_step(..., overlap=...)`` with an
  :class:`~repro.train.overlap_grads.OverlapGradReducer` built from the
  planned ``(algo, perm, bucket_bytes)``), checks the overlapped loss
  against the baseline step bit-for-bit at float tolerance, and derives
  the exposed-comm fraction from ``repro.obs`` timers around
  separately-jitted comm-only / compute-only / full-step runs.
  Interpret-mode host wall times are reported, not gated — a CPU
  simulation of the mesh cannot show real fabric overlap; the modeled
  section is the gated claim (precedent: ``lowering_e2e`` gates on
  ``sim_speedup``).

Usage::

    PYTHONPATH=src python benchmarks/overlap_step.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

import numpy as np

try:
    from .common import std_fabric, write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import std_fabric, write_json

from repro.collective import SimExecutor
from repro.core import probe_fabric
from repro.plan import CollectiveRequest, JobMix, PlanCompiler, SolveBudget

N = 8
SIZE = 4 << 20          # full grad payload priced in the modeled section
SPEEDUP_FLOOR = 1.15

_HOST_SCRIPT = r"""
import json, sys
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro import obs
from repro.configs import get_config
from repro.data import SyntheticLM, host_batch
from repro.models import get_model
from repro.optim import AdamWConfig
from repro.train import init_state, jit_train_step, make_train_step
from repro.train.overlap_grads import OverlapGradReducer, certified_allreduce
from repro.kernels.schedule_runner import check_postcondition
from repro.kernels.overlap import run_overlapped

cfg_in = json.load(open(sys.argv[1]))
n = cfg_in["n"]
mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
cfg = get_config("qwen2-0.5b").smoke()
model = get_model(cfg)
opt = AdamWConfig(lr=1e-3)
state = init_state(model, jax.random.PRNGKey(0))
batch = host_batch(SyntheticLM(cfg.vocab_size, 16, n, seed=0), 0)
pbytes = float(sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(state.params)))
bb = min(cfg_in["bucket_bytes"], pbytes / 2)
sched = certified_allreduce(n, bb, algo=cfg_in["algo"], perm=cfg_in["perm"],
                            chunk_factor=max(1, cfg_in["chunks"]),
                            **cfg_in["algo_kwargs"])

def timed(name, fn, reps):
    fn()                                  # compile + warm
    t = obs.tracer().timer(name)
    with t:
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
    return t.elapsed / reps

reps = cfg_in["reps"]
out = {"param_bytes": pbytes, "bucket_bytes": bb}

base = jax.jit(make_train_step(model, opt))
out["baseline_s"] = timed("bench.base", lambda: base(state, batch)[1]["loss"],
                          reps)
base_loss = float(base(state, batch)[1]["loss"])

# per-shard grads for the comm-only run
shard = lambda l, i: l[i * (l.shape[0] // n):(i + 1) * (l.shape[0] // n)]
g = jax.jit(jax.grad(model.loss))
gstack = jax.tree.map(lambda *ls: jnp.stack(ls),
                      *[g(state.params,
                          jax.tree.map(lambda l, i=i: shard(l, i), batch))
                        for i in range(n)])

for mode in cfg_in["modes"]:
    red = OverlapGradReducer(mesh, "data", sched, bucket_bytes=bb, mode=mode)
    step = jit_train_step(model, opt, cfg, mesh, None, None, donate=False,
                          overlap=mode, reducer=red, axis="data")
    with mesh:
        out[f"{mode}_s"] = timed(f"bench.{mode}",
                                 lambda: step(state, batch)[1]["loss"], reps)
        loss = float(step(state, batch)[1]["loss"])
    out[f"{mode}_loss_ok"] = bool(np.isclose(loss, base_loss, rtol=2e-5))

# exposed-comm fraction: obs timers around separately-jitted comm-only /
# compute-only / full-step runs (spans inside traced code are meaningless)
red = OverlapGradReducer(mesh, "data", sched, bucket_bytes=bb,
                         mode="bucketed")
comm_fn = jax.jit(lambda gs: jax.tree.leaves(red(gs)[0])[0])
with mesh:
    t_comm = timed("bench.comm_only", lambda: comm_fn(gstack), reps)
t_compute = timed("bench.compute_only",
                  lambda: jax.tree.leaves(g(state.params, batch))[0], reps)
t_full = out.get("bucketed_s", t_comm + t_compute)
exposed = max(0.0, t_full - t_compute)
out["comm_only_s"] = t_comm
out["compute_only_s"] = t_compute
# fraction of the full step that is exposed (non-hidden) communication;
# ~1.0 on a host CPU mesh, where nothing truly runs concurrently — the
# modeled section reports the fabric-level counterpart
out["exposed_comm_fraction"] = min(1.0, exposed / max(t_full, 1e-12))

# per-bucket postcondition on the certified schedule
d = sched.n_chunks * max(1, sched.chunk_factor) * 32
x = np.arange(n * d, dtype=np.float32).reshape(n, d) / 1e3
res, _ = run_overlapped(x, mesh, "data", sched, use_pallas_add=False)
out["postcondition_ok"] = not check_postcondition(sched, x, np.asarray(res))

json.dump(out, open(cfg_in["out"], "w"))
print("HOST DONE")
"""


def _plan_overlap(seed: int = 0) -> dict:
    """Plan the all-reduce on the oversubscribed scrambled fabric."""
    fab = std_fabric(N, seed=seed)
    probe = probe_fabric(fab, seed=seed)
    mix = JobMix((CollectiveRequest("all-reduce", float(SIZE)),),
                 name="overlap")
    plan = PlanCompiler(fabric=fab,
                        budget=SolveBudget(iters=200, chains=4)).compile(
        probe, mix)
    entry = plan.lookup("all-reduce", float(SIZE))
    bucket = plan.lookup("all-reduce", entry.bucket_bytes or float(SIZE))
    sim = SimExecutor(fab)

    # the reducer path runs only schedules that end replicated; price
    # the same ring-at-planned-order fallback reducer_from_plan applies
    from repro.collective import JaxExecutor
    algo_fallback = JaxExecutor().lower_schedule(
        entry.program()).postcondition != "allreduce"
    if algo_fallback:
        entry = dataclasses.replace(entry, algo="ring", algo_kwargs={})
        bucket = dataclasses.replace(bucket, algo="ring", algo_kwargs={})

    def priced(e, size):
        prog = dataclasses.replace(e, size_bytes=float(size)).program()
        return float(sim.estimate(prog))

    t_full_planned = priced(entry, SIZE)
    t_full_identity = priced(
        dataclasses.replace(entry, perm=tuple(range(N)), chunks=1), SIZE)
    bb = float(entry.bucket_bytes or SIZE)
    n_buckets = int(np.ceil(SIZE / bb))
    t_bucket = priced(bucket, bb)
    return {
        "fabric": "scrambled datacenter, 8 nodes (std_fabric)",
        "size_bytes": SIZE,
        "algo": entry.algo,
        "algo_fallback": bool(algo_fallback),
        "algo_kwargs": {k: int(v) for k, v in entry.algo_kwargs.items()},
        "chunks": int(entry.chunks),
        "perm": [int(p) for p in entry.perm],
        "bucket_bytes": bb,
        "n_buckets": n_buckets,
        "sim_full_planned_s": t_full_planned,
        "sim_full_identity_s": t_full_identity,
        "sim_bucket_s": t_bucket,
    }


def _pipeline_model(o: dict) -> dict:
    """Bucket-pipeline makespan at the balanced compute:comm boundary.

    ``C`` (backward compute) is pinned to the planned sequential comm
    time; bucket ``b`` may go on the wire once backward slice ``b`` is
    done AND the previous bucket left the wire (one serialized fabric).
    """
    C = o["sim_full_planned_s"]
    nb, tb = o["n_buckets"], o["sim_bucket_s"]
    t_seq = C + o["sim_full_planned_s"]            # no overlap
    t_seq_identity = C + o["sim_full_identity_s"]
    finish = 0.0
    for b in range(nb):
        ready = C * (b + 1) / nb
        finish = max(ready, finish) + tb
    t_bucketed = max(C, finish)
    return {
        "compute_s": C,
        "modeled_sequential_s": t_seq,
        "modeled_sequential_identity_s": t_seq_identity,
        "modeled_bucketed_s": t_bucketed,
        "modeled_exposed_s": max(0.0, t_bucketed - C),
        "modeled_exposed_fraction": max(0.0, t_bucketed - C) / t_bucketed,
        "speedup_bucketed_vs_sequential": t_seq / t_bucketed,
        "speedup_bucketed_vs_identity": t_seq_identity / t_bucketed,
        "floor": SPEEDUP_FLOOR,
    }


def _run_host(o: dict, smoke: bool, workdir: str) -> dict:
    cfg_path = os.path.join(workdir, "overlap_cfg.json")
    out_path = os.path.join(workdir, "overlap_out.json")
    script = os.path.join(workdir, "overlap_run.py")
    with open(script, "w") as f:
        f.write(_HOST_SCRIPT)
    with open(cfg_path, "w") as f:
        json.dump({"n": N, "algo": o["algo"],
                   "algo_kwargs": o["algo_kwargs"], "perm": o["perm"],
                   "chunks": o["chunks"], "bucket_bytes": o["bucket_bytes"],
                   "modes": ["bucketed"] if smoke
                   else ["sequential", "bucketed", "fused"],
                   "reps": 2 if smoke else 5, "out": out_path}, f)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, script, cfg_path], cwd=repo,
                          env=env, capture_output=True, text=True,
                          timeout=900)
    if proc.returncode != 0 or "HOST DONE" not in proc.stdout:
        raise RuntimeError(f"host subprocess failed: {proc.stderr[-2000:]}")
    with open(out_path) as f:
        return json.load(f)


def run(smoke: bool = False, out_path: str = "BENCH_overlap.json",
        seed: int = 0):
    orders = _plan_overlap(seed=seed)
    model = _pipeline_model(orders)

    with tempfile.TemporaryDirectory() as td:
        host = _run_host(orders, smoke, td)

    equiv_ok = all(host.get(f"{m}_loss_ok", True)
                   for m in ("sequential", "bucketed", "fused"))
    gate_ok = (model["speedup_bucketed_vs_sequential"] >= SPEEDUP_FLOOR
               and equiv_ok and host["postcondition_ok"])

    rows = [
        {"name": "overlap_modeled_sequential",
         "us": model["modeled_sequential_s"] * 1e6,
         "derived": f"algo={orders['algo']};buckets={orders['n_buckets']}"},
        {"name": "overlap_modeled_bucketed",
         "us": model["modeled_bucketed_s"] * 1e6,
         "derived": "speedup="
                    f"{model['speedup_bucketed_vs_sequential']:.2f}x;"
                    f"floor={SPEEDUP_FLOOR}"},
        {"name": "overlap_host_step",
         "us": host.get("bucketed_s", 0.0) * 1e6,
         "derived": f"equiv_ok={equiv_ok};"
                    f"exposed_frac={host['exposed_comm_fraction']:.2f}"},
        {"name": "overlap_gate", "us": 0.0,
         "derived": f"post_ok={host['postcondition_ok']};"
                    f"{'OK' if gate_ok else 'FAIL'}"},
    ]
    results = {
        "benchmark": "overlap_step",
        "smoke": smoke,
        "scenario": orders,
        "modeled": model,
        "host": host,
        "gate_ok": bool(gate_ok),
    }
    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    if not gate_ok:
        raise RuntimeError(
            f"overlap gate failed: "
            f"speedup={model['speedup_bucketed_vs_sequential']:.3f} "
            f"equiv_ok={equiv_ok} post_ok={host['postcondition_ok']}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: bucketed mode only, fewer reps")
    ap.add_argument("--out", default="BENCH_overlap.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
