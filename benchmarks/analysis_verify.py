"""Static-verifier benchmark: gate cost, mutant kill rate, efficiency.

The verifier (:mod:`repro.analysis`, DESIGN.md §11) now gates every
plan compile, so three numbers must be committed and tracked:

* **verifier µs/program** — the gate passes (validate + deps +
  liveness) on each registered builder's program, plus the full-pass
  cost with the contention/bounds measurements;
* **gate share of compile time** — a sim-oracle plan compile with the
  gate on, vs the same compile with verification stubbed out; the gate
  must stay below 10% of compile wall time (acceptance criterion);
* **mutant kill rate** — the seeded mutator (drop / swap / corrupt /
  duplicate) over the full catalogue; must stay >= 0.95;

plus the per-algorithm **bandwidth-efficiency table** the bounds pass
derives — the static half of the paper's Table I story (the naive
sequential ring at 1/n is the motivating regime).

Usage::

    PYTHONPATH=src python benchmarks/analysis_verify.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

try:
    from .common import std_fabric, write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import std_fabric, write_json

from repro.analysis import GATE_PASSES, kill_rate, verify_program
from repro.collective import CollectiveOp, compile_op, get_builder, \
    registered_builders
from repro.collective.builders import candidates
from repro.fabric import probe_fabric
from repro.plan import CollectiveRequest, JobMix, PlanCompiler, SolveBudget

SIZE = 8e6


def _catalogue(n: int):
    """(algo, program) for every registered builder feasible at n."""
    out = []
    for algo in sorted(registered_builders()):
        b = get_builder(algo)
        for kind in b.kinds:
            akws = [akw for a, akw in candidates(kind, n) if a == algo]
            if not akws:
                continue
            op = CollectiveOp(kind=kind, size_bytes=SIZE,
                              group=tuple(range(n)))
            out.append((algo, compile_op(op, algo, **dict(akws[0]))))
    return out


def _time_verify(programs, passes, reps: int):
    t0 = time.perf_counter()
    for _ in range(reps):
        for _, prog in programs:
            verify_program(prog, passes=passes)
    return (time.perf_counter() - t0) / (reps * len(programs)) * 1e6


def run(smoke: bool = False, out_path: str = "BENCH_analysis.json",
        seed: int = 0):
    n = 8 if smoke else 16
    reps = 2 if smoke else 10
    programs = _catalogue(n)
    rows = []

    # -- verifier latency --------------------------------------------------
    gate_us = _time_verify(programs, GATE_PASSES, reps)
    full_us = _time_verify(programs, None, reps)
    rows.append({"name": "analysis.verify_gate", "us": gate_us,
                 "derived": f"n={n} passes={'+'.join(GATE_PASSES)}"})
    rows.append({"name": "analysis.verify_full", "us": full_us,
                 "derived": f"n={n} all_passes"})

    # -- gate share of a sim-oracle plan compile ---------------------------
    fab = std_fabric(n, seed=seed)
    probe = probe_fabric(fab, seed=seed)
    # the share is measured against the production SolveBudget — a
    # smoke-sized budget under-reports the compile and over-reports the
    # gate (the gate's absolute cost is the same either way)
    budget = SolveBudget(iters=60, chains=2) if smoke else SolveBudget()
    mix = JobMix(name="bench", requests=(
        CollectiveRequest(op="all-reduce", size_bytes=SIZE, count=4),
        CollectiveRequest(op="all-gather", size_bytes=SIZE / 4, count=2),
        CollectiveRequest(op="reduce-scatter", size_bytes=SIZE / 4, count=2),
        CollectiveRequest(op="all-to-all", size_bytes=SIZE / 8, count=1),
    ))

    t0 = time.perf_counter()
    compiler = PlanCompiler(fabric=fab, budget=budget, seed=seed)
    compiler.compile(probe, mix)
    t_gated = time.perf_counter() - t0

    t0 = time.perf_counter()
    ungated = PlanCompiler(fabric=fab, budget=budget, seed=seed)
    ungated._verify_gate = lambda *a, **kw: None   # stub the gate out
    ungated.compile(probe, mix)
    t_plain = time.perf_counter() - t0

    gate_share = max(t_gated - t_plain, 0.0) / max(t_gated, 1e-12)
    rows.append({"name": "analysis.compile_gate_share",
                 "us": (t_gated - t_plain) * 1e6,
                 "derived": f"share={gate_share:.4f} gated={t_gated:.3f}s"})

    # -- mutant kill rate over the catalogue -------------------------------
    t0 = time.perf_counter()
    rate, survivors = kill_rate([p for _, p in programs], seed=seed)
    t_kill = time.perf_counter() - t0
    rows.append({"name": "analysis.mutant_kill_rate", "us": t_kill * 1e6,
                 "derived": f"rate={rate:.4f} survivors={len(survivors)}"})

    # -- per-algorithm bandwidth efficiency --------------------------------
    efficiency = {}
    for algo, prog in programs:
        rep = verify_program(prog, passes=("bounds",))
        efficiency[algo] = rep.stats["bounds"]["bandwidth_efficiency"]
        rows.append({"name": f"analysis.efficiency.{algo}", "us": 0.0,
                     "derived": f"{efficiency[algo]:.4f}"})

    results = {
        "n": n,
        "verify_gate_us_per_program": gate_us,
        "verify_full_us_per_program": full_us,
        "compile_gate_share": gate_share,
        "compile_gated_s": t_gated,
        "compile_ungated_s": t_plain,
        "mutant_kill_rate": rate,
        "mutant_survivors": [list(s) for s in survivors],
        "bandwidth_efficiency": efficiency,
        "gate_under_10pct": bool(gate_share < 0.10),
        "kill_rate_ok": bool(rate >= 0.95),
    }
    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    if not results["kill_rate_ok"]:
        raise RuntimeError(f"mutant kill rate {rate:.4f} below 0.95")
    if not smoke and not results["gate_under_10pct"]:
        # smoke mode shrinks the compile, not the gate: the share
        # criterion only means anything at the production budget
        raise RuntimeError(
            f"verify gate is {gate_share * 100:.1f}% of compile time "
            f"(>= 10%)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: smaller group, fewer reps")
    ap.add_argument("--out", default="BENCH_analysis.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
