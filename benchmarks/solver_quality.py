"""Solver-quality benchmark: paper stage-1 SA vs our full pipeline.

Not tied to a paper figure; quantifies the beyond-paper solver additions
(greedy construction, 2-opt/Or-opt refinement, Held-Karp exactness at
small N) against the paper's SA on the same budget — EXPERIMENTS §5.
"""

from __future__ import annotations

import numpy as np

from repro.core import exhaustive, make_cost_model, solve, solve_sa

from .common import Timer, emit, probed_cost, std_fabric


def run(seed: int = 0):
    rows = []

    # exactness check at small N: auto must hit the global optimum
    fab8 = std_fabric(8, seed=seed)
    c8 = probed_cost(fab8, 0.0, seed=seed)
    m8 = make_cost_model("ring", c8, 0.0)
    _, best8 = exhaustive(m8)
    res8 = solve(m8, method="auto")
    rows.append({
        "name": "solver_exact_n8",
        "us_per_call": res8.wall_s * 1e6,
        "derived": f"optimum={best8:.6e};auto={res8.cost:.6e};"
                   f"hit={abs(res8.cost - best8) < 1e-12}",
    })

    # quality at n=64 on equal iteration budgets
    fab = std_fabric(64, seed=seed + 1)
    c = probed_cost(fab, 0.0, seed=seed + 1)
    m = make_cost_model("ring", c, 0.0)
    with Timer() as t_sa:
        sa = solve_sa(m, iters=3000, chains=16, seed=0)
    with Timer() as t_paper:
        paper = solve(m, method="paper", iters=3000, chains=16, seed=0)
    with Timer() as t_auto:
        auto = solve(m, method="auto", iters=3000, chains=16, seed=0)
    rng = np.random.default_rng(0)
    rand = m.cost_batch(np.stack([rng.permutation(64) for _ in range(128)]))
    rows += [
        {"name": "solver_sa_only_n64", "us_per_call": t_sa.s * 1e6,
         "derived": f"cost={sa.cost:.5e};vs_rand={rand.mean() / sa.cost:.2f}x"},
        {"name": "solver_paper_pipeline_n64", "us_per_call": t_paper.s * 1e6,
         "derived": f"cost={paper.cost:.5e};gain_over_sa={sa.cost / paper.cost:.3f}x"},
        {"name": "solver_auto_pipeline_n64", "us_per_call": t_auto.s * 1e6,
         "derived": f"cost={auto.cost:.5e};gain_over_sa={sa.cost / auto.cost:.3f}x;"
                    f"stage2={auto.trace[-1][0]}"},
    ]
    emit(rows)
    return {"sa": sa.cost, "paper": paper.cost, "auto": auto.cost}


if __name__ == "__main__":
    run()
