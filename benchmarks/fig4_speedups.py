"""Paper Fig. 4: best-vs-worst rank-order speedup per algorithm.

Paper: 512 F16 nodes (64 GPU nodes for NCCL), 100 MB allreduce; ring
family gains most (up to 3.7x), halving-doubling / tree / bcube less —
their sum-of-max objectives are flatter under permutation.  We reproduce
the per-algorithm ordering and magnitudes on the simulated fabric.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    CollectiveSimulator,
    make_cost_model,
    solve,
    solve_worst,
)

from .common import N_FAST, Timer, emit, probed_cost, std_fabric

#: (schedule, cost-model kwargs, options).  ``bw=True`` parameterizes the
#: cost matrix with the per-edge payload (lat + S_edge/bw) — the paper's
#: §VI "incorporate bandwidth" suggestion, which our experiments show is
#: required for the bandwidth-bound tree/HD objectives (EXPERIMENTS §Fig4).
ALGOS = [
    ("ring", {}, {}),
    ("ring_sequential", {}, {"model": "ring"}),
    ("halving_doubling", {}, {"bw": True, "payload_frac": 0.5}),
    ("double_binary_tree", {}, {"tag": "path", "bw": True, "payload_frac": 0.5}),
    ("double_binary_tree", {"mode": "barrier"},
     {"tag": "barrier", "bw": True, "payload_frac": 0.5}),
    ("bcube", {"base": 4}, {"bw": True, "payload_frac": 0.25}),
]


def run(n_nodes: int = N_FAST, size: float = 100e6, seed: int = 0,
        iters: int = 800):
    fab = std_fabric(n_nodes, seed=seed)
    rows, results = [], {}
    for sched_name, kw, opts in ALGOS:
        model_name = opts.get("model", sched_name)
        tag = opts.get("tag")
        payload = size * opts["payload_frac"] if opts.get("bw") else 0.0
        c = probed_cost(fab, payload, seed=seed)
        m = make_cost_model(model_name, c, payload, **kw)
        with Timer() as t:
            best = solve(m, iters=iters, seed=0)
            worst = solve_worst(m, iters=iters, seed=0)
            sim = CollectiveSimulator(fab, sched_name, size)
            t_best = sim.run(best.perm)
            t_worst = sim.run(worst.perm)
        speedup = t_worst / t_best
        key = sched_name if not tag else f"{sched_name}_{tag}"
        results[key] = speedup
        rows.append({
            "name": f"fig4_speedup_{key}",
            "us_per_call": t.s * 1e6,
            "derived": (
                f"best_ms={t_best * 1e3:.1f};worst_ms={t_worst * 1e3:.1f};"
                f"speedup={speedup:.2f}x"
            ),
        })
    emit(rows)
    return results


if __name__ == "__main__":
    run()
