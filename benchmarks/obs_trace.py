"""Observability benchmark: tracing overhead + capture→replay.

Two committed claims live in ``BENCH_obs.json``:

* **overhead** — the same ``PlanCompiler.compile`` timed with the obs
  tracer disabled (the PR-6-equivalent baseline path: ``span()``
  returns the shared null span and records nothing) and enabled; the
  enabled median must sit within 2% of the disabled median;
* **capture → replay** — a synthetic bursty workload trace
  (:func:`repro.obs.synthetic_bursty_trace`) folded into per-phase
  windows (:func:`repro.obs.fold`), replayed under per-window plans vs
  the single declared-mix plan.  Phase-aware planning must not lose.

Emits the harness CSV rows and writes ``BENCH_obs.json`` at the repo
root (stamped with git sha / versions / seed via ``common.run_meta``).
Runnable standalone: ``python benchmarks/obs_trace.py [--smoke]``.
"""

from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

try:
    from .common import write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import write_json

from repro.cli import run_obs_scenario

#: committed budget for enabled-tracer overhead on plan compiles
OVERHEAD_BUDGET_PCT = 2.0


def run(smoke: bool = False, out_path: str = "BENCH_obs.json",
        seed: int = 0):
    results = run_obs_scenario(smoke=smoke, seed=seed)
    results["benchmark"] = "obs_trace"
    results["overhead_budget_pct"] = OVERHEAD_BUDGET_PCT

    c = results["compile"]
    r = results["replay"]
    rows = [
        {"name": "obs_compile_disabled",
         "us": c["disabled_s"] * 1e6,
         "derived": f"median_of={c['reps']}"},
        {"name": "obs_compile_enabled",
         "us": c["enabled_s"] * 1e6,
         "derived": f"overhead={c['overhead_pct']:+.2f}%"},
        {"name": "obs_replay_declared",
         "us": r["declared_s"] * 1e6,
         "derived": f"records={r['records']}"},
        {"name": "obs_replay_phased",
         "us": r["phased_s"] * 1e6,
         "derived": f"windows={r['windows']};beats_declared="
                    f"{r['phased_beats_declared']}"},
    ]
    for row in rows:
        print(f"{row['name']},{row['us']:.3f},{row['derived']}")
    write_json(out_path, results, seed)
    # acceptance gates.  RuntimeError (not SystemExit): benchmarks/run.py
    # catches Exception per module, so one failed gate must not abort the
    # whole suite.  The overhead gate only binds on full (non-smoke) runs
    # — smoke compiles are too short for a stable 2% measurement.
    if not r["phased_beats_declared"]:
        raise RuntimeError(
            f"phase-windowed plans lost to the declared-mix plan "
            f"({r['phased_s']:.6f}s vs {r['declared_s']:.6f}s)")
    if not smoke and c["overhead_pct"] >= OVERHEAD_BUDGET_PCT:
        raise RuntimeError(
            f"enabled-tracer overhead {c['overhead_pct']:.2f}% exceeds "
            f"the {OVERHEAD_BUDGET_PCT}% budget")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: smaller fabric, fewer reps")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
