"""Paper §V-D: end-to-end training speedup from rank reordering.

Paper: LightGBM (allreduce + reducescatter per split, halving-doubling at
512 nodes) gains 1.3x; Caffe2 ring-chunked data-parallel DNN training
gains 1.2x — communication-only changes.

Two parts here:

1. **Simulated end-to-end model** — per training step:
   ``t_step = t_compute + t_allreduce(order)`` with the gradient-size
   allreduce simulated on the fabric under best vs worst order, compute
   time from the roofline compute term of a mid-size assigned arch.  This
   mirrors the paper's experiment at the same communication/computation
   granularity.

2. **Real mini-run** — a smoke-scale model trained on CPU with the
   Trainer on a reordered 1-device mesh: validates the plumbing end to
   end (loss falls; checkpoint; rerank hooks) though single-device wall
   time cannot show a network win.
"""

from __future__ import annotations

import numpy as np

from repro.core import CollectiveSimulator, make_cost_model, solve, solve_worst

from .common import N_FAST, Timer, emit, probed_cost, std_fabric


def run(n_nodes: int = N_FAST, grad_mb: float = 100.0, seed: int = 0):
    fab = std_fabric(n_nodes, seed=seed)
    c = probed_cost(fab, 0.0, seed=seed)
    size = grad_mb * 1e6

    m = make_cost_model("ring", c, 0.0)
    best = solve(m, iters=800, seed=0)
    worst = solve_worst(m, iters=800, seed=0)
    sim = CollectiveSimulator(fab, "ring", size)
    t_comm_best = sim.run(best.perm)
    t_comm_worst = sim.run(worst.perm)

    # compute share: glm4-9b train step compute-roofline on v5e-256
    # (6 * 9.4e9 * 1.05e6 tokens / (256 * 197e12) ~ 1.17 s) scaled to the
    # simulated DP world size.
    t_compute = 6 * 9.4e9 * (256 * 4096) / (256 * 197e12)

    e2e = (t_compute + t_comm_worst) / (t_compute + t_comm_best)
    rows = [{
        "name": "e2e_training_speedup_sim",
        "us_per_call": 0.0,
        "derived": (
            f"comm_best_ms={t_comm_best * 1e3:.1f};"
            f"comm_worst_ms={t_comm_worst * 1e3:.1f};"
            f"compute_ms={t_compute * 1e3:.1f};"
            f"e2e_speedup={e2e:.2f}x;paper=1.2-1.3x"
        ),
    }]

    # part 2: real mini training run through the Trainer
    import jax

    from repro.configs import get_config
    from repro.data import SyntheticLM, host_batch
    from repro.models import get_model
    from repro.optim import AdamWConfig
    from repro.train import init_state, make_train_step

    cfg = get_config("qwen2-0.5b").smoke()
    model = get_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=5e-3)))
    ds = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    losses = []
    with Timer() as t:
        for i in range(20):
            state, metrics = step(state, host_batch(ds, i))
            losses.append(float(metrics["loss"]))
    rows.append({
        "name": "e2e_mini_train_real",
        "us_per_call": t.s * 1e6 / 20,
        "derived": f"loss0={losses[0]:.3f};loss19={losses[-1]:.3f};falls={losses[-1] < losses[0]}",
    })
    emit(rows)
    return {"e2e_speedup": e2e, "losses": losses}


if __name__ == "__main__":
    run()
