"""Fabric subsystem benchmark: probe-count and solve-time scaling.

Three sections, all through the public ``repro.fabric`` surface:

* **sparse vs dense plan quality** — on a scrambled multi-tenant
  datacenter and a scrambled two-pod TPU fleet, compile a plan from a
  dense probe and from a ≤25%-budget sparse probe (analytic compile),
  then referee both plans with the contention-aware simulator (the
  synthetic "real cloud").  Acceptance bar: the sparse plan's oracle
  time within 5% of the dense plan's.
* **hierarchy-decomposed solve scaling** — at N up to 1024, flat SA
  solve vs :func:`repro.core.optimize_rank_order_hierarchical` over the
  recovered tree.  Acceptance bar: ≥3x faster at N=1024 at matching
  ring cost.
* **probe-count scaling** — sparse probes spent vs the dense n(n-1),
  showing the O(n·log n + K²) trajectory.

Emits the harness CSV rows and writes ``BENCH_fabric.json`` at the repo
root so the trajectory is tracked across PRs.

Usage::

    PYTHONPATH=src python benchmarks/fabric_probe.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

import numpy as np

try:
    from .common import write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import write_json

from repro.collective import (
    CollectiveOp,
    SimExecutor,
    apply_permutation,
    chunk,
    compile_op,
    kind_from_op,
)
from repro.core import (
    make_cost_model,
    optimize_rank_order_hierarchical,
    solve,
)
from repro.fabric import (
    cost_matrix,
    infer_hierarchy,
    make_datacenter,
    make_tpu_fleet,
    probe_fabric,
    scramble,
    sparse_probe_fabric,
)
from repro.plan import CollectiveRequest, JobMix, PlanCompiler, SolveBudget

SPARSE_BUDGET = 0.25


def train_mix() -> JobMix:
    return JobMix((
        CollectiveRequest("all-reduce", 64e6),
        CollectiveRequest("all-gather", 8e6, count=2.0),
        CollectiveRequest("reduce-scatter", 8e6, count=2.0),
        CollectiveRequest("all-to-all", 4e6, count=4.0),
    ), name="train")


def sim_total(fab, plan, mix: JobMix) -> float:
    """Referee a compiled plan on the contention-aware simulator."""
    ex = SimExecutor(fab)
    total = 0.0
    for r in mix.requests:
        e = plan.lookup(r.op, r.size_bytes, r.group)
        prog = chunk(apply_permutation(
            compile_op(CollectiveOp(kind_from_op(e.op), e.size_bytes,
                                    e.group), e.algo, **e.algo_kwargs),
            e.perm), e.chunks)
        total += r.count * ex.estimate(prog)
    return total


def bench_plan_quality(smoke: bool, seed: int):
    mix = train_mix()
    budget = SolveBudget(iters=200 if smoke else 600, chains=4)
    fabrics = {
        "datacenter": scramble(make_datacenter(64, seed=0), seed=1)[0],
        "tpu_fleet": scramble(make_tpu_fleet(n_pods=2, pod_shape=(4, 8),
                                             seed=0), seed=1)[0],
    }
    out, rows = {}, []
    for name, fab in fabrics.items():
        comp = PlanCompiler(budget=budget, seed=seed)   # analytic compile
        t0 = time.perf_counter()
        dense_plan = comp.compile(probe_fabric(fab, seed=seed), mix)
        dense_compile_s = time.perf_counter() - t0
        sp = sparse_probe_fabric(fab, budget=SPARSE_BUDGET, seed=seed)
        t0 = time.perf_counter()
        sparse_plan = comp.compile(sp, mix)
        sparse_compile_s = time.perf_counter() - t0
        td = sim_total(fab, dense_plan, mix)
        ts = sim_total(fab, sparse_plan, mix)
        ratio = ts / td
        out[name] = {
            "n": fab.n,
            "probe_fraction": round(float(sp.probe_fraction), 4),
            "probe_budget": SPARSE_BUDGET,
            "hierarchy_tiers": sp.hierarchy.n_tiers,
            "dense_sim_s": float(td),
            "sparse_sim_s": float(ts),
            "sparse_vs_dense_ratio": round(float(ratio), 4),
            "within_5pct": bool(ratio <= 1.05),
            "dense_compile_s": round(dense_compile_s, 3),
            "sparse_compile_s": round(sparse_compile_s, 3),
            "compile_speedup": round(dense_compile_s /
                                     max(sparse_compile_s, 1e-9), 1),
        }
        rows.append({
            "name": f"fabric_sparse_quality_{name}",
            "us": ts * 1e6,
            "derived": f"dense={td * 1e6:.1f}us;ratio={ratio:.3f};"
                       f"probes={sp.probe_fraction * 100:.1f}%"})
    return out, rows


def bench_solve_scaling(smoke: bool, seed: int):
    sizes = [256] if smoke else [256, 1024]
    out, rows = {}, []
    for n in sizes:
        fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
        c = cost_matrix(probe_fabric(fab, seed=seed), 0.0)
        t0 = time.perf_counter()
        h = infer_hierarchy(c)
        infer_s = time.perf_counter() - t0
        model = make_cost_model("ring", c, 0.0)
        t0 = time.perf_counter()
        flat = solve(model, iters=800, chains=8, seed=seed)
        flat_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        hier = optimize_rank_order_hierarchical(c, h, "ring")
        hier_s = time.perf_counter() - t0
        speedup = flat_s / max(hier_s, 1e-9)
        out[str(n)] = {
            "tiers": h.n_tiers,
            "infer_s": round(infer_s, 3),
            "flat_solve_s": round(flat_s, 3),
            "hier_solve_s": round(hier_s, 4),
            "solve_speedup": round(speedup, 1),
            "flat_cost": float(flat.cost),
            "hier_cost": float(hier.cost),
            "cost_ratio_hier_vs_flat": round(hier.cost /
                                             max(flat.cost, 1e-30), 4),
            "geq_3x": bool(speedup >= 3.0),
        }
        rows.append({
            "name": f"fabric_hier_solve_n{n}",
            "us": hier_s * 1e6,
            "derived": f"flat={flat_s * 1e6:.0f}us;speedup={speedup:.1f}x;"
                       f"cost_ratio={hier.cost / max(flat.cost, 1e-30):.3f}"})
    return out, rows


def bench_probe_scaling(smoke: bool, seed: int):
    """Probes spent vs n: with fill_budget=False the structural floor
    (landmarks + intra-cluster + inter reps) grows ~O(n·log n + K²)
    while the dense cost grows n² — the declining fraction is the
    scaling story; the default budget-filling mode pads to the cap."""
    sizes = [64, 128] if smoke else [64, 128, 256, 512, 1024]
    out, rows = {}, []
    for n in sizes:
        fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
        t0 = time.perf_counter()
        sp = sparse_probe_fabric(fab, budget=SPARSE_BUDGET, seed=seed,
                                 fill_budget=False)
        probe_s = time.perf_counter() - t0
        filled = sparse_probe_fabric(fab, budget=SPARSE_BUDGET, seed=seed)
        out[str(n)] = {
            "structural_probes": int(sp.probes_used),
            "filled_probes": int(filled.probes_used),
            "dense_probes": n * (n - 1),
            "structural_fraction": round(float(sp.probe_fraction), 4),
            "filled_fraction": round(float(filled.probe_fraction), 4),
            "probe_s": round(probe_s, 3),
            "tiers": sp.hierarchy.n_tiers,
        }
        rows.append({
            "name": f"fabric_sparse_probes_n{n}",
            "us": probe_s * 1e6,
            "derived": f"structural={sp.probes_used}/{n * (n - 1)}"
                       f"({sp.probe_fraction * 100:.1f}%);"
                       f"filled={filled.probe_fraction * 100:.1f}%"})
    return out, rows


def run(smoke: bool = False, out_path: str = "BENCH_fabric.json",
        seed: int = 0):
    quality, q_rows = bench_plan_quality(smoke, seed)
    solving, s_rows = bench_solve_scaling(smoke, seed)
    probing, p_rows = bench_probe_scaling(smoke, seed)
    results = {
        "benchmark": "fabric_probe",
        "smoke": smoke,
        "sparse_budget": SPARSE_BUDGET,
        "plan_quality": quality,
        "solve_scaling": solving,
        "probe_scaling": probing,
    }
    rows = q_rows + s_rows + p_rows
    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    # acceptance gates (full runs only; smoke sizes are reduced).
    # RuntimeError (not SystemExit): benchmarks/run.py catches Exception
    # per module, so one failed gate must not abort the whole suite.
    if not smoke:
        bad = [k for k, v in quality.items() if not v["within_5pct"]]
        if bad:
            raise RuntimeError(f"sparse plan quality exceeded 5% on: {bad}")
        if not solving.get("1024", {}).get("geq_3x", False):
            raise RuntimeError("hierarchy-decomposed solve < 3x at N=1024")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: reduced sizes and solver budget")
    ap.add_argument("--out", default="BENCH_fabric.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
