"""Shared benchmark scaffolding: fabrics, CSV emission, provenance."""

from __future__ import annotations

import datetime
import json
import os
import subprocess
import sys
import time
from typing import Any, Dict, List

import numpy as np

from repro.core import (
    cost_matrix,
    make_datacenter,
    probe_fabric,
    scramble,
)

#: Benchmarks run at a reduced node count by default so the whole suite
#: finishes in minutes on CPU; pass full=True for the paper's 512.
N_FAST = 64
N_FULL = 512


def std_fabric(n: int, seed: int = 0):
    """The scrambled multi-tenant datacenter every benchmark shares."""
    fab, _ = scramble(make_datacenter(n, seed=seed), seed=seed + 1)
    return fab


def probed_cost(fab, size_bytes: float = 0.0, seed: int = 0) -> np.ndarray:
    return cost_matrix(probe_fabric(fab, seed=seed), size_bytes)


def spearman(x, y) -> float:
    rx = np.argsort(np.argsort(np.asarray(x)))
    ry = np.argsort(np.argsort(np.asarray(y)))
    return float(np.corrcoef(rx, ry)[0, 1])


def emit(rows: List[Dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV rows (harness contract)."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.3f},{r.get('derived', '')}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def run_meta(seed: int = 0) -> Dict[str, Any]:
    """Provenance stamp for committed ``BENCH_*.json`` artifacts.

    Records everything needed to reproduce (or distrust) a committed
    number: the git sha the benchmark ran at, library versions, the
    seed, and a UTC timestamp.  Never raises — a benchmark must not
    fail because provenance is unavailable (e.g. no git in CI).
    """
    meta: Dict[str, Any] = {
        "seed": seed,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "python": sys.version.split()[0],
        "numpy": np.__version__,
    }
    try:
        import jax
        meta["jax"] = jax.__version__
    except Exception:
        meta["jax"] = None
    try:
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo,
            capture_output=True, text=True, timeout=10).stdout.strip()
        meta["git_sha"] = sha or None
    except Exception:
        meta["git_sha"] = None
    return meta


def write_json(path: str, payload: Dict[str, Any], seed: int = 0) -> None:
    """Write a benchmark payload stamped with :func:`run_meta`."""
    payload = dict(payload)
    payload["meta"] = run_meta(seed)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}", file=sys.stderr)
