"""Shared benchmark scaffolding: fabrics, CSV emission, Spearman."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import (
    cost_matrix,
    make_datacenter,
    probe_fabric,
    scramble,
)

#: Benchmarks run at a reduced node count by default so the whole suite
#: finishes in minutes on CPU; pass full=True for the paper's 512.
N_FAST = 64
N_FULL = 512


def std_fabric(n: int, seed: int = 0):
    """The scrambled multi-tenant datacenter every benchmark shares."""
    fab, _ = scramble(make_datacenter(n, seed=seed), seed=seed + 1)
    return fab


def probed_cost(fab, size_bytes: float = 0.0, seed: int = 0) -> np.ndarray:
    return cost_matrix(probe_fabric(fab, seed=seed), size_bytes)


def spearman(x, y) -> float:
    rx = np.argsort(np.argsort(np.asarray(x)))
    ry = np.argsort(np.argsort(np.asarray(y)))
    return float(np.corrcoef(rx, ry)[0, 1])


def emit(rows: List[Dict]) -> None:
    """Print ``name,us_per_call,derived`` CSV rows (harness contract)."""
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.3f},{r.get('derived', '')}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
