"""Paper Fig. 2: pairwise RTT probe heatmap on 64 nodes.

Paper: 64 Azure F64v2 VMs; pairwise RTT ranges sub-10 us to hundreds of
us, visibly structured by the hidden hierarchy.  We report the probed
latency statistics + a locality-structure check (intra-rack vs cross-agg
ratio recovered from the *scrambled* fabric through probing alone).
"""

from __future__ import annotations

import numpy as np

from repro.core import make_datacenter, probe_fabric, scramble

from .common import Timer, emit


def run(n_nodes: int = 64, seed: int = 0):
    fab = make_datacenter(n_nodes, seed=seed)
    scr, hidden = scramble(fab, seed=seed + 1)
    with Timer() as t:
        pr = probe_fabric(scr, seed=seed + 2)
    lat_us = pr.lat[~np.eye(n_nodes, dtype=bool)] * 1e6
    # structure check: probed costs must recover true locality ordering
    inv = np.argsort(hidden)
    recovered = pr.lat[np.ix_(inv, inv)]
    intra = recovered[0, 1] * 1e6          # same rack in true layout
    cross = recovered[0, n_nodes - 1] * 1e6
    rows = [{
        "name": "fig2_pairwise_probe",
        "us_per_call": t.s * 1e6,
        "derived": (
            f"n={n_nodes};min_us={lat_us.min():.1f};p50_us={np.median(lat_us):.1f};"
            f"max_us={lat_us.max():.1f};intra_rack_us={intra:.1f};"
            f"cross_agg_us={cross:.1f};ratio={cross / intra:.1f}x"
        ),
    }]
    emit(rows)
    return {"lat_us": lat_us}


if __name__ == "__main__":
    run()
