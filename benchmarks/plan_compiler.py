"""Plan-compiler benchmark: compile time, cache-hit latency, plan quality.

On two synthetic fabrics (a scrambled multi-tenant datacenter and a
scrambled two-pod TPU fleet) this benchmark measures, for a
training-shaped collective mix:

* **cold compile** — wall seconds for ``PlanningService.request`` with an
  empty cache (fingerprint + per-entry joint (algo, chunks, perm) search
  against the contention-aware simulator + N-D mesh plan);
* **warm hit** — the same request served from the fingerprint-keyed
  cache after a fresh (differently-seeded) probe of the same fabric; the
  acceptance bar is >= 100x faster than the cold compile;
* **plan quality** — the plan's simulated completion time for one pass
  over the mix vs the best *single fixed* backend policy (one algorithm
  family at identity order for every op — the strongest thing a
  topology-blind backend can do), summed over the job's message-size
  histogram.

Emits the harness CSV rows and writes ``BENCH_plan_compiler.json`` at
the repo root so the trajectory is tracked across PRs.

Usage::

    PYTHONPATH=src python benchmarks/plan_compiler.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

try:
    from .common import write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import write_json

from repro.core import make_datacenter, make_tpu_fleet, probe_fabric, scramble
from repro.plan import (
    CollectiveRequest,
    JobMix,
    PlanCache,
    PlanCompiler,
    PlanningService,
    SolveBudget,
)

#: One backend family at identity order per op — what a topology-blind
#: runtime pins globally.  all-to-all has a single schedule, so every
#: policy shares it; the comparison isolates algorithm + order choice.
FIXED_POLICIES = {
    "ring": {"all-reduce": "ring", "all-gather": "ring_all_gather",
             "reduce-scatter": "ring_all_gather", "all-to-all": "all_to_all"},
    "ring_sequential": {"all-reduce": "ring_sequential",
                        "all-gather": "ring_all_gather",
                        "reduce-scatter": "ring_all_gather",
                        "all-to-all": "all_to_all"},
    "tree": {"all-reduce": "double_binary_tree",
             "all-gather": "ring_all_gather",
             "reduce-scatter": "ring_all_gather", "all-to-all": "all_to_all"},
    "halving_doubling": {"all-reduce": "halving_doubling",
                         "all-gather": "recursive_doubling",
                         "reduce-scatter": "recursive_doubling",
                         "all-to-all": "all_to_all"},
    "bcube": {"all-reduce": "bcube", "all-gather": "recursive_doubling",
              "reduce-scatter": "recursive_doubling",
              "all-to-all": "all_to_all"},
}


def train_mix() -> JobMix:
    """A training step's histogram: big gradient all-reduce, per-layer
    TP all-gather/reduce-scatter pair, EP all-to-alls, small control ops."""
    return JobMix((
        CollectiveRequest("all-reduce", 64e6),
        CollectiveRequest("all-reduce", 256e3, count=4.0),
        CollectiveRequest("all-gather", 8e6, count=2.0),
        CollectiveRequest("reduce-scatter", 8e6, count=2.0),
        CollectiveRequest("all-to-all", 4e6, count=4.0),
    ), name="train")


def make_fabrics(smoke: bool):
    n_dc = 16 if smoke else 32
    pods = 1 if smoke else 2
    dc, _ = scramble(make_datacenter(n_dc, seed=0), seed=1)
    tpu, _ = scramble(make_tpu_fleet(n_pods=pods, pod_shape=(4, 4), seed=0),
                      seed=1)
    return {"datacenter": dc, "tpu_fleet": tpu}


def fixed_baselines(plan, mix: JobMix):
    """Total identity-order seconds per fixed policy over the mix."""
    totals = {}
    for policy, op_algo in FIXED_POLICIES.items():
        total, ok = 0.0, True
        for r in mix.requests:
            entry = plan.lookup(r.op, r.size_bytes, r.group)
            algo = op_algo[r.op]
            if entry is None or algo not in entry.identity_times:
                ok = False  # infeasible at this n (e.g. non-pow2 HD)
                break
            total += r.count * entry.identity_times[algo]
        if ok:
            totals[policy] = total
    return totals


def run(smoke: bool = False, out_path: str = "BENCH_plan_compiler.json",
        seed: int = 0):
    mix = train_mix()
    budget = SolveBudget(iters=200 if smoke else 600, chains=8)
    rows = []
    results = {
        "benchmark": "plan_compiler",
        "smoke": smoke,
        "mix": [[r.op, r.size_bytes, r.count] for r in mix.requests],
        "budget": {"iters": budget.iters, "chains": budget.chains},
        "fabrics": {},
    }

    for name, fab in make_fabrics(smoke).items():
        service = PlanningService(PlanCompiler(fabric=fab, budget=budget,
                                               seed=seed), PlanCache())
        probe = probe_fabric(fab, seed=seed)
        t0 = time.perf_counter()
        plan = service.request(probe, mix)
        cold_s = time.perf_counter() - t0

        # warm path: fresh probes of the same fabric must hit the cache
        warm_s = float("inf")
        for s in range(1, 6):
            reprobe = probe_fabric(fab, seed=seed + s)
            t0 = time.perf_counter()
            warm_plan = service.request(reprobe, mix)
            warm_s = min(warm_s, time.perf_counter() - t0)
            assert warm_plan is plan, "warm request missed the plan cache"
        assert service.stats["compiles"] == 1, service.stats
        service.close()

        plan_total = plan.total_time(mix)
        baselines = fixed_baselines(plan, mix)
        best_policy = min(baselines, key=baselines.get)
        best_fixed = baselines[best_policy]
        entry_rows = [
            {"op": e.op, "bucket": e.bucket, "algo": e.algo,
             "chunks": e.chunks,
             "expected_time_s": float(e.expected_time),
             "best_identity_time_s": float(e.best_identity_time)}
            for e in plan.entries.values()
        ]
        results["fabrics"][name] = {
            "n": fab.n,
            "fingerprint": plan.fingerprint.digest,
            "cold_compile_s": round(float(cold_s), 4),
            "warm_hit_s": round(float(warm_s), 6),
            "cache_hit_speedup": round(float(cold_s) / max(warm_s, 1e-9), 1),
            "cache_hit_geq_100x": bool(cold_s / max(warm_s, 1e-9) >= 100.0),
            "plan_total_s": float(plan_total),
            "fixed_policy_totals_s": {k: float(v) for k, v in baselines.items()},
            "best_fixed_policy": best_policy,
            "best_fixed_total_s": float(best_fixed),
            "speedup_vs_best_fixed": round(float(best_fixed) /
                                           max(float(plan_total), 1e-30), 3),
            "beats_best_fixed": bool(plan_total < best_fixed),
            "entries": entry_rows,
        }
        rows.append({
            "name": f"plan_compiler_cold_{name}", "us": cold_s * 1e6,
            "derived": f"n={fab.n};entries={len(plan.entries)}"})
        rows.append({
            "name": f"plan_compiler_warm_{name}", "us": warm_s * 1e6,
            "derived": f"hit_speedup={cold_s / max(warm_s, 1e-9):.0f}x"})
        rows.append({
            "name": f"plan_compiler_quality_{name}",
            "us": plan_total * 1e6,
            "derived": f"best_fixed={best_policy}:"
                       f"{best_fixed * 1e6:.1f}us;"
                       f"speedup={best_fixed / max(plan_total, 1e-30):.2f}x"})

    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small fabrics, reduced solver budget")
    ap.add_argument("--out", default="BENCH_plan_compiler.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
