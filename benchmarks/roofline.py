"""Roofline table builder: reads dry-run artifacts -> EXPERIMENTS §Roofline.

Also quantifies the paper's contribution at the mesh level: per-axis ring
cost under identity vs solved device order on the simulated 512-chip
fleet (the 'topology-aware collective term').
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

import numpy as np

from .common import Timer, emit

ARTIFACT_DIR = "experiments/dryrun_baseline"


def load_cells(directory: str = ARTIFACT_DIR, mesh: str = "16x16") -> List[Dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(directory, "*.json"))):
        r = json.load(open(p))
        if r.get("mesh") == mesh:
            cells.append(r)
    return cells


def table(directory: str = ARTIFACT_DIR) -> List[Dict]:
    rows = []
    for r in load_cells(directory):
        if r["status"] != "ok":
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "status": r["status"],
                "reason": r.get("reason", r.get("error", ""))[:70],
            })
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "model_flops": rf["model_flops"], "hlo_flops": rf["hlo_flops"],
            "useful_frac": rf["useful_flops_frac"],
            "live_gb": r["memory"]["live_bytes_per_device"] / 1e9,
            "fits": r["memory"]["fits_16GB"],
            "source": rf["source"],
        })
    return rows


def mesh_reorder_gain(seed: int = 0) -> Dict[str, float]:
    """Collective-term improvement from the solved device order on a
    simulated 2-pod fleet (fragmented ICI + loaded DCN)."""
    from repro.core import (
        cost_matrix,
        make_tpu_fleet,
        mesh_total_cost,
        optimize_mesh_assignment,
        probe_fabric,
        scramble,
    )

    fleet = make_tpu_fleet(n_pods=2, pod_shape=(16, 16),
                           fragmentation=0.15, seed=seed)
    scr, _ = scramble(fleet, seed=seed + 1)
    c = cost_matrix(probe_fabric(scr, seed=seed + 2), 4e6)
    plan = optimize_mesh_assignment(c, (2, 16, 16), ("pod", "data", "model"))
    return {
        "baseline_cost": plan.baseline_cost,
        "optimized_cost": plan.cost,
        "gain": plan.baseline_cost / plan.cost,
        "per_axis": plan.per_axis,
    }


def run(directory: str = ARTIFACT_DIR):
    rows = []
    t = table(directory)
    ok = [r for r in t if r["status"] == "ok"]
    for r in ok:
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}",
            "us_per_call": r["compute_s"] * 1e6,
            "derived": (
                f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
                f"collective_s={r['collective_s']:.4f};dominant={r['dominant']};"
                f"useful_frac={r['useful_frac']:.2f};live_gb={r['live_gb']:.1f}"
            ),
        })
    if not ok:
        rows.append({"name": "roofline_no_artifacts", "us_per_call": 0,
                     "derived": f"run `python -m repro.launch.dryrun --all` first"})
    with Timer() as tm:
        gain = mesh_reorder_gain()
    rows.append({
        "name": "mesh_reorder_collective_gain",
        "us_per_call": tm.s * 1e6,
        "derived": (
            f"identity_cost={gain['baseline_cost']:.5f};"
            f"optimized_cost={gain['optimized_cost']:.5f};"
            f"gain={gain['gain']:.2f}x"
        ),
    })
    emit(rows)
    return {"table": t, "mesh_gain": gain}


if __name__ == "__main__":
    run()
