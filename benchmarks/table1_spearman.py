"""Paper Table I: Spearman correlation of cost model vs actual time.

Paper methodology (§V-B): 10 rank orders at ~10i-th cost percentiles from
the solver, correlate predicted vs measured (Gloo/OpenMPI ring, 100 MB,
64 nodes; reported rho = 0.58-0.94).  Our 'actual' is the contention-
aware flow-level simulator, which models what the latency-only cost model
does not — so the correlation is informative, not circular.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    CollectiveSimulator,
    make_cost_model,
    percentile_orders,
    solve,
    solve_worst,
)

from .common import Timer, emit, probed_cost, spearman, std_fabric


def run(n_nodes: int = 64, size: float = 100e6, seed: int = 0):
    fab = std_fabric(n_nodes, seed=seed)
    c = probed_cost(fab, 0.0, seed=seed)
    rows = []
    results = {}
    for algo in ("ring", "halving_doubling"):
        m = make_cost_model(algo, c, 0.0)
        with Timer() as t:
            best = solve(m, iters=800, seed=0)
            worst = solve_worst(m, iters=800, seed=0)
            orders = percentile_orders(m, best.perm, worst.perm, k=10, seed=0)
            pred = m.cost_batch(np.stack(orders))
            sim = CollectiveSimulator(fab, algo, size)
            act = sim.run_many(orders)
        rho = spearman(pred, act)
        results[algo] = rho
        rows.append({
            "name": f"table1_spearman_{algo}",
            "us_per_call": t.s * 1e6,
            "derived": f"rho={rho:.3f};paper_range=0.58-0.94",
        })
    emit(rows)
    return results


if __name__ == "__main__":
    run()
