"""Paper Fig. 1: allreduce time distribution over random rank orders.

Paper: 500 random orders of 512 VMs, ring, 100 MB -> 330-3400 ms,
mean 1012 ms, std 418 ms.  We reproduce the *shape* of the claim on the
simulated fabric: a wide, unpredictable distribution whose best tail is
far from its worst — the motivation for solving for an order instead of
taking whatever the provider hands out.
"""

from __future__ import annotations

import numpy as np

from repro.core import CollectiveSimulator

from .common import N_FAST, Timer, emit, std_fabric


def run(n_nodes: int = N_FAST, n_orders: int = 100, size: float = 100e6,
        seed: int = 0):
    fab = std_fabric(n_nodes, seed=seed)
    rng = np.random.default_rng(seed)
    sim = CollectiveSimulator(fab, "ring", size)
    with Timer() as t:
        times = sim.run_many([rng.permutation(n_nodes) for _ in range(n_orders)])
    ms = times * 1e3
    rows = [{
        "name": "fig1_ring_random_orders",
        "us_per_call": t.s * 1e6 / n_orders,
        "derived": (
            f"n={n_nodes};orders={n_orders};min_ms={ms.min():.1f};"
            f"mean_ms={ms.mean():.1f};std_ms={ms.std():.1f};"
            f"max_ms={ms.max():.1f};spread={ms.max() / ms.min():.2f}x"
        ),
    }]
    emit(rows)
    return {"ms": ms}


if __name__ == "__main__":
    run()
