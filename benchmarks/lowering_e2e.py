"""Generalized lowering benchmark: certify everything, execute for real.

Three gates, one artifact (``BENCH_lowering.json``):

* **bisimulation matrix** — every registered builder x kind x
  n in {4, 8, 16, 64} lowers to a ppermute schedule and bisimulates
  against its IR with zero mismatches; per-program lower+certify cost
  is tracked in µs so the translation-validation gate stays cheap
  relative to a plan compile;
* **mutant kill floor** — the seeded lowering-mutant batch
  (:func:`repro.analysis.lowering_kill_rate`) must be killed at
  >= ``KILL_FLOOR`` — the validator's teeth, pinned so a future
  refactor can't quietly blunt them;
* **end-to-end execution** — ring (control) plus the newly-lowerable
  halving_doubling and double_binary_tree run planned-vs-identity rank
  orders through real ``ppermute`` on a host-local 8-device mesh in a
  subprocess (``XLA_FLAGS`` device-count pinning must precede jax
  init), numeric postconditions checked, orders priced with
  ``SimExecutor`` for the simulated speedup.

``ring_sequential`` is certified in the matrix but excluded from
numeric execution: its second lap re-reduces circulating partials —
sound in the idempotent contributor-set domain and as a pricing regime
model, but numerically double-counting (see its builder docstring).

Usage::

    PYTHONPATH=src python benchmarks/lowering_e2e.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

import numpy as np

try:
    from .common import std_fabric, write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import std_fabric, write_json

from repro.analysis import bisimulate, lowering_kill_rate
from repro.collective import (
    CollectiveOp,
    JaxExecutor,
    SimExecutor,
    compile_op,
    get_builder,
    registered_builders,
)
from repro.collective.builders import candidates
from repro.collective.passes import apply_permutation

KILL_FLOOR = 0.95
SIZE = 1 << 20

#: numerically executed algorithms: ring is the legacy control, the
#: other two only became executable with the generalized lowering
E2E_ALGOS = ("ring", "halving_doubling", "double_binary_tree")
E2E_N = 8

_E2E_SCRIPT = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import Mesh

from repro.analysis import require_certified
from repro.collective import CollectiveOp, JaxExecutor, compile_op
from repro.collective.passes import apply_permutation
from repro.kernels.schedule_runner import check_postcondition, run_schedule

cfg = json.load(open(sys.argv[1]))
n = cfg["n"]
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
ex = JaxExecutor()
out = {}
for algo, perms in cfg["cases"].items():
    out[algo] = {}
    for label, perm in perms.items():
        op = CollectiveOp(kind="allreduce", size_bytes=cfg["size_bytes"],
                          group=tuple(range(n)))
        prog = apply_permutation(compile_op(op, algo), perm)
        sched = ex.lower_schedule(prog)
        require_certified(prog, sched)
        d = cfg["size_bytes"] // 4
        x = np.arange(n * d, dtype=np.float32).reshape(n, d) / (n * d)
        t0 = time.time()
        res = np.asarray(run_schedule(x, mesh, "x", sched,
                                      use_pallas_add=False))
        t_first = time.time() - t0
        t0 = time.time()
        res = np.asarray(run_schedule(x, mesh, "x", sched,
                                      use_pallas_add=False))
        t_steady = time.time() - t0
        bad = check_postcondition(sched, x, res)
        out[algo][label] = {"postcondition_ok": not bad,
                            "mismatches": bad[:4],
                            "first_call_ms": t_first * 1e3,
                            "steady_ms": t_steady * 1e3}
json.dump(out, open(cfg["out"], "w"))
print("E2E DONE")
"""


def _bisim_matrix(n_list) -> tuple:
    rows, matrix, n_bad = [], [], 0
    for algo in sorted(registered_builders()):
        b = get_builder(algo)
        for kind in b.kinds:
            for n in n_list:
                for a, akw in candidates(kind, n):
                    if a != algo:
                        continue
                    op = CollectiveOp(kind=kind, size_bytes=SIZE,
                                      group=tuple(range(n)))
                    prog = compile_op(op, algo, **akw)
                    t0 = time.time()
                    findings, stats = bisimulate(prog)
                    dt = time.time() - t0
                    errs = [f for f in findings if f.severity == "error"]
                    ok = stats["bisimilar"] and not errs
                    n_bad += 0 if ok else 1
                    matrix.append({"algorithm": algo, "kind": kind,
                                   "n": n, "ok": ok,
                                   "n_steps": stats["n_steps"],
                                   "n_transfers": stats["n_transfers"],
                                   "certify_us": round(dt * 1e6, 1)})
        n_max = max(n_list)
        per = [m for m in matrix if m["algorithm"] == algo]
        rows.append({"name": f"lowering_bisim_{algo}",
                     "us": max(m["certify_us"] for m in per),
                     "derived": f"programs={len(per)};"
                                f"ok={sum(m['ok'] for m in per)};"
                                f"n_max={n_max}"})
    return rows, matrix, n_bad


def _kill_rate(n: int = 8, seed: int = 0) -> tuple:
    progs = []
    for algo in sorted(registered_builders()):
        b = get_builder(algo)
        for kind in b.kinds:
            for a, akw in candidates(kind, n):
                if a == algo:
                    op = CollectiveOp(kind=kind, size_bytes=SIZE,
                                      group=tuple(range(n)))
                    progs.append(compile_op(op, algo, **akw))
    t0 = time.time()
    rate, survivors = lowering_kill_rate(progs, seed=seed)
    return rate, survivors, len(progs), time.time() - t0


def _plan_orders(seed: int = 0) -> dict:
    """Planned (solver) vs identity rank order per e2e algorithm."""
    from repro.core import make_cost_model, solve

    try:
        from .common import probed_cost
    except ImportError:
        from common import probed_cost

    fab = std_fabric(E2E_N, seed=seed)
    c = probed_cost(fab, SIZE, seed=seed)
    sim = SimExecutor(fab)
    orders = {}
    for algo in E2E_ALGOS:
        m = make_cost_model(get_builder(algo).cost_model, c, SIZE)
        planned = [int(x) for x in solve(m, iters=300, seed=seed).perm]
        identity = list(range(E2E_N))
        op = CollectiveOp(kind="allreduce", size_bytes=SIZE,
                          group=tuple(range(E2E_N)))
        t_id = sim.estimate(apply_permutation(compile_op(op, algo), identity))
        t_pl = sim.estimate(apply_permutation(compile_op(op, algo), planned))
        orders[algo] = {"identity": identity, "planned": planned,
                        "sim_identity_s": float(t_id),
                        "sim_planned_s": float(t_pl),
                        "sim_speedup": float(t_id / max(t_pl, 1e-30))}
    return orders


def _run_e2e(orders: dict, workdir: str) -> dict:
    cfg_path = os.path.join(workdir, "lowering_e2e_cfg.json")
    out_path = os.path.join(workdir, "lowering_e2e_out.json")
    script = os.path.join(workdir, "lowering_e2e_run.py")
    with open(script, "w") as f:
        f.write(_E2E_SCRIPT)
    with open(cfg_path, "w") as f:
        json.dump({"n": E2E_N, "size_bytes": 1 << 12, "out": out_path,
                   "cases": {a: {"identity": o["identity"],
                                 "planned": o["planned"]}
                             for a, o in orders.items()}}, f)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, script, cfg_path], cwd=repo,
                          env=env, capture_output=True, text=True,
                          timeout=900)
    if proc.returncode != 0 or "E2E DONE" not in proc.stdout:
        raise RuntimeError(f"e2e subprocess failed: {proc.stderr[-2000:]}")
    with open(out_path) as f:
        return json.load(f)


def run(smoke: bool = False, out_path: str = "BENCH_lowering.json",
        seed: int = 0):
    n_list = (4, 8, 16) if smoke else (4, 8, 16, 64)
    rows, matrix, n_bad = _bisim_matrix(n_list)

    rate, survivors, n_progs, kill_dt = _kill_rate(seed=seed)
    rows.append({"name": "lowering_mutant_kill", "us": kill_dt * 1e6,
                 "derived": f"rate={rate:.3f};programs={n_progs};"
                            f"floor={KILL_FLOOR}"})

    orders = _plan_orders(seed=seed)
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        e2e = _run_e2e(orders, td)
    e2e_ok = all(v["postcondition_ok"]
                 for per in e2e.values() for v in per.values())
    for algo, per in e2e.items():
        rows.append({
            "name": f"lowering_e2e_{algo}",
            "us": per["planned"]["steady_ms"] * 1e3,
            "derived": f"post_ok={all(v['postcondition_ok'] for v in per.values())};"
                       f"sim_speedup={orders[algo]['sim_speedup']:.2f}"})

    ok = n_bad == 0 and rate >= KILL_FLOOR and e2e_ok
    rows.append({"name": "lowering_gate", "us": 0.0,
                 "derived": f"bisim_bad={n_bad};kill={rate:.3f};"
                            f"e2e_ok={e2e_ok};{'OK' if ok else 'FAIL'}"})

    results = {
        "benchmark": "lowering_e2e",
        "smoke": smoke,
        "n_list": list(n_list),
        "bisim": {"n_programs": len(matrix), "n_bad": n_bad,
                  "matrix": matrix},
        "mutants": {"kill_rate": rate, "floor": KILL_FLOOR,
                    "n_programs": n_progs,
                    "survivors": [list(s) for s in survivors]},
        "e2e": {"n": E2E_N,
                "excluded": {"ring_sequential":
                             "regime model; numerically double-counts"},
                "orders": {a: {k: v for k, v in o.items()
                               if k != "identity"}
                           for a, o in orders.items()},
                "runs": e2e},
        "gate_ok": bool(ok),
    }
    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    if not ok:
        raise RuntimeError(
            f"lowering gate failed: bisim_bad={n_bad} kill={rate:.3f} "
            f"e2e_ok={e2e_ok}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: drop the n=64 bisim column")
    ap.add_argument("--out", default="BENCH_lowering.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
