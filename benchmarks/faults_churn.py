"""Fault/churn benchmark: recovery latency and plan quality under churn.

Three sections, all through the public ``repro`` surface:

* **elastic churn** — a seeded :class:`repro.faults.FaultSchedule`
  preempts 25% of the nodes mid-session and rejoins them later; the
  session's ``on_node_leave`` / ``on_node_join`` warm-recover the plan.
  Measured: recovery latency per membership event, ladder rungs used,
  and — refereed on the contention-aware simulator over the surviving
  fabric — the recovered planned order vs identity order per entry.
  Acceptance bar: recovery never serves an order worse than identity.
* **warm vs cold at N=256** — preempt 25% of a 256-node fabric and
  compare the warm-start ladder recovery (restrict + budgeted
  refinement) against a cold ``PlanCompiler.compile`` at the surviving
  size.  Acceptance bar: warm recovery ≥ 5x faster.
* **monitor ladder** — a storm of injected probe timeouts drives the
  session monitor through healthy → degraded → halted; recorded: tick
  outcomes, health transitions, and that no exception escaped the
  monitor thread.

Emits the harness CSV rows and writes ``BENCH_faults.json`` at the repo
root so the trajectory is tracked across PRs.

Usage::

    PYTHONPATH=src python benchmarks/faults_churn.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

import numpy as np

try:
    from .common import write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import write_json

from repro.collective import (
    CollectiveOp,
    SimExecutor,
    apply_permutation,
    chunk,
    compile_op,
    kind_from_op,
)
from repro.fabric import make_datacenter, probe_fabric, scramble
from repro.faults import FaultEvent, FaultSchedule, FaultyFabric, recover_plan
from repro.plan import (
    CollectiveRequest,
    JobMix,
    PlanCompiler,
    SolveBudget,
)
from repro.session import Session, SessionConfig

PREEMPT_FRAC = 0.25


def churn_mix() -> JobMix:
    return JobMix((
        CollectiveRequest("all-reduce", 16e6),
        CollectiveRequest("all-gather", 2e6, count=2.0),
        CollectiveRequest("reduce-scatter", 2e6, count=2.0),
    ), name="churn")


def _entry_sim_seconds(fab, entry, perm) -> float:
    """Sim-refereed time of ``entry`` run in ``perm`` order on ``fab``."""
    prog = chunk(apply_permutation(
        compile_op(CollectiveOp(kind_from_op(entry.op), entry.size_bytes,
                                entry.group), entry.algo,
                   **entry.algo_kwargs), perm), entry.chunks)
    return SimExecutor(fab).estimate(prog)


def referee_vs_identity(fab, plan) -> dict:
    """Per-entry sim ratio planned/identity over the surviving fabric."""
    ratios = {}
    for key, e in plan.entries.items():
        planned = _entry_sim_seconds(fab, e, e.perm)
        ident = _entry_sim_seconds(fab, e, tuple(e.group))
        ratios[f"{key[0]}@{key[1]}"] = round(planned / max(ident, 1e-30), 4)
    return ratios


def bench_churn(smoke: bool, seed: int):
    """25% preemption mid-session + rejoin; session recovers via ladder."""
    n = 32 if smoke else 64
    ticks = 8
    fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
    schedule = FaultSchedule.generate(
        n, ticks=ticks, seed=seed, preempt_frac=PREEMPT_FRAC,
        timeout_rate=0.0, drop_rate=0.0, nan_rate=0.0)
    faulty = FaultyFabric(fab, schedule)
    cfg = SessionConfig.from_dict({
        "probe": {"n_probes": 4},
        "solver": {"budget": {"iters": 200 if smoke else 400, "chains": 4}},
    })
    out = {"n": n, "preempt_frac": PREEMPT_FRAC,
           "schedule_seed": seed, "events": []}
    rows = []
    with Session(cfg) as s:
        s.attach(fab)
        s.plan(churn_mix())
        for _ in range(ticks):
            for ev in faulty.advance():
                base_ids = [b for b in ev.nodes if b is not None]
                t0 = time.perf_counter()
                if ev.kind == "node_preempt":
                    alive = s.alive
                    local = [alive.index(b) for b in base_ids if b in alive]
                    plan = s.on_node_leave(local)
                else:
                    plan = s.on_node_join([b for b in base_ids
                                           if b not in s.alive])
                latency_ms = (time.perf_counter() - t0) * 1e3
                assert plan is not None, "recovery degraded to plan-less"
                for e in plan.entries.values():
                    assert sorted(e.perm) == list(e.group), \
                        f"invalid recovered perm for {e.op}"
                sub_fab = fab.subset(s.alive)
                ratios = referee_vs_identity(sub_fab, plan)
                rungs = sorted(set(plan.meta.get("rungs", {}).values()))
                out["events"].append({
                    "kind": ev.kind, "nodes": list(ev.nodes),
                    "survivors": len(s.alive),
                    "recovery_ms": round(latency_ms, 2),
                    "rungs": rungs,
                    "sim_ratio_vs_identity": ratios,
                    "max_ratio": max(ratios.values()),
                })
                rows.append({
                    "name": f"faults_{ev.kind}_n{len(s.alive)}",
                    "us": latency_ms * 1e3,
                    "derived": f"max_ratio={max(ratios.values()):.3f};"
                               f"rungs={'/'.join(rungs)}"})
        out["health"] = s.health
    out["max_ratio_overall"] = max(
        (e["max_ratio"] for e in out["events"]), default=0.0)
    out["never_worse_than_identity"] = bool(
        out["max_ratio_overall"] <= 1.0 + 1e-9)
    return out, rows


def bench_warm_vs_cold(smoke: bool, seed: int):
    """Warm ladder recovery vs cold compile after losing 25% of N=256."""
    n = 64 if smoke else 256
    budget = SolveBudget(iters=200 if smoke else 600, chains=4)
    fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
    probe = probe_fabric(fab, n_probes=4, seed=seed)
    mix = churn_mix()
    comp = PlanCompiler(budget=budget, seed=seed)
    plan = comp.compile(probe, mix)

    rng = np.random.default_rng(seed)
    k = int(round(PREEMPT_FRAC * n))
    dead = set(int(x) for x in rng.choice(n, size=k, replace=False))
    survivors = [i for i in range(n) if i not in dead]
    o2n = {old: new for new, old in enumerate(survivors)}
    idx = np.ix_(survivors, survivors)
    sub_lat, sub_bw = probe.lat[idx], probe.bw[idx]

    t0 = time.perf_counter()
    warm_plan, rungs = recover_plan(plan, o2n, sub_lat, sub_bw, seed=seed)
    warm_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    cold_plan = comp.compile(
        probe_fabric(fab.subset(survivors), n_probes=4, seed=seed), mix)
    cold_s = time.perf_counter() - t0
    speedup = cold_s / max(warm_s, 1e-9)

    # quality check: warm recovery must stay in the cold plan's league
    # (and beat identity, per the ladder guard) on its own cost models
    quality = {}
    for key, e in warm_plan.entries.items():
        ck = (key[0], key[1], key[2])
        ce = cold_plan.entries.get(ck)
        quality[f"{key[0]}@{key[1]}"] = {
            "warm_expected": float(e.expected_time),
            "cold_expected": None if ce is None
            else float(ce.expected_time),
            "identity": float(e.best_identity_time),
        }
    out = {
        "n": n, "survivors": len(survivors),
        "preempt_frac": PREEMPT_FRAC,
        "warm_recover_s": round(warm_s, 4),
        "cold_compile_s": round(cold_s, 3),
        "warm_speedup_x": round(speedup, 1),
        "geq_5x": bool(speedup >= 5.0),
        "rungs": sorted(set(rungs.values())),
        "quality": quality,
    }
    row = {"name": f"faults_warm_recover_n{n}",
           "us": warm_s * 1e6,
           "derived": f"cold={cold_s * 1e6:.0f}us;speedup={speedup:.1f}x"}
    return out, [row]


def bench_monitor_ladder(smoke: bool, seed: int):
    """Probe-timeout storm: healthy → degraded → halted, no escape."""
    n = 16
    fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
    # a solid wall of timeouts from tick 1: every poll fails
    schedule = FaultSchedule(events=tuple(
        FaultEvent("probe_timeout", t) for t in range(0, 64)), seed=seed)
    faulty = FaultyFabric(fab, schedule, tick=1)
    cfg = SessionConfig.from_dict({
        "probe": {"n_probes": 2},
        "solver": {"budget": {"iters": 100, "chains": 1}},
        "retry": {"max_retries": 0, "base_delay_s": 0.001,
                  "max_delay_s": 0.01, "failure_threshold": 2,
                  "halt_threshold": 5},
    })
    transitions = []
    with Session(cfg) as s:
        s.attach(fab)
        s.plan(churn_mix())
        s.on("degraded", lambda sess, **info: transitions.append(
            (info.get("state"), "degraded_hook")))
        s.on("recovered", lambda sess, **info: transitions.append(
            ("healthy", "recovered_hook")))

        def poll():
            faulty.advance()
            return faulty.cost_matrix(16e6)   # raises ProbeTimeout

        t = s.monitor(poll=poll, interval_s=0.005)
        deadline = time.time() + (5.0 if smoke else 10.0)
        while t.is_alive() and time.time() < deadline:
            time.sleep(0.01)
        halted = s.health == "halted"
        identity_pinned = all(e.perm == e.group
                              for e in s.planned.entries.values())
        thread_exited = not t.is_alive()
    out = {
        "n": n,
        "final_health": "halted" if halted else s.health,
        "transitions": transitions,
        "identity_pinned": identity_pinned,
        "monitor_thread_exited_cleanly": thread_exited,
        "no_escape": thread_exited,   # an escaping exception kills the
                                      # thread *before* reaching halted
        "halted": halted,
    }
    row = {"name": "faults_monitor_ladder",
           "us": 0.0,
           "derived": f"health={out['final_health']};"
                      f"transitions={len(transitions)}"}
    return out, [row]


def run(smoke: bool = False, out_path: str = "BENCH_faults.json",
        seed: int = 0):
    churn, c_rows = bench_churn(smoke, seed)
    warm, w_rows = bench_warm_vs_cold(smoke, seed)
    ladder, l_rows = bench_monitor_ladder(smoke, seed)
    results = {
        "benchmark": "faults_churn",
        "smoke": smoke,
        "preempt_frac": PREEMPT_FRAC,
        "churn": churn,
        "warm_vs_cold": warm,
        "monitor_ladder": ladder,
    }
    rows = c_rows + w_rows + l_rows
    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    # acceptance gates.  RuntimeError (not SystemExit): benchmarks/run.py
    # catches Exception per module, so one failed gate must not abort the
    # whole suite.  The identity and no-escape gates hold in smoke too;
    # the 5x warm-start gate is only meaningful at the full N=256.
    if not churn["never_worse_than_identity"]:
        raise RuntimeError(
            f"recovered plan worse than identity under the simulator "
            f"(max ratio {churn['max_ratio_overall']})")
    if not (ladder["halted"] and ladder["identity_pinned"]
            and ladder["no_escape"]):
        raise RuntimeError(f"monitor ladder failed: {ladder}")
    if not smoke and not warm["geq_5x"]:
        raise RuntimeError(
            f"warm-start recovery only {warm['warm_speedup_x']}x faster "
            f"than cold compile at N=256 (needs >= 5x)")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: reduced sizes and solver budget")
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
