"""Collective-IR benchmark: lowering overhead + executor agreement.

The typed IR (:mod:`repro.collective`, DESIGN.md §7) sits between the
plan compiler and every backend, so two properties must hold and stay
held:

* **lowering overhead** — compiling a ``CollectiveOp`` into a
  ``Program``, applying the permutation pass, and materializing legacy
  flows must stay cheap relative to a plan compile (µs per program;
  the compiler builds hundreds per plan);
* **executor agreement** — ``SimExecutor`` on the compiled program must
  reproduce the legacy ``simulate_collective`` timing, and
  ``AnalyticExecutor`` the corresponding ``CostModel``, to float
  precision; the per-algorithm max relative error is committed so any
  future builder/pass change that skews pricing shows up in review.

Emits the harness CSV rows and writes ``BENCH_collective_ir.json`` at
the repo root so the trajectory is tracked across PRs.

Usage::

    PYTHONPATH=src python benchmarks/collective_ir.py [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

import numpy as np

try:
    from .common import write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import write_json

from repro.collective import (
    AnalyticExecutor,
    CollectiveOp,
    JaxExecutor,
    SimExecutor,
    apply_permutation,
    compile_op,
    validate,
)
from repro.core import make_datacenter, make_cost_model
from repro.fabric import probe_fabric
from repro.core.simulator import simulate_rounds
from repro.core import schedule as legacy_schedule

SIZE = 8e6

#: the INDEPENDENT legacy reference: the free builders kept in
#: repro.core.schedule (NOT simulate_collective, which now compiles
#: through the registry itself — comparing against it would be
#: tautological)
LEGACY_BUILDERS = {
    "ring": legacy_schedule.ring_allreduce_chunked,
    "ring_sequential": legacy_schedule.ring_allreduce_sequential,
    "double_binary_tree": legacy_schedule.double_binary_tree_allreduce,
    "halving_doubling": legacy_schedule.halving_doubling_allreduce,
    "bcube": legacy_schedule.bcube_allreduce,
    "ring_all_gather": legacy_schedule.ring_all_gather,
    "recursive_doubling": legacy_schedule.recursive_doubling_all_gather,
    "all_to_all": legacy_schedule.all_to_all,
}

#: the historical schedule→cost-model mapping, spelled out (not read
#: from the registry) so a builder mis-declaring its cost_model shows
#: up as analytic disagreement here
SOLVER_MODEL = {
    "ring": "ring",
    "ring_sequential": "ring",
    "double_binary_tree": "double_binary_tree",
    "halving_doubling": "halving_doubling",
    "bcube": "bcube",
    "ring_all_gather": "ring",
    "recursive_doubling": "halving_doubling",
    "all_to_all": "all_to_all",
}

#: (builder, kind, kwargs) — every registered seed algorithm; sizes are
#: picked per-case so power-of-two builders stay feasible.
CASES = [
    ("ring", "allreduce", {}),
    ("ring_sequential", "allreduce", {}),
    ("double_binary_tree", "allreduce", {}),
    ("halving_doubling", "allreduce", {}),
    ("bcube", "allreduce", {"base": 4}),
    ("ring_all_gather", "all_gather", {}),
    ("recursive_doubling", "all_gather", {}),
    ("all_to_all", "all_to_all", {}),
]


def _bench_lowering(n: int, reps: int, rng) -> list:
    rows = []
    perm = [int(x) for x in rng.permutation(n)]
    for name, kind, kw in CASES:
        t0 = time.perf_counter()
        for _ in range(reps):
            prog = apply_permutation(
                compile_op(CollectiveOp(kind, SIZE, range(n)), name, **kw),
                perm)
            flows = prog.to_flows()
        dt = (time.perf_counter() - t0) / reps
        n_flows = sum(len(r) for r in flows)
        rows.append({"name": f"collective_ir_lower_{name}",
                     "us": dt * 1e6,
                     "derived": f"n={n};rounds={len(flows)};flows={n_flows}"})
    return rows


def _bench_agreement(n: int, rng) -> tuple:
    fab = make_datacenter(n, seed=1)
    probe = probe_fabric(fab, seed=0, measure_bw=True)
    sim = SimExecutor(fab)
    analytic = AnalyticExecutor(lat=probe.lat, bw=probe.bw)
    jax_ex = JaxExecutor()
    rows, agree = [], {}
    for name, kind, kw in CASES:
        perm = [int(x) for x in rng.permutation(n)]
        prog = apply_permutation(
            compile_op(CollectiveOp(kind, SIZE, range(n)), name, **kw), perm)
        validate(prog)
        t_ir = sim.estimate(prog)
        t_legacy = simulate_rounds(fab, LEGACY_BUILDERS[name](perm, SIZE, **kw))
        sim_err = abs(t_ir - t_legacy) / max(t_legacy, 1e-30)
        model = make_cost_model(SOLVER_MODEL[name],
                                size_bytes=SIZE, lat=probe.lat,
                                bw=probe.bw, **kw)
        a_ir = analytic.estimate(prog)
        a_legacy = float(model.cost(np.asarray(perm)))
        ana_err = abs(a_ir - a_legacy) / max(abs(a_legacy), 1e-30)
        agree[name] = {
            "sim_seconds": float(t_ir),
            "sim_rel_err_vs_legacy": float(sim_err),
            "analytic_seconds": float(a_ir),
            "analytic_rel_err_vs_cost_model": float(ana_err),
            "lowerable": bool(jax_ex.can_lower(prog)),
            "fingerprint": prog.fingerprint(),
        }
        rows.append({
            "name": f"collective_ir_agree_{name}",
            "us": t_ir * 1e6,
            "derived": f"sim_err={sim_err:.1e};analytic_err={ana_err:.1e}"})
    return rows, agree


def run(smoke: bool = False, out_path: str = "BENCH_collective_ir.json",
        seed: int = 0):
    n = 16 if smoke else 64
    reps = 5 if smoke else 20
    rng = np.random.default_rng(seed)

    rows = _bench_lowering(n, reps, rng)
    agree_rows, agree = _bench_agreement(16, rng)
    rows += agree_rows

    max_sim = max(a["sim_rel_err_vs_legacy"] for a in agree.values())
    max_ana = max(a["analytic_rel_err_vs_cost_model"] for a in agree.values())
    ok = max_sim < 1e-9 and max_ana < 1e-9
    rows.append({"name": "collective_ir_max_err", "us": 0.0,
                 "derived": f"sim={max_sim:.1e};analytic={max_ana:.1e};"
                            f"{'OK' if ok else 'DISAGREE'}"})

    results = {
        "benchmark": "collective_ir",
        "smoke": smoke,
        "lowering_n": n,
        "size_bytes": SIZE,
        "lowering_us": {r["name"].removeprefix("collective_ir_lower_"):
                        round(r["us"], 2)
                        for r in rows if r["name"].startswith(
                            "collective_ir_lower_")},
        "agreement": agree,
        "max_sim_rel_err": float(max_sim),
        "max_analytic_rel_err": float(max_ana),
        "executors_agree": bool(ok),
    }
    for r in rows:
        print(f"{r['name']},{r['us']:.3f},{r['derived']}")
    write_json(out_path, results, seed)
    if not ok:
        # RuntimeError (not SystemExit): benchmarks/run.py catches
        # Exception to print-and-continue; standalone main() still
        # exits non-zero on the propagated error
        raise RuntimeError("executor disagreement above tolerance")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: smaller group, fewer reps")
    ap.add_argument("--out", default="BENCH_collective_ir.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
