"""Solver-scaling benchmark: vectorized engine vs the seed implementation.

Sweeps N in {64, 128, 256, 512, 1024} and times

* ``solve(method="paper", iters=3000, chains=16)`` with the vectorized
  engine (block-pregenerated moves + O(K) ring deltas, knn 2-opt), and
* the same call with ``engine="reference"`` — the seed implementation
  kept verbatim in ``repro.core.solver`` — at the smaller N where its
  Python-loop hot paths finish in reasonable time;
* ``optimize_mesh_assignment`` on a ``(pod, data, model)`` mesh covering
  all N devices (the 1024-device mesh must finish in < 10 s on CPU).

Emits the harness CSV rows and writes ``BENCH_solver_scaling.json`` at
the repo root so the perf trajectory is tracked across PRs.

Usage::

    PYTHONPATH=src python benchmarks/solver_scaling.py [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # runnable as a plain script without PYTHONPATH
    _repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_repo_root, "src"))

try:
    from .common import write_json
except ImportError:   # plain-script mode: benchmarks/ is sys.path[0]
    from common import write_json

from repro.core import make_cost_model, optimize_mesh_assignment, solve

#: Full sweep; --quick trims to the first two entries for CI smoke runs.
SWEEP_NS = (64, 128, 256, 512, 1024)
QUICK_NS = (64, 128)
#: The reference (seed) engine's Python loops get impractical beyond this.
REFERENCE_MAX_N = 256

SOLVE_ITERS = 3000
SOLVE_CHAINS = 16


def _cost_matrix(n: int, seed: int = 0) -> np.ndarray:
    """Symmetric multi-tier fabric-like cost matrix (fast to build at any N)."""
    rng = np.random.default_rng(seed)
    # 3-level hierarchy: nodes in racks of 8, racks in pods of 64
    ids = np.arange(n)
    rack = ids // 8
    pod = ids // 64
    base = np.full((n, n), 12.0)
    base[pod[:, None] == pod[None, :]] = 4.0
    base[rack[:, None] == rack[None, :]] = 1.0
    jitter = rng.uniform(0.8, 1.25, (n, n))
    c = base * np.maximum(jitter, jitter.T)
    c = np.maximum(c, c.T)
    np.fill_diagonal(c, 0.0)
    # scramble so locality is hidden, as the cloud would hand it to us
    p = rng.permutation(n)
    return c[np.ix_(p, p)]


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t)
    return best


def _paired_times(fn_a, fn_b, reps: int):
    """Interleave timed reps of two callables so background load hits both.

    Returns (best_a, best_b, best_b / best_a).  Interleaving gives each
    side quiet shots under drifting load; the min of each side then
    estimates its intrinsic wall clock, and the ratio of mins is the
    robust speedup.
    """
    ta, tb = [], []
    for _ in range(reps):
        t = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t)
        t = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t)
    return min(ta), min(tb), min(tb) / min(ta)


def run(quick: bool = False, out_path: str = "BENCH_solver_scaling.json",
        seed: int = 0):
    ns = QUICK_NS if quick else SWEEP_NS
    iters = 600 if quick else SOLVE_ITERS
    reps = 2 if quick else 3
    rows = []
    results = {
        "benchmark": "solver_scaling",
        "iters": iters,
        "chains": SOLVE_CHAINS,
        "timing": "best of %d interleaved reps per engine" % reps,
        "solve": [],
        "mesh": [],
    }

    for n in ns:
        c = _cost_matrix(n, seed=seed)
        model = make_cost_model("ring", c, 0.0)
        kwargs = dict(method="paper", iters=iters, chains=SOLVE_CHAINS, seed=seed)
        # warm once (first call pays structure-cache and allocator setup)
        res_vec = solve(model, **kwargs)
        if n <= REFERENCE_MAX_N:
            res_ref = solve(model, engine="reference", **kwargs)
            t_vec, t_ref, speedup = _paired_times(
                lambda: solve(model, **kwargs),
                lambda: solve(model, engine="reference", **kwargs),
                reps)
            entry = {
                "n": n,
                "vectorized_s": round(t_vec, 4),
                "vectorized_cost": res_vec.cost,
                "reference_s": round(t_ref, 4),
                "reference_cost": res_ref.cost,
                "speedup": round(speedup, 2),
            }
        else:
            t_vec = _best_of(lambda: solve(model, **kwargs), reps)
            entry = {
                "n": n,
                "vectorized_s": round(t_vec, 4),
                "vectorized_cost": res_vec.cost,
            }
        results["solve"].append(entry)
        derived = ";".join(f"{k}={v}" for k, v in entry.items() if k != "n")
        rows.append({"name": f"solver_scaling_solve_n{n}",
                     "us_per_call": t_vec * 1e6, "derived": derived})

    # N-D mesh assignment: (pod, data, model) covering all N devices
    mesh_shapes = {64: (4, 4, 4), 128: (2, 8, 8), 256: (4, 8, 8),
                   512: (8, 8, 8), 1024: (16, 8, 8)}
    for n in ns:
        shape = mesh_shapes[n]
        c = _cost_matrix(n, seed=seed + 1)
        t = time.perf_counter()
        plan = optimize_mesh_assignment(c, shape, ("pod", "data", "model"))
        dt = time.perf_counter() - t
        entry = {
            "n": n,
            "mesh_shape": list(shape),
            "seconds": round(dt, 4),
            "cost": plan.cost,
            "baseline_cost": plan.baseline_cost,
            "improvement": round(plan.baseline_cost / max(plan.cost, 1e-30), 3),
        }
        if n <= REFERENCE_MAX_N:
            t = time.perf_counter()
            optimize_mesh_assignment(c, shape, ("pod", "data", "model"),
                                     engine="reference")
            entry["reference_seconds"] = round(time.perf_counter() - t, 4)
        results["mesh"].append(entry)
        rows.append({"name": f"solver_scaling_mesh_n{n}",
                     "us_per_call": dt * 1e6,
                     "derived": f"shape={shape};cost={plan.cost:.4g};"
                                f"improvement={entry['improvement']}x"})

    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.3f},{r.get('derived', '')}")

    write_json(out_path, results, seed)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small N sweep, reduced iterations")
    ap.add_argument("--out", default="BENCH_solver_scaling.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(quick=args.quick, out_path=args.out, seed=args.seed)


if __name__ == "__main__":
    main()
