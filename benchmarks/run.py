"""Benchmark harness: one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (harness contract).  Each module
is also runnable standalone: ``python -m benchmarks.fig1_distribution``.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        analysis_verify,
        collective_ir,
        e2e_training,
        fabric_probe,
        faults_churn,
        fig1_distribution,
        fig2_heatmap,
        fig4_speedups,
        lowering_e2e,
        obs_trace,
        overlap_step,
        plan_compiler,
        roofline,
        solver_quality,
        table1_spearman,
    )

    failures = 0
    for mod in (fig1_distribution, fig2_heatmap, table1_spearman,
                fig4_speedups, e2e_training, solver_quality, roofline,
                plan_compiler, collective_ir, fabric_probe, faults_churn,
                obs_trace, analysis_verify, lowering_e2e, overlap_step):
        try:
            mod.run()
        except Exception as e:  # print and continue; report at exit
            failures += 1
            print(f"{mod.__name__}.FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
