"""Explicit flow schedules for collective algorithms (legacy surface).

While :mod:`repro.core.cost_models` is the paper's *analytic* view (used by
the solver), this module emits the actual per-round point-to-point flows a
backend would issue, so the contention-aware simulator
(:mod:`repro.core.simulator`) can act as the "real cloud" oracle that the
cost model is validated against (paper Table I).

A schedule is ``List[List[Flow]]``: rounds of concurrent flows.  Flows in
one round contend for links; rounds are separated by barriers (the
conservative standard model for collectives).

All builders take ``perm`` with ``perm[rank] = node`` and emit flows in
*node* space.

.. deprecated::
    The typed collective IR (:mod:`repro.collective`, DESIGN.md §7) is
    the primary representation: builders there compile a
    ``CollectiveOp`` into a chunk-annotated ``Program`` and the
    executors price/lower it.  :data:`SCHEDULES` remains as a thin
    compatibility shim *over that registry* — indexing it warns with
    ``DeprecationWarning`` and returns a wrapper that compiles through
    the registered builder.  The free functions below are kept
    (warning-free) as the independent reference implementation the
    IR's cross-backend equivalence suite pins itself against.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Iterator, List, Mapping, Sequence

import numpy as np

__all__ = [
    "Flow",
    "ring_allreduce_chunked",
    "ring_allreduce_sequential",
    "halving_doubling_allreduce",
    "double_binary_tree_allreduce",
    "bcube_allreduce",
    "ring_all_gather",
    "recursive_doubling_all_gather",
    "all_to_all",
    "SCHEDULES",
]


@dataclasses.dataclass(frozen=True)
class Flow:
    src: int
    dst: int
    size: float  # bytes


def _p(perm: Sequence[int], rank: int) -> int:
    return int(perm[rank % len(perm)])


def _require_power_of_two(n: int, algo: str) -> None:
    if n < 1 or n & (n - 1) != 0:
        raise ValueError(
            f"{algo} requires a power-of-two world size, got n={n}; "
            "fall back to 'ring' (valid for any n) or pad/split the group"
        )


def _require_power_of_base(n: int, base: int, algo: str) -> int:
    """Validate n == base**k (k >= 0) and return the number of rounds k."""
    if base < 2:
        raise ValueError(f"{algo} requires base >= 2, got base={base}")
    n_rounds, m = 0, 1
    while m < n:
        m *= base
        n_rounds += 1
    if m != n:
        raise ValueError(
            f"{algo} requires world size a power of its base "
            f"({n} is not a power of {base}); fall back to 'ring' "
            "(valid for any n) or choose a base b with n == b**k"
        )
    return n_rounds


def ring_allreduce_chunked(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Bandwidth-optimal ring: reduce-scatter + all-gather, S/N chunks.

    2(N-1) rounds; in each round every node sends one S/N chunk to its
    ring successor (Gloo ``ring_chunked``, the paper's §III microbenchmark).
    """
    n = len(perm)
    chunk = size / n
    rounds = []
    for _ in range(2 * (n - 1)):
        rounds.append([Flow(_p(perm, r), _p(perm, r + 1), chunk) for r in range(n)])
    return rounds


def ring_allreduce_sequential(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Naive ring: the full buffer circulates; one hop active per round.

    This is the regime the paper's ring cost model C_r = sum_i c_{i,i-1}(S)
    describes exactly (total = sum of per-hop costs of the full payload).
    """
    n = len(perm)
    rounds = []
    for _lap in range(2):  # reduce lap + broadcast lap, same hop sequence
        for r in range(n - 1):
            rounds.append([Flow(_p(perm, r), _p(perm, r + 1), size)])
    return rounds


def halving_doubling_allreduce(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Recursive vector-halving distance-doubling RS + mirrored AG.

    Raises :class:`ValueError` for non-power-of-two ``n`` (the recursive
    pairing has no partner for stray ranks); callers that cannot pad or
    split the group should fall back to ``ring``.
    """
    n = len(perm)
    _require_power_of_two(n, "halving_doubling")
    log_n = int(np.log2(n))
    rounds = []
    # reduce-scatter: payload halves each round
    for i in range(log_n):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            flows.append(Flow(_p(perm, j), _p(perm, partner), size / (2 ** (i + 1))))
        rounds.append(flows)
    # all-gather: mirror
    for i in reversed(range(log_n)):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            flows.append(Flow(_p(perm, j), _p(perm, partner), size / (2 ** (i + 1))))
        rounds.append(flows)
    return rounds


def _balanced_tree_edges(lo: int, hi: int) -> List[tuple]:
    """(parent, child, depth) edges of the balanced tree over [lo, hi]."""
    out = []

    def rec(lo: int, hi: int, depth: int) -> int:
        mid = (lo + hi) // 2
        if lo <= mid - 1:
            c = rec(lo, mid - 1, depth + 1)
            out.append((mid, c, depth))
        if mid + 1 <= hi:
            c = rec(mid + 1, hi, depth + 1)
            out.append((mid, c, depth))
        return mid

    rec(lo, hi, 0)
    return out


def double_binary_tree_allreduce(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Two complementary trees, each reducing+broadcasting S/2.

    The trees run CONCURRENTLY (that is the point of the double tree:
    together they use full bisection bandwidth), so each round holds the
    same-depth edges of *both* trees.  Reduce goes leaf->root, broadcast
    root->leaf (NCCL-style, paper §II-B Tree).
    """
    n = len(perm)
    edges = _balanced_tree_edges(0, n - 1)
    max_depth = max((d for _, _, d in edges), default=0)
    trees = [
        [((p - shift) % n, (c - shift) % n, d) for p, c, d in edges]
        for shift in (0, 1)
    ]
    rounds: List[List[Flow]] = []
    for d in range(max_depth, -1, -1):   # reduce: deepest first
        flows = [
            Flow(_p(perm, c), _p(perm, p), size / 2)
            for tree in trees
            for p, c, dd in tree
            if dd == d
        ]
        if flows:
            rounds.append(flows)
    for d in range(0, max_depth + 1):    # broadcast: root out
        flows = [
            Flow(_p(perm, p), _p(perm, c), size / 2)
            for tree in trees
            for p, c, dd in tree
            if dd == d
        ]
        if flows:
            rounds.append(flows)
    return rounds


def bcube_allreduce(perm: Sequence[int], size: float, base: int = 4) -> List[List[Flow]]:
    """BCube allreduce over ``k`` digit-rounds; requires ``n == base**k``.

    Raises :class:`ValueError` otherwise (every rank needs exactly
    ``base - 1`` peers per digit); fall back to ``ring`` for arbitrary n.
    """
    n = len(perm)
    n_rounds = _require_power_of_base(n, base, "bcube")
    rounds = []
    for i in range(n_rounds):
        stride = base ** i
        flows = []
        for j in range(n):
            digit = (j // stride) % base
            for k in range(1, base):
                partner = j + (((digit + k) % base) - digit) * stride
                flows.append(Flow(_p(perm, j), _p(perm, partner), size / (base ** (i + 1))))
        rounds.append(flows)
    return rounds


def ring_all_gather(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """One-lap chunked ring: N-1 rounds, each node forwards one S/N chunk.

    Models a standalone all-gather; a reduce-scatter is the same flow
    structure run in reverse, so the plan compiler prices both with this
    builder (the simulator is direction-agnostic at the flow level).
    """
    n = len(perm)
    chunk = size / n
    rounds = []
    for _ in range(n - 1):
        rounds.append([Flow(_p(perm, r), _p(perm, r + 1), chunk) for r in range(n)])
    return rounds


def recursive_doubling_all_gather(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Recursive-doubling all-gather: log2(N) rounds of doubling payloads.

    Round ``i`` pairs rank j with j XOR 2^i and exchanges the S/N * 2^i
    bytes accumulated so far.  Power-of-two N only (raises ValueError);
    reduce-scatter is the mirrored halving pass with identical flows.
    """
    n = len(perm)
    _require_power_of_two(n, "recursive_doubling")
    rounds = []
    for i in range(int(np.log2(n))):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            flows.append(Flow(_p(perm, j), _p(perm, partner), size / n * (2 ** i)))
        rounds.append(flows)
    return rounds


def all_to_all(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Shift-scheduled all-to-all; every node holds S split N ways."""
    n = len(perm)
    rounds = []
    for k in range(1, n):
        rounds.append([Flow(_p(perm, j), _p(perm, j + k), size / n) for j in range(n)])
    return rounds


#: default CollectiveOp kind each legacy builder name compiles under the
#: typed IR (the registry's builders are kind-aware; the legacy call
#: signature is not).
_SHIM_KINDS = {
    "ring": "allreduce",
    "ring_sequential": "allreduce",
    "halving_doubling": "allreduce",
    "double_binary_tree": "allreduce",
    "bcube": "allreduce",
    "ring_all_gather": "all_gather",
    "recursive_doubling": "all_gather",
    "all_to_all": "all_to_all",
}


def _registry_wrapper(algo: str) -> Callable[..., List[List[Flow]]]:
    """Legacy ``(perm, size, **kw) -> List[List[Flow]]`` via the IR."""

    def build(perm: Sequence[int], size: float, **kwargs) -> List[List[Flow]]:
        from repro.collective import (
            CollectiveOp, apply_permutation, compile_op)

        perm = [int(p) for p in perm]
        op = CollectiveOp(_SHIM_KINDS[algo], float(size), sorted(perm))
        return apply_permutation(
            compile_op(op, algo, **kwargs), perm).to_flows()

    build.__name__ = f"{algo}_via_registry"
    return build


class UnknownAlgorithmError(KeyError, ValueError):
    """Unknown algorithm name in the legacy ``SCHEDULES`` shim.

    Subclasses BOTH ``KeyError`` (the old plain-dict contract, so
    ``SCHEDULES.get(name, default)`` and ``except KeyError`` callers
    keep working) and ``ValueError`` (the registry's actionable-error
    contract).
    """

    def __str__(self) -> str:          # KeyError repr-quotes its arg
        return self.args[0] if self.args else ""


class _ScheduleShim(Mapping):
    """Deprecating view of the :mod:`repro.collective` builder registry.

    Indexing warns (``DeprecationWarning``, once per call site under the
    default warning filters) and returns a legacy-signature wrapper that
    compiles through the registered builder; unknown names raise
    :class:`UnknownAlgorithmError` (a ``KeyError`` *and* ``ValueError``)
    listing the registered builders.
    """

    def _names(self) -> tuple:
        from repro.collective import registered_builders

        return registered_builders()

    def __getitem__(self, algo: str) -> Callable[..., List[List[Flow]]]:
        warnings.warn(
            "repro.core.schedule.SCHEDULES is deprecated; compile typed "
            "programs via repro.collective (compile_op / candidates) and "
            "price them through the Executor protocol",
            DeprecationWarning, stacklevel=2)
        from repro.collective import get_builder

        try:
            get_builder(algo)
        except ValueError as e:
            raise UnknownAlgorithmError(str(e)) from None
        if algo not in _SHIM_KINDS:
            raise UnknownAlgorithmError(
                f"builder {algo!r} has no legacy SCHEDULES signature; "
                f"use repro.collective.compile_op directly")
        return _registry_wrapper(algo)

    def __iter__(self) -> Iterator[str]:
        return iter(n for n in self._names() if n in _SHIM_KINDS)

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def __contains__(self, algo: object) -> bool:
        return algo in _SHIM_KINDS and algo in self._names()


SCHEDULES = _ScheduleShim()
