"""Explicit flow schedules for collective algorithms.

While :mod:`repro.core.cost_models` is the paper's *analytic* view (used by
the solver), this module emits the actual per-round point-to-point flows a
backend would issue, so the contention-aware simulator
(:mod:`repro.core.simulator`) can act as the "real cloud" oracle that the
cost model is validated against (paper Table I).

A schedule is ``List[List[Flow]]``: rounds of concurrent flows.  Flows in
one round contend for links; rounds are separated by barriers (the
conservative standard model for collectives).

All builders take ``perm`` with ``perm[rank] = node`` and emit flows in
*node* space.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

__all__ = [
    "Flow",
    "ring_allreduce_chunked",
    "ring_allreduce_sequential",
    "halving_doubling_allreduce",
    "double_binary_tree_allreduce",
    "bcube_allreduce",
    "ring_all_gather",
    "recursive_doubling_all_gather",
    "all_to_all",
    "SCHEDULES",
]


@dataclasses.dataclass(frozen=True)
class Flow:
    src: int
    dst: int
    size: float  # bytes


def _p(perm: Sequence[int], rank: int) -> int:
    return int(perm[rank % len(perm)])


def _require_power_of_two(n: int, algo: str) -> None:
    if n < 1 or n & (n - 1) != 0:
        raise ValueError(
            f"{algo} requires a power-of-two world size, got n={n}; "
            "fall back to 'ring' (valid for any n) or pad/split the group"
        )


def _require_power_of_base(n: int, base: int, algo: str) -> int:
    """Validate n == base**k (k >= 0) and return the number of rounds k."""
    if base < 2:
        raise ValueError(f"{algo} requires base >= 2, got base={base}")
    n_rounds, m = 0, 1
    while m < n:
        m *= base
        n_rounds += 1
    if m != n:
        raise ValueError(
            f"{algo} requires world size a power of its base "
            f"({n} is not a power of {base}); fall back to 'ring' "
            "(valid for any n) or choose a base b with n == b**k"
        )
    return n_rounds


def ring_allreduce_chunked(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Bandwidth-optimal ring: reduce-scatter + all-gather, S/N chunks.

    2(N-1) rounds; in each round every node sends one S/N chunk to its
    ring successor (Gloo ``ring_chunked``, the paper's §III microbenchmark).
    """
    n = len(perm)
    chunk = size / n
    rounds = []
    for _ in range(2 * (n - 1)):
        rounds.append([Flow(_p(perm, r), _p(perm, r + 1), chunk) for r in range(n)])
    return rounds


def ring_allreduce_sequential(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Naive ring: the full buffer circulates; one hop active per round.

    This is the regime the paper's ring cost model C_r = sum_i c_{i,i-1}(S)
    describes exactly (total = sum of per-hop costs of the full payload).
    """
    n = len(perm)
    rounds = []
    for _lap in range(2):  # reduce lap + broadcast lap, same hop sequence
        for r in range(n - 1):
            rounds.append([Flow(_p(perm, r), _p(perm, r + 1), size)])
    return rounds


def halving_doubling_allreduce(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Recursive vector-halving distance-doubling RS + mirrored AG.

    Raises :class:`ValueError` for non-power-of-two ``n`` (the recursive
    pairing has no partner for stray ranks); callers that cannot pad or
    split the group should fall back to ``ring``.
    """
    n = len(perm)
    _require_power_of_two(n, "halving_doubling")
    log_n = int(np.log2(n))
    rounds = []
    # reduce-scatter: payload halves each round
    for i in range(log_n):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            flows.append(Flow(_p(perm, j), _p(perm, partner), size / (2 ** (i + 1))))
        rounds.append(flows)
    # all-gather: mirror
    for i in reversed(range(log_n)):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            flows.append(Flow(_p(perm, j), _p(perm, partner), size / (2 ** (i + 1))))
        rounds.append(flows)
    return rounds


def _balanced_tree_edges(lo: int, hi: int) -> List[tuple]:
    """(parent, child, depth) edges of the balanced tree over [lo, hi]."""
    out = []

    def rec(lo: int, hi: int, depth: int) -> int:
        mid = (lo + hi) // 2
        if lo <= mid - 1:
            c = rec(lo, mid - 1, depth + 1)
            out.append((mid, c, depth))
        if mid + 1 <= hi:
            c = rec(mid + 1, hi, depth + 1)
            out.append((mid, c, depth))
        return mid

    rec(lo, hi, 0)
    return out


def double_binary_tree_allreduce(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Two complementary trees, each reducing+broadcasting S/2.

    The trees run CONCURRENTLY (that is the point of the double tree:
    together they use full bisection bandwidth), so each round holds the
    same-depth edges of *both* trees.  Reduce goes leaf->root, broadcast
    root->leaf (NCCL-style, paper §II-B Tree).
    """
    n = len(perm)
    edges = _balanced_tree_edges(0, n - 1)
    max_depth = max((d for _, _, d in edges), default=0)
    trees = [
        [((p - shift) % n, (c - shift) % n, d) for p, c, d in edges]
        for shift in (0, 1)
    ]
    rounds: List[List[Flow]] = []
    for d in range(max_depth, -1, -1):   # reduce: deepest first
        flows = [
            Flow(_p(perm, c), _p(perm, p), size / 2)
            for tree in trees
            for p, c, dd in tree
            if dd == d
        ]
        if flows:
            rounds.append(flows)
    for d in range(0, max_depth + 1):    # broadcast: root out
        flows = [
            Flow(_p(perm, p), _p(perm, c), size / 2)
            for tree in trees
            for p, c, dd in tree
            if dd == d
        ]
        if flows:
            rounds.append(flows)
    return rounds


def bcube_allreduce(perm: Sequence[int], size: float, base: int = 4) -> List[List[Flow]]:
    """BCube allreduce over ``k`` digit-rounds; requires ``n == base**k``.

    Raises :class:`ValueError` otherwise (every rank needs exactly
    ``base - 1`` peers per digit); fall back to ``ring`` for arbitrary n.
    """
    n = len(perm)
    n_rounds = _require_power_of_base(n, base, "bcube")
    rounds = []
    for i in range(n_rounds):
        stride = base ** i
        flows = []
        for j in range(n):
            digit = (j // stride) % base
            for k in range(1, base):
                partner = j + (((digit + k) % base) - digit) * stride
                flows.append(Flow(_p(perm, j), _p(perm, partner), size / (base ** (i + 1))))
        rounds.append(flows)
    return rounds


def ring_all_gather(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """One-lap chunked ring: N-1 rounds, each node forwards one S/N chunk.

    Models a standalone all-gather; a reduce-scatter is the same flow
    structure run in reverse, so the plan compiler prices both with this
    builder (the simulator is direction-agnostic at the flow level).
    """
    n = len(perm)
    chunk = size / n
    rounds = []
    for _ in range(n - 1):
        rounds.append([Flow(_p(perm, r), _p(perm, r + 1), chunk) for r in range(n)])
    return rounds


def recursive_doubling_all_gather(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Recursive-doubling all-gather: log2(N) rounds of doubling payloads.

    Round ``i`` pairs rank j with j XOR 2^i and exchanges the S/N * 2^i
    bytes accumulated so far.  Power-of-two N only (raises ValueError);
    reduce-scatter is the mirrored halving pass with identical flows.
    """
    n = len(perm)
    _require_power_of_two(n, "recursive_doubling")
    rounds = []
    for i in range(int(np.log2(n))):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            flows.append(Flow(_p(perm, j), _p(perm, partner), size / n * (2 ** i)))
        rounds.append(flows)
    return rounds


def all_to_all(perm: Sequence[int], size: float) -> List[List[Flow]]:
    """Shift-scheduled all-to-all; every node holds S split N ways."""
    n = len(perm)
    rounds = []
    for k in range(1, n):
        rounds.append([Flow(_p(perm, j), _p(perm, j + k), size / n) for j in range(n)])
    return rounds


SCHEDULES = {
    "ring": ring_allreduce_chunked,
    "ring_sequential": ring_allreduce_sequential,
    "halving_doubling": halving_doubling_allreduce,
    "double_binary_tree": double_binary_tree_allreduce,
    "bcube": bcube_allreduce,
    "ring_all_gather": ring_all_gather,
    "recursive_doubling": recursive_doubling_all_gather,
    "all_to_all": all_to_all,
}
