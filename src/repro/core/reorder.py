"""Rank reordering for JAX meshes — the paper's technique, N-D generalized.

The paper reorders a flat rank list and feeds it to an unmodified backend.
In JAX the "rank list" is the device array inside ``jax.sharding.Mesh``:
XLA's per-axis collectives follow mesh-axis adjacency, so permuting the
device array before building the mesh changes which physical links every
ring / all-gather hop crosses — with zero changes to the model or the
compiled step function.  (See DESIGN.md §2.)

1-D (paper-faithful): :func:`optimize_rank_order`.

N-D (beyond paper): a production mesh ``(pod, data, model)`` runs
collectives on *every* axis, with very different traffic:

* ``model`` (TP): all-gather/reduce-scatter per layer, every microbatch —
  the hot axis;
* ``data``/``pod`` (DP): one gradient reduce-scatter+all-gather per step.

:func:`optimize_mesh_assignment` therefore solves hierarchically, hottest
axis first: partition devices into same-group sets with minimal intra-
group cost (greedy agglomeration), order each group with the ring TSP
solver, then collapse groups to supernodes (mean inter-group cost) and
recurse on the next axis.  The result is an integer array of shape
``mesh_shape`` assigning a device id to every mesh coordinate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost_models import make_cost_model
from .solver import SolveResult, or_opt, solve, two_opt

__all__ = [
    "optimize_rank_order",
    "optimize_mesh_assignment",
    "mesh_axis_cost",
    "mesh_total_cost",
    "MeshPlan",
    "random_assignment",
]


def optimize_rank_order(
    cost_matrix: np.ndarray,
    algo: str = "ring",
    size_bytes: float = 0.0,
    method: str = "auto",
    seed: int = 0,
    iters: int = 3000,
    **kwargs,
) -> SolveResult:
    """Paper-faithful flat reordering: minimize C_algo over permutations."""
    model = make_cost_model(algo, cost_matrix, size_bytes, **kwargs)
    return solve(model, method=method, seed=seed, iters=iters)


# ---------------------------------------------------------------------------
# N-D mesh assignment
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshPlan:
    """Result of an N-D mesh reordering."""

    assignment: np.ndarray          # int array, shape mesh_shape -> device id
    axis_names: Tuple[str, ...]
    cost: float                     # weighted objective after optimization
    baseline_cost: float            # same objective for the identity order
    per_axis: Dict[str, float]      # optimized per-axis cost

    @property
    def flat(self) -> np.ndarray:
        return self.assignment.reshape(-1)


def _group_greedy(c: np.ndarray, units: List[int], k: int) -> List[List[int]]:
    """Partition ``units`` into groups of size k with low intra-group cost.

    Greedy agglomeration: seed each group with the unassigned unit that is
    farthest from all others (hardest to place), then grow by repeatedly
    adding the unit with the smallest mean cost to the current group.

    Vectorized: instead of re-slicing submatrices per pick (the seed's
    O(m^2 k) inner loops), two running sum vectors — cost-to-remaining
    and cost-to-current-group — are updated with one O(m) axpy per pick,
    so the whole partition is O(m^2) with m numpy ops total.
    """
    units = list(units)
    m = len(units)
    active = np.ones(m, dtype=bool)
    cu = c if units == list(range(c.shape[0])) else c[np.ix_(units, units)]
    sum_rem = cu.sum(axis=1)                       # cost to remaining units
    groups: List[List[int]] = []
    n_active = m
    while n_active > k:
        seed_i = int(np.argmax(np.where(active, sum_rem, -np.inf)))
        group = [seed_i]
        active[seed_i] = False
        sum_rem -= cu[:, seed_i]
        sum_grp = cu[:, seed_i].copy()             # cost to current group
        while len(group) < k:
            pick = int(np.argmin(np.where(active, sum_grp, np.inf)))
            group.append(pick)
            active[pick] = False
            sum_rem -= cu[:, pick]
            sum_grp += cu[:, pick]
        groups.append(group)
        n_active -= k
    rest = np.nonzero(active)[0]
    if rest.size:
        groups.append([int(i) for i in rest])
    return [[units[i] for i in g] for g in groups]


def _group_greedy_reference(c: np.ndarray, units: List[int], k: int) -> List[List[int]]:
    """Seed greedy agglomeration (per-pick submatrix slicing), kept
    verbatim for the equivalence property tests and benchmarks."""
    remaining = set(units)
    groups: List[List[int]] = []
    while remaining:
        rem = list(remaining)
        if len(rem) <= k:
            groups.append(rem)
            break
        sub = c[np.ix_(rem, rem)]
        seed_i = rem[int(np.argmax(sub.sum(axis=1)))]
        group = [seed_i]
        remaining.remove(seed_i)
        while len(group) < k:
            rem = list(remaining)
            costs = c[np.ix_(rem, group)].mean(axis=1)
            pick = rem[int(np.argmin(costs))]
            group.append(pick)
            remaining.remove(pick)
        groups.append(group)
    return groups


def _order_ring(c: np.ndarray, members: List[int]) -> List[int]:
    """Order ``members`` along a ring with 2-opt + Or-opt on the submatrix."""
    if len(members) <= 3:
        return list(members)
    sub = c[np.ix_(members, members)]
    perm = two_opt(sub, np.arange(len(members)))
    perm = or_opt(sub, perm)
    return [members[i] for i in perm]


def default_axis_weights(axis_names: Sequence[str]) -> Dict[str, float]:
    """Relative traffic weights per axis role (TP >> DP > pod-DP)."""
    w = {}
    for name in axis_names:
        if name in ("model", "tensor", "tp"):
            w[name] = 100.0     # per-layer activation collectives
        elif name in ("expert", "ep"):
            w[name] = 30.0      # per-layer all-to-alls
        elif name in ("data", "fsdp", "dp"):
            w[name] = 10.0      # per-step gradient reduction
        elif name in ("pod", "dcn"):
            w[name] = 1.0       # per-step, but DCN bytes are precious
        else:
            w[name] = 1.0
    return w


def _collapse_cost(cost_matrix: np.ndarray, new_units: List[List[int]]) -> np.ndarray:
    """Inter-group mean cost matrix after collapsing groups to supernodes.

    All units have equal size on the mesh path, so the seed's O(m^2)
    Python loop of submatrix ``.mean()`` calls becomes one blocked
    reduction: gather the permuted matrix, reshape to [m, b, m, b], mean
    over the block axes.
    """
    m = len(new_units)
    sizes = {len(u) for u in new_units}
    if len(sizes) == 1:
        ids = np.asarray(new_units, dtype=np.int64).reshape(-1)
        b = len(new_units[0])
        blk = cost_matrix[np.ix_(ids, ids)].reshape(m, b, m, b)
        nc = blk.mean(axis=(1, 3))
        np.fill_diagonal(nc, 0.0)
        return nc
    return _collapse_cost_reference(cost_matrix, new_units)


def _collapse_cost_reference(cost_matrix: np.ndarray,
                             new_units: List[List[int]]) -> np.ndarray:
    """Seed supernode collapse: O(m^2) Python loop of submatrix means.

    Kept as the ``engine="reference"`` implementation and as
    :func:`_collapse_cost`'s unequal-size fallback.
    """
    m = len(new_units)
    nc = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i == j:
                continue
            nc[i, j] = cost_matrix[np.ix_(new_units[i], new_units[j])].mean()
    return nc


def optimize_mesh_assignment(
    cost_matrix: np.ndarray,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    axis_weights: Optional[Dict[str, float]] = None,
    seed: int = 0,
    engine: str = "vectorized",
) -> MeshPlan:
    """Hierarchical N-D rank reordering (see module docstring).

    ``engine="reference"`` runs the seed implementation (per-pick
    submatrix means in the grouping loop, O(m^2) Python supernode
    collapse) — kept for equivalence tests and benchmarks.
    """
    mesh_shape = tuple(mesh_shape)
    axis_names = tuple(axis_names)
    n = int(np.prod(mesh_shape))
    assert cost_matrix.shape == (n, n)
    weights = axis_weights or default_axis_weights(axis_names)
    group_greedy = (_group_greedy_reference if engine == "reference"
                    else _group_greedy)

    # Process axes hottest-first; by convention that is innermost-first
    # (model), which also matches how group nesting composes.
    order = sorted(range(len(mesh_shape)), key=lambda a: -weights[axis_names[a]])

    # units: currently-assembled blocks of device ids, in axis-nesting order.
    units: List[List[int]] = [[i] for i in range(n)]
    unit_cost = cost_matrix.copy()

    axis_members: Dict[int, List[List[int]]] = {}
    for a in order:
        k = mesh_shape[a]
        ids = list(range(len(units)))
        groups = group_greedy(unit_cost, ids, k)
        groups = [_order_ring(unit_cost, g) for g in groups]
        axis_members[a] = groups
        # Collapse: each ordered group becomes one unit.
        new_units: List[List[int]] = []
        for g in groups:
            merged: List[int] = []
            for u in g:
                merged.extend(units[u])
            new_units.append(merged)
        if engine == "reference":
            nc = _collapse_cost_reference(cost_matrix, new_units)
        else:
            nc = _collapse_cost(cost_matrix, new_units)
        units, unit_cost = new_units, nc

    # Reassemble the assignment: the nesting order of merges is `order`
    # reversed; reconstruct coordinates by unrolling group structure.
    # After the loop, len(units) == 1 and units[0] lists device ids in
    # nesting order: outermost processed axis slowest.
    flat = np.asarray(units[0], dtype=np.int64)
    # The merge loop nested blocks as [last-processed axis outermost ...
    # first-processed innermost]; reshape accordingly, then permute the
    # dims back to canonical mesh-axis order.
    rev = list(reversed(order))
    arr = flat.reshape([mesh_shape[a] for a in rev])
    assignment = np.transpose(arr, axes=[rev.index(a) for a in range(len(order))])

    base = np.arange(n, dtype=np.int64).reshape(mesh_shape)
    per_axis = {
        axis_names[a]: mesh_axis_cost(assignment, cost_matrix, a)
        for a in range(len(mesh_shape))
    }
    cost = mesh_total_cost(assignment, cost_matrix, axis_names, weights)
    baseline = mesh_total_cost(base, cost_matrix, axis_names, weights)
    return MeshPlan(
        assignment=assignment,
        axis_names=axis_names,
        cost=cost,
        baseline_cost=baseline,
        per_axis=per_axis,
    )


def mesh_axis_cost(
    assignment: np.ndarray, cost_matrix: np.ndarray, axis: int, algo: str = "ring"
) -> float:
    """Mean collective cost over all groups along ``axis`` of the assignment.

    All groups share one schedule structure (they have the same size), so
    every group is evaluated in a single batched gather over the full
    cost matrix — the structure comes from one template model, the node
    ids from the assignment rows.  Models without a flat round structure
    (the path-mode tree) fall back to the per-group loop.
    """
    arr = np.moveaxis(assignment, axis, -1)
    groups = arr.reshape(-1, arr.shape[-1])
    g = groups.shape[1]
    if g < 2:
        return 0.0
    if algo == "ring":
        total = cost_matrix[groups, np.roll(groups, 1, axis=1)].sum()
        return float(total / len(groups))
    template = make_cost_model(algo, np.zeros((g, g)), 0.0)
    if template.rounds:
        total = np.zeros(len(groups))
        for rnd in template.rounds:
            a = groups[:, rnd.pairs[:, 0]]
            b = groups[:, rnd.pairs[:, 1]]
            edge = cost_matrix[a, b]
            if template.aggregator == "sum_of_max":
                total += edge.max(axis=1)
            else:
                total += edge.sum(axis=1)
        return float(total.sum() / len(groups))
    total = 0.0
    for grp in groups:
        sub = cost_matrix[np.ix_(grp, grp)]
        sub_model = make_cost_model(algo, sub, 0.0)
        total += sub_model.cost(np.arange(len(grp)))
    return total / max(len(groups), 1)


def mesh_total_cost(
    assignment: np.ndarray,
    cost_matrix: np.ndarray,
    axis_names: Sequence[str],
    axis_weights: Optional[Dict[str, float]] = None,
) -> float:
    weights = axis_weights or default_axis_weights(axis_names)
    return float(
        sum(
            weights[axis_names[a]] * mesh_axis_cost(assignment, cost_matrix, a)
            for a in range(assignment.ndim)
        )
    )


def random_assignment(mesh_shape: Sequence[int], seed: int = 0) -> np.ndarray:
    n = int(np.prod(tuple(mesh_shape)))
    return np.random.default_rng(seed).permutation(n).reshape(tuple(mesh_shape))
