"""Rank reordering for JAX meshes — the paper's technique, N-D generalized.

The paper reorders a flat rank list and feeds it to an unmodified backend.
In JAX the "rank list" is the device array inside ``jax.sharding.Mesh``:
XLA's per-axis collectives follow mesh-axis adjacency, so permuting the
device array before building the mesh changes which physical links every
ring / all-gather hop crosses — with zero changes to the model or the
compiled step function.  (See DESIGN.md §2.)

1-D (paper-faithful): :func:`optimize_rank_order`.

N-D (beyond paper): a production mesh ``(pod, data, model)`` runs
collectives on *every* axis, with very different traffic:

* ``model`` (TP): all-gather/reduce-scatter per layer, every microbatch —
  the hot axis;
* ``data``/``pod`` (DP): one gradient reduce-scatter+all-gather per step.

:func:`optimize_mesh_assignment` therefore solves hierarchically, hottest
axis first: partition devices into same-group sets with minimal intra-
group cost (greedy agglomeration), order each group with the ring TSP
solver, then collapse groups to supernodes (mean inter-group cost) and
recurse on the next axis.  The result is an integer array of shape
``mesh_shape`` assigning a device id to every mesh coordinate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.fabric.hierarchy import HierarchyModel

from .cost_models import make_cost_model
from .solver import SolveResult, or_opt, solve, two_opt

__all__ = [
    "optimize_rank_order",
    "optimize_rank_order_hierarchical",
    "hierarchical_perm",
    "optimize_mesh_assignment",
    "mesh_axis_cost",
    "mesh_total_cost",
    "MeshPlan",
    "random_assignment",
]


def optimize_rank_order(
    cost_matrix: np.ndarray,
    algo: str = "ring",
    size_bytes: float = 0.0,
    method: str = "auto",
    seed: int = 0,
    iters: int = 3000,
    **kwargs,
) -> SolveResult:
    """Paper-faithful flat reordering: minimize C_algo over permutations."""
    model = make_cost_model(algo, cost_matrix, size_bytes, **kwargs)
    return solve(model, method=method, seed=seed, iters=iters)


# ---------------------------------------------------------------------------
# hierarchy-decomposed solving
# ---------------------------------------------------------------------------

def _unit_mean_cost(c: np.ndarray, units: Sequence[Sequence[int]]) -> np.ndarray:
    """Mean inter-unit cost via one indicator matmul (no python loops)."""
    m = len(units)
    a = np.zeros((m, c.shape[0]))
    for u, members in enumerate(units):
        a[u, list(members)] = 1.0 / len(members)
    nc = a @ c @ a.T
    np.fill_diagonal(nc, 0.0)
    return nc


def _splice(c: np.ndarray, ordered_units: Sequence[Sequence[int]]) -> List[int]:
    """Concatenate pre-ordered units, flipping each to cheapen the junction."""
    out = list(ordered_units[0])
    for u in ordered_units[1:]:
        u = list(u)
        if c[out[-1], u[-1]] < c[out[-1], u[0]]:
            u.reverse()
        out.extend(u)
    return out


def hierarchical_perm(cost_matrix: np.ndarray,
                      hierarchy: Optional[HierarchyModel],
                      seed: int = 0) -> np.ndarray:
    """A locality-nested ring permutation from the recovered tree.

    Bottom-up over the tiers: order the nodes inside every finest block
    (2-opt + Or-opt on the tiny submatrix), collapse each ordered block
    to a supernode (mean inter-block cost), order the supernodes within
    their parent block, splice, recurse.  Total work is a stack of
    small solves — O(n · b) for blocks of size b — instead of one flat
    n-sized search, which is where the ≥3x solve speedup at N=1024
    comes from (see benchmarks/fabric_probe.py).

    The permutation is algorithm-agnostic (pure locality nesting), so
    the plan compiler computes it once per entry and scores it under
    every candidate algorithm's cost model.
    """
    c = np.asarray(cost_matrix, dtype=np.float64)
    n = c.shape[0]
    if hierarchy is None or hierarchy.flat:
        return np.asarray(_order_ring(c, list(range(n))), dtype=np.int64)
    if hierarchy.n != n:
        raise ValueError(
            f"hierarchy covers {hierarchy.n} nodes but the cost matrix has "
            f"{n}; restrict() the hierarchy to the group first")
    units: List[List[int]] = [
        _order_ring(c, list(b)) for b in hierarchy.blocks(0)]
    for t in range(1, hierarchy.n_tiers + 1):
        if len(units) == 1:
            break
        if t < hierarchy.n_tiers:
            lab = hierarchy.labels(t)
            parents = [int(lab[u[0]]) for u in units]
        else:
            parents = [0] * len(units)
        nc = _unit_mean_cost(c, units)
        groups: Dict[int, List[int]] = {}
        for idx, p in enumerate(parents):
            groups.setdefault(p, []).append(idx)
        new_units: List[List[int]] = []
        for p in sorted(groups):
            order = _order_ring(nc, groups[p])
            new_units.append(_splice(c, [units[i] for i in order]))
        units = new_units
    if len(units) > 1:                     # top tier did not reach the root
        nc = _unit_mean_cost(c, units)
        order = _order_ring(nc, list(range(len(units))))
        units = [_splice(c, [units[i] for i in order])]
    return np.asarray(units[0], dtype=np.int64)


def optimize_rank_order_hierarchical(
    cost_matrix: np.ndarray,
    hierarchy: Optional[HierarchyModel],
    algo: str = "ring",
    size_bytes: float = 0.0,
    seed: int = 0,
    **kwargs,
) -> SolveResult:
    """Rank reordering by hierarchy decomposition (solve per cluster,
    then inter-cluster over supernodes) instead of a flat n-sized
    stochastic search.  Falls back to the flat construction heuristic
    on a flat (structureless) hierarchy."""
    timer = obs.tracer().timer("reorder.hierarchical", algo=algo)
    with timer:
        model = make_cost_model(algo, cost_matrix, size_bytes, **kwargs)
        perm = hierarchical_perm(cost_matrix, hierarchy, seed=seed)
        cost = float(model.cost(perm))
    return SolveResult(perm=perm, cost=cost,
                       trace=[("hierarchical", 0, cost)],
                       wall_s=timer.elapsed)


# ---------------------------------------------------------------------------
# N-D mesh assignment
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshPlan:
    """Result of an N-D mesh reordering."""

    assignment: np.ndarray          # int array, shape mesh_shape -> device id
    axis_names: Tuple[str, ...]
    cost: float                     # weighted objective after optimization
    baseline_cost: float            # same objective for the identity order
    per_axis: Dict[str, float]      # optimized per-axis cost

    @property
    def flat(self) -> np.ndarray:
        return self.assignment.reshape(-1)


def _group_greedy(c: np.ndarray, units: List[int], k: int) -> List[List[int]]:
    """Partition ``units`` into groups of size k with low intra-group cost.

    Greedy agglomeration: seed each group with the unassigned unit that is
    farthest from all others (hardest to place), then grow by repeatedly
    adding the unit with the smallest mean cost to the current group.

    Vectorized: instead of re-slicing submatrices per pick (the seed's
    O(m^2 k) inner loops), two running sum vectors — cost-to-remaining
    and cost-to-current-group — are updated with one O(m) axpy per pick,
    so the whole partition is O(m^2) with m numpy ops total.
    """
    units = list(units)
    m = len(units)
    active = np.ones(m, dtype=bool)
    cu = c if units == list(range(c.shape[0])) else c[np.ix_(units, units)]
    sum_rem = cu.sum(axis=1)                       # cost to remaining units
    groups: List[List[int]] = []
    n_active = m
    while n_active > k:
        seed_i = int(np.argmax(np.where(active, sum_rem, -np.inf)))
        group = [seed_i]
        active[seed_i] = False
        sum_rem -= cu[:, seed_i]
        sum_grp = cu[:, seed_i].copy()             # cost to current group
        while len(group) < k:
            pick = int(np.argmin(np.where(active, sum_grp, np.inf)))
            group.append(pick)
            active[pick] = False
            sum_rem -= cu[:, pick]
            sum_grp += cu[:, pick]
        groups.append(group)
        n_active -= k
    rest = np.nonzero(active)[0]
    if rest.size:
        groups.append([int(i) for i in rest])
    return [[units[i] for i in g] for g in groups]


def _group_greedy_reference(c: np.ndarray, units: List[int], k: int) -> List[List[int]]:
    """Seed greedy agglomeration (per-pick submatrix slicing), kept
    verbatim for the equivalence property tests and benchmarks."""
    remaining = set(units)
    groups: List[List[int]] = []
    while remaining:
        rem = list(remaining)
        if len(rem) <= k:
            groups.append(rem)
            break
        sub = c[np.ix_(rem, rem)]
        seed_i = rem[int(np.argmax(sub.sum(axis=1)))]
        group = [seed_i]
        remaining.remove(seed_i)
        while len(group) < k:
            rem = list(remaining)
            costs = c[np.ix_(rem, group)].mean(axis=1)
            pick = rem[int(np.argmin(costs))]
            group.append(pick)
            remaining.remove(pick)
        groups.append(group)
    return groups


def _order_ring(c: np.ndarray, members: List[int]) -> List[int]:
    """Order ``members`` along a ring with 2-opt + Or-opt on the submatrix."""
    if len(members) <= 3:
        return list(members)
    sub = c[np.ix_(members, members)]
    perm = two_opt(sub, np.arange(len(members)))
    perm = or_opt(sub, perm)
    return [members[i] for i in perm]


def default_axis_weights(axis_names: Sequence[str]) -> Dict[str, float]:
    """Relative traffic weights per axis role (TP >> DP > pod-DP)."""
    w = {}
    for name in axis_names:
        if name in ("model", "tensor", "tp"):
            w[name] = 100.0     # per-layer activation collectives
        elif name in ("expert", "ep"):
            w[name] = 30.0      # per-layer all-to-alls
        elif name in ("data", "fsdp", "dp"):
            w[name] = 10.0      # per-step gradient reduction
        elif name in ("pod", "dcn"):
            w[name] = 1.0       # per-step, but DCN bytes are precious
        else:
            w[name] = 1.0
    return w


def _collapse_cost(cost_matrix: np.ndarray, new_units: List[List[int]]) -> np.ndarray:
    """Inter-group mean cost matrix after collapsing groups to supernodes.

    All units have equal size on the mesh path, so the seed's O(m^2)
    Python loop of submatrix ``.mean()`` calls becomes one blocked
    reduction: gather the permuted matrix, reshape to [m, b, m, b], mean
    over the block axes.
    """
    m = len(new_units)
    sizes = {len(u) for u in new_units}
    if len(sizes) == 1:
        ids = np.asarray(new_units, dtype=np.int64).reshape(-1)
        b = len(new_units[0])
        blk = cost_matrix[np.ix_(ids, ids)].reshape(m, b, m, b)
        nc = blk.mean(axis=(1, 3))
        np.fill_diagonal(nc, 0.0)
        return nc
    return _collapse_cost_reference(cost_matrix, new_units)


def _collapse_cost_reference(cost_matrix: np.ndarray,
                             new_units: List[List[int]]) -> np.ndarray:
    """Seed supernode collapse: O(m^2) Python loop of submatrix means.

    Kept as the ``engine="reference"`` implementation and as
    :func:`_collapse_cost`'s unequal-size fallback.
    """
    m = len(new_units)
    nc = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i == j:
                continue
            nc[i, j] = cost_matrix[np.ix_(new_units[i], new_units[j])].mean()
    return nc


def optimize_mesh_assignment(
    cost_matrix: np.ndarray,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str],
    axis_weights: Optional[Dict[str, float]] = None,
    seed: int = 0,
    engine: str = "vectorized",
    hierarchy: Optional[HierarchyModel] = None,
) -> MeshPlan:
    """Hierarchical N-D rank reordering (see module docstring).

    ``engine="reference"`` runs the seed implementation (per-pick
    submatrix means in the grouping loop, O(m^2) Python supernode
    collapse) — kept for equivalence tests and benchmarks.

    ``hierarchy``, when given (a recovered
    :class:`repro.fabric.HierarchyModel`), replaces the greedy
    agglomeration on the hottest axis with supernode collapse over the
    inferred blocks: devices are laid out along a locality-nested ring
    (:func:`hierarchical_perm`) and the axis groups are consecutive
    slices of it — already local, already ordered.
    """
    mesh_shape = tuple(mesh_shape)
    axis_names = tuple(axis_names)
    n = int(np.prod(mesh_shape))
    assert cost_matrix.shape == (n, n)
    weights = axis_weights or default_axis_weights(axis_names)
    group_greedy = (_group_greedy_reference if engine == "reference"
                    else _group_greedy)

    # Process axes hottest-first; by convention that is innermost-first
    # (model), which also matches how group nesting composes.
    order = sorted(range(len(mesh_shape)), key=lambda a: -weights[axis_names[a]])

    # units: currently-assembled blocks of device ids, in axis-nesting order.
    units: List[List[int]] = [[i] for i in range(n)]
    unit_cost = cost_matrix.copy()

    axis_members: Dict[int, List[List[int]]] = {}
    for a in order:
        k = mesh_shape[a]
        ids = list(range(len(units)))
        if hierarchy is not None and not hierarchy.flat \
                and engine != "reference" and len(units) == n:
            # hottest axis over the raw devices: slice the locality-
            # nested ring instead of greedy agglomeration from scratch
            ring = hierarchical_perm(unit_cost, hierarchy, seed=seed)
            groups = [list(ring[i:i + k]) for i in range(0, n, k)]
            groups = [_order_ring(unit_cost, g) for g in groups]
        else:
            groups = group_greedy(unit_cost, ids, k)
            groups = [_order_ring(unit_cost, g) for g in groups]
        axis_members[a] = groups
        # Collapse: each ordered group becomes one unit.
        new_units: List[List[int]] = []
        for g in groups:
            merged: List[int] = []
            for u in g:
                merged.extend(units[u])
            new_units.append(merged)
        if engine == "reference":
            nc = _collapse_cost_reference(cost_matrix, new_units)
        else:
            nc = _collapse_cost(cost_matrix, new_units)
        units, unit_cost = new_units, nc

    # Reassemble the assignment: the nesting order of merges is `order`
    # reversed; reconstruct coordinates by unrolling group structure.
    # After the loop, len(units) == 1 and units[0] lists device ids in
    # nesting order: outermost processed axis slowest.
    flat = np.asarray(units[0], dtype=np.int64)
    # The merge loop nested blocks as [last-processed axis outermost ...
    # first-processed innermost]; reshape accordingly, then permute the
    # dims back to canonical mesh-axis order.
    rev = list(reversed(order))
    arr = flat.reshape([mesh_shape[a] for a in rev])
    assignment = np.transpose(arr, axes=[rev.index(a) for a in range(len(order))])

    base = np.arange(n, dtype=np.int64).reshape(mesh_shape)
    per_axis = {
        axis_names[a]: mesh_axis_cost(assignment, cost_matrix, a)
        for a in range(len(mesh_shape))
    }
    cost = mesh_total_cost(assignment, cost_matrix, axis_names, weights)
    baseline = mesh_total_cost(base, cost_matrix, axis_names, weights)
    return MeshPlan(
        assignment=assignment,
        axis_names=axis_names,
        cost=cost,
        baseline_cost=baseline,
        per_axis=per_axis,
    )


def mesh_axis_cost(
    assignment: np.ndarray, cost_matrix: np.ndarray, axis: int, algo: str = "ring"
) -> float:
    """Mean collective cost over all groups along ``axis`` of the assignment.

    All groups share one schedule structure (they have the same size), so
    every group is evaluated in a single batched gather over the full
    cost matrix — the structure comes from one template model, the node
    ids from the assignment rows.  Models without a flat round structure
    (the path-mode tree) fall back to the per-group loop.

    ``cost_matrix`` may be a :class:`repro.fabric.HierarchyModel`: the
    assignment is then priced on the tree's ultrametric
    :meth:`~repro.fabric.HierarchyModel.distance_ranks` — how many tier
    boundaries each hop crosses — which is noise-free and needs no
    probed matrix at all (drift-robust plan comparisons).
    """
    if isinstance(cost_matrix, HierarchyModel):
        cost_matrix = cost_matrix.distance_ranks().astype(np.float64)
    arr = np.moveaxis(assignment, axis, -1)
    groups = arr.reshape(-1, arr.shape[-1])
    g = groups.shape[1]
    if g < 2:
        return 0.0
    if algo == "ring":
        total = cost_matrix[groups, np.roll(groups, 1, axis=1)].sum()
        return float(total / len(groups))
    template = make_cost_model(algo, np.zeros((g, g)), 0.0)
    if template.rounds:
        total = np.zeros(len(groups))
        for rnd in template.rounds:
            a = groups[:, rnd.pairs[:, 0]]
            b = groups[:, rnd.pairs[:, 1]]
            edge = cost_matrix[a, b]
            if template.aggregator == "sum_of_max":
                total += edge.max(axis=1)
            else:
                total += edge.sum(axis=1)
        return float(total.sum() / len(groups))
    total = 0.0
    for grp in groups:
        sub = cost_matrix[np.ix_(grp, grp)]
        sub_model = make_cost_model(algo, sub, 0.0)
        total += sub_model.cost(np.arange(len(grp)))
    return total / max(len(groups), 1)


def mesh_total_cost(
    assignment: np.ndarray,
    cost_matrix: np.ndarray,
    axis_names: Sequence[str],
    axis_weights: Optional[Dict[str, float]] = None,
) -> float:
    weights = axis_weights or default_axis_weights(axis_names)
    if isinstance(cost_matrix, HierarchyModel):
        cost_matrix = cost_matrix.distance_ranks().astype(np.float64)
    return float(
        sum(
            weights[axis_names[a]] * mesh_axis_cost(assignment, cost_matrix, a)
            for a in range(assignment.ndim)
        )
    )


def random_assignment(mesh_shape: Sequence[int], seed: int = 0) -> np.ndarray:
    n = int(np.prod(tuple(mesh_shape)))
    return np.random.default_rng(seed).permutation(n).reshape(tuple(mesh_shape))
