"""Deprecated shim — probing moved to :mod:`repro.fabric`.

``repro.core.probe`` was absorbed into the unified fabric subsystem
(``repro.fabric.probe``); importing this module keeps working but
warns.  Migrate::

    from repro.core.probe import probe_fabric, cost_matrix    # old
    from repro.fabric import probe_fabric, cost_matrix        # new
"""

import warnings

warnings.warn(
    "repro.core.probe has moved to repro.fabric.probe (part of the "
    "unified repro.fabric subsystem); this import shim will be removed — "
    "import from repro.fabric instead",
    DeprecationWarning, stacklevel=2)

from repro.fabric.probe import (  # noqa: F401,E402
    ProbeResult,
    cost_matrix,
    probe_fabric,
    probe_mesh_pairwise,
)

__all__ = ["ProbeResult", "probe_fabric", "probe_mesh_pairwise", "cost_matrix"]
