"""Deprecated shim — the fabric generators moved to :mod:`repro.fabric`.

``repro.core.topology`` was absorbed into the unified fabric subsystem
(``repro.fabric.topology``) together with probing, hierarchy inference,
and sparse probing; importing this module keeps working but warns.
Migrate::

    from repro.core.topology import Fabric, make_datacenter   # old
    from repro.fabric import Fabric, make_datacenter          # new
"""

import warnings

warnings.warn(
    "repro.core.topology has moved to repro.fabric.topology (part of the "
    "unified repro.fabric subsystem); this import shim will be removed — "
    "import from repro.fabric instead",
    DeprecationWarning, stacklevel=2)

from repro.fabric.topology import (  # noqa: F401,E402
    Fabric,
    make_datacenter,
    make_tpu_fleet,
    scramble,
)

__all__ = ["Fabric", "make_datacenter", "make_tpu_fleet", "scramble"]
