"""Dynamic rank adaptation (paper §VI).

The paper sketches two runtime mechanisms we implement fully:

* **bottleneck replacement** — "we can determine the critical path and
  find bottleneck transfer between node n_i and n_j ... find a n_k to
  replace n_i such that the replacement results in a minimized cost
  objective".  :func:`bottleneck_swap` does exactly this: locate the
  critical edge via :meth:`CostModel.critical_edges`, try swapping either
  endpoint with every other node (batched evaluation), keep the best.

* **adaptation to dynamic traffic** — :class:`AdaptiveReranker` consumes
  refreshed cost matrices (from live TCP_INFO-style link monitoring, from
  re-probes, or from the trainer's straggler detector) and re-ranks when
  the current order has degraded beyond a threshold.  The paper notes the
  framework must tolerate rank changes cheaply because "a full mesh of
  connections can be established beforehand" — in JAX terms: rebuilding a
  Mesh over the same devices re-lowers cheaply against the compilation
  cache, and parameters move with a resharding collective.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from .cost_models import CostModel

__all__ = ["bottleneck_swap", "AdaptiveReranker", "StragglerDetector"]


def bottleneck_swap(
    cost_model: CostModel,
    perm: np.ndarray,
    max_rounds: int = 8,
) -> Tuple[np.ndarray, float, List[Tuple[int, int]]]:
    """Iteratively repair the critical edge by endpoint replacement.

    Returns (new_perm, new_cost, swaps applied).  Each round is O(N)
    candidate evaluations (batched), so this is cheap enough to run
    online between training steps.
    """
    perm = np.asarray(perm).copy()
    cur = cost_model.cost(perm)
    swaps: List[Tuple[int, int]] = []
    n = len(perm)
    pos_of = np.empty(n, dtype=np.int64)
    rows = np.arange(n)

    for _ in range(max_rounds):
        crit = cost_model.critical_edges(perm)
        if not crit:
            break
        a, b, _ = max(crit, key=lambda t: t[2])
        pos_of[perm] = rows
        # candidates for both endpoints in one [2n, n] batch: row
        # (e * n + k) swaps endpoint e's rank with node k's rank
        cands = np.tile(perm, (2 * n, 1))
        other_pos = pos_of[rows]
        for e, endpoint in enumerate((a, b)):
            pe = pos_of[endpoint]
            blk = cands[e * n : (e + 1) * n]
            blk[rows, pe] = perm[other_pos]
            blk[rows, other_pos] = endpoint
        costs = cost_model.cost_batch(cands)
        k = int(np.argmin(costs))
        if costs[k] >= cur - 1e-15:
            break
        e, kk = divmod(k, n)
        perm, cur = cands[k], float(costs[k])
        swaps.append(((a, b)[e], kk))
    return perm, cur, swaps


@dataclasses.dataclass
class AdaptiveReranker:
    """Re-rank online when the network (or a straggler) degrades.

    ``model_factory(cost_matrix) -> CostModel`` rebuilds the objective for
    a refreshed cost matrix; re-ranking triggers when the current order's
    cost exceeds ``threshold`` x its cost at the last (re)solve.
    """

    model_factory: Callable[[np.ndarray], CostModel]
    perm: np.ndarray
    threshold: float = 1.15
    #: cost of `perm` under the matrix that last produced it
    reference_cost: Optional[float] = None
    history: List[Tuple[float, float, bool]] = dataclasses.field(default_factory=list)

    def update(self, cost_matrix: np.ndarray) -> Tuple[np.ndarray, bool]:
        c = np.asarray(cost_matrix, dtype=np.float64)
        n = len(self.perm)
        if c.ndim != 2 or c.shape[0] != c.shape[1]:
            raise ValueError(
                f"AdaptiveReranker.update cost_matrix must be a square "
                f"[n, n] matrix; got shape {c.shape}")
        if c.shape[0] != n:
            raise ValueError(
                f"AdaptiveReranker.update cost_matrix covers {c.shape[0]} "
                f"nodes but the tracked permutation covers {n}")
        if np.isnan(c).any():
            raise ValueError(
                f"AdaptiveReranker.update cost_matrix contains "
                f"{int(np.isnan(c).sum())} NaN entries; a corrupted probe "
                f"sample must be dropped upstream, not fed into the "
                f"re-rank objective")
        if (c < 0).any():
            i, j = np.argwhere(c < 0)[0]
            raise ValueError(
                f"AdaptiveReranker.update cost_matrix contains negative "
                f"entries (first at [{i}, {j}] = {c[i, j]}); costs are "
                f"times and must be >= 0")
        model = self.model_factory(c)
        cur = model.cost(self.perm)
        if self.reference_cost is None:
            self.reference_cost = cur
        changed = False
        if cur > self.threshold * self.reference_cost:
            new_perm, new_cost, swaps = bottleneck_swap(model, self.perm)
            if swaps and new_cost < cur:
                self.perm = new_perm
                self.reference_cost = new_cost
                changed = True
                cur = new_cost
        self.history.append((float(cur), float(self.reference_cost), changed))
        return self.perm, changed


class StragglerDetector:
    """Per-node EWMA of step/transfer times -> cost-matrix inflation.

    Feeds :class:`AdaptiveReranker`: a node whose EWMA exceeds
    ``ratio_threshold`` x the median is treated as if all its links
    slowed down proportionally (the latency analogue of a slow worker).
    """

    def __init__(self, n: int, alpha: float = 0.2, ratio_threshold: float = 1.5):
        self.n = n
        self.alpha = alpha
        self.ratio_threshold = ratio_threshold
        self.ewma = np.zeros(n)
        self._initialized = np.zeros(n, dtype=bool)

    def observe(self, node: int, seconds: float) -> None:
        if not self._initialized[node]:
            self.ewma[node] = seconds
            self._initialized[node] = True
        else:
            self.ewma[node] = (1 - self.alpha) * self.ewma[node] + self.alpha * seconds

    def stragglers(self) -> np.ndarray:
        ready = self._initialized
        if ready.sum() < max(2, self.n // 2):
            return np.zeros(0, dtype=np.int64)
        med = np.median(self.ewma[ready])
        mask = ready & (self.ewma > self.ratio_threshold * med)
        return np.nonzero(mask)[0]

    def inflate(self, cost_matrix: np.ndarray) -> np.ndarray:
        """Return a copy of the cost matrix with straggler rows/cols scaled."""
        c = cost_matrix.copy()
        ready = self._initialized
        if not ready.any():
            return c
        med = float(np.median(self.ewma[ready])) or 1.0
        for node in self.stragglers():
            f = float(self.ewma[node] / med)
            c[node, :] *= f
            c[:, node] *= f
        np.fill_diagonal(c, 0.0)
        return c
