"""Rank-order solvers (paper §IV-C).

The paper minimizes C_O over the N! permutations with a two-stage process:

1. **stochastic search** — simulated annealing with "standard heuristics
   (e.g., permuting a random sub-array, permuting random pairs) for
   obtaining neighboring states and a timeout";
2. **solver refinement** — feed the SA incumbent C0 to an SMT solver as
   the constraint ``C_O < C0`` and let it tighten the bound.

Stage 1 is reproduced faithfully (:func:`solve_sa`, including the paper's
neighborhood moves).  Stage 2's Z3 is unavailable offline, so we
substitute deterministic refiners with the same contract (take the SA
incumbent, return something no worse):

* ring objectives are closed-tour TSPs — :func:`two_opt` / :func:`or_opt`
  with O(1) delta evaluation, and exact :func:`held_karp` for N <= 12;
* other objectives get a best-improvement pairwise-swap hill climb.

Beyond the paper, :func:`solve` also runs multi-chain SA with batched
vectorized cost evaluation (one numpy gather evaluates all chains), and a
greedy nearest-neighbor construction for ring inits.

Engine notes (see DESIGN.md §3): the SA hot path is fully vectorized —
:func:`_propose` generates one neighborhood move per chain with a handful
of numpy ops regardless of chain count (position-remap gathers and
argsort-key tricks), and for symmetric ring objectives each move carries
its changed-edge list so acceptance uses O(K) edge deltas
(:func:`_edge_delta`) instead of a full re-evaluation.  The seed
implementations are retained as ``engine="reference"``
(:func:`_propose_reference`, :func:`_or_opt_reference`) for equivalence
tests and the ``benchmarks/solver_scaling.py`` baseline.  An optional
``backend="jax"`` routes full ring evaluations through a ``jax.jit``
kernel (``repro.kernels.solver_eval``) for very large chain counts.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .cost_models import CostModel, RingCost

__all__ = [
    "SolveResult",
    "solve",
    "solve_sa",
    "solve_worst",
    "greedy_ring",
    "two_opt",
    "or_opt",
    "held_karp",
    "exhaustive",
    "swap_hill_climb",
]


@dataclasses.dataclass
class SolveResult:
    perm: np.ndarray
    cost: float
    trace: List[Tuple[str, int, float]]
    wall_s: float
    #: final states of the best few SA chains (vectorized engine only);
    #: stage-2 refiners use them as extra hill-climb starts
    pool: Optional[np.ndarray] = None

    def improvement_over(self, baseline_cost: float) -> float:
        return baseline_cost / max(self.cost, 1e-30)


# ---------------------------------------------------------------------------
# Constructive + exact
# ---------------------------------------------------------------------------

def greedy_ring(c: np.ndarray, start: int = 0) -> np.ndarray:
    """Nearest-neighbor tour construction on cost matrix ``c``."""
    n = c.shape[0]
    unvisited = set(range(n))
    unvisited.remove(start)
    perm = [start]
    cur = start
    while unvisited:
        nxt = min(unvisited, key=lambda j: c[cur, j])
        unvisited.remove(nxt)
        perm.append(nxt)
        cur = nxt
    return np.asarray(perm, dtype=np.int64)


def held_karp(c: np.ndarray) -> Tuple[np.ndarray, float]:
    """Exact closed-tour TSP via Held–Karp DP.  O(2^N * N^2); N <= ~13."""
    n = c.shape[0]
    assert n <= 13, "Held-Karp limited to N <= 13"
    full = 1 << (n - 1)  # subsets of {1..n-1}; city 0 fixed as start
    INF = np.inf
    dp = np.full((full, n - 1), INF)
    parent = np.full((full, n - 1), -1, dtype=np.int64)
    for j in range(n - 1):
        dp[1 << j, j] = c[0, j + 1]
    for mask in range(full):
        for j in range(n - 1):
            if not mask & (1 << j) or dp[mask, j] == INF:
                continue
            base = dp[mask, j]
            for k in range(n - 1):
                if mask & (1 << k):
                    continue
                nm = mask | (1 << k)
                cand = base + c[j + 1, k + 1]
                if cand < dp[nm, k]:
                    dp[nm, k] = cand
                    parent[nm, k] = j
    mask = full - 1
    costs = dp[mask] + c[1:, 0]
    j = int(np.argmin(costs))
    best = float(costs[j])
    tour = [j + 1]
    while parent[mask, j] >= 0:
        pj = int(parent[mask, j])
        mask ^= 1 << j
        j = pj
        tour.append(j + 1)
    tour.append(0)
    tour.reverse()
    return np.asarray(tour, dtype=np.int64), best


def exhaustive(cost_model: CostModel) -> Tuple[np.ndarray, float]:
    """Brute force over all N! permutations (N <= 8), batched eval."""
    n = cost_model.n
    assert n <= 8, "exhaustive limited to N <= 8"
    perms = np.asarray(list(itertools.permutations(range(n))), dtype=np.int64)
    costs = np.concatenate(
        [cost_model.cost_batch(perms[i : i + 8192]) for i in range(0, len(perms), 8192)]
    )
    k = int(np.argmin(costs))
    return perms[k].copy(), float(costs[k])


# ---------------------------------------------------------------------------
# Ring-specific local search (stage-2 refinement; TSP moves)
# ---------------------------------------------------------------------------

def _tour_cost(c: np.ndarray, perm: np.ndarray) -> float:
    return float(c[perm, np.roll(perm, 1)].sum())


def _apply_non_overlapping(perm: np.ndarray, moves, deltas) -> bool:
    """Greedily apply best-first non-overlapping improving reversals.

    ``moves`` is a sequence of (i, j) position pairs with i < j, sorted by
    delta; disjoint position intervals i..j+1 keep every pre-computed
    delta exact.  Returns True if any move was applied.
    """
    n = len(perm)
    occupied = np.zeros(n, dtype=bool)
    covered = 0
    applied = False
    for (i, j), d in zip(moves, deltas):
        if d >= -1e-15 or covered > n - 4:
            break
        wrap = j == n - 1              # span i..j+1 aliases position 0
        if occupied[i : j + 2].any() or (wrap and occupied[0]):
            continue
        occupied[i : j + 2] = True
        if wrap:
            occupied[0] = True
        covered += j + 2 - i
        perm[i + 1 : j + 1] = perm[i + 1 : j + 1][::-1]
        applied = True
    return applied


def two_opt(c: np.ndarray, perm: np.ndarray, max_sweeps: int = 200,
            neighbors: int = 12) -> np.ndarray:
    """Vectorized 2-opt on a closed tour, batched acceptance per sweep.

    Reversing the segment (i+1 .. j) replaces edges (i,i+1),(j,j+1) with
    (i,j),(i+1,j+1); for symmetric c the delta needs only those 4 edges.
    Each sweep evaluates candidate deltas in bulk, then greedily applies
    a best-first maximal set of *non-overlapping* improving reversals
    (disjoint position intervals keep every applied delta exact), so one
    sweep does the work of many single-move sweeps.

    For large N the sweeps run on a K-nearest-neighbor candidate list
    (a move is only ever improving if at least one created edge is
    short, so candidates pair each city with its K cheapest partners —
    O(N*K) per sweep instead of O(N^2)); full dense sweeps then verify
    convergence, so the fixpoint is a true full-2-opt local optimum.
    """
    perm = perm.copy()
    n = len(perm)
    if n < 4:
        return perm
    cand_k = min(128, (n * (n - 1)) // 2)

    def dense_sweep() -> bool:
        p = perm
        nxt = np.roll(p, -1)              # successor city of each position
        d_cur = c[p, nxt]                 # [n] current edge costs
        # cand[i, j] = c[p_i, p_j] + c[p_i+1, p_j+1] - d_i - d_j  (i < j);
        # cross2[i, j] = cross1[i+1, j+1] cyclically, so one gather + roll
        cross1 = c[np.ix_(p, p)]
        delta = cross1 + np.roll(cross1, (-1, -1), axis=(0, 1)) \
            - d_cur[:, None] - d_cur[None, :]
        # mask the no-op "reversals": i == j and adjacent (j == i+1 / wrap)
        np.fill_diagonal(delta, np.inf)
        flat = delta.ravel()
        flat[1 :: n + 1] = np.inf          # j == i + 1
        flat[n :: n + 1] = np.inf          # i == j + 1
        delta[0, n - 1] = delta[n - 1, 0] = np.inf
        # best-first top-k improving candidates (delta is symmetric; the
        # apply step canonicalizes i < j and dedups via the overlap check)
        top = np.argpartition(flat, cand_k - 1)[:cand_k]
        top = top[np.argsort(flat[top])]
        ij = [tuple(sorted(divmod(int(t), n))) for t in top]
        return _apply_non_overlapping(perm, ij, flat[top])

    use_knn = n >= 128 and neighbors > 0
    if use_knn:
        K = min(neighbors, n - 1)
        cc = c + np.where(np.eye(n, dtype=bool), np.inf, 0.0)
        knn = np.argpartition(cc, K - 1, axis=1)[:, :K]    # [n, K] node ids
        cnn = np.take_along_axis(c, knn, axis=1)           # static edge costs
        pos_of = np.empty(n, dtype=np.int64)

    def knn_sweep() -> bool:
        p = perm
        pos_of[p] = np.arange(n)
        nxt = np.roll(p, -1)
        d_cur = c[p, nxt]
        J = pos_of[knn[p]]                                 # [n, K] partner pos
        delta = cnn[p] + c[nxt[:, None], nxt[J]] \
            - d_cur[:, None] - d_cur[J]
        flat = delta.ravel()
        kk = min(cand_k, flat.size)
        top = np.argpartition(flat, kk - 1)[:kk]
        top = top[np.argsort(flat[top])]
        ij, ds = [], []
        for t in top:
            d = flat[t]
            if d >= -1e-15:
                break
            i, kcol = divmod(int(t), K)
            j = int(J[i, kcol])
            if i > j:
                i, j = j, i
            if j - i <= 1 or (i == 0 and j == n - 1):      # no-op moves
                continue
            ij.append((i, j))
            ds.append(d)
        return _apply_non_overlapping(perm, ij, ds) if ij else False

    knn_phase = use_knn
    for _ in range(max_sweeps):
        if knn_phase:
            if not knn_sweep():
                knn_phase = False      # verify convergence with dense sweeps
            continue
        if not dense_sweep():
            break
        knn_phase = use_knn
    return perm


def _two_opt_reference(c: np.ndarray, perm: np.ndarray, max_sweeps: int = 200) -> np.ndarray:
    """Seed 2-opt (one best-improvement reversal per sweep), kept verbatim
    as the ``engine="reference"`` stage-2 baseline."""
    perm = perm.copy()
    n = len(perm)
    for _ in range(max_sweeps):
        p = perm
        nxt = np.roll(p, -1)
        d_cur = c[p, nxt]
        cross1 = c[p[:, None], p[None, :]]
        cross2 = c[nxt[:, None], nxt[None, :]]
        delta = cross1 + cross2 - d_cur[:, None] - d_cur[None, :]
        iu = np.triu_indices(n, k=1)
        mask = (iu[1] - iu[0] == 1) | ((iu[0] == 0) & (iu[1] == n - 1))
        vals = delta[iu]
        vals[mask] = np.inf
        k = int(np.argmin(vals))
        if vals[k] >= -1e-15:
            break
        i, j = int(iu[0][k]), int(iu[1][k])
        perm[i + 1 : j + 1] = perm[i + 1 : j + 1][::-1]
    return perm


def or_opt(c: np.ndarray, perm: np.ndarray, seg_lens=(1, 2, 3),
           max_sweeps: Optional[int] = None) -> np.ndarray:
    """Or-opt: relocate short segments to better positions (best-improve).

    Vectorized: each sweep evaluates every (segment start, segment length,
    insertion slot) relocation delta with three [n, n] gathers per length,
    then greedily applies a best-first set of *non-overlapping* improving
    relocations — a relocation only permutes positions inside the
    interval spanned by its segment and insertion slot, so moves with
    disjoint intervals keep each other's pre-computed deltas and position
    indices exact (the same argument as ``two_opt``'s batched
    acceptance).  One sweep therefore applies O(n / interval) moves and
    the fixpoint is reached within ``max_sweeps`` recomputations even at
    large N.  Handles asymmetric cost matrices (directed edge costs
    throughout).

    ``max_sweeps=None`` (default) budgets ``max(50, n)`` sweeps — a
    relocation's interval spans segment-to-slot, so overlap rejection can
    cap a sweep at a handful of applied moves and a cold start needs
    O(n) sweeps to reach the fixpoint.  An explicit ``max_sweeps`` is
    respected as a hard cap for callers bounding runtime.
    """
    perm = np.asarray(perm, dtype=np.int64).copy()
    n = len(perm)
    if n < 4:
        return perm
    pos = np.arange(n)
    top_k = 64
    if max_sweeps is None:
        max_sweeps = max(50, n)
    for _ in range(max_sweeps):
        p = perm
        pprev = np.roll(p, 1)            # pprev[k] = p[k-1]
        dcur = c[pprev, p]               # [n] cost of edge k
        cand_i: list = []
        cand_L: list = []
        cand_k: list = []
        cand_d: list = []
        for L in seg_lens:
            if L >= n - 1:
                continue
            i = pos[: n - L + 1]         # segment start (no wrap, as seed)
            j = i + L - 1
            s0, s1 = p[i], p[j]
            prev_node = p[(i - 1) % n]
            next_node = p[(j + 1) % n]
            gain = c[prev_node, s0] + c[s1, next_node] - c[prev_node, next_node]
            # delta[ii, k]: move segment ii into the slot at edge k
            add = c[np.ix_(pprev, s0)].T + c[np.ix_(s1, p)] - dcur[None, :]
            delta = add - gain[:, None]
            # slots at edges destroyed by the removal are invalid
            km = (pos[None, :] - i[:, None]) % n
            delta[km <= L] = np.inf
            flat = delta.ravel()
            top = np.argpartition(flat, min(top_k, flat.size - 1))[:top_k]
            good = top[flat[top] < -1e-15]
            if good.size:
                ii, kk = np.divmod(good, n)
                cand_i.append(i[ii])
                cand_L.append(np.full(good.size, L))
                cand_k.append(kk)
                cand_d.append(flat[good])
        if not cand_d:
            break
        d = np.concatenate(cand_d)
        ci = np.concatenate(cand_i)
        cL = np.concatenate(cand_L)
        ck = np.concatenate(cand_k)
        occupied = np.zeros(n, dtype=bool)
        applied = False
        for t in np.argsort(d):
            i, L, k = int(ci[t]), int(cL[t]), int(ck[t])
            # positions/edges the move may change: the segment, the slot,
            # everything shifted between them, plus both boundary edges
            span = np.arange(min(i, k) - 1, max(i + L, k) + 1) % n
            if occupied[span].any():
                continue
            occupied[span] = True
            seg = perm[i : i + L].copy()
            rest = np.concatenate([perm[:i], perm[i + L :]])
            slot = k if k < i else k - L
            perm = np.concatenate([rest[:slot], seg, rest[slot:]])
            applied = True
        if not applied:
            break
    return perm


def _or_opt_reference(c: np.ndarray, perm: np.ndarray, seg_lens=(1, 2, 3),
                      max_sweeps: int = 50) -> np.ndarray:
    """Seed or-opt (first-improve, per-candidate Python loops).

    Kept verbatim as the ``engine="reference"`` stage-2 baseline for the
    equivalence property tests and the scaling benchmark.
    """
    perm = list(perm)
    n = len(perm)

    def edge(a: int, b: int) -> float:
        return float(c[perm[a % n], perm[b % n]])

    improved = True
    sweeps = 0
    while improved and sweeps < max_sweeps:
        improved = False
        sweeps += 1
        for L in seg_lens:
            for i in range(n):
                j = i + L - 1
                if j >= n:
                    continue
                gain_remove = edge(i - 1, i) + edge(j, j + 1) - edge(i - 1, j + 1)
                if gain_remove <= 1e-15:
                    continue
                seg = perm[i : j + 1]
                rest = perm[:i] + perm[j + 1 :]
                best_pos, best_add = None, np.inf
                m = len(rest)
                for k in range(m):
                    a, b = rest[k - 1], rest[k % m]
                    add = float(c[a, seg[0]] + c[seg[-1], b] - c[a, b])
                    if add < best_add:
                        best_add, best_pos = add, k
                if best_add < gain_remove - 1e-15:
                    perm = rest[:best_pos] + seg + rest[best_pos:]
                    improved = True
    return np.asarray(perm, dtype=np.int64)


def swap_hill_climb(cost_model: CostModel, perm: np.ndarray, max_sweeps: int = 30) -> np.ndarray:
    """Generic stage-2 refiner: best pairwise swap until no improvement.

    Batched: each sweep evaluates all N(N-1)/2 swap neighbors in chunks
    with ``cost_batch``.
    """
    perm = perm.copy()
    n = len(perm)
    pairs = np.asarray([(i, j) for i in range(n) for j in range(i + 1, n)])
    cur = cost_model.cost(perm)
    for _ in range(max_sweeps):
        cands = np.tile(perm, (len(pairs), 1))
        rows = np.arange(len(pairs))
        a = cands[rows, pairs[:, 0]].copy()
        cands[rows, pairs[:, 0]] = cands[rows, pairs[:, 1]]
        cands[rows, pairs[:, 1]] = a
        costs = np.concatenate(
            [cost_model.cost_batch(cands[i : i + 4096]) for i in range(0, len(cands), 4096)]
        )
        k = int(np.argmin(costs))
        if costs[k] >= cur - 1e-15:
            break
        perm = cands[k]
        cur = float(costs[k])
    return perm


# ---------------------------------------------------------------------------
# Simulated annealing (stage-1, paper-faithful moves, multi-chain batched)
# ---------------------------------------------------------------------------

#: Per-move changed-edge slots (pair swap 4, reversal 2, window shuffle
#: <= 7, span roll 3); unused slots are padded with duplicates which the
#: delta evaluator masks after a sort.
_EDGE_SLOTS = 8


def _propose_moves(M: int, n: int, rng: np.random.Generator):
    """Generate M state-independent neighborhood moves (paper heuristics).

    * permute random pairs (swap),
    * permute a random sub-array (reversal — the 2-opt move — and a
      random shuffle of a short window),
    * segment relocation (or-opt move), expressed as a cyclic roll of a
      random span so positions outside the span are untouched and only
      three tour edges change.

    Every move is a pure position remap, so it is generated *without*
    the current permutations: ``proposal = perms[src]`` applies it.  The
    SA loop exploits this to pre-generate whole blocks of iterations in
    one vectorized shot.

    Returns ``(src, edge_new, edge_old)``: the remap [M, n] plus two
    (padded) tour-edge position lists per move — edges the move creates
    (positions in the proposal) and edges it destroys (positions in the
    input); edge ``e`` is the adjacency between positions ``e-1`` and
    ``e``.  The lists coincide for position-preserving moves but differ
    for the span roll, whose junctions land at shifted positions.  They
    enable O(K) ring-cost deltas (reversal entries assume a symmetric
    matrix; the caller gates on that).
    """
    idt = np.int16 if n < (1 << 15) else np.int32
    pos = np.arange(n, dtype=idt)
    src = np.tile(pos, (M, 1))
    edge_new = np.zeros((M, _EDGE_SLOTS), dtype=np.int32)
    edge_old = edge_new
    if n < 2:
        return src, edge_new, edge_old
    kinds = rng.integers(0, 4, size=M)

    sel = np.nonzero(kinds == 0)[0]          # --- pair swap
    if sel.size:
        ij = rng.integers(0, n, size=(sel.size, 2), dtype=idt)
        i, j = ij[:, 0], ij[:, 1]
        src[sel, i] = j
        src[sel, j] = i
        edge_new[sel, 0] = i
        edge_new[sel, 1] = (i + 1) % n
        edge_new[sel, 2] = j
        edge_new[sel, 3] = (j + 1) % n
        edge_new[sel, 4:] = i[:, None]

    sel = np.nonzero(kinds == 1)[0]          # --- sub-array reversal
    if sel.size:
        ij = np.sort(rng.integers(0, n, size=(sel.size, 2), dtype=idt), axis=1)
        i, j = ij[:, 0][:, None], ij[:, 1][:, None]
        src[sel] = np.where((pos >= i) & (pos <= j), i + j - pos, pos[None, :])
        edge_new[sel, 0] = ij[:, 0]
        edge_new[sel, 1] = (ij[:, 1] + 1) % n
        edge_new[sel, 2:] = ij[:, 0][:, None]

    sel = np.nonzero(kinds == 2)[0]          # --- short-window shuffle
    if sel.size:
        m = sel.size
        wmax = min(6, n)
        i = rng.integers(0, n, size=m, dtype=idt)
        w = rng.integers(2, wmax + 1, size=m, dtype=idt)
        ar = np.arange(wmax, dtype=idt)
        # argsort-key trick: random keys on the first w slots produce a
        # uniform permutation there; ordered keys keep the tail in place.
        keys = np.where(ar[None, :] < w[:, None],
                        rng.random((m, wmax)), 1.0 + ar[None, :])
        sigma = np.argsort(keys, axis=1)
        # widen before the add: i + ar can exceed the int16 range for
        # n within wmax of 2**15, corrupting the wrap-around window
        winpos = (i[:, None].astype(np.int32) + np.arange(wmax)) % n
        # sparse scatter: only the <= wmax window columns change per row
        flat_idx = winpos.astype(np.int64) + (sel[:, None] * n)
        src.reshape(-1)[flat_idx] = np.take_along_axis(winpos, sigma, axis=1)
        cols = np.arange(_EDGE_SLOTS, dtype=np.int32)
        edge_new[sel] = (i[:, None] + np.minimum(cols[None, :], w[:, None])) % n

    sel = np.nonzero(kinds == 3)[0]          # --- span roll (relocation)
    if sel.size and n >= 3:
        m = sel.size
        a = rng.integers(0, n - 1, size=m, dtype=idt)
        # span length capped at n-1: a full-ring roll is a pure rotation
        # (cost no-op) whose uniformly shifted edges defeat edge deltas
        s = rng.integers(2, np.minimum(n - a, n - 1) + 1, dtype=idt)
        # roll by d (or s-d) relocates a short d-element segment across
        # the span — matching the seed's 1..3-element relocation moves
        # (a roll by r in the middle of the range would displace every
        # span element, a far larger perturbation than the paper's move)
        d = rng.integers(1, np.minimum(3, s - 1) + 1, dtype=idt)
        r = np.where(rng.random(m) < 0.5, s - d, d).astype(idt)
        rel = pos[None, :] - a[:, None]
        inspan = (rel >= 0) & (rel < s[:, None])
        # (rel - r) mod s without integer division: rel - r is in [-r, s-r)
        shifted = rel - r[:, None]
        shifted += (shifted < 0) * s[:, None]
        src[sel] = np.where(inspan, a[:, None] + shifted, pos[None, :])
        # junctions land at different positions in the two frames:
        # created edges at {a, a+r, a+s}, destroyed at {a, a+s-r, a+s}
        edge_old = edge_new.copy()
        b = a + s
        edge_new[sel, 0] = a
        edge_new[sel, 1] = (a + r) % n
        edge_new[sel, 2] = b % n
        edge_new[sel, 3:] = a[:, None]
        edge_old[sel, 0] = a
        edge_old[sel, 1] = (b - r) % n
        edge_old[sel, 2] = b % n
        edge_old[sel, 3:] = a[:, None]

    return src, edge_new, edge_old


def _propose(perms: np.ndarray, rng: np.random.Generator,
             return_edges: bool = False):
    """One neighborhood move per chain, all chains at once."""
    P, n = perms.shape
    src, edge_new, edge_old = _propose_moves(P, n, rng)
    out = np.take_along_axis(perms, src, axis=1)
    return (out, edge_new, edge_old) if return_edges else out


def _propose_reference(perms: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Seed proposal kernel (per-chain Python loop), kept verbatim for
    ``engine="reference"`` baselines and equivalence tests."""
    out = perms.copy()
    P, n = perms.shape
    kinds = rng.integers(0, 4, size=P)
    for p in range(P):
        k = kinds[p]
        if k == 0:  # pair swap
            i, j = rng.integers(0, n, size=2)
            out[p, i], out[p, j] = out[p, j], out[p, i]
        elif k == 1:  # sub-array reversal
            i, j = np.sort(rng.integers(0, n, size=2))
            out[p, i : j + 1] = out[p, i : j + 1][::-1]
        elif k == 2:  # sub-array shuffle (short window)
            i = rng.integers(0, n)
            w = int(rng.integers(2, min(6, n) + 1))
            idx = (i + np.arange(w)) % n
            out[p, idx] = out[p, idx[rng.permutation(w)]]
        else:  # segment relocation
            L = int(rng.integers(1, min(4, n)))
            i = int(rng.integers(0, n - L + 1))
            seg = out[p, i : i + L].copy()
            rest = np.delete(out[p], np.s_[i : i + L])
            k2 = int(rng.integers(0, len(rest) + 1))
            out[p] = np.concatenate([rest[:k2], seg, rest[k2:]])
    return out


def _edge_sum(cmat: np.ndarray, perms: np.ndarray, edge_idx: np.ndarray) -> np.ndarray:
    """Sum of ring-edge costs ``cmat[perm[e], perm[e-1]]`` over the unique
    edges in each chain's (padded) list — duplicates are masked after an
    in-row sort.  O(P * K), independent of N."""
    n = perms.shape[1]
    es = np.sort(edge_idx, axis=1)
    dup = np.zeros(es.shape, dtype=bool)
    dup[:, 1:] = es[:, 1:] == es[:, :-1]
    prev = (es - 1) % n
    cost = cmat[np.take_along_axis(perms, es, 1), np.take_along_axis(perms, prev, 1)]
    cost[dup] = 0.0
    return cost.sum(axis=1)


def _edge_delta(cmat: np.ndarray, old: np.ndarray, new: np.ndarray,
                edge_new: np.ndarray, edge_old: np.ndarray) -> np.ndarray:
    """Ring-cost delta per chain: created-edge sum minus destroyed-edge
    sum.  The two lists coincide for position-preserving moves; the span
    roll destroys edges at positions shifted from where it creates them.
    """
    return _edge_sum(cmat, new, edge_new) - _edge_sum(cmat, old, edge_old)


def solve_sa(
    cost_model: CostModel,
    iters: int = 3000,
    chains: int = 16,
    t0: Optional[float] = None,
    t_final_frac: float = 1e-3,
    seed: int = 0,
    init: Optional[np.ndarray] = None,
    timeout_s: Optional[float] = None,
    maximize: bool = False,
    engine: str = "vectorized",
    backend: str = "numpy",
    resync_every: int = 256,
) -> SolveResult:
    """Multi-chain simulated annealing with batched cost evaluation.

    ``engine="vectorized"`` (default) proposes moves for all chains with
    vectorized numpy and, for symmetric ring objectives, scores them with
    O(K) edge deltas (full evaluations only every ``resync_every`` iters
    to cancel float drift).  ``engine="reference"`` is the seed per-chain
    loop with full re-evaluation every iteration.  ``backend="jax"``
    routes full ring evaluations through the jitted batched evaluator in
    ``repro.kernels.solver_eval`` (useful at very large chain counts).
    """
    # solver wall clock stays raw: the SA hot loop checks timeout_s
    # per iteration and cannot afford a tracer call per check
    t_start = time.perf_counter()  # lint: allow(raw-perf-counter)
    rng = np.random.default_rng(seed)
    n = cost_model.n
    sign = -1.0 if maximize else 1.0

    evaluate = cost_model.cost_batch
    ring_mat = None
    if isinstance(cost_model, RingCost):
        ring_mat = _ring_matrix(cost_model)
        if backend == "jax":
            from ..kernels.solver_eval import make_ring_evaluator

            evaluate = make_ring_evaluator(ring_mat)
    use_delta = (
        engine == "vectorized"
        and ring_mat is not None
        and np.array_equal(ring_mat, ring_mat.T)
    )

    perms = np.stack([rng.permutation(n) for _ in range(chains)])
    if init is not None:
        perms[0] = np.asarray(init)
    costs = sign * evaluate(perms)
    best_i = int(np.argmin(costs))
    best_perm, best_cost = perms[best_i].copy(), float(costs[best_i])
    trace: List[Tuple[str, int, float]] = [("sa", 0, sign * best_cost)]

    if t0 is None:
        t0 = float(np.std(costs)) + 1e-12
    t_final = max(t0 * t_final_frac, 1e-30)

    if engine == "reference":
        for it in range(1, iters + 1):
            temp = t0 * (t_final / t0) ** (it / iters)
            proposal = _propose_reference(perms, rng)
            new_costs = sign * evaluate(proposal)
            accept = (new_costs < costs) | (
                rng.random(chains)
                < np.exp(np.clip((costs - new_costs) / temp, -60, 0))
            )
            perms[accept] = proposal[accept]
            costs[accept] = new_costs[accept]
            i = int(np.argmin(costs))
            if costs[i] < best_cost:
                best_cost = float(costs[i])
                best_perm = perms[i].copy()
                trace.append(("sa", it, sign * best_cost))
            if timeout_s is not None and \
                    time.perf_counter() - t_start > timeout_s:  # lint: allow(raw-perf-counter)
                break
    else:
        # Vectorized engine: moves are state-independent position remaps,
        # so whole blocks of iterations are pre-generated in one shot —
        # including the flattened gather indices and signed dedup weights
        # for the O(K) ring delta — and the sequential loop is one [P,32]
        # gather plus ~a dozen tiny numpy ops per iteration.
        # Pre-generate moves in blocks sized to stay cache-friendly.
        block = max(32, min(256, (1 << 22) // max(chains * n, 1)))
        K = _EDGE_SLOTS
        perms = np.ascontiguousarray(perms, dtype=np.int32)
        best_perm = best_perm.astype(np.int32)
        perms_flat = perms.reshape(-1)           # view; updated in place
        chain_off = (np.arange(chains, dtype=np.int32) * n)[:, None]
        cflat = ring_mat.reshape(-1) if use_delta else None
        np_nonzero = np.nonzero
        perf_counter = time.perf_counter  # lint: allow(raw-perf-counter)
        it = 0
        stop = False
        while it < iters and not stop:
            B = min(block, iters - it)
            M = B * chains
            src_b, e_new, e_old = _propose_moves(M, n, rng)
            u_acc = rng.random((B, chains))
            # log-space acceptance: u < exp(min(arg, 0)) == log(u) < arg
            # (improving moves have arg > 0 > log u, so they always pass)
            with np.errstate(divide="ignore"):
                log_u = np.log(u_acc)
            temps = t0 * (t_final / t0) ** (np.arange(it + 1, it + B + 1) / iters)
            neg_inv_t = (-sign / temps)
            src_b = src_b.reshape(B, chains, n)
            if use_delta:
                # per-row flat offsets into perms_flat (row r -> chain r%chains)
                moff = np.tile(chain_off.T.reshape(1, chains), (B, 1)).reshape(M, 1)
                es_n = np.sort(e_new, axis=1)
                es_o = np.sort(e_old, axis=1)
                # edge "a" side = value at position e, "b" side = position e-1;
                # the new frame reads through the move's src remap
                sflat = src_b.reshape(M, n)
                rows = (np.arange(M, dtype=np.int32) * n)[:, None]
                a_new = sflat.reshape(-1)[es_n + rows] + moff
                b_new = sflat.reshape(-1)[(es_n - 1) % n + rows] + moff
                a_old = es_o + moff
                b_old = (es_o - 1) % n + moff
                # one [.., 2, P, 2K] index tensor: a single per-iter gather
                # yields contiguous a- and b-side planes for the a*n+b fuse
                pos_ab = np.stack([
                    np.concatenate([a_new, a_old], axis=1).reshape(B, chains, 2 * K),
                    np.concatenate([b_new, b_old], axis=1).reshape(B, chains, 2 * K),
                ], axis=1)
                w_n = (es_n[:, 1:] != es_n[:, :-1])
                w_o = (es_o[:, 1:] != es_o[:, :-1])
                wsign = np.concatenate([
                    np.ones((M, 1)), w_n.astype(np.float64),
                    -np.ones((M, 1)), -w_o.astype(np.float64)], axis=1
                ).reshape(B, chains, 2 * K)
                # fold the acceptance scaling into the weights so the loop
                # computes arg = delta * (-sign/temp) with one dot product
                wsign_t = wsign * neg_inv_t[:, None, None]
                temp_back = -sign * temps            # arg -> delta
            for k in range(B):
                it += 1
                if use_delta:
                    vab = perms_flat[pos_ab[k]]              # [2, P, 2K]
                    ce = cflat[vab[0] * np.int32(n) + vab[1]]
                    arg = (ce * wsign_t[k]).sum(axis=1)      # delta * -sign/T
                    sel = np_nonzero(log_u[k] < arg)[0]
                    if sel.size:
                        perms[sel] = perms_flat[src_b[k][sel] + chain_off[sel]]
                        cs = costs[sel] + sign * (arg[sel] * temp_back[k])
                        costs[sel] = cs
                        mn = cs.min()
                        if mn < best_cost:
                            best_cost = float(mn)
                            best_perm = perms[sel[int(np.argmin(cs))]].copy()
                            trace.append(("sa", it, sign * best_cost))
                    if it % resync_every == 0:
                        costs = sign * evaluate(perms)
                else:
                    proposal = perms_flat[src_b[k] + chain_off]
                    new_costs = sign * evaluate(proposal)
                    accept = (new_costs < costs) | (
                        u_acc[k]
                        < np.exp(np.clip((costs - new_costs) / temps[k], -60, 0))
                    )
                    perms[accept] = proposal[accept]
                    costs[accept] = new_costs[accept]
                    i = int(np.argmin(costs))
                    if costs[i] < best_cost:
                        best_cost = float(costs[i])
                        best_perm = perms[i].copy()
                        trace.append(("sa", it, sign * best_cost))
                if (timeout_s is not None
                        and perf_counter() - t_start > timeout_s):
                    stop = True
                    break

    # Report the exact cost of the incumbent (the delta path accumulates
    # O(1e-15) float drift between resyncs).
    pool = None
    if engine != "reference":
        order = np.argsort(costs)[: min(3, chains)]
        pool = np.asarray(perms)[order].astype(np.int64)
    return SolveResult(
        perm=best_perm,
        cost=float(cost_model.cost(best_perm)),
        trace=trace,
        wall_s=time.perf_counter() - t_start,  # lint: allow(raw-perf-counter)
        pool=pool,
    )


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

def _ring_matrix(cost_model: CostModel) -> np.ndarray:
    """Effective symmetric edge-cost matrix for ring objectives."""
    if cost_model.c is not None:
        return cost_model.c
    return cost_model.lat + cost_model.size_bytes * cost_model.invbw


def solve(
    cost_model: CostModel,
    method: str = "auto",
    iters: int = 3000,
    chains: int = 16,
    seed: int = 0,
    timeout_s: Optional[float] = None,
    engine: str = "vectorized",
    backend: str = "numpy",
) -> SolveResult:
    """Full two-stage pipeline.

    ``method``:
      * ``"paper"`` — SA with the paper's moves, then stage-2 refinement
        (our Z3 substitute) seeded with the SA incumbent.
      * ``"auto"``  — additionally: exhaustive for tiny N, Held–Karp for
        small ring N, greedy+2-opt+Or-opt construction for rings; keeps
        the best of all candidates.
      * ``"sa"``    — stage-1 only.

    ``engine="reference"`` runs the seed implementation end to end (seed
    SA loop + first-improve or-opt); ``backend`` is forwarded to stage 1.
    """
    t_start = time.perf_counter()  # lint: allow(raw-perf-counter)
    n = cost_model.n
    is_ring = isinstance(cost_model, RingCost)
    oropt = _or_opt_reference if engine == "reference" else or_opt
    twoopt = _two_opt_reference if engine == "reference" else two_opt
    candidates: List[Tuple[np.ndarray, float, str]] = []

    if method == "auto" and n <= 8:
        perm, cost = exhaustive(cost_model)
        return SolveResult(perm, cost, [("exhaustive", 0, cost)],
                           time.perf_counter() - t_start)  # lint: allow(raw-perf-counter)

    sa = solve_sa(cost_model, iters=iters, chains=chains, seed=seed,
                  timeout_s=timeout_s, engine=engine, backend=backend)
    candidates.append((sa.perm, sa.cost, "sa"))
    trace = list(sa.trace)

    if method in ("paper", "auto"):
        # Stage 2: refine the incumbent (Z3-substitute, see module doc).
        if is_ring:
            c = _ring_matrix(cost_model)
            if n <= 12 and method == "auto":
                perm, cost = held_karp(c)
                candidates.append((perm, cost, "held_karp"))
            if engine == "reference":
                refined = oropt(c, twoopt(c, sa.perm))
            else:
                # alternate 2-opt / Or-opt (joint refinement), keeping the
                # best round by *model* cost: on asymmetric matrices the
                # refiners optimize the transposed tour direction (the
                # seed's convention), so a later round can regress the
                # model objective and must not overwrite an earlier win
                refined = np.asarray(sa.perm)
                best_c = cost_model.cost(refined)
                cand = refined
                for _ in range(2):
                    cand = oropt(c, twoopt(c, cand))
                    cur = cost_model.cost(cand)
                    if cur < best_c - 1e-12:
                        refined, best_c = cand, cur
                    else:
                        break
            candidates.append((refined, cost_model.cost(refined), "2opt+oropt"))
            if method == "auto":
                g = greedy_ring(c)
                g = oropt(c, twoopt(c, g))
                candidates.append((g, cost_model.cost(g), "greedy+2opt"))
        else:
            refined = swap_hill_climb(cost_model, sa.perm)
            candidates.append((refined, cost_model.cost(refined), "swap_hc"))
            # vectorized engine: also climb from the best few SA chain
            # states — different basins often beat the single incumbent
            if sa.pool is not None and n <= 128:
                for start in sa.pool:
                    r = swap_hill_climb(cost_model, np.asarray(start))
                    candidates.append((r, cost_model.cost(r), "swap_hc_pool"))

    perm, cost, tag = min(candidates, key=lambda t: t[1])
    trace.append((tag, -1, cost))
    return SolveResult(np.asarray(perm), float(cost), trace,
                       time.perf_counter() - t_start)  # lint: allow(raw-perf-counter)


def solve_worst(
    cost_model: CostModel, iters: int = 3000, chains: int = 16, seed: int = 0,
    engine: str = "vectorized",
) -> SolveResult:
    """Find a *bad* ordering (paper's speedup baseline is the worst order)."""
    return solve_sa(cost_model, iters=iters, chains=chains, seed=seed,
                    maximize=True, engine=engine)


def percentile_orders(
    cost_model: CostModel,
    best: np.ndarray,
    worst: np.ndarray,
    k: int = 10,
    pool: int = 600,
    seed: int = 0,
) -> List[np.ndarray]:
    """Rank orders spanning the solver's cost range (paper §V-B).

    The paper validates its cost model with "10 different rank orders,
    with the i-th order approximately corresponding to the 10i-th
    percentile in the range of costs found by the solver".  We rebuild
    that population with a random walk away from the best order (random
    pair swaps of increasing strength), then pick, for each of k evenly
    spaced cost targets between best and worst, the sampled order whose
    model cost is closest.
    """
    rng = np.random.default_rng(seed)
    n = cost_model.n
    samples = [np.asarray(best).copy(), np.asarray(worst).copy()]
    cur = np.asarray(best).copy()
    restart_every = max(pool // 4, 1)  # guard: pool < 4 must not div-by-zero
    for i in range(pool):
        for _ in range(1 + i * 3 // pool):
            a, b = rng.integers(0, n, size=2)
            cur[a], cur[b] = cur[b], cur[a]
        samples.append(cur.copy())
        if (i + 1) % restart_every == 0:  # restart walks from random points
            cur = rng.permutation(n)
    arr = np.stack(samples)
    costs = cost_model.cost_batch(arr)
    targets = np.linspace(costs.min(), costs.max(), k)
    picks = []
    for t in targets:
        picks.append(arr[int(np.argmin(np.abs(costs - t)))])
    return picks
