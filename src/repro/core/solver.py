"""Rank-order solvers (paper §IV-C).

The paper minimizes C_O over the N! permutations with a two-stage process:

1. **stochastic search** — simulated annealing with "standard heuristics
   (e.g., permuting a random sub-array, permuting random pairs) for
   obtaining neighboring states and a timeout";
2. **solver refinement** — feed the SA incumbent C0 to an SMT solver as
   the constraint ``C_O < C0`` and let it tighten the bound.

Stage 1 is reproduced faithfully (:func:`solve_sa`, including the paper's
neighborhood moves).  Stage 2's Z3 is unavailable offline, so we
substitute deterministic refiners with the same contract (take the SA
incumbent, return something no worse):

* ring objectives are closed-tour TSPs — :func:`two_opt` / :func:`or_opt`
  with O(1) delta evaluation, and exact :func:`held_karp` for N <= 12;
* other objectives get a best-improvement pairwise-swap hill climb.

Beyond the paper, :func:`solve` also runs multi-chain SA with batched
vectorized cost evaluation (one numpy gather evaluates all chains), and a
greedy nearest-neighbor construction for ring inits.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .cost_models import CostModel, RingCost

__all__ = [
    "SolveResult",
    "solve",
    "solve_sa",
    "solve_worst",
    "greedy_ring",
    "two_opt",
    "or_opt",
    "held_karp",
    "exhaustive",
    "swap_hill_climb",
]


@dataclasses.dataclass
class SolveResult:
    perm: np.ndarray
    cost: float
    trace: List[Tuple[str, int, float]]
    wall_s: float

    def improvement_over(self, baseline_cost: float) -> float:
        return baseline_cost / max(self.cost, 1e-30)


# ---------------------------------------------------------------------------
# Constructive + exact
# ---------------------------------------------------------------------------

def greedy_ring(c: np.ndarray, start: int = 0) -> np.ndarray:
    """Nearest-neighbor tour construction on cost matrix ``c``."""
    n = c.shape[0]
    unvisited = set(range(n))
    unvisited.remove(start)
    perm = [start]
    cur = start
    while unvisited:
        nxt = min(unvisited, key=lambda j: c[cur, j])
        unvisited.remove(nxt)
        perm.append(nxt)
        cur = nxt
    return np.asarray(perm, dtype=np.int64)


def held_karp(c: np.ndarray) -> Tuple[np.ndarray, float]:
    """Exact closed-tour TSP via Held–Karp DP.  O(2^N * N^2); N <= ~13."""
    n = c.shape[0]
    assert n <= 13, "Held-Karp limited to N <= 13"
    full = 1 << (n - 1)  # subsets of {1..n-1}; city 0 fixed as start
    INF = np.inf
    dp = np.full((full, n - 1), INF)
    parent = np.full((full, n - 1), -1, dtype=np.int64)
    for j in range(n - 1):
        dp[1 << j, j] = c[0, j + 1]
    for mask in range(full):
        for j in range(n - 1):
            if not mask & (1 << j) or dp[mask, j] == INF:
                continue
            base = dp[mask, j]
            for k in range(n - 1):
                if mask & (1 << k):
                    continue
                nm = mask | (1 << k)
                cand = base + c[j + 1, k + 1]
                if cand < dp[nm, k]:
                    dp[nm, k] = cand
                    parent[nm, k] = j
    mask = full - 1
    costs = dp[mask] + c[1:, 0]
    j = int(np.argmin(costs))
    best = float(costs[j])
    tour = [j + 1]
    while parent[mask, j] >= 0:
        pj = int(parent[mask, j])
        mask ^= 1 << j
        j = pj
        tour.append(j + 1)
    tour.append(0)
    tour.reverse()
    return np.asarray(tour, dtype=np.int64), best


def exhaustive(cost_model: CostModel) -> Tuple[np.ndarray, float]:
    """Brute force over all N! permutations (N <= 8), batched eval."""
    n = cost_model.n
    assert n <= 8, "exhaustive limited to N <= 8"
    perms = np.asarray(list(itertools.permutations(range(n))), dtype=np.int64)
    costs = np.concatenate(
        [cost_model.cost_batch(perms[i : i + 8192]) for i in range(0, len(perms), 8192)]
    )
    k = int(np.argmin(costs))
    return perms[k].copy(), float(costs[k])


# ---------------------------------------------------------------------------
# Ring-specific local search (stage-2 refinement; TSP moves)
# ---------------------------------------------------------------------------

def _tour_cost(c: np.ndarray, perm: np.ndarray) -> float:
    return float(c[perm, np.roll(perm, 1)].sum())


def two_opt(c: np.ndarray, perm: np.ndarray, max_sweeps: int = 200) -> np.ndarray:
    """Vectorized best-improvement 2-opt on a closed tour.

    Reversing the segment (i+1 .. j) replaces edges (i,i+1),(j,j+1) with
    (i,j),(i+1,j+1); for symmetric c the delta needs only those 4 edges —
    we evaluate all O(N^2) candidate deltas with one outer-sum per sweep.
    """
    perm = perm.copy()
    n = len(perm)
    for _ in range(max_sweeps):
        p = perm
        nxt = np.roll(p, -1)              # successor city of each position
        d_cur = c[p, nxt]                 # [n] current edge costs
        # cand[i, j] = c[p_i, p_j] + c[p_i+1, p_j+1] - d_i - d_j  (i < j)
        cross1 = c[p[:, None], p[None, :]]
        cross2 = c[nxt[:, None], nxt[None, :]]
        delta = cross1 + cross2 - d_cur[:, None] - d_cur[None, :]
        iu = np.triu_indices(n, k=1)
        # adjacent edges (j == i+1 or wrap) are no-ops; mask them
        mask = (iu[1] - iu[0] == 1) | ((iu[0] == 0) & (iu[1] == n - 1))
        vals = delta[iu]
        vals[mask] = np.inf
        k = int(np.argmin(vals))
        if vals[k] >= -1e-15:
            break
        i, j = int(iu[0][k]), int(iu[1][k])
        perm[i + 1 : j + 1] = perm[i + 1 : j + 1][::-1]
    return perm


def or_opt(c: np.ndarray, perm: np.ndarray, seg_lens=(1, 2, 3), max_sweeps: int = 50) -> np.ndarray:
    """Or-opt: relocate short segments to better positions (first-improve)."""
    perm = list(perm)
    n = len(perm)

    def edge(a: int, b: int) -> float:
        return float(c[perm[a % n], perm[b % n]])

    improved = True
    sweeps = 0
    while improved and sweeps < max_sweeps:
        improved = False
        sweeps += 1
        for L in seg_lens:
            for i in range(n):
                j = i + L - 1
                if j >= n:
                    continue
                gain_remove = edge(i - 1, i) + edge(j, j + 1) - edge(i - 1, j + 1)
                if gain_remove <= 1e-15:
                    continue
                seg = perm[i : j + 1]
                rest = perm[:i] + perm[j + 1 :]
                best_pos, best_add = None, np.inf
                m = len(rest)
                for k in range(m):
                    a, b = rest[k - 1], rest[k % m]
                    add = float(c[a, seg[0]] + c[seg[-1], b] - c[a, b])
                    if add < best_add:
                        best_add, best_pos = add, k
                if best_add < gain_remove - 1e-15:
                    perm = rest[:best_pos] + seg + rest[best_pos:]
                    improved = True
    return np.asarray(perm, dtype=np.int64)


def swap_hill_climb(cost_model: CostModel, perm: np.ndarray, max_sweeps: int = 30) -> np.ndarray:
    """Generic stage-2 refiner: best pairwise swap until no improvement.

    Batched: each sweep evaluates all N(N-1)/2 swap neighbors in chunks
    with ``cost_batch``.
    """
    perm = perm.copy()
    n = len(perm)
    pairs = np.asarray([(i, j) for i in range(n) for j in range(i + 1, n)])
    cur = cost_model.cost(perm)
    for _ in range(max_sweeps):
        cands = np.tile(perm, (len(pairs), 1))
        rows = np.arange(len(pairs))
        a = cands[rows, pairs[:, 0]].copy()
        cands[rows, pairs[:, 0]] = cands[rows, pairs[:, 1]]
        cands[rows, pairs[:, 1]] = a
        costs = np.concatenate(
            [cost_model.cost_batch(cands[i : i + 4096]) for i in range(0, len(cands), 4096)]
        )
        k = int(np.argmin(costs))
        if costs[k] >= cur - 1e-15:
            break
        perm = cands[k]
        cur = float(costs[k])
    return perm


# ---------------------------------------------------------------------------
# Simulated annealing (stage-1, paper-faithful moves, multi-chain batched)
# ---------------------------------------------------------------------------

def _propose(perms: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """One neighborhood move per chain: the paper's heuristics.

    * permute random pairs (swap),
    * permute a random sub-array (we use reversal — the 2-opt move — and
      random shuffle of a short window),
    * segment relocation (or-opt move).
    """
    out = perms.copy()
    P, n = perms.shape
    kinds = rng.integers(0, 4, size=P)
    for p in range(P):
        k = kinds[p]
        if k == 0:  # pair swap
            i, j = rng.integers(0, n, size=2)
            out[p, i], out[p, j] = out[p, j], out[p, i]
        elif k == 1:  # sub-array reversal
            i, j = np.sort(rng.integers(0, n, size=2))
            out[p, i : j + 1] = out[p, i : j + 1][::-1]
        elif k == 2:  # sub-array shuffle (short window)
            i = rng.integers(0, n)
            w = int(rng.integers(2, min(6, n) + 1))
            idx = (i + np.arange(w)) % n
            out[p, idx] = out[p, idx[rng.permutation(w)]]
        else:  # segment relocation
            L = int(rng.integers(1, min(4, n)))
            i = int(rng.integers(0, n - L + 1))
            seg = out[p, i : i + L].copy()
            rest = np.delete(out[p], np.s_[i : i + L])
            k2 = int(rng.integers(0, len(rest) + 1))
            out[p] = np.concatenate([rest[:k2], seg, rest[k2:]])
    return out


def solve_sa(
    cost_model: CostModel,
    iters: int = 3000,
    chains: int = 16,
    t0: Optional[float] = None,
    t_final_frac: float = 1e-3,
    seed: int = 0,
    init: Optional[np.ndarray] = None,
    timeout_s: Optional[float] = None,
    maximize: bool = False,
) -> SolveResult:
    """Multi-chain simulated annealing with batched cost evaluation."""
    t_start = time.perf_counter()
    rng = np.random.default_rng(seed)
    n = cost_model.n
    sign = -1.0 if maximize else 1.0

    perms = np.stack([rng.permutation(n) for _ in range(chains)])
    if init is not None:
        perms[0] = np.asarray(init)
    costs = sign * cost_model.cost_batch(perms)
    best_i = int(np.argmin(costs))
    best_perm, best_cost = perms[best_i].copy(), float(costs[best_i])
    trace: List[Tuple[str, int, float]] = [("sa", 0, sign * best_cost)]

    if t0 is None:
        t0 = float(np.std(costs)) + 1e-12
    t_final = max(t0 * t_final_frac, 1e-30)

    for it in range(1, iters + 1):
        temp = t0 * (t_final / t0) ** (it / iters)
        proposal = _propose(perms, rng)
        new_costs = sign * cost_model.cost_batch(proposal)
        accept = (new_costs < costs) | (
            rng.random(chains) < np.exp(np.clip((costs - new_costs) / temp, -60, 0))
        )
        perms[accept] = proposal[accept]
        costs[accept] = new_costs[accept]
        i = int(np.argmin(costs))
        if costs[i] < best_cost:
            best_cost = float(costs[i])
            best_perm = perms[i].copy()
            trace.append(("sa", it, sign * best_cost))
        if timeout_s is not None and time.perf_counter() - t_start > timeout_s:
            break

    return SolveResult(
        perm=best_perm,
        cost=sign * best_cost,
        trace=trace,
        wall_s=time.perf_counter() - t_start,
    )


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

def _ring_matrix(cost_model: CostModel) -> np.ndarray:
    """Effective symmetric edge-cost matrix for ring objectives."""
    if cost_model.c is not None:
        return cost_model.c
    return cost_model.lat + cost_model.size_bytes * cost_model.invbw


def solve(
    cost_model: CostModel,
    method: str = "auto",
    iters: int = 3000,
    chains: int = 16,
    seed: int = 0,
    timeout_s: Optional[float] = None,
) -> SolveResult:
    """Full two-stage pipeline.

    ``method``:
      * ``"paper"`` — SA with the paper's moves, then stage-2 refinement
        (our Z3 substitute) seeded with the SA incumbent.
      * ``"auto"``  — additionally: exhaustive for tiny N, Held–Karp for
        small ring N, greedy+2-opt+Or-opt construction for rings; keeps
        the best of all candidates.
      * ``"sa"``    — stage-1 only.
    """
    t_start = time.perf_counter()
    n = cost_model.n
    is_ring = isinstance(cost_model, RingCost)
    candidates: List[Tuple[np.ndarray, float, str]] = []

    if method == "auto" and n <= 8:
        perm, cost = exhaustive(cost_model)
        return SolveResult(perm, cost, [("exhaustive", 0, cost)],
                           time.perf_counter() - t_start)

    sa = solve_sa(cost_model, iters=iters, chains=chains, seed=seed,
                  timeout_s=timeout_s)
    candidates.append((sa.perm, sa.cost, "sa"))
    trace = list(sa.trace)

    if method in ("paper", "auto"):
        # Stage 2: refine the incumbent (Z3-substitute, see module doc).
        if is_ring:
            c = _ring_matrix(cost_model)
            if n <= 12 and method == "auto":
                perm, cost = held_karp(c)
                candidates.append((perm, cost, "held_karp"))
            refined = or_opt(c, two_opt(c, sa.perm))
            candidates.append((refined, cost_model.cost(refined), "2opt+oropt"))
            if method == "auto":
                g = greedy_ring(c)
                g = or_opt(c, two_opt(c, g))
                candidates.append((g, cost_model.cost(g), "greedy+2opt"))
        else:
            refined = swap_hill_climb(cost_model, sa.perm)
            candidates.append((refined, cost_model.cost(refined), "swap_hc"))

    perm, cost, tag = min(candidates, key=lambda t: t[1])
    trace.append((tag, -1, cost))
    return SolveResult(np.asarray(perm), float(cost), trace,
                       time.perf_counter() - t_start)


def solve_worst(
    cost_model: CostModel, iters: int = 3000, chains: int = 16, seed: int = 0
) -> SolveResult:
    """Find a *bad* ordering (paper's speedup baseline is the worst order)."""
    return solve_sa(cost_model, iters=iters, chains=chains, seed=seed, maximize=True)


def percentile_orders(
    cost_model: CostModel,
    best: np.ndarray,
    worst: np.ndarray,
    k: int = 10,
    pool: int = 600,
    seed: int = 0,
) -> List[np.ndarray]:
    """Rank orders spanning the solver's cost range (paper §V-B).

    The paper validates its cost model with "10 different rank orders,
    with the i-th order approximately corresponding to the 10i-th
    percentile in the range of costs found by the solver".  We rebuild
    that population with a random walk away from the best order (random
    pair swaps of increasing strength), then pick, for each of k evenly
    spaced cost targets between best and worst, the sampled order whose
    model cost is closest.
    """
    rng = np.random.default_rng(seed)
    n = cost_model.n
    samples = [np.asarray(best).copy(), np.asarray(worst).copy()]
    cur = np.asarray(best).copy()
    for i in range(pool):
        for _ in range(1 + i * 3 // pool):
            a, b = rng.integers(0, n, size=2)
            cur[a], cur[b] = cur[b], cur[a]
        samples.append(cur.copy())
        if (i + 1) % (pool // 4) == 0:  # restart walks from random points
            cur = rng.permutation(n)
    arr = np.stack(samples)
    costs = cost_model.cost_batch(arr)
    targets = np.linspace(costs.min(), costs.max(), k)
    picks = []
    for t in targets:
        picks.append(arr[int(np.argmin(np.abs(costs - t)))])
    return picks
