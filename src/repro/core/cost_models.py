"""Cost models for collective algorithms (paper §IV-A).

Each model computes C_O(N, c, S) for a candidate rank permutation ``perm``
where ``perm[rank] = node``: the node placed at logical rank ``rank``.

Two cost parameterizations are supported:

* **paper-faithful**: a single pairwise matrix ``c[i, j]`` (latency-centric,
  paper §IV-B); rounds moving S_r != S rescale linearly.
* **exact lat/bw** (TPU adaptation): per-pair ``lat`` and ``bw`` matrices;
  a round moving S_r costs ``lat + S_r / bw`` — the alpha-beta model, so
  small log-round payloads are not over-charged for latency.

All models share one internal representation (rounds of rank-space pairs)
so scalar and *batched* (many permutations at once — used by the
stochastic solvers) evaluation is pure vectorized numpy:

* ``ring``               total = SUM over ring edges of  c(S)
* ``halving_doubling``   total = SUM over rounds of MAX over pairs of c(S_r)
* ``double_binary_tree`` total = MAX over two trees of MAX over root->leaf
                                  paths of SUM of edge costs (S/2)
* ``bcube``              total = SUM over rounds of MAX over (B-1)-peer
                                  exchanges of c(S_r)
* ``all_to_all``         (beyond paper — MoE expert parallelism) total =
                                  SUM over N-1 shifts of MAX over pairs of c(S/N)

N is assumed a power of two for halving-doubling (paper assumption); rank
arithmetic wraps mod N (paper: "allow arbitrary rank r to alias to
canonical rank (r+N) mod N").
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .schedule import _require_power_of_base, _require_power_of_two

__all__ = [
    "CostModel",
    "RingCost",
    "HalvingDoublingCost",
    "DoubleBinaryTreeCost",
    "BCubeCost",
    "AllToAllCost",
    "make_cost_model",
    "COST_MODELS",
]


def _as_batch(perms: np.ndarray) -> np.ndarray:
    perms = np.asarray(perms)
    return perms[None, :] if perms.ndim == 1 else perms


@dataclasses.dataclass
class _Round:
    """One communication round: pairs of logical ranks + payload bytes."""

    pairs: np.ndarray  # [k, 2] int, rank-space
    payload: float     # bytes transferred by each pair in this round


#: Round structure depends only on (class, n, per-class extras) — never on
#: the cost matrix or message size (per-round payloads are fixed fractions
#: of ``size_bytes``, so they are cached at unit size and scaled per
#: instance).  Shared across model instances so repeated construction
#: (solver sweeps, per-group mesh costs, message-size sweeps) skips the
#: Python round-building loops and the key space stays finite.
_STRUCT_CACHE: Dict[tuple, dict] = {}

#: cost_batch processes the flattened edge tensor in slabs at most this many
#: elements (P * E) at a time, so huge schedules (all-to-all at N=1024 is
#: ~1M edges) don't allocate multi-hundred-MB intermediates.
_BATCH_SLAB_ELEMS = 1 << 24


class CostModel:
    """Base: rounds of (pairs, payload); subclasses set the aggregator."""

    name = "base"
    #: 'sum_of_max' (HD/BCube/a2a) or 'sum_of_sum' (ring); trees override.
    aggregator = "sum_of_max"

    def __init__(
        self,
        n: int,
        size_bytes: float,
        cost_matrix: Optional[np.ndarray] = None,
        *,
        lat: Optional[np.ndarray] = None,
        bw: Optional[np.ndarray] = None,
    ):
        self.n = n
        self.size_bytes = float(size_bytes)
        if lat is not None:
            assert bw is not None
            self.lat = np.asarray(lat, dtype=np.float64)
            with np.errstate(divide="ignore"):
                self.invbw = np.where(np.isinf(bw), 0.0, 1.0 / np.asarray(bw))
            self.c = None
        else:
            assert cost_matrix is not None
            assert cost_matrix.shape == (n, n), (cost_matrix.shape, n)
            self.c = np.asarray(cost_matrix, dtype=np.float64)
            self.lat = None
            self.invbw = None
        self._build_structure()

    def _structure_key(self) -> tuple:
        """Cache key for the permutation-independent round structure."""
        return (type(self).__name__, self.n) + self._structure_extras()

    def _structure_extras(self) -> tuple:
        """Per-class extra key fields (e.g. bcube base, tree mode)."""
        return ()

    def _build_structure(self) -> None:
        key = self._structure_key()
        cached = _STRUCT_CACHE.get(key)
        if cached is None:
            # Build at unit message size: per-round payloads become the
            # size-independent fractions, so one cache entry serves every
            # message size at this (class, n, extras).
            real_size = self.size_bytes
            self.size_bytes = 1.0
            try:
                unit_rounds = self._make_rounds()
            finally:
                self.size_bytes = real_size
            cached = {"rounds": unit_rounds,
                      "flat": self._flatten_rounds(unit_rounds)}
            # DBT path mode builds per-instance tensors in _make_rounds;
            # snapshot them so cache hits restore the full structure.
            for attr in ("_edge_arr", "_paths_mat"):
                if hasattr(self, attr):
                    cached[attr] = getattr(self, attr)
            _STRUCT_CACHE[key] = cached
        # Materialize real payloads (pairs arrays are shared, not copied).
        self.rounds = [_Round(pairs=r.pairs, payload=r.payload * self.size_bytes)
                       for r in cached["rounds"]]
        if cached["flat"] is None:
            self._flat = None
        else:
            a, b, frac, starts = cached["flat"]
            self._flat = (a, b, frac * self.size_bytes, starts)
        for attr in ("_edge_arr", "_paths_mat"):
            if attr in cached:
                setattr(self, attr, cached[attr])

    @staticmethod
    def _flatten_rounds(rounds: List[_Round]):
        """Concatenate all rounds into single gather-ready index tensors.

        Returns (a, b, payload, starts): flat rank indices [E], per-edge
        payload [E], and the offset of each round for segment reductions.
        """
        if not rounds:
            return None
        a = np.concatenate([r.pairs[:, 0] for r in rounds])
        b = np.concatenate([r.pairs[:, 1] for r in rounds])
        payload = np.concatenate(
            [np.full(len(r.pairs), r.payload) for r in rounds]
        )
        starts = np.cumsum([0] + [len(r.pairs) for r in rounds])[:-1]
        return a, b, payload, starts

    # -- schedule structure (rank space, permutation independent) --------
    def _make_rounds(self) -> List[_Round]:
        raise NotImplementedError

    # -- edge costs -------------------------------------------------------
    def _edge_costs(self, a: np.ndarray, b: np.ndarray, payload: float) -> np.ndarray:
        """Cost of transferring ``payload`` bytes for node pairs (a, b)."""
        if self.c is not None:
            scale = 1.0 if self.size_bytes == 0 else payload / self.size_bytes
            return self.c[a, b] * scale
        return self.lat[a, b] + payload * self.invbw[a, b]

    # -- evaluation -------------------------------------------------------
    def cost(self, perm: Sequence[int]) -> float:
        return float(self.cost_batch(np.asarray(perm)[None, :])[0])

    def cost_batch(self, perms: np.ndarray) -> np.ndarray:
        """Evaluate P permutations at once -> [P] costs.

        All rounds are evaluated with one gather over the flattened edge
        tensor followed by a per-round segment reduction — no Python loop
        over rounds (the seed implementation's per-round loop dominated
        wall clock for round-heavy schedules like all-to-all / bcube).
        """
        perms = _as_batch(perms)
        if self._flat is None:
            return np.zeros(perms.shape[0])
        fa, fb, payload, starts = self._flat
        P, E = perms.shape[0], len(fa)
        if P * E <= _BATCH_SLAB_ELEMS or len(starts) == 1:
            return self._cost_batch_slab(perms, fa, fb, payload, starts)
        # Slab along round boundaries to bound peak memory.
        bounds = list(starts) + [E]
        total = np.zeros(P)
        lo_r = 0
        per_round_edges = max(E // len(starts), 1)
        rounds_per_slab = max(_BATCH_SLAB_ELEMS // max(P * per_round_edges, 1), 1)
        while lo_r < len(starts):
            hi_r = min(lo_r + rounds_per_slab, len(starts))
            lo, hi = bounds[lo_r], bounds[hi_r]
            total += self._cost_batch_slab(
                perms, fa[lo:hi], fb[lo:hi], payload[lo:hi],
                starts[lo_r:hi_r] - lo)
            lo_r = hi_r
        return total

    def _cost_batch_slab(self, perms, fa, fb, payload, starts) -> np.ndarray:
        a = perms[:, fa]                           # [P, E] node ids
        b = perms[:, fb]
        if self.c is not None:
            edge = self.c[a, b]
            if self.size_bytes != 0:
                edge = edge * (payload / self.size_bytes)[None, :]
        else:
            edge = self.lat[a, b] + payload[None, :] * self.invbw[a, b]
        if self.aggregator == "sum_of_sum":
            return edge.sum(axis=1)
        if self.aggregator == "sum_of_max":
            return np.maximum.reduceat(edge, starts, axis=1).sum(axis=1)
        raise NotImplementedError(self.aggregator)  # pragma: no cover

    # -- introspection ----------------------------------------------------
    def critical_edges(self, perm: Sequence[int]) -> List[Tuple[int, int, float]]:
        """Edges (node_a, node_b, cost) that set each round's cost.

        Used by the dynamic re-ranker (paper §VI: find the bottleneck
        transfer on the critical path).
        """
        perm = np.asarray(perm)
        out: List[Tuple[int, int, float]] = []
        for rnd in self.rounds:
            a = perm[rnd.pairs[:, 0]]
            b = perm[rnd.pairs[:, 1]]
            edge = self._edge_costs(a, b, rnd.payload)
            if self.aggregator == "sum_of_max":
                k = int(np.argmax(edge))
                out.append((int(a[k]), int(b[k]), float(edge[k])))
            else:
                out.extend(
                    (int(a[k]), int(b[k]), float(edge[k])) for k in range(len(edge))
                )
        return out


class RingCost(CostModel):
    """C_r = sum_i c_{i, i-1}(S)  (paper §IV-A, Ring).

    This is exactly a closed-tour traveling-salesman objective over the
    symmetric cost matrix — which is why classic TSP refinements (2-opt,
    Or-opt, Held–Karp) apply; the paper's SA "segment reversal" heuristic
    is the 2-opt move.
    """

    name = "ring"
    aggregator = "sum_of_sum"

    def _make_rounds(self) -> List[_Round]:
        i = np.arange(self.n)
        pairs = np.stack([i, (i - 1) % self.n], axis=1)
        return [_Round(pairs=pairs, payload=self.size_bytes)]


class HalvingDoublingCost(CostModel):
    """C_hd = sum_rounds max_pairs c(S / 2^{i+1})  (paper §IV-A).

    Round ``i`` pairs rank j with rank j XOR 2^i (recursive halving,
    distance doubling); each round moves half the previous payload.
    """

    name = "halving_doubling"
    aggregator = "sum_of_max"

    def _make_rounds(self) -> List[_Round]:
        n = self.n
        _require_power_of_two(n, "halving_doubling")
        rounds = []
        for i in range(int(np.log2(n))):
            j = np.arange(n)
            partner = j ^ (1 << i)
            keep = j < partner
            pairs = np.stack([j[keep], partner[keep]], axis=1)
            rounds.append(_Round(pairs=pairs, payload=self.size_bytes / (2 ** (i + 1))))
        return rounds


class DoubleBinaryTreeCost(CostModel):
    """C_dbt over two complementary balanced binary trees.

    Two modes:

    * ``mode="path"`` (paper §IV-A, default): critical path —
      T(i,j,S) = max over the two subtree edges of (edge cost + subtree
      T); the mirrored tree shifts every rank by -1 mod N; each tree
      carries S/2; total = max(tree, mirror).
    * ``mode="barrier"`` (beyond paper): depth-synchronized execution —
      sum over depth rounds of the max edge cost across BOTH concurrent
      trees (reduce + broadcast phases).  Matches backends that barrier
      between tree levels; our Fig. 4 reproduction shows the paper's
      path model can mis-rank orders under such backends (see
      EXPERIMENTS.md §Fig4).

    Internally (path mode): precompute, per tree, every root->node path's
    edge list; cost(perm) = max over paths of sum of permuted edge costs
    — batched evaluation is one gather + matmul.
    """

    name = "double_binary_tree"
    aggregator = "path_max"

    def __init__(self, n, size_bytes, cost_matrix=None, *, mode: str = "path", **kw):
        self.mode = mode
        super().__init__(n, size_bytes, cost_matrix, **kw)
        if mode == "barrier":
            self.aggregator = "sum_of_max"

    def _structure_extras(self) -> tuple:
        return (self.mode,)

    def _tree_edge_list(self) -> List[tuple]:
        """(parent, child, depth) of the balanced tree over [0, n-1]."""
        out: List[tuple] = []

        def rec(lo: int, hi: int, depth: int) -> int:
            mid = (lo + hi) // 2
            if lo <= mid - 1:
                c = rec(lo, mid - 1, depth + 1)
                out.append((mid, c, depth))
            if mid + 1 <= hi:
                c = rec(mid + 1, hi, depth + 1)
                out.append((mid, c, depth))
            return mid

        rec(0, self.n - 1, 0)
        return out

    def _barrier_rounds(self) -> List[_Round]:
        edges = self._tree_edge_list()
        max_depth = max((d for _, _, d in edges), default=0)
        payload = self.size_bytes / 2.0
        rounds: List[_Round] = []
        for phase in ("reduce", "broadcast"):
            depths = range(max_depth, -1, -1) if phase == "reduce" \
                else range(0, max_depth + 1)
            for d in depths:
                pairs = []
                for shift in (0, 1):
                    for p_, c_, dd in edges:
                        if dd == d:
                            pairs.append(((p_ - shift) % self.n,
                                          (c_ - shift) % self.n))
                if pairs:
                    rounds.append(_Round(
                        pairs=np.asarray(pairs, dtype=np.int64),
                        payload=payload))
        return rounds

    def _make_rounds(self) -> List[_Round]:
        if getattr(self, "mode", "path") == "barrier":
            return self._barrier_rounds()
        out_paths: List[List[Tuple[int, int]]] = []

        def rec(lo: int, hi: int, path: List[Tuple[int, int]]) -> None:
            if lo > hi:
                return
            mid = (lo + hi) // 2
            if lo <= mid - 1:
                lmid = (lo + mid - 1) // 2
                e = (mid, lmid)
                out_paths.append(path + [e])
                rec(lo, mid - 1, path + [e])
            if mid + 1 <= hi:
                rmid = (mid + 1 + hi) // 2
                e = (mid, rmid)
                out_paths.append(path + [e])
                rec(mid + 1, hi, path + [e])

        rec(0, self.n - 1, [])
        edge_list: List[Tuple[int, int]] = []
        edge_id: Dict[Tuple[int, int], int] = {}
        for path in out_paths:
            for e in path:
                if e not in edge_id:
                    edge_id[e] = len(edge_list)
                    edge_list.append(e)
        paths_mat = np.zeros((len(out_paths), len(edge_list)), dtype=np.float64)
        for r, path in enumerate(out_paths):
            for e in path:
                paths_mat[r, edge_id[e]] = 1.0
        self._edge_arr = (
            np.asarray(edge_list, dtype=np.int64)
            if edge_list
            else np.zeros((0, 2), dtype=np.int64)
        )
        self._paths_mat = paths_mat
        return []

    def cost_batch(self, perms: np.ndarray) -> np.ndarray:
        if self.mode == "barrier":
            return super().cost_batch(perms)
        perms = _as_batch(perms)
        payload = self.size_bytes / 2.0 if self.size_bytes else 0.0
        total = np.zeros(perms.shape[0])
        if not len(self._edge_arr):
            return total
        for shift in (0, 1):  # tree and its mirrored (rank - 1) twin
            ranks = (self._edge_arr - shift) % self.n
            a = perms[:, ranks[:, 0]]
            b = perms[:, ranks[:, 1]]
            if self.c is not None:
                scale = 0.5 if self.size_bytes else 1.0
                edge = self.c[a, b] * scale                       # [P, E]
            else:
                edge = self.lat[a, b] + payload * self.invbw[a, b]
            path_cost = edge @ self._paths_mat.T                  # [P, R]
            if path_cost.shape[1]:
                total = np.maximum(total, path_cost.max(axis=1))
        return total

    def critical_edges(self, perm: Sequence[int]) -> List[Tuple[int, int, float]]:
        if self.mode == "barrier":
            return super().critical_edges(perm)
        perm = np.asarray(perm)
        payload = self.size_bytes / 2.0 if self.size_bytes else 0.0
        best: Optional[Tuple[float, int, int]] = None
        if not len(self._edge_arr):
            return []
        for shift in (0, 1):
            ranks = (self._edge_arr - shift) % self.n
            a = perm[ranks[:, 0]]
            b = perm[ranks[:, 1]]
            if self.c is not None:
                edge = self.c[a, b] * (0.5 if self.size_bytes else 1.0)
            else:
                edge = self.lat[a, b] + payload * self.invbw[a, b]
            path_cost = edge @ self._paths_mat.T
            if not len(path_cost):
                continue
            r = int(np.argmax(path_cost))
            e_ids = np.nonzero(self._paths_mat[r])[0]
            k = e_ids[int(np.argmax(edge[e_ids]))]
            cand = (float(edge[k]), int(a[k]), int(b[k]))
            if best is None or cand[0] > best[0]:
                best = cand
        return [(best[1], best[2], best[0])] if best else []


class BCubeCost(CostModel):
    """C_b = sum_rounds max over B-peer exchanges of c(S / B^{i+1}).

    Round ``i`` groups ranks by all base-B digits except digit ``i``; each
    rank exchanges with the B-1 peers differing only in digit ``i``
    (paper §IV-A / Gloo's bcube).
    """

    name = "bcube"
    aggregator = "sum_of_max"

    def __init__(self, n, size_bytes, cost_matrix=None, *, base: int = 4, **kw):
        self.base = base
        super().__init__(n, size_bytes, cost_matrix, **kw)

    def _structure_extras(self) -> tuple:
        return (self.base,)

    def _make_rounds(self) -> List[_Round]:
        n, b = self.n, self.base
        n_rounds = _require_power_of_base(n, b, "bcube")
        rounds = []
        for i in range(n_rounds):
            stride = b ** i
            pairs = []
            for j in range(n):
                digit = (j // stride) % b
                for k in range(1, b):
                    p = j + (((digit + k) % b) - digit) * stride
                    if j < p:
                        pairs.append((j, p))
            rounds.append(
                _Round(
                    pairs=np.asarray(pairs, dtype=np.int64),
                    payload=self.size_bytes / (b ** (i + 1)),
                )
            )
        return rounds


class AllToAllCost(CostModel):
    """Beyond-paper: shift-scheduled all-to-all (MoE dispatch/EP traffic).

    N-1 shift rounds; in round k every rank j sends S/N to rank (j+k)%N.
    Reordering changes which shifts cross slow links — the locality
    argument the paper makes for ring applies to EP all-to-alls too.
    """

    name = "all_to_all"
    aggregator = "sum_of_max"

    def _make_rounds(self) -> List[_Round]:
        n = self.n
        j = np.arange(n)
        return [
            _Round(pairs=np.stack([j, (j + k) % n], axis=1), payload=self.size_bytes / n)
            for k in range(1, n)
        ]


COST_MODELS: Dict[str, Callable[..., CostModel]] = {
    "ring": RingCost,
    "halving_doubling": HalvingDoublingCost,
    "double_binary_tree": DoubleBinaryTreeCost,
    "bcube": BCubeCost,
    "all_to_all": AllToAllCost,
}


def make_cost_model(
    algo: str,
    cost_matrix: Optional[np.ndarray] = None,
    size_bytes: float = 0.0,
    *,
    lat: Optional[np.ndarray] = None,
    bw: Optional[np.ndarray] = None,
    **kwargs,
) -> CostModel:
    if algo not in COST_MODELS:
        raise ValueError(
            f"unknown cost model {algo!r}; registered models: "
            f"{', '.join(sorted(COST_MODELS))}")
    if cost_matrix is not None:
        n = cost_matrix.shape[0]
    else:
        n = lat.shape[0]
    return COST_MODELS[algo](n, size_bytes, cost_matrix, lat=lat, bw=bw, **kwargs)
