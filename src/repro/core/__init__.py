"""Cloud Collectives core: cost models, probing, solving, mesh reordering.

Most applications should use the :class:`repro.session.Session` facade
(or ``python -m repro``), which drives this whole pipeline — attach →
plan → apply → monitor — behind one declarative config.  The manual
steps below remain supported for the paper mapping
(examples/manual_pipeline.py)::

    fabric  = repro.fabric.make_tpu_fleet(...)    # or a live cluster
    probed  = repro.fabric.probe_fabric(fabric)   # §IV-B pairwise probing
    c       = repro.fabric.cost_matrix(probed, S) # c_{i,j}(S)
    result  = reorder.optimize_rank_order(c, "ring", S)   # §IV-C solving
    plan    = reorder.optimize_mesh_assignment(c, (16, 16), ("data", "model"))
    mesh    = launch.mesh.make_production_mesh(plan=plan) # reordered Mesh
"""

from .cost_models import (  # noqa: F401
    COST_MODELS,
    AllToAllCost,
    BCubeCost,
    CostModel,
    DoubleBinaryTreeCost,
    HalvingDoublingCost,
    RingCost,
    make_cost_model,
)
from .dynamic import AdaptiveReranker, StragglerDetector, bottleneck_swap  # noqa: F401

# probing + topology live in repro.fabric now; re-exported here (directly,
# not via the warning repro.core.probe/topology shims) for compatibility
from repro.fabric.probe import (  # noqa: F401
    ProbeResult,
    cost_matrix,
    probe_fabric,
    probe_mesh_pairwise,
)
from .reorder import (  # noqa: F401
    MeshPlan,
    hierarchical_perm,
    mesh_axis_cost,
    mesh_total_cost,
    optimize_mesh_assignment,
    optimize_rank_order,
    optimize_rank_order_hierarchical,
    random_assignment,
)
from .schedule import SCHEDULES, Flow  # noqa: F401
from .simulator import CollectiveSimulator, simulate_collective, simulate_rounds  # noqa: F401
from .solver import (  # noqa: F401
    SolveResult,
    exhaustive,
    greedy_ring,
    held_karp,
    or_opt,
    percentile_orders,
    solve,
    solve_sa,
    solve_worst,
    swap_hill_climb,
    two_opt,
)
from repro.fabric.topology import (  # noqa: F401
    Fabric,
    make_datacenter,
    make_tpu_fleet,
    scramble,
)
