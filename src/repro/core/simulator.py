"""Flow-level network simulator: the "real cloud" oracle.

The paper validates its cost model against measured collectives on
Azure/EC2 (Table I).  Offline we need a ground truth that is *richer* than
the cost model, so correlation numbers are meaningful rather than
tautological.  This simulator models what the latency-only cost model
does not:

* per-link **contention**: concurrent flows sharing a link get a max-min
  fair share (progressive filling);
* hierarchical paths from :class:`repro.core.topology.Fabric`;
* optional stochastic jitter (multi-tenant background traffic).

Time for one round = completion time of its slowest flow; rounds are
barriers.  This matches how Gloo/NCCL ring/tree phases synchronize and is
the standard flow-level abstraction used by SimAI-style simulators.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from .schedule import Flow
from repro.fabric.topology import Fabric

__all__ = ["simulate_rounds", "simulate_collective", "CollectiveSimulator"]


def _fair_share_rates(fabric: Fabric, flows: Sequence[Flow]) -> np.ndarray:
    """Max-min fair rates (bytes/s) via progressive filling.

    Classic water-filling: repeatedly find the most-congested unfrozen
    link, freeze its flows at the equal share, remove capacity, repeat.
    """
    n_flows = len(flows)
    rates = np.zeros(n_flows)
    active = [i for i, f in enumerate(flows) if f.src != f.dst]
    link_cap: Dict[int, float] = {}
    link_flows: Dict[int, List[int]] = {}
    for i in active:
        f = flows[i]
        for l in fabric.paths[f.src][f.dst]:
            link_cap.setdefault(l, float(fabric.link_bw[l]))
            link_flows.setdefault(l, []).append(i)
    frozen = np.zeros(n_flows, dtype=bool)
    # Flows with no links (e.g. same-host) get infinite rate.
    for i in active:
        f = flows[i]
        if not fabric.paths[f.src][f.dst]:
            rates[i] = np.inf
            frozen[i] = True
    for _ in range(len(link_cap) + 1):
        best_l, best_share = None, np.inf
        for l, fl in link_flows.items():
            live = [i for i in fl if not frozen[i]]
            if not live:
                continue
            share = link_cap[l] / len(live)
            if share < best_share:
                best_share, best_l = share, l
        if best_l is None:
            break
        for i in link_flows[best_l]:
            if frozen[i]:
                continue
            rates[i] = best_share
            frozen[i] = True
            f = flows[i]
            for l2 in fabric.paths[f.src][f.dst]:
                if l2 != best_l:
                    link_cap[l2] -= best_share
        link_flows.pop(best_l)
    return rates


def simulate_rounds(
    fabric: Fabric,
    rounds: Sequence[Sequence[Flow]],
    rng: Optional[np.random.Generator] = None,
    jitter: float = 0.0,
) -> float:
    """Total seconds to execute the schedule (rounds are barriers)."""
    total = 0.0
    for flows in rounds:
        flows = [f for f in flows if f.src != f.dst]
        if not flows:
            continue
        rates = _fair_share_rates(fabric, flows)
        t = 0.0
        for f, r in zip(flows, rates):
            lat = fabric.lat[f.src, f.dst]
            xfer = 0.0 if np.isinf(r) else f.size / max(r, 1.0)
            ft = lat + xfer
            if rng is not None and jitter > 0:
                ft *= 1.0 + jitter * rng.exponential()
            t = max(t, ft)
        total += t
    return total


def simulate_collective(
    fabric: Fabric,
    algo: str,
    perm: Sequence[int],
    size_bytes: float,
    seed: Optional[int] = None,
    jitter: float = 0.0,
    **kwargs,
) -> float:
    """Simulate one collective of ``size_bytes`` under rank order ``perm``.

    ``algo`` names a registered :mod:`repro.collective` builder; the
    schedule is compiled through the typed IR (this function stays a
    supported oracle API — it does not route through the deprecated
    ``SCHEDULES`` shim).
    """
    from repro.collective import CollectiveOp, apply_permutation, compile_op
    from .schedule import _SHIM_KINDS

    perm = [int(p) for p in perm]
    kind = _SHIM_KINDS.get(algo)
    if kind is None:
        from repro.collective import get_builder

        kind = get_builder(algo).kinds[0]    # ValueError on unknown algo
    prog = apply_permutation(
        compile_op(CollectiveOp(kind, float(size_bytes), sorted(perm)),
                   algo, **kwargs),
        perm)
    rng = np.random.default_rng(seed) if seed is not None else None
    return simulate_rounds(fabric, prog.to_flows(), rng=rng, jitter=jitter)


class CollectiveSimulator:
    """Convenience wrapper binding a fabric + algorithm + payload."""

    def __init__(self, fabric: Fabric, algo: str, size_bytes: float, **kwargs):
        self.fabric = fabric
        self.algo = algo
        self.size_bytes = size_bytes
        self.kwargs = kwargs

    def run(self, perm: Sequence[int], seed: Optional[int] = None, jitter: float = 0.0) -> float:
        return simulate_collective(
            self.fabric, self.algo, perm, self.size_bytes,
            seed=seed, jitter=jitter, **self.kwargs,
        )

    def run_many(
        self, perms: Sequence[Sequence[int]], seed: Optional[int] = None, jitter: float = 0.0
    ) -> np.ndarray:
        return np.asarray(
            [self.run(p, seed=None if seed is None else seed + i, jitter=jitter)
             for i, p in enumerate(perms)]
        )
