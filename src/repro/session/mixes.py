"""Canonical workload job mixes.

One place declares the collective histograms the launchers, CLI, and
session defaults all share; ``repro.launch.train.default_job_mix`` /
``repro.launch.serve.serve_job_mix`` are deprecated aliases.
"""

from __future__ import annotations

from repro.plan import CollectiveRequest, JobMix

__all__ = ["train_mix", "serve_mix", "default_mix"]


def train_mix(payload_bytes: float, moe: bool = False) -> JobMix:
    """A training step's collective histogram at ``payload_bytes``
    gradients: the per-step DP reduction plus the per-layer TP pair, and
    the EP all-to-all when the arch routes experts."""
    reqs = [
        CollectiveRequest("all-reduce", payload_bytes),           # gradients
        CollectiveRequest("all-gather", payload_bytes / 8, count=2.0),
        CollectiveRequest("reduce-scatter", payload_bytes / 8, count=2.0),
    ]
    if moe:
        reqs.append(CollectiveRequest("all-to-all", payload_bytes / 16,
                                      count=2.0))
    return JobMix(requests=tuple(reqs), name="train")


def serve_mix(payload_bytes: float, moe: bool = False) -> JobMix:
    """The decode path's collective histogram: per-layer TP all-gather /
    reduce-scatter dominate; a small all-reduce syncs sampling state; MoE
    archs add the EP all-to-all.  (No gradient all-reduce — that is the
    training mix.)"""
    reqs = [
        CollectiveRequest("all-gather", payload_bytes, count=2.0),
        CollectiveRequest("reduce-scatter", payload_bytes, count=2.0),
        CollectiveRequest("all-reduce", max(payload_bytes / 64, 1.0)),
    ]
    if moe:
        reqs.append(CollectiveRequest("all-to-all", payload_bytes, count=2.0))
    return JobMix(requests=tuple(reqs), name="serve")


def default_mix(workload: str, payload_bytes: float, moe: bool = False) -> JobMix:
    """Mix for a :class:`~repro.session.SessionConfig` workload name."""
    if workload == "serve":
        return serve_mix(payload_bytes, moe=moe)
    if workload == "train":
        return train_mix(payload_bytes, moe=moe)
    raise ValueError(f"unknown workload {workload!r}; "
                     f"expected 'train' or 'serve'")
