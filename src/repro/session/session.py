"""The Session facade: probe → plan → apply → monitor in one object.

The paper's headline property is that Cloud Collectives is
*non-intrusive* — no application changes, no rebuild.  Before this
module our own API required ~8 manually wired steps (`make_tpu_fleet →
probe_fabric → cost_matrix → PlanCompiler → PlanCache →
PlanningService.request → make_planned_mesh → arm_ep`).  A
:class:`Session` owns that whole lifecycle behind a declarative
:class:`~repro.session.config.SessionConfig`::

    from repro import Session, SessionConfig

    cfg = SessionConfig.from_dict({
        "fabric": {"kind": "datacenter", "nodes": 64, "scramble_seed": 1},
        "mesh": {"shape": "8x8"},
    })
    with Session(cfg) as s:
        applied = s.apply()          # lazily probes + plans + applies
        mesh = applied.mesh          # reordered jax Mesh (when devices fit)
        hints = applied.hints        # per-op (algo, chunks, speedup)

Lifecycle is an explicit state machine — ``created → attached → planned
→ applied → closed`` — with registered hooks (``on("plan", fn)`` etc.),
a :meth:`Session.observe` / :meth:`Session.monitor` drift path wiring
:class:`repro.plan.DriftMonitor` re-plans, and a non-intrusive
:meth:`Session.wrap` that patches ``make_production_mesh`` / ``arm_ep``
so existing launch code picks up planned orders with zero call-site
edits.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.fabric import (
    Fabric,
    ProbeResult,
    SparseProbeResult,
    cost_matrix,
    make_datacenter,
    make_tpu_fleet,
    probe_fabric,
    refresh_sparse,
    scramble,
    sparse_probe_fabric,
)
from repro.faults import (
    HealthTracker,
    call_with_retries,
    identity_fallback,
    recover_plan,
)
from repro.plan import (
    DriftMonitor,
    DriftReport,
    JobMix,
    Plan,
    PlanCache,
    PlanCompiler,
    PlanningService,
)

from .config import ObsConfig, SessionConfig
from .mixes import default_mix

__all__ = ["Session", "SessionError", "AppliedPlan", "EVENTS"]

#: lifecycle hook names accepted by :meth:`Session.on`; ``degraded`` /
#: ``recovered`` report health-state edges, ``node_leave`` /
#: ``node_join`` report elastic membership changes
EVENTS = ("attach", "plan", "apply", "drift", "replan",
          "degraded", "recovered", "node_leave", "node_join", "close")

_STATES = ("created", "attached", "planned", "applied", "closed")


class SessionError(RuntimeError):
    """Lifecycle misuse (e.g. planning on a closed session)."""


@dataclasses.dataclass
class AppliedPlan:
    """What :meth:`Session.apply` hands the application."""

    plan: Plan
    #: flat device order for Mesh() construction (None without a mesh plan)
    order: Optional[np.ndarray]
    #: reordered jax Mesh — built only when the live device count matches
    mesh: Optional[Any]
    #: per-op entry summaries: {op: {algo, chunks, expected_time, ...}}
    hints: Dict[str, Dict[str, Any]]

    def summary(self) -> str:
        lines = [f"plan {self.plan.fingerprint.digest}: "
                 f"{len(self.plan.entries)} entries, "
                 f"compiled in {self.plan.compile_seconds:.2f}s"]
        mp = self.plan.mesh_plan
        if mp is not None:
            lines.append(
                f"mesh {mp.assignment.shape} cost {mp.baseline_cost:.5f} -> "
                f"{mp.cost:.5f} "
                f"({mp.baseline_cost / max(mp.cost, 1e-30):.2f}x vs identity)")
        for op, h in sorted(self.hints.items()):
            lines.append(
                f"  {op:<15} {h['algo']:<20} chunks={h['chunks']} "
                f"{h['speedup_vs_identity']:.2f}x vs identity order")
        return "\n".join(lines)


class _WrapGuard:
    """Returned by :meth:`Session.wrap`; scopes the patches to a ``with``
    block without closing the session (bare calls patch until
    ``unwrap``/``close``)."""

    def __init__(self, session: "Session"):
        self.session = session

    def __enter__(self) -> "Session":
        return self.session

    def __exit__(self, *exc) -> None:
        self.session.unwrap()


class Session:
    """Owns the probe → plan → apply → monitor lifecycle (see module doc)."""

    def __init__(self, config: Optional[SessionConfig] = None, **overrides: Any):
        if isinstance(config, dict):
            config = SessionConfig.from_dict(config)
        self.config = (config or SessionConfig())
        if overrides:
            self.config = self.config.replace(**overrides)
        # apply a non-default obs section to the process singletons; the
        # default section is left alone so a tracer a test (or another
        # session) enabled explicitly is not silently disabled here
        if self.config.obs != ObsConfig():
            obs.configure(self.config.obs)
        self.state = "created"
        self.events: List[Tuple[str, Dict[str, Any]]] = []
        self._hooks: Dict[str, List[Callable]] = {e: [] for e in EVENTS}
        self._fabric: Optional[Fabric] = None
        #: oracle the compiler scores candidates against; equals _fabric
        #: after attach, None after a drift re-plan (the stale fabric no
        #: longer reflects observed conditions -> cost-model oracle)
        self._oracle_fabric: Optional[Fabric] = None
        self._probe: Optional[ProbeResult] = None
        self._plan: Optional[Plan] = None
        self._mix: Optional[JobMix] = None
        self._mesh_shape: Optional[Tuple[int, ...]] = None
        self._axis_names: Optional[Tuple[str, ...]] = None
        self._cache: Optional[PlanCache] = None
        self._service: Optional[PlanningService] = None
        self._drift: Optional[DriftMonitor] = None
        self._monitor_thread: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()
        #: the sparse poll's freshly refreshed probe, consumed by the
        #: next _replan so a drift recompile keeps the hierarchy (and
        #: does not re-spend the probe budget from scratch)
        self._sparse_fresh: Optional[SparseProbeResult] = None
        self._patches: List[Tuple[Any, str, Any]] = []
        self._lock = threading.RLock()
        #: healthy → degraded → halted (thresholds from the retry policy)
        self._health = HealthTracker(
            failure_threshold=self.config.retry.failure_threshold,
            halt_threshold=self.config.retry.halt_threshold)
        #: the fabric as first attached — the topology elastic membership
        #: subsets (None when attached from a bare probe / live fleet)
        self._base_fabric: Optional[Fabric] = None
        #: currently-live node ids in the attached numbering; index k of
        #: the current probe/plan is node _alive[k] of the attach-time
        #: fabric (None before attach)
        self._alive: Optional[List[int]] = None

    # -- context management ------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        return (f"Session(name={self.config.name!r}, state={self.state!r}, "
                f"fabric={self.config.fabric.kind!r})")

    # -- hooks -------------------------------------------------------------
    def on(self, event: str, fn: Callable[..., None]) -> "Session":
        """Register ``fn(session, **info)`` for a lifecycle event."""
        if event not in EVENTS:
            raise ValueError(f"unknown session event {event!r}; "
                             f"expected one of {EVENTS}")
        self._hooks[event].append(fn)
        return self

    def _fire(self, event: str, **info: Any) -> None:
        self.events.append((event, info))
        for fn in self._hooks[event]:
            fn(self, **info)

    def _require_open(self, doing: str) -> None:
        if self.state == "closed":
            raise SessionError(f"cannot {doing}: session is closed")

    # -- lifecycle: attach -------------------------------------------------
    def attach(self, fabric: Optional[Fabric] = None,
               probe: Optional[Any] = None) -> "Session":
        """Bind the session to a fabric and/or probe result.

        With no arguments the configured fabric is built (synthetic
        kinds) or live devices are probed (``fabric.kind="live"``).
        ``probe`` may be a :class:`ProbeResult` or a raw [n, n] cost
        matrix.  Re-attaching resets any existing plan.
        """
        self._require_open("attach")
        cfg = self.config
        with obs.tracer().span("session.attach", kind=cfg.fabric.kind):
            if probe is not None and not isinstance(probe, ProbeResult):
                lat = np.asarray(probe, dtype=np.float64)
                probe = ProbeResult(lat=lat)
            if fabric is None and probe is None:
                fabric, probe = self._build_configured_fabric()
            elif probe is None:
                probe = self._probe_fabric(fabric)
        obs.metrics().counter("session.attaches").inc()
        with self._lock:
            self._fabric = fabric
            self._oracle_fabric = fabric
            self._probe = probe
            self._plan = None
            self._drift = None
            self._sparse_fresh = None
            self._base_fabric = fabric
            self._alive = list(range(probe.n))
            self._health.reset()
            if self._service is not None:
                self._service.close()
                self._service = None
            self.state = "attached"
        self._fire("attach", fabric=fabric, probe=probe)
        return self

    def _build_configured_fabric(self) -> Tuple[Optional[Fabric], ProbeResult]:
        cfg = self.config
        f = cfg.fabric
        if f.kind == "live":
            from repro.fabric import probe_mesh_pairwise

            return None, probe_mesh_pairwise(percentile=cfg.probe.percentile)
        if f.kind == "tpu-fleet":
            fabric = make_tpu_fleet(
                n_pods=f.n_pods, pod_shape=tuple(f.pod_shape),
                fragmentation=f.fragmentation, seed=f.seed)
        else:
            fabric = make_datacenter(f.nodes, seed=f.seed)
        if f.scramble_seed is not None:
            fabric, _ = scramble(fabric, seed=f.scramble_seed)
        return fabric, self._probe_fabric(fabric)

    def _probe_fabric(self, fabric: Fabric) -> ProbeResult:
        """Probe per the configured mode: dense (paper §IV-B) or sparse
        (budgeted O(n·log n) probing + hierarchy recovery).

        Runs under the session retry policy: a transient probe failure
        (an injected :class:`repro.faults.ProbeTimeout`, a wedged
        sweep) is retried with capped backoff before it surfaces.
        """
        p = self.config.probe

        def sweep() -> ProbeResult:
            if p.mode == "sparse":
                return sparse_probe_fabric(
                    fabric, budget=p.budget, n_probes=p.n_probes,
                    percentile=p.percentile, noise_scale=p.noise_scale,
                    seed=p.seed, measure_bw=p.measure_bw)
            return probe_fabric(
                fabric, n_probes=p.n_probes, percentile=p.percentile,
                noise_scale=p.noise_scale, seed=p.seed,
                measure_bw=p.measure_bw)

        return call_with_retries(sweep, self.config.retry,
                                 sleep=self._monitor_stop.wait)

    # -- lifecycle: plan ---------------------------------------------------
    @property
    def cache(self) -> PlanCache:
        """The session-lifetime plan cache (survives re-attaches, so an
        elastic restart on an unchanged fabric hits the cached plan)."""
        with self._lock:
            if self._cache is None:
                cfg = self.config
                self._cache = PlanCache(capacity=cfg.cache.capacity,
                                        store_dir=cfg.cache.dir,
                                        tol=cfg.cache.tol)
            return self._cache

    @property
    def service(self) -> PlanningService:
        """The lazily built planning service (fabric-bound compiler over
        the session-lifetime cache)."""
        self._require_open("use the planning service")
        cache = self.cache
        with self._lock:
            if self._service is None:
                cfg = self.config
                self._service = PlanningService(
                    PlanCompiler(fabric=self._oracle_fabric,
                                 budget=cfg.solver.budget,
                                 seed=cfg.solver.seed),
                    cache, retry=cfg.retry)
            return self._service

    def plan(self, mix: Optional[JobMix] = None,
             mesh_shape: Optional[Sequence[int]] = None,
             axis_names: Optional[Sequence[str]] = None) -> Plan:
        """Compile (or fetch from cache) the plan for this session.

        Lazy: attaches the configured fabric first if needed.  ``mix``
        defaults to the configured workload's canonical histogram;
        ``mesh_shape`` / ``axis_names`` default to the configured mesh.
        """
        self._require_open("plan")
        if self.state == "created":
            self.attach()
        cfg = self.config
        mix = mix or default_mix(cfg.workload, cfg.payload_bytes, moe=cfg.moe)
        if mesh_shape is None and cfg.mesh.shape:
            mesh_shape = cfg.mesh.shape
            axis_names = axis_names or cfg.mesh.axis_names
        mesh_shape = tuple(mesh_shape) if mesh_shape else None
        axis_names = tuple(axis_names) if axis_names else None
        if mesh_shape is not None and \
                int(np.prod(mesh_shape)) != self._probe.n:
            raise ValueError(
                f"mesh shape {mesh_shape} needs "
                f"{int(np.prod(mesh_shape))} nodes but the attached "
                f"fabric has {self._probe.n}; attach a matching fabric "
                f"or fix mesh.shape in the session config")
        with obs.tracer().span("session.plan", mix=mix.name) as sp:
            plan = self.service.request(
                self._probe, mix, mesh_shape=mesh_shape,
                axis_names=axis_names)
            sp.set(entries=len(plan.entries),
                   digest=plan.fingerprint.digest)
        with self._lock:
            self._plan = plan
            self._mix = mix
            self._mesh_shape = mesh_shape
            self._axis_names = axis_names
            self._drift = DriftMonitor(
                plan, self.reference_matrix(),
                cache=self.service.cache,
                threshold=cfg.drift.threshold)
            if self.state in ("created", "attached"):
                self.state = "planned"
        self._fire("plan", plan=plan, mix=mix)
        return plan

    def reference_matrix(self) -> np.ndarray:
        """The cost matrix the current plan is calibrated against
        (probed latency + payload/bandwidth at the session payload) —
        the baseline that :meth:`observe` inputs are compared to."""
        if self._probe is None:
            raise SessionError(
                "reference_matrix() needs an attached probe; call "
                "attach() first")
        return cost_matrix(self._probe, self.config.payload_bytes)

    @property
    def planned(self) -> Optional[Plan]:
        """The current plan, or None before :meth:`plan` ran."""
        return self._plan

    @property
    def probe(self) -> Optional[ProbeResult]:
        """The attached probe result, or None before :meth:`attach`."""
        return self._probe

    @property
    def mix(self) -> Optional[JobMix]:
        """The job mix of the current plan, or None before :meth:`plan`."""
        return self._mix

    @property
    def hierarchy(self):
        """The recovered locality tree of the attached probe
        (:class:`repro.fabric.HierarchyModel`), or None when the probe
        carries none (dense mode / raw matrices)."""
        return getattr(self._probe, "hierarchy", None)

    @property
    def health(self) -> str:
        """Current health state: ``healthy`` / ``degraded`` / ``halted``."""
        return self._health.state

    @property
    def health_tracker(self) -> HealthTracker:
        """The underlying tracker (transition log, counters, reset)."""
        return self._health

    @property
    def alive(self) -> Optional[List[int]]:
        """Live node ids in the attach-time numbering (None pre-attach)."""
        return None if self._alive is None else list(self._alive)

    # -- lifecycle: apply --------------------------------------------------
    def apply(self, devices: Optional[Sequence] = None) -> AppliedPlan:
        """Materialize the plan for the application (lazily planning).

        Returns an :class:`AppliedPlan`: the plan, the flat device order
        of its N-D mesh assignment, the reordered ``jax`` Mesh when the
        live device count matches the assignment, and per-op hints.
        """
        self._require_open("apply")
        plan = self._plan if self._plan is not None else self.plan()
        order = None
        mesh = None
        with obs.tracer().span("session.apply",
                               digest=plan.fingerprint.digest):
            if plan.mesh_plan is not None:
                order = plan.mesh_plan.flat
                mesh = self._try_build_mesh(plan, devices)
        obs.metrics().counter("session.applies").inc()
        applied = AppliedPlan(plan=plan, order=order, mesh=mesh,
                              hints=self.hints())
        with self._lock:
            if self.state == "planned":
                self.state = "applied"
        self._fire("apply", applied=applied)
        return applied

    @staticmethod
    def _try_build_mesh(plan: Plan, devices: Optional[Sequence]):
        try:
            import jax

            from repro.launch.mesh import make_planned_mesh

            devs = list(devices) if devices is not None else jax.devices()
            if len(devs) == plan.mesh_plan.flat.size:
                return make_planned_mesh(plan, devices=devs)
        except Exception as e:                 # no jax / wrong backend
            # Never silently drop the reordering the system exists to
            # apply: the caller decides how to proceed on mesh=None.
            # stacklevel walks _try_build_mesh -> apply -> apply's caller
            # (3 frames): the warning points at application code.
            obs.tracer().event("session.mesh_build_failed", error=repr(e))
            obs.metrics().counter("session.mesh_build_failures").inc()
            warnings.warn(
                f"session could not build the reordered mesh ({e!r}); "
                f"AppliedPlan.mesh is None — apply the plan's order "
                f"manually or fix the jax device setup",
                RuntimeWarning, stacklevel=3)
            return None
        return None

    def hints(self, payload_bytes: Optional[float] = None) -> Dict[str, Dict]:
        """Per-op entry summaries of the current plan (empty pre-plan)."""
        if self._plan is None:
            return {}
        payload = payload_bytes or self.config.payload_bytes
        out: Dict[str, Dict] = {}
        for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
            e = self._plan.lookup(op, payload)
            if e is not None:
                out[op] = {
                    "algo": e.algo, "chunks": e.chunks,
                    "expected_time": e.expected_time,
                    "speedup_vs_identity":
                        e.best_identity_time / max(e.expected_time, 1e-30),
                }
        return out

    # -- collective IR: executors + lowering -------------------------------
    def executor(self, backend: str = "auto"):
        """An :class:`repro.collective.Executor` bound to this session.

        * ``"sim"`` — :class:`~repro.collective.SimExecutor` over the
          attached fabric (the contention-aware oracle the plan was
          scored on);
        * ``"analytic"`` — :class:`~repro.collective.AnalyticExecutor`
          over the probed lat/bw matrices (the only pricing available
          on live fleets, and after a drift re-plan);
        * ``"jax"`` — :class:`~repro.collective.JaxExecutor` (lowering
          to ppermute schedules; no pricing);
        * ``"auto"`` — ``sim`` when a fabric oracle is attached, else
          ``analytic`` — i.e. whatever oracle the compiler itself would
          score candidates with right now.
        """
        from repro.collective import (
            AnalyticExecutor, JaxExecutor, SimExecutor)

        self._require_open("build an executor")
        if backend == "jax":
            return JaxExecutor()
        if backend not in ("auto", "sim", "analytic"):
            raise ValueError(f"unknown executor backend {backend!r}; "
                             f"expected 'auto', 'sim', 'analytic' or 'jax'")
        # attach BEFORE resolving "auto": a pre-attach session has no
        # oracle fabric yet, and resolving on that transient state would
        # pick a different backend than the compiler's own oracle
        if self._probe is None:
            self.attach()
        if backend == "auto":
            backend = "sim" if self._oracle_fabric is not None else "analytic"
        if backend == "sim":
            if self._oracle_fabric is None:
                raise SessionError(
                    "executor('sim') needs an attached fabric oracle; "
                    "attach a synthetic fabric or use 'analytic'")
            return SimExecutor(self._oracle_fabric)
        probe = self._probe
        if probe.bw is not None:
            return AnalyticExecutor(lat=probe.lat, bw=probe.bw)
        return AnalyticExecutor(cost_matrix=probe.lat)

    def lower(self, op: str, size_bytes: Optional[float] = None,
              group: Optional[Sequence[int]] = None):
        """The plan's lowered schedule for ``op`` (lazily planning).

        Looks up the plan entry for ``op`` at ``size_bytes`` (default:
        the session payload), rebuilds its typed Program, and lowers it
        with :class:`repro.collective.JaxExecutor`.  This is how
        runtime consumers (``moe_a2a.arm_ep``, the serve engine, the
        generalized ``schedule_runner``) pull ppermute schedules from
        the plan instead of re-deriving them from ``(algo, perm)``
        string tuples.

        Every algorithm lowers (the ring family and all_to_all keep
        their closed-form views; everything else ships the generalized
        per-round ``LoweredSchedule``), and no unverified lowering
        escapes: the program is re-verified through the full gate —
        which includes the ``equiv`` translation validator — and the
        *exact artifact returned* is certified chunk-for-chunk against
        its IR before the runtime sees it.
        """
        from repro.collective import JaxExecutor

        self._require_open("lower")
        if self._plan is None:
            self.plan()
        payload = self.config.payload_bytes if size_bytes is None \
            else float(size_bytes)
        entry = self._plan.lookup(op, payload, group)
        if entry is None:
            raise SessionError(
                f"plan has no entry for op {op!r} at {payload:.0f} bytes; "
                f"planned ops: {sorted({k[0] for k in self._plan.entries})}")
        ex = JaxExecutor()
        prog = entry.program()
        # pre-flight: a cached/deserialized plan entry re-materializes
        # its Program here, after the compiler's gate — re-verify the
        # exact program we are about to hand to the runtime (GATE_PASSES
        # includes the equiv bisimulation of the program's own lowering)
        from repro.analysis import GATE_PASSES, require_certified, require_valid
        require_valid(prog, passes=GATE_PASSES)
        lowered = ex.lower(prog)
        # translation validation on the artifact itself: certify the
        # schedule object being returned, not just "a" lowering of prog
        # — defense in depth against a stale or foreign schedule
        require_certified(prog, lowered.schedule)
        return lowered

    def overlap_step(self, mesh: Any, axis: Optional[str] = None, *,
                     total_bytes: Optional[float] = None,
                     mode: Optional[str] = None,
                     bucket_bytes: Optional[float] = None,
                     interpret: bool = True):
        """A certified overlap reducer for the train step's grad all-reduce.

        Returns an :class:`~repro.train.overlap_grads.OverlapGradReducer`
        bound to ``mesh`` and the plan's certified all-reduce schedule,
        ready to pass to ``jit_train_step(..., overlap=..., reducer=...)``.
        Resolution order for every knob is explicit argument >
        ``config.overlap`` > plan: the bucket payload defaults to the
        planned :attr:`repro.plan.PlanEntry.bucket_bytes` of the full
        grad payload's octave, and the schedule itself comes from
        :meth:`lower` at the bucket octave — so both the bucket size and
        the per-bucket algorithm/permutation are planned dimensions, and
        the schedule is certified before any fusion.

        ``mesh`` must carry a 1-D data-parallel ``axis`` whose size
        matches the plan's all-reduce group.
        """
        self._require_open("build an overlap reducer")
        from repro.train.overlap_grads import OVERLAP_MODES

        cfg = self.config.overlap
        mode = cfg.mode if mode is None else mode
        if mode == "off":
            raise SessionError(
                "overlap_step() with mode 'off'; set "
                "SessionConfig.overlap.mode or pass mode= one of "
                f"{OVERLAP_MODES}")
        if mode not in OVERLAP_MODES:
            raise SessionError(
                f"unknown overlap mode {mode!r}; expected one of "
                f"{OVERLAP_MODES}")
        axis = cfg.axis if axis is None else axis
        total = self.config.payload_bytes if total_bytes is None \
            else float(total_bytes)
        bb = cfg.bucket_bytes if bucket_bytes is None else float(bucket_bytes)
        if self._plan is None:
            self.plan()
        if self._plan.lookup("all-reduce", total) is None:
            raise SessionError(
                "plan has no all-reduce entry; overlap_step() plans the "
                "gradient all-reduce — include one in the job mix")
        # reducer_from_plan lowers and certifies the exact schedule
        # artifact before any fusion; the reducer never edits rounds
        from repro.train.overlap_grads import reducer_from_plan

        return reducer_from_plan(
            self._plan, mesh, axis, total, mode=mode,
            bucket_bytes=bb if bb > 0 else None,
            use_pallas_add=cfg.use_pallas_add, interpret=interpret)

    # -- drift: observe / monitor -----------------------------------------
    def observe(self, cost_matrix_now: np.ndarray) -> DriftReport:
        """Feed a refreshed full-fabric cost matrix into drift tracking.

        Degraded entries are hot-patched via the per-entry
        :class:`~repro.core.dynamic.AdaptiveReranker`s, the cached plan
        is invalidated, and — with ``drift.auto_replan`` — the session
        recompiles against the observed matrix and fires ``replan``.
        """
        self._require_open("observe")
        if self._drift is None:
            raise SessionError("observe() needs a plan; call plan() first")
        with obs.tracer().span("session.observe") as sp:
            report = self._drift.observe(cost_matrix_now)
            sp.set(stale=report.stale, degraded=len(report.degraded))
        if report.stale:
            self._fire("drift", report=report)
            if self.config.drift.auto_replan:
                self._replan(np.asarray(cost_matrix_now, dtype=np.float64))
        return report

    def set_drift_threshold(self, threshold: float) -> None:
        """Change drift sensitivity, applying to the live monitor too.

        Consumers with their own sensitivity knob (the Trainer's
        ``rerank_threshold``) call this so one configured value governs
        both paths.
        """
        self.config = self.config.replace(
            drift={"threshold": float(threshold)})
        if self._drift is not None:
            self._drift.set_threshold(threshold)

    def _replan(self, observed: np.ndarray) -> Plan:
        """Recompile against drifted costs.

        The observed matrix is a full cost matrix at the session payload
        — it already embeds the bandwidth term — so it becomes the
        single (paper-mode) cost matrix of the re-plan.  Re-attaching
        the probed bw here would double-count bandwidth in the compiler
        and inflate the next drift reference.  The compiler's oracle
        also switches to the analytic cost model: the attached fabric
        simulator predates the drift, so ranking candidates on it would
        ignore exactly the congestion that triggered the re-plan.

        When the observation came from the sparse poll, the poll's
        freshly refreshed :class:`SparseProbeResult` (separate lat/bw,
        recovered hierarchy, landmark state) becomes the re-plan probe
        instead: the recompile stays hierarchy-decomposed and keeps the
        tree fingerprint, and the next poll tick resumes cluster
        tracking from it rather than re-spending the probe budget.
        """
        old = self._plan
        fresh, self._sparse_fresh = self._sparse_fresh, None
        if fresh is not None and fresh.n == observed.shape[0]:
            probe: ProbeResult = fresh
        else:
            probe = ProbeResult(lat=observed, bw=None)
        with self._lock:
            self._probe = probe
            self._oracle_fabric = None
            if self._service is not None:      # rebuild on the new oracle
                self._service.close()
                self._service = None
        with obs.tracer().span("session.replan"):
            plan = self.plan(mix=self._mix, mesh_shape=self._mesh_shape,
                             axis_names=self._axis_names)
        obs.metrics().counter("session.replans").inc()
        self._fire("replan", plan=plan, previous=old)
        return plan

    def monitor(self, poll: Optional[Callable[[], Optional[np.ndarray]]] = None,
                interval_s: Optional[float] = None) -> threading.Thread:
        """Start the background drift monitor.

        ``poll()`` returns a refreshed cost matrix (or None to skip a
        tick); the default re-probes the attached synthetic fabric with
        a rotating seed.  The thread is a daemon and stops at
        :meth:`close`.

        Tick failures (a timed-out probe, a recompile racing a
        re-attach) are governed by the session retry policy instead of
        a bare warning per failure: consecutive failures back off
        exponentially (capped, jittered — a flapping probe cannot spin
        the thread hot), cross ``retry.failure_threshold`` and the
        session enters ``degraded`` (firing the ``degraded`` hook while
        continuing to serve the last good plan), cross
        ``retry.halt_threshold`` and it enters ``halted``: the plan is
        pinned to identity order — the one order that needs no fresh
        fabric knowledge — and the monitor stops burning probes.  A
        clean tick from ``degraded`` fires ``recovered``.  No exception
        ever escapes the monitor thread.
        """
        self._require_open("monitor")
        if self._plan is None:
            self.plan()
        if self._monitor_thread is not None and self._monitor_thread.is_alive():
            raise SessionError("monitor already running")
        interval = self.config.drift.interval_s if interval_s is None \
            else float(interval_s)
        if poll is None:
            if self._fabric is None:
                raise SessionError(
                    "default monitor poll needs an attached fabric; pass "
                    "poll= for live fleets")
            poll = self._default_poll()
        self._monitor_stop.clear()
        policy = self.config.retry
        rng = np.random.default_rng(policy.seed)

        def tick() -> None:
            obs.metrics().counter("session.monitor.ticks").inc()
            with obs.tracer().span("session.monitor.tick") as sp:
                c = poll()
                sp.set(observed=c is not None)
                if c is not None and self.state != "closed" \
                        and self._drift is not None:
                    self.observe(c)

        def loop() -> None:
            while not self._monitor_stop.wait(interval):
                if self._health.state == "halted":
                    return
                try:
                    tick()
                except Exception as e:
                    obs.metrics().counter("session.monitor.failures").inc()
                    entered = self._health.record_failure(repr(e))
                    if entered == "degraded":
                        self._safe_fire("degraded", state="degraded",
                                        reason=repr(e))
                    elif entered == "halted":
                        self._halt(repr(e))
                        return
                    # capped, jittered backoff between consecutive
                    # failures; close() interrupts it immediately
                    backoff = policy.delay(
                        self._health.consecutive_failures, rng)
                    if backoff > 0.0 and self._monitor_stop.wait(backoff):
                        return
                else:
                    if self._health.record_success() == "healthy":
                        self._safe_fire("recovered", state="healthy")

        t = threading.Thread(target=loop, daemon=True,
                             name=f"repro-session-monitor-{self.config.name}")
        self._monitor_thread = t
        t.start()
        return t

    def _safe_fire(self, event: str, **info: Any) -> None:
        """Fire hooks from the monitor thread; a raising hook is reported
        as a warning, never an escaping exception."""
        try:
            self._fire(event, **info)
        except Exception as e:
            # stacklevel=2 points at the monitor-loop frame that fired
            # the hook — there is no user frame above a daemon thread
            obs.tracer().event("session.hook_error", event=event,
                               error=repr(e))
            obs.metrics().counter("session.hook_errors").inc()
            warnings.warn(
                f"session {event!r} hook raised {e!r}; monitor continues",
                RuntimeWarning, stacklevel=2)

    def _halt(self, reason: str) -> None:
        """Bottom of the degradation ladder: pin identity order.

        Probing has failed ``retry.halt_threshold`` consecutive times —
        whatever the plan believes about the fabric is stale beyond
        repair, and identity order is the one order that is never worse
        than identity.  Only :meth:`HealthTracker.reset` (or a
        re-attach) returns the session to service.
        """
        with self._lock:
            if self._plan is not None:
                identity_fallback(self._plan)
        self._safe_fire("degraded", state="halted", reason=reason)

    def _default_poll(self) -> Callable[[], Optional[np.ndarray]]:
        tick = {"n": 0}
        cfg = self.config
        if cfg.probe.mode == "sparse" and \
                isinstance(self._probe, SparseProbeResult):
            # cluster-scoped monitoring: each tick re-probes every
            # cluster's sentinel against the landmarks and fully
            # re-probes ONLY the clusters that moved — a quiet fabric
            # costs O(K·L) probes per tick, not n^2
            state = {"probe": self._probe, "attached": self._probe}

            def poll_sparse() -> Optional[np.ndarray]:
                tick["n"] += 1
                fab = self._fabric
                if fab is None:          # re-attached onto a raw probe
                    return None
                if self._probe is not state["attached"]:
                    # a re-attach replaced the probe mid-monitor: restart
                    # cluster tracking from the session's current state
                    # (a fresh sparse probe when the new one isn't sparse)
                    state["attached"] = self._probe
                    state["probe"] = self._probe \
                        if isinstance(self._probe, SparseProbeResult) \
                        else None
                if state["probe"] is None or state["probe"].n != fab.n:
                    state["probe"] = self._probe_fabric(fab)
                    if not isinstance(state["probe"], SparseProbeResult):
                        return cost_matrix(state["probe"],
                                           cfg.payload_bytes)
                refreshed, moved = refresh_sparse(
                    fab, state["probe"],
                    seed=cfg.probe.seed + tick["n"],
                    percentile=cfg.probe.percentile,
                    noise_scale=cfg.probe.noise_scale,
                    measure_bw=cfg.probe.measure_bw)
                state["probe"] = refreshed
                if not moved:
                    return None          # nothing moved: skip the tick
                self._sparse_fresh = refreshed
                return cost_matrix(refreshed, cfg.payload_bytes)

            return poll_sparse

        def poll() -> np.ndarray:
            tick["n"] += 1
            probed = probe_fabric(
                self._fabric, n_probes=cfg.probe.n_probes,
                percentile=cfg.probe.percentile,
                noise_scale=cfg.probe.noise_scale,
                seed=cfg.probe.seed + tick["n"],
                measure_bw=cfg.probe.measure_bw)
            return cost_matrix(probed, cfg.payload_bytes)

        return poll

    # -- elastic membership ------------------------------------------------
    def on_node_leave(self, nodes: Sequence[int]) -> Optional[Plan]:
        """Handle departed nodes (preemption, failure) without recompiling.

        ``nodes`` are rank ids in the *current* numbering.  The fabric
        and probe are restricted to the survivors (``Fabric.subset`` /
        ``ProbeResult.subset``, which also restricts the recovered
        hierarchy), and every cached plan entry is warm-recovered onto
        the surviving ranks through the degradation ladder
        (:func:`repro.faults.recover_plan`): the previous permutation is
        restricted and refined with a small budget — no cold compile —
        and entries whose algorithm became infeasible at the new group
        size (power-of-two builders) are re-selected among feasible
        candidates.  Fires ``node_leave`` with the per-entry ladder
        rungs.  Returns the recovered plan (None when the session had
        no plan, or recovery itself failed and the session degraded to
        plan-less).
        """
        self._require_open("handle node departure")
        if self._probe is None:
            raise SessionError(
                "on_node_leave needs an attached session; call attach()")
        n = self._probe.n
        leave = sorted({int(x) for x in nodes})
        if not leave:
            raise ValueError("on_node_leave needs at least one node id")
        bad = [x for x in leave if x < 0 or x >= n]
        if bad:
            raise ValueError(
                f"on_node_leave ids {bad} outside the fabric of {n} nodes")
        survivors = [i for i in range(n) if i not in set(leave)]
        if len(survivors) < 2:
            raise SessionError(
                f"cannot drop {len(leave)} of {n} nodes: fewer than 2 "
                f"survivors")
        new_fabric = self._fabric.subset(survivors) \
            if self._fabric is not None else None
        new_probe = self._probe.subset(survivors)
        old_to_new = {old: new for new, old in enumerate(survivors)}
        with self._lock:
            if self._alive is not None and len(self._alive) == n:
                self._alive = [self._alive[k] for k in survivors]
        plan, rungs = self._rebind_membership(
            new_fabric, new_probe, old_to_new, ())
        self._fire("node_leave", nodes=tuple(leave),
                   survivors=tuple(survivors), rungs=rungs, plan=plan)
        return plan

    def on_node_join(self, nodes: Optional[Sequence[int]] = None,
                     count: int = 1) -> Optional[Plan]:
        """Handle (re)joining nodes — the other half of elastic churn.

        ``nodes`` are ids in the *attach-time* numbering (the ids
        :meth:`on_node_leave` reported via ``self.alive``); default: the
        first ``count`` departed nodes.  The grown fabric is re-probed
        (the joiners have no measurements), full-fabric plan entries
        absorb the joiners — appended to the warm-start order, placed by
        the budgeted refinement — and sub-group entries are left as
        they are.  Fires ``node_join``.  Requires the attach-time
        fabric topology (synthetic kinds); live fleets re-attach.
        """
        self._require_open("handle node join")
        if self._base_fabric is None or self._alive is None:
            raise SessionError(
                "on_node_join needs the attach-time fabric topology to "
                "re-probe the joined nodes; attach a fabric (synthetic "
                "kinds) — live fleets should re-attach instead")
        base_n = self._base_fabric.n
        alive = list(self._alive)
        dead = set(range(base_n)) - set(alive)
        if nodes is None:
            if not dead:
                raise SessionError(
                    "on_node_join: every attach-time node is already live")
            joining = sorted(dead)[:max(1, int(count))]
        else:
            joining = sorted({int(x) for x in nodes})
            bad = [x for x in joining if x not in dead]
            if bad:
                raise ValueError(
                    f"on_node_join ids {bad} are not departed members of "
                    f"the attach-time fabric ({len(alive)}/{base_n} live)")
        if not joining:
            raise ValueError("on_node_join needs at least one node id")
        new_alive = sorted(set(alive) | set(joining))
        new_fabric = self._base_fabric if len(new_alive) == base_n \
            else self._base_fabric.subset(new_alive)
        new_probe = self._probe_fabric(new_fabric)
        pos = {b: i for i, b in enumerate(new_alive)}
        old_to_new = {k: pos[b] for k, b in enumerate(alive)}
        joiners = tuple(pos[b] for b in joining)
        with self._lock:
            self._alive = new_alive
        plan, rungs = self._rebind_membership(
            new_fabric, new_probe, old_to_new, joiners)
        self._fire("node_join", nodes=tuple(joining), joiners=joiners,
                   rungs=rungs, plan=plan)
        return plan

    def _rebind_membership(self, new_fabric: Optional[Fabric],
                           new_probe: ProbeResult,
                           old_to_new: Dict[int, int],
                           joiners: Tuple[int, ...]):
        """Swap fabric+probe after a membership change and warm-recover
        the plan; returns ``(plan, rungs)``."""
        cfg = self.config
        rungs = None
        with self._lock:
            old_plan = self._plan
            self._fabric = new_fabric
            if self._oracle_fabric is not None:
                self._oracle_fabric = new_fabric
            self._probe = new_probe
            self._sparse_fresh = None
            if self._service is not None:   # compiler bound to old oracle
                self._service.close()
                self._service = None
            if self._mesh_shape is not None and \
                    int(np.prod(self._mesh_shape)) != new_probe.n:
                # an N-D assignment cannot survive a node-count change
                self._mesh_shape = None
                self._axis_names = None
            if old_plan is None:
                return None, None
            try:
                new_plan, rungs = recover_plan(
                    old_plan, old_to_new, new_probe.lat, new_probe.bw,
                    hierarchy=getattr(new_probe, "hierarchy", None),
                    joiners=joiners, seed=cfg.solver.seed)
            except Exception as e:
                # keeping a plan whose numbering no longer matches the
                # fabric would be worse than having none: degrade to
                # plan-less (the next plan() recompiles cold)
                self._plan = None
                self._drift = None
                if self._health.force_degraded(
                        f"membership recovery failed: {e!r}") == "degraded":
                    self._safe_fire("degraded", state="degraded",
                                    reason=repr(e))
                return None, None
            self._plan = new_plan
            if self._mix is not None:
                self.cache.put(new_plan, self._mix.key())
            self._drift = DriftMonitor(
                new_plan, self.reference_matrix(),
                cache=self.cache, threshold=cfg.drift.threshold)
            if rungs and any(r in ("stale", "identity")
                             for r in rungs.values()):
                # a rung below warm-resolve means the plan is serving a
                # weaker order than a compile would produce
                if self._health.force_degraded(
                        "membership recovery served a stale/identity "
                        "rung") == "degraded":
                    self._safe_fire("degraded", state="degraded",
                                    reason="ladder")
        return self._plan, rungs

    # -- non-intrusive wrap ------------------------------------------------
    def wrap(self) -> "_WrapGuard":
        """Patch the launch surface so unmodified code gets planned orders.

        * ``repro.launch.mesh.make_production_mesh`` returns the
          session's reordered mesh when its assignment matches the
          production shape;
        * ``repro.parallel.moe_a2a.arm_ep`` is armed with the session's
          plan whenever the caller didn't pass one.

        Usable as a context manager (``with session.wrap(): ...``);
        :meth:`unwrap` (also run by :meth:`close`) restores the
        originals.  This is the paper's "no code changes nor rebuild"
        property applied to our own launchers.
        """
        self._require_open("wrap")
        if self._patches:
            raise SessionError("session is already wrapped")
        from repro.launch import mesh as mesh_mod
        from repro.parallel import moe_a2a

        session = self
        orig_make = mesh_mod.make_production_mesh
        orig_arm = moe_a2a.arm_ep

        def make_production_mesh(*, multi_pod: bool = False):
            plan = session._plan
            if plan is not None and plan.mesh_plan is not None:
                shape, _axes = mesh_mod.production_shape(multi_pod)
                if tuple(plan.mesh_plan.assignment.shape) == tuple(shape):
                    return mesh_mod.make_reordered_mesh(plan.mesh_plan)
            return orig_make(multi_pod=multi_pod)

        def arm_ep(mesh, ep_axis="data", tp_axis="model", plan=None, **kw):
            if plan is None:
                plan = session._plan
            return orig_arm(mesh, ep_axis, tp_axis, plan=plan, **kw)

        self._patch(mesh_mod, "make_production_mesh", make_production_mesh)
        self._patch(moe_a2a, "arm_ep", arm_ep)
        return _WrapGuard(self)

    def _patch(self, module: Any, attr: str, replacement: Any) -> None:
        self._patches.append((module, attr, getattr(module, attr)))
        setattr(module, attr, replacement)

    def unwrap(self) -> None:
        """Restore every attribute :meth:`wrap` replaced (idempotent)."""
        while self._patches:
            module, attr, original = self._patches.pop()
            setattr(module, attr, original)

    @property
    def wrapped(self) -> bool:
        return bool(self._patches)

    # -- lifecycle: close --------------------------------------------------
    def close(self) -> None:
        """Stop monitoring, unwrap patches, shut the service (idempotent)."""
        if self.state == "closed":
            return
        self._monitor_stop.set()
        t = self._monitor_thread
        if t is not None and t.is_alive():
            t.join(timeout=5.0)
        self.unwrap()
        with self._lock:
            if self._service is not None:
                self._service.close()
                self._service = None
            self.state = "closed"
        obs.metrics().counter("session.closes").inc()
        self._export_obs()
        self._fire("close")

    def _export_obs(self) -> None:
        """Write configured obs artifacts (trace / capture) on close.

        Export failures warn instead of raising: close() must stay
        usable from error paths and __exit__.
        """
        cfg = self.config.obs
        if cfg.export_path:
            try:
                obs.tracer().export(cfg.export_path)
            except Exception as e:
                warnings.warn(
                    f"session could not export the obs trace to "
                    f"{cfg.export_path!r} ({e!r})",
                    RuntimeWarning, stacklevel=3)
        if cfg.capture_path:
            try:
                obs.recorder().trace(name="session").save(cfg.capture_path)
            except Exception as e:
                warnings.warn(
                    f"session could not save the workload capture to "
                    f"{cfg.capture_path!r} ({e!r})",
                    RuntimeWarning, stacklevel=3)
