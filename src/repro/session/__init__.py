"""repro.session — the one-call, non-intrusive Session facade.

Entry point for the whole pipeline::

    from repro import Session, SessionConfig

    with Session(SessionConfig.from_dict({
            "fabric": {"kind": "datacenter", "nodes": 64},
            "mesh": {"shape": "8x8"}})) as s:
        applied = s.apply()            # probe -> plan -> apply, lazily
        print(applied.summary())

See DESIGN.md §6 for the facade architecture, the lifecycle state
machine, and the deprecation policy for the older manual pipeline.
"""

from .config import (  # noqa: F401
    CacheConfig,
    DriftConfig,
    FabricConfig,
    MeshConfig,
    ObsConfig,
    ProbeConfig,
    RetryPolicy,
    SessionConfig,
    SolverConfig,
)
from .mixes import default_mix, serve_mix, train_mix  # noqa: F401
from .session import EVENTS, AppliedPlan, Session, SessionError  # noqa: F401
