"""Unified, declarative session configuration.

Every knob that was previously hand-threaded through ``core`` / ``plan``
/ ``launch`` call sites lives here as one frozen dataclass tree:

* :class:`FabricConfig` — which fabric to attach (synthetic datacenter /
  TPU fleet, or live device probing);
* :class:`ProbeConfig` — paper §IV-B probing parameters;
* :class:`SolverConfig` — solver seed + :class:`repro.plan.SolveBudget`
  (iters, chains, chunk candidates, engine, backend);
* :class:`CacheConfig` — plan-cache directory / capacity / fuzzy-match
  tolerance;
* :class:`DriftConfig` — drift threshold and re-plan policy;
* :class:`repro.faults.RetryPolicy` — probe/re-plan backoff and the
  monitor's degraded/halted health thresholds (the ``retry`` section);
* :class:`MeshConfig` — N-D mesh shape + axis names;
* :class:`ObsConfig` — observability: tracing on/off + ring-buffer
  size, workload capture, metrics, and export paths (see
  :mod:`repro.obs`);
* :class:`OverlapConfig` — compute–communication overlap mode and
  bucket-size override for the certified train/serve step (see
  :mod:`repro.train.overlap_grads`).

The tree round-trips through plain dicts (:meth:`SessionConfig.to_dict`
/ :meth:`SessionConfig.from_dict`), JSON files (:meth:`SessionConfig.load`
/ :meth:`SessionConfig.dump`), and the environment
(:meth:`SessionConfig.from_env`, ``REPRO_<SECTION>_<FIELD>`` variables),
so the same declaration drives the Python API, ``python -m repro``, and
launcher scripts.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.faults.retry import RetryPolicy
from repro.plan.cache import DEFAULT_TOL
from repro.plan.compiler import SolveBudget

__all__ = [
    "FabricConfig",
    "ProbeConfig",
    "SolverConfig",
    "CacheConfig",
    "DriftConfig",
    "MeshConfig",
    "ObsConfig",
    "OverlapConfig",
    "RetryPolicy",
    "SessionConfig",
]


def _parse_dims(value: Any) -> Tuple[int, ...]:
    """Accept (8, 8), [8, 8], "8x8", or "8,8"."""
    if value is None:
        return ()
    if isinstance(value, str):
        sep = "x" if "x" in value else ","
        parts = [p for p in value.split(sep) if p.strip()]
        return tuple(int(p) for p in parts)
    return tuple(int(v) for v in value)


def _parse_names(value: Any) -> Tuple[str, ...]:
    if value is None:
        return ()
    if isinstance(value, str):
        return tuple(p.strip() for p in value.split(",") if p.strip())
    return tuple(str(v) for v in value)


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Which fabric a session attaches to when none is passed explicitly."""

    kind: str = "datacenter"           # "datacenter" | "tpu-fleet" | "live"
    nodes: int = 64                    # datacenter size
    n_pods: int = 1                    # tpu-fleet pods
    pod_shape: Tuple[int, ...] = (8, 8)
    fragmentation: float = 0.0
    seed: int = 0
    #: scramble the node labels (the cloud's "random IP list", paper §I);
    #: None = no scramble
    scramble_seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "pod_shape", _parse_dims(self.pod_shape))
        if self.kind not in ("datacenter", "tpu-fleet", "live"):
            raise ValueError(
                f"FabricConfig.kind must be 'datacenter', 'tpu-fleet', or "
                f"'live'; got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """Paper §IV-B probing parameters (see :func:`repro.fabric.probe_fabric`).

    ``mode="sparse"`` switches to budgeted probing
    (:func:`repro.fabric.sparse_probe_fabric`): ``budget`` of the dense
    n(n-1) probes reconstructs a plan-grade cost matrix and recovers
    the locality hierarchy, which the compiler then exploits.
    """

    n_probes: int = 1000
    percentile: float = 10.0
    noise_scale: float = 0.3
    measure_bw: bool = True
    seed: int = 0
    mode: str = "dense"                # "dense" | "sparse"
    budget: float = 0.25               # sparse probe fraction of n(n-1)

    def __post_init__(self):
        if self.mode not in ("dense", "sparse"):
            raise ValueError(
                f"ProbeConfig.mode must be 'dense' or 'sparse'; "
                f"got {self.mode!r}")


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Solver engine selection + per-entry effort budget."""

    seed: int = 0
    budget: SolveBudget = dataclasses.field(default_factory=SolveBudget)


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Plan-cache policy (see :class:`repro.plan.PlanCache`)."""

    dir: Optional[str] = None          # None = in-memory only
    capacity: int = 32
    tol: float = DEFAULT_TOL           # fuzzy fingerprint-match octaves


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """When an observed cost matrix invalidates the current plan."""

    threshold: float = 1.15            # degradation ratio triggering repair
    auto_replan: bool = True           # recompile after a stale observation
    interval_s: float = 5.0            # background monitor poll period


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """N-D mesh the plan's assignment targets; empty = no mesh plan."""

    shape: Tuple[int, ...] = ()
    axis_names: Tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "shape", _parse_dims(self.shape))
        names = _parse_names(self.axis_names)
        if self.shape and not names:
            names = ("pod", "data", "model")[-len(self.shape):]
        object.__setattr__(self, "axis_names", names)
        if self.shape and len(names) != len(self.shape):
            raise ValueError(
                f"MeshConfig needs one axis name per dim: shape {self.shape} "
                f"vs axis_names {names}")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability switches (see :mod:`repro.obs`).

    A session applies this section to the process-global tracer /
    metrics registry / workload recorder on attach
    (:func:`repro.obs.configure`); the env overlay spells it
    ``REPRO_OBS_ENABLED=1``, ``REPRO_OBS_CAPTURE=1``,
    ``REPRO_OBS_EXPORT_PATH=trace.json`` etc.
    """

    enabled: bool = False              # span/event tracing
    buffer: int = 8192                 # tracer ring-buffer records
    metrics: bool = True               # counter/gauge/histogram registry
    capture: bool = False              # workload (op, bytes, group, t) capture
    #: write the Chrome trace here on Session.close() (None = don't)
    export_path: Optional[str] = None
    #: write the captured WorkloadTrace JSON here on Session.close()
    capture_path: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class OverlapConfig:
    """Compute–communication overlap of the certified collective path.

    Consumed by ``Session.overlap_step`` and the train layer
    (:mod:`repro.train.overlap_grads`): ``mode`` selects how the
    bucketed gradient all-reduce interleaves with compute, and
    ``bucket_bytes`` overrides the plan-selected bucket payload
    (``0`` = use :attr:`repro.plan.PlanEntry.bucket_bytes`).  Env
    overlay: ``REPRO_OVERLAP_MODE=bucketed`` etc.
    """

    mode: str = "off"            # "off" | "sequential" | "bucketed" | "fused"
    #: bucket payload override (bytes); 0 = planned per octave
    bucket_bytes: float = 0.0
    #: mesh axis the bucketed all-reduce runs over
    axis: str = "data"
    #: accumulate reduces through the Pallas fused_add kernel
    use_pallas_add: bool = False

    def __post_init__(self):
        if self.mode not in ("off", "sequential", "bucketed", "fused"):
            raise ValueError(
                f"OverlapConfig.mode must be 'off', 'sequential', "
                f"'bucketed', or 'fused'; got {self.mode!r}")


_SECTIONS: Dict[str, type] = {
    "fabric": FabricConfig,
    "probe": ProbeConfig,
    "solver": SolverConfig,
    "cache": CacheConfig,
    "drift": DriftConfig,
    "retry": RetryPolicy,
    "mesh": MeshConfig,
    "obs": ObsConfig,
    "overlap": OverlapConfig,
}


def _coerce(ftype: Any, value: Any) -> Any:
    """Best-effort string coercion for env/CLI-sourced values."""
    if not isinstance(value, str):
        return value
    s = value.strip()
    if s.lower() in ("none", "null"):
        return None
    if ftype is int:
        return int(float(s))
    if ftype is float:
        return float(s)
    if ftype is bool:
        return s.lower() in ("1", "true", "yes", "on")
    return s


def _field_hint(f: dataclasses.Field) -> Optional[type]:
    """Scalar type of a dataclass field, robust to string annotations."""
    t = str(f.type).replace("typing.", "")
    if t in ("int", "Optional[int]"):
        return int
    if t in ("float", "Optional[float]"):
        return float
    if t == "bool":
        return bool
    return None


def _dataclass_from_dict(cls: type, d: Mapping[str, Any], path: str) -> Any:
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - set(fields))
    if unknown:
        raise ValueError(
            f"unknown {path} config keys {unknown}; "
            f"expected a subset of {sorted(fields)}")
    kwargs: Dict[str, Any] = {}
    for name, value in d.items():
        f = fields[name]
        # the solver's "budget" is a nested SolveBudget dataclass; the
        # probe's "budget" is a plain float (sparse probe fraction)
        if name == "budget" and cls is SolverConfig:
            kwargs[name] = value if isinstance(value, SolveBudget) else \
                _dataclass_from_dict(SolveBudget, dict(value), f"{path}.{name}")
            continue
        kwargs[name] = _coerce(_field_hint(f), value)
        if name in ("chunk_candidates", "bucket_candidates") \
                and kwargs[name] is not None:
            kwargs[name] = _parse_dims(kwargs[name])
    return cls(**kwargs)


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """The one declaration a :class:`repro.session.Session` needs.

    Everything defaults to a CPU-runnable synthetic setup; a production
    launch overrides ``fabric.kind="live"``, the mesh shape, and the
    cache directory — nothing else has to change.
    """

    fabric: FabricConfig = dataclasses.field(default_factory=FabricConfig)
    probe: ProbeConfig = dataclasses.field(default_factory=ProbeConfig)
    solver: SolverConfig = dataclasses.field(default_factory=SolverConfig)
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)
    drift: DriftConfig = dataclasses.field(default_factory=DriftConfig)
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    mesh: MeshConfig = dataclasses.field(default_factory=MeshConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    overlap: OverlapConfig = dataclasses.field(default_factory=OverlapConfig)
    #: dominant collective payload of the workload (bytes)
    payload_bytes: float = 4e6
    #: workload shape for the default job mix ("train" | "serve")
    workload: str = "train"
    #: MoE workload: adds the EP all-to-all to the default mix
    moe: bool = False
    name: str = "session"

    def __post_init__(self):
        if self.workload not in ("train", "serve"):
            raise ValueError(
                f"SessionConfig.workload must be 'train' or 'serve'; "
                f"got {self.workload!r}")

    # -- dict / JSON round-trip -------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "SessionConfig":
        d = dict(d)
        kwargs: Dict[str, Any] = {}
        for section, cls in _SECTIONS.items():
            if section in d:
                value = d.pop(section)
                kwargs[section] = value if isinstance(value, cls) else \
                    _dataclass_from_dict(cls, dict(value), section)
        scalars = {"payload_bytes", "workload", "moe", "name"}
        unknown = sorted(set(d) - scalars)
        if unknown:
            raise ValueError(
                f"unknown session config keys {unknown}; expected sections "
                f"{sorted(_SECTIONS)} or scalars {sorted(scalars)}")
        if "payload_bytes" in d:
            kwargs["payload_bytes"] = float(d["payload_bytes"])
        if "moe" in d:
            kwargs["moe"] = _coerce(bool, d["moe"])
        for k in ("workload", "name"):
            if k in d:
                kwargs[k] = str(d[k])
        return SessionConfig(**kwargs)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @staticmethod
    def from_json(s: str) -> "SessionConfig":
        return SessionConfig.from_dict(json.loads(s))

    @staticmethod
    def load(path: str) -> "SessionConfig":
        with open(path) as f:
            return SessionConfig.from_json(f.read())

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    # -- overrides ---------------------------------------------------------
    def replace(self, **updates: Any) -> "SessionConfig":
        """Functional update; section values may be partial dicts.

        Merging is deep: ``replace(solver={"budget": {"iters": 200}})``
        keeps every other budget field of the current config.
        """
        def deep_merge(dst: Dict[str, Any], src: Mapping[str, Any]) -> None:
            for k, v in src.items():
                if isinstance(v, Mapping) and isinstance(dst.get(k), dict):
                    deep_merge(dst[k], v)
                else:
                    dst[k] = v

        merged = self.to_dict()
        for key, value in updates.items():
            if key in _SECTIONS and isinstance(value, Mapping):
                deep_merge(merged[key], value)
            elif key in _SECTIONS and dataclasses.is_dataclass(value):
                merged[key] = dataclasses.asdict(value)
            else:
                merged[key] = value
        return SessionConfig.from_dict(merged)

    # -- environment -------------------------------------------------------
    @staticmethod
    def from_env(prefix: str = "REPRO_",
                 base: Optional["SessionConfig"] = None,
                 environ: Optional[Mapping[str, str]] = None) -> "SessionConfig":
        """Overlay ``REPRO_<SECTION>_<FIELD>`` variables onto ``base``.

        ``REPRO_FABRIC_KIND=tpu-fleet``, ``REPRO_CACHE_DIR=.plan_cache``,
        ``REPRO_MESH_SHAPE=8x8``, ``REPRO_PAYLOAD_BYTES=4e6`` — the CLI
        and launchers all honor the same variables.
        """
        env = dict(os.environ if environ is None else environ)
        cfg = base if base is not None else SessionConfig()
        merged = cfg.to_dict()
        scalars = {"payload_bytes", "workload", "moe", "name"}
        for key, value in sorted(env.items()):
            if not key.startswith(prefix):
                continue
            rest = key[len(prefix):].lower()
            head, _, tail = rest.partition("_")
            if head in _SECTIONS and tail:
                if head == "solver" and tail.startswith("budget_"):
                    merged["solver"].setdefault("budget", {})
                    merged["solver"]["budget"][tail[len("budget_"):]] = value
                else:
                    merged[head][tail] = value
            elif rest in scalars:
                merged[rest] = value
            else:
                raise ValueError(
                    f"unrecognized environment override {key}: no section "
                    f"or scalar named {rest!r}")
        return SessionConfig.from_dict(merged)
