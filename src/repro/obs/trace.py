"""Structured span/event tracing with Chrome trace-event export.

One :class:`Tracer` instance collects timing *spans* (nested wall-clock
intervals) and instant *events* from every layer of the planning
pipeline into a bounded ring buffer.  Design constraints, in order:

* **zero-overhead when disabled** — the hot paths (plan cache lookups,
  monitor ticks, decode steps) call ``tracer.span(...)`` unconditionally;
  on a disabled tracer that returns the shared :data:`NULL_SPAN`
  singleton without allocating or reading the clock.  The contract is
  tested: a disabled tracer performs **no** allocation per call and
  records nothing;
* **injected monotonic clock** — ``Tracer(clock=...)`` takes any
  ``() -> float`` (default :func:`time.perf_counter`), so tests drive
  deterministic timestamps and replay tooling can re-stamp;
* **thread-safe** — spans may open/close on the session monitor thread,
  the planning-service pool, and the caller's thread concurrently; the
  ring buffer is lock-guarded and nesting depth is tracked per thread;
* **bounded** — the buffer is a ``deque(maxlen=...)``: a long-running
  session keeps the most recent window instead of growing without bound;
* **viewable** — :meth:`Tracer.to_chrome` emits the Chrome trace-event
  JSON format (``ph: "X"`` complete events + thread-name metadata),
  loadable directly in Perfetto / ``chrome://tracing``.

:meth:`Tracer.timer` is the one deliberate exception to the
disabled-no-clock rule: it *always* measures (the caller needs the
number — ``compile_seconds``, a CLI wall-clock line, a recovery
latency) and only *records* when tracing is enabled.  This is the
single instrumented path that replaced the ad-hoc
``time.perf_counter()`` pairs scattered through the CLI, compiler,
ladder, and trainer.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["NULL_SPAN", "Span", "TraceRecord", "Tracer"]

#: one buffered record: (phase, name, t0_s, dur_s, thread, depth, attrs)
#: phase is "X" (complete span) or "i" (instant event); times are
#: seconds on the tracer clock relative to the tracer epoch.
TraceRecord = Tuple[str, str, float, float, str, int, Optional[Dict[str, Any]]]


class _NullSpan:
    """The shared no-op span a disabled tracer hands out.

    A singleton: ``span()`` on a disabled tracer returns this exact
    object every time — no allocation, no clock read, no buffer touch.
    ``elapsed`` stays 0.0 (callers that need real timing use
    :meth:`Tracer.timer`, which always measures).
    """

    __slots__ = ()
    elapsed = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """A timed interval; use as a context manager.

    ``elapsed`` (seconds) is valid after ``__exit__`` — the one number
    every former ``perf_counter`` pair now reads from here.  ``set()``
    attaches result attributes (entry counts, cache digests) that land
    in the exported event's ``args``.
    """

    __slots__ = ("_tracer", "name", "attrs", "t0", "elapsed", "_record")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, Any]], record: bool):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.elapsed = 0.0
        self._record = record

    def set(self, **attrs: Any) -> "Span":
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.t0 = self._tracer.clock()
        if self._record:
            self._tracer._depth_push()
        return self

    def __exit__(self, etype: Any, evalue: Any, tb: Any) -> bool:
        self.elapsed = self._tracer.clock() - self.t0
        if self._record:
            if etype is not None:
                self.set(error=f"{etype.__name__}: {evalue}")
            self._tracer._finish_span(self)
        return False


class Tracer:
    """Thread-safe bounded span/event collector (see module docstring)."""

    def __init__(self, enabled: bool = False, buffer: int = 8192,
                 clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._enabled = bool(enabled)
        self._buf: "deque[TraceRecord]" = deque(maxlen=int(buffer))
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._epoch = clock()
        #: monotone count of records ever buffered (survives ring wrap)
        self.emitted = 0

    # -- state -------------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    @property
    def buffer(self) -> int:
        return self._buf.maxlen or 0

    def set_enabled(self, flag: bool) -> None:
        self._enabled = bool(flag)

    def set_buffer(self, buffer: int) -> None:
        """Resize the ring buffer, keeping the newest records."""
        with self._lock:
            self._buf = deque(self._buf, maxlen=int(buffer))

    def __len__(self) -> int:
        return len(self._buf)

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """A traced interval — :data:`NULL_SPAN` when disabled."""
        if not self._enabled:
            return NULL_SPAN
        return Span(self, name, attrs or None, record=True)

    def timer(self, name: str, **attrs: Any) -> Span:
        """An always-measuring interval (recorded only when enabled).

        The instrumented replacement for ad-hoc ``perf_counter`` pairs:
        product numbers (compile seconds, recovery ms) read
        ``timer.elapsed``, and the same interval shows up in the trace
        whenever tracing is on.
        """
        return Span(self, name, attrs or None, record=self._enabled)

    def event(self, name: str, **attrs: Any) -> None:
        """An instant event — no-op when disabled."""
        if not self._enabled:
            return
        t = self.clock() - self._epoch
        rec: TraceRecord = ("i", name, t, 0.0, threading.current_thread().name,
                            self._depth(), attrs or None)
        with self._lock:
            self._buf.append(rec)
            self.emitted += 1

    # -- nesting (per-thread depth, for display only) ----------------------
    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def _depth_push(self) -> None:
        self._tls.depth = self._depth() + 1

    def _finish_span(self, span: Span) -> None:
        depth = max(self._depth() - 1, 0)
        self._tls.depth = depth
        rec: TraceRecord = ("X", span.name, span.t0 - self._epoch,
                            span.elapsed, threading.current_thread().name,
                            depth, span.attrs)
        with self._lock:
            self._buf.append(rec)
            self.emitted += 1

    # -- reading -----------------------------------------------------------
    def records(self) -> List[TraceRecord]:
        """A snapshot copy of the buffered records, oldest first."""
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """The buffer as a Chrome trace-event JSON object.

        Complete spans become ``ph: "X"`` events (``ts``/``dur`` in
        microseconds), instant events ``ph: "i"``; threads get stable
        integer ``tid``s plus ``thread_name`` metadata so Perfetto shows
        readable lanes.
        """
        records = self.records()
        tids: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []
        for ph, name, t0, dur, thread, _depth, attrs in records:
            tid = tids.setdefault(thread, len(tids))
            ev: Dict[str, Any] = {
                "name": name, "ph": ph, "pid": 0, "tid": tid,
                "ts": round(t0 * 1e6, 3),
            }
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            else:
                ev["s"] = "t"                      # instant scope: thread
            if attrs:
                ev["args"] = {k: _jsonable(v) for k, v in attrs.items()}
            events.append(ev)
        meta = [
            {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": thread}}
            for thread, tid in tids.items()
        ]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Chrome trace JSON to ``path``; returns event count."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)
