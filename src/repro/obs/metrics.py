"""Counters, gauges, and histograms for the planning pipeline.

A :class:`MetricsRegistry` is process-global by default (see
``repro.obs.metrics()``) but fully injectable: every instrumented call
site asks the registry accessor each time, so a test can swap in a
fresh registry and read back exactly the increments its scenario
produced.  Unlike the tracer, metrics default to **enabled** — a
counter bump is two dict ops and an add, cheap enough for every hot
path — but a disabled registry hands out shared null instruments so
the cost drops to one attribute check.

Instrument names are dotted (``plan.cache.hits``,
``fabric.probe.seconds``); :meth:`MetricsRegistry.to_prometheus`
sanitises them to underscore form for the text exposition format, and
:meth:`MetricsRegistry.snapshot` returns a plain-JSON dict for
``repro status``.

Histograms keep count/sum/min/max plus log2-spaced bucket counts —
enough for latency distributions (probe sweeps, compile seconds)
without reservoir sampling.
"""

from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Union

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """A monotonically increasing count."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """A value that goes up and down (health state, buffer depth)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """count/sum/min/max plus log2 buckets.

    Bucket ``i`` counts observations with ``2**(i-1) < v <= 2**i`` on
    the chosen ``scale`` (default 1.0; pass ``scale=1e-6`` to bucket
    seconds with microsecond resolution).  Good enough to eyeball a
    latency distribution in ``repro status`` without a reservoir.
    """

    __slots__ = ("name", "scale", "_count", "_sum", "_min", "_max",
                 "_buckets", "_lock")

    def __init__(self, name: str, scale: float = 1.0):
        self.name = name
        self.scale = scale
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._buckets: Dict[int, int] = {}
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        scaled = v / self.scale
        exp = math.ceil(math.log2(scaled)) if scaled > 0 else 0
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            self._buckets[exp] = self._buckets.get(exp, 0) + 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "count": self._count,
                "sum": self._sum,
                "mean": self._sum / self._count if self._count else 0.0,
                "min": self._min if self._count else None,
                "max": self._max if self._count else None,
                "buckets": {str(k): v
                            for k, v in sorted(self._buckets.items())},
            }


class _NullInstrument:
    """Shared no-op instrument handed out by a disabled registry."""

    __slots__ = ()
    name = ""
    value = 0.0
    count = 0
    sum = 0.0
    mean = 0.0

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def summary(self) -> Dict[str, Any]:
        return {"count": 0, "sum": 0.0, "mean": 0.0, "min": None,
                "max": None, "buckets": {}}


_NULL = _NullInstrument()

Instrument = Union[Counter, Gauge, Histogram, _NullInstrument]


class MetricsRegistry:
    """Lazily-created named instruments with JSON/Prometheus snapshots."""

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- instrument accessors (create on first use) ------------------------
    def counter(self, name: str) -> Instrument:
        if not self.enabled:
            return _NULL
        with self._lock:
            inst = self._counters.get(name)
            if inst is None:
                inst = self._counters[name] = Counter(name)
            return inst

    def gauge(self, name: str) -> Instrument:
        if not self.enabled:
            return _NULL
        with self._lock:
            inst = self._gauges.get(name)
            if inst is None:
                inst = self._gauges[name] = Gauge(name)
            return inst

    def histogram(self, name: str, scale: float = 1.0) -> Instrument:
        if not self.enabled:
            return _NULL
        with self._lock:
            inst = self._histograms.get(name)
            if inst is None:
                inst = self._histograms[name] = Histogram(name, scale=scale)
            return inst

    # -- snapshots ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """All instruments as one plain-JSON dict (``repro status``)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(histograms.items())},
        }

    def to_prometheus(self) -> str:
        """The registry in Prometheus text exposition format."""
        snap = self.snapshot()
        lines: List[str] = []
        for name, value in snap["counters"].items():
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {_prom_num(value)}")
        for name, value in snap["gauges"].items():
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {_prom_num(value)}")
        for name, summ in snap["histograms"].items():
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} histogram")
            cumulative = 0
            for exp, count in sorted(
                    ((int(k), v) for k, v in summ["buckets"].items())):
                cumulative += count
                le = (2.0 ** exp) * self._hist_scale(name)
                lines.append(
                    f'{pn}_bucket{{le="{_prom_num(le)}"}} {cumulative}')
            lines.append(f'{pn}_bucket{{le="+Inf"}} {summ["count"]}')
            lines.append(f"{pn}_sum {_prom_num(summ['sum'])}")
            lines.append(f"{pn}_count {summ['count']}")
        return "\n".join(lines) + ("\n" if lines else "")

    def _hist_scale(self, name: str) -> float:
        h = self._histograms.get(name)
        return h.scale if h is not None else 1.0

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


def _prom_name(name: str) -> str:
    out = []
    for i, ch in enumerate(name):
        if ch.isalnum() or ch == "_" or (ch == ":" and i):
            out.append(ch)
        else:
            out.append("_")
    s = "".join(out)
    if s and s[0].isdigit():
        s = "_" + s
    return s


def _prom_num(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)
