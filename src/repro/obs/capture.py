"""Workload trace capture → phase-windowed JobMixes → replay.

Real ML jobs do not issue one stationary collective mix: profiling of
production workloads (arxiv 2507.07117) shows bursty, phase-dependent
op distributions — decode steps dominated by small latency-bound
all-gathers, MoE phases by large all-to-alls, optimizer steps by huge
all-reduces.  A plan compiled for a single *declared* mix therefore
prices some phases with entries tuned for the wrong size band.

This module closes the ROADMAP's "workload-trace-driven JobMix" item:

* :class:`WorkloadRecorder` — a thread-safe, bounded stream of
  :class:`OpRecord` ``(op, size_bytes, group, t)`` rows, fed by hooks
  in the serve engine (per decode step), the trainer (per train step),
  and ``moe_a2a`` (per dispatch).  Like the tracer it has an injected
  clock and a zero-work disabled mode;
* :func:`fold` — fold a captured trace into time-windowed
  phase-specific :class:`repro.plan.JobMix`es.  Records are aggregated
  per ``(op, size-octave, group)`` cell with a count-weighted geometric
  mean size, mirroring :meth:`PlanCompiler.compile`'s own cell merge,
  so a captured stationary workload folds to a mix whose ``key()``
  equals the declared mix it came from;
* :func:`replay` — price a captured trace under a compiled plan by
  rebuilding each entry's analytic cost model *at the record's actual
  payload* and evaluating the entry's rank permutation.  Replaying the
  same trace under (a) the single declared-mix plan and (b) per-window
  plans compiled from :func:`fold` output is the benchmark scenario
  that shows phase-aware planning beating a stationary plan on bursty
  traces.

``repro.obs`` must not import ``repro.plan`` at module level (plan
code itself is instrumented through ``repro.obs``); the fold/replay
helpers import it lazily inside the call.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "OpRecord",
    "PhaseWindow",
    "WorkloadRecorder",
    "WorkloadTrace",
    "declared_mix",
    "fold",
    "replay",
    "synthetic_bursty_trace",
]


@dataclasses.dataclass(frozen=True)
class OpRecord:
    """One observed collective issue: ``(op, bytes, group, t)``."""

    op: str
    size_bytes: float
    group: Optional[Tuple[int, ...]]   # global node ids; None = all nodes
    t: float                           # seconds on the recorder clock

    def to_row(self) -> list:
        return [self.op, self.size_bytes,
                list(self.group) if self.group is not None else None, self.t]

    @staticmethod
    def from_row(row: Sequence[Any]) -> "OpRecord":
        op, size, group, t = row
        return OpRecord(op=str(op), size_bytes=float(size),
                        group=tuple(int(x) for x in group)
                        if group is not None else None,
                        t=float(t))


@dataclasses.dataclass
class WorkloadTrace:
    """An ordered capture of collective issues plus provenance meta."""

    records: List[OpRecord]
    name: str = "capture"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def duration(self) -> float:
        if not self.records:
            return 0.0
        return self.records[-1].t - self.records[0].t

    @property
    def total_bytes(self) -> float:
        return sum(r.size_bytes for r in self.records)

    # -- serialization (round-trip tested) --------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "version": 1,
            "name": self.name,
            "meta": self.meta,
            "records": [r.to_row() for r in self.records],
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "WorkloadTrace":
        d = json.loads(s)
        return WorkloadTrace(
            records=[OpRecord.from_row(r) for r in d["records"]],
            name=d.get("name", "capture"),
            meta=dict(d.get("meta", {})),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "WorkloadTrace":
        with open(path) as f:
            return WorkloadTrace.from_json(f.read())


class WorkloadRecorder:
    """Thread-safe bounded ``(op, bytes, group, t)`` stream.

    Hooked call sites call :meth:`record` unconditionally; when
    disabled the call is one attribute check.  Timestamps come from the
    injected ``clock`` relative to the recorder's construction epoch so
    traces are self-relative and deterministic under a fake clock.
    """

    def __init__(self, enabled: bool = False, buffer: int = 65536,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = bool(enabled)
        self.clock = clock
        self._epoch = clock()
        self._buf: "deque[OpRecord]" = deque(maxlen=int(buffer))
        self._lock = threading.Lock()
        #: monotone count of records ever captured (survives ring wrap)
        self.captured = 0

    def record(self, op: str, size_bytes: float,
               group: Optional[Sequence[int]] = None) -> None:
        if not self.enabled:
            return
        rec = OpRecord(op=op, size_bytes=float(size_bytes),
                       group=tuple(int(x) for x in group)
                       if group is not None else None,
                       t=self.clock() - self._epoch)
        with self._lock:
            self._buf.append(rec)
            self.captured += 1

    def __len__(self) -> int:
        return len(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def trace(self, name: str = "capture",
              meta: Optional[Dict[str, Any]] = None) -> WorkloadTrace:
        """Snapshot the buffer as a :class:`WorkloadTrace`."""
        with self._lock:
            records = list(self._buf)
        return WorkloadTrace(records=records, name=name, meta=dict(meta or {}))


@dataclasses.dataclass(frozen=True)
class PhaseWindow:
    """One folded time window: ``[t0, t1)`` and the mix observed in it."""

    t0: float
    t1: float
    mix: "Any"          # repro.plan.JobMix (lazy import; see module doc)
    n_records: int


def fold(trace: WorkloadTrace, window_s: float = 0.0,
         steps_per_window: float = 1.0) -> List[PhaseWindow]:
    """Fold a trace into per-window :class:`JobMix`es.

    ``window_s == 0`` folds the whole trace into one window (one mix).
    Within a window, records are merged per ``(op, size-octave, group)``
    cell: the cell's request carries the geometric-mean payload (which
    stays inside the octave, so the folded mix's :meth:`JobMix.key`
    matches a declared mix with the same cells) and ``count`` =
    records-in-cell / ``steps_per_window`` (calls per step, matching
    how declared mixes count).
    """
    from repro.plan import CollectiveRequest, JobMix, size_bucket

    if not trace.records:
        return []
    t_lo = trace.records[0].t
    t_hi = trace.records[-1].t
    if window_s <= 0:
        window_s = max(t_hi - t_lo, 1e-9) + 1e-9   # one window spans all

    windows: Dict[int, Dict[Tuple[str, int, Optional[Tuple[int, ...]]],
                            List[OpRecord]]] = {}
    for rec in trace.records:
        w = int((rec.t - t_lo) / window_s)
        cell = (rec.op, size_bucket(rec.size_bytes), rec.group)
        windows.setdefault(w, {}).setdefault(cell, []).append(rec)

    out: List[PhaseWindow] = []
    for w, cells in sorted(windows.items()):
        reqs = []
        n_rec = 0
        for (op, _bucket, group), recs in sorted(
                cells.items(),
                key=lambda kv: (kv[0][0], kv[0][1], kv[0][2] or ())):
            sizes = np.asarray([r.size_bytes for r in recs], dtype=np.float64)
            geo = float(np.exp(np.mean(np.log(np.maximum(sizes, 1.0)))))
            reqs.append(CollectiveRequest(
                op=op, size_bytes=geo,
                count=len(recs) / max(steps_per_window, 1e-9),
                group=group))
            n_rec += len(recs)
        out.append(PhaseWindow(
            t0=t_lo + w * window_s, t1=t_lo + (w + 1) * window_s,
            mix=JobMix(requests=tuple(reqs),
                       name=f"{trace.name}.w{w}"),
            n_records=n_rec))
    return out


def declared_mix(trace: WorkloadTrace, name: str = "declared") -> "Any":
    """The stationary mix an operator would declare *without* capture.

    One request per op, all at the trace's single overall geometric-mean
    payload — the "pick one representative size" compromise a config
    file encodes.  This is the baseline :func:`replay` compares
    phase-windowed plans against: its entries are solved at a size no
    phase actually issues, so bursty traces price badly under it.
    """
    from repro.plan import CollectiveRequest, JobMix

    if not trace.records:
        raise ValueError("declared_mix needs a non-empty trace")
    sizes = np.asarray([r.size_bytes for r in trace.records],
                       dtype=np.float64)
    geo = float(np.exp(np.mean(np.log(np.maximum(sizes, 1.0)))))
    counts: Dict[Tuple[str, Optional[Tuple[int, ...]]], int] = {}
    for r in trace.records:
        counts[(r.op, r.group)] = counts.get((r.op, r.group), 0) + 1
    reqs = tuple(
        CollectiveRequest(op=op, size_bytes=geo, count=float(c), group=group)
        for (op, group), c in sorted(
            counts.items(), key=lambda kv: (kv[0][0], kv[0][1] or ())))
    return JobMix(requests=reqs, name=name)


def _entry_cost_at(entry, size_bytes: float, lat: np.ndarray,
                   bw: Optional[np.ndarray]) -> float:
    """Price one plan entry's (algo, perm) at an arbitrary payload."""
    from repro.collective import get_builder
    from repro.core.cost_models import make_cost_model

    g = np.asarray(entry.group, dtype=np.int64)
    sub_lat = lat[np.ix_(g, g)]
    sub_bw = bw[np.ix_(g, g)] if bw is not None else None
    m_algo = get_builder(entry.algo).cost_model
    kwargs = {"base": entry.algo_kwargs["base"]} \
        if "base" in entry.algo_kwargs else {}
    if sub_bw is not None:
        model = make_cost_model(m_algo, size_bytes=size_bytes,
                                lat=sub_lat, bw=sub_bw, **kwargs)
    else:
        model = make_cost_model(m_algo, cost_matrix=sub_lat,
                                size_bytes=size_bytes, **kwargs)
    return float(model.cost(entry.local_perm))


def replay(trace: WorkloadTrace, plan, lat: np.ndarray,
           bw: Optional[np.ndarray] = None,
           windows: Optional[Sequence[Tuple[PhaseWindow, Any]]] = None,
           ) -> Dict[str, Any]:
    """Price a captured trace under a compiled plan (or per-window plans).

    Each record is looked up in the governing plan (``plan``, or the
    plan of the window containing ``record.t`` when ``windows`` =
    ``[(PhaseWindow, Plan), ...]`` is given, falling back to ``plan``
    between windows) and priced by rebuilding the winning entry's
    analytic cost model **at the record's actual payload** — so a plan
    whose entries were optimized for the wrong size band pays for it.
    Records whose (op, group) have no entry in the governing plan are
    skipped and counted in ``unplanned``.
    """
    total = 0.0
    unplanned = 0
    per_op: Dict[str, float] = {}
    for rec in trace.records:
        governing = plan
        if windows:
            for win, wplan in windows:
                if win.t0 <= rec.t < win.t1:
                    governing = wplan
                    break
        entry = governing.lookup(rec.op, rec.size_bytes, rec.group)
        if entry is None:
            unplanned += 1
            continue
        c = _entry_cost_at(entry, rec.size_bytes, lat, bw)
        total += c
        per_op[rec.op] = per_op.get(rec.op, 0.0) + c
    return {
        "trace": trace.name,
        "records": len(trace.records),
        "unplanned": unplanned,
        "total_seconds": total,
        "per_op_seconds": dict(sorted(per_op.items())),
    }


def synthetic_bursty_trace(n: int, *, steps: int = 6,
                           step_period: float = 1.0,
                           small_bytes: float = 64 * 1024,
                           large_bytes: float = 256 * 1024 * 1024,
                           small_per_step: int = 12,
                           large_per_step: int = 2,
                           seed: int = 0,
                           name: str = "bursty") -> WorkloadTrace:
    """A phase-alternating trace: latency-bound decode-like bursts of
    small all-gathers interleaved with bandwidth-bound optimizer-like
    phases of huge all-reduces — the regime where one stationary plan
    must compromise between size bands but per-phase plans need not.
    """
    rng = np.random.default_rng(seed)
    records: List[OpRecord] = []
    t = 0.0
    for step in range(steps):
        if step % 2 == 0:       # decode-like phase: many small ops
            for _ in range(small_per_step):
                size = small_bytes * float(rng.uniform(0.8, 1.25))
                records.append(OpRecord("all-gather", size, None, t))
                t += step_period / (small_per_step + 1)
        else:                   # optimizer-like phase: few huge ops
            for _ in range(large_per_step):
                size = large_bytes * float(rng.uniform(0.8, 1.25))
                records.append(OpRecord("all-reduce", size, None, t))
                t += step_period / (large_per_step + 1)
        t = (step + 1) * step_period
    return WorkloadTrace(records=records, name=name,
                         meta={"n": n, "steps": steps, "seed": seed,
                               "synthetic": True})
