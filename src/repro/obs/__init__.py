"""repro.obs — tracing, metrics, and workload capture for the pipeline.

Three process-global but injectable singletons back every instrumented
call site in the stack:

* :func:`tracer` — a :class:`Tracer` (disabled by default; a disabled
  ``span()`` is the shared no-op singleton, a ``timer()`` always
  measures so product numbers like ``compile_seconds`` keep working);
* :func:`metrics` — a :class:`MetricsRegistry` (enabled by default;
  counter bumps are cheap enough for hot paths);
* :func:`recorder` — a :class:`WorkloadRecorder` (disabled by default;
  serve/train/moe call sites feed it ``(op, bytes, group, t)`` rows).

Call sites fetch the accessor **at call time** (``obs.tracer().span``,
never a cached module-level reference), so tests and sessions can swap
instances with the ``set_*`` functions — :func:`configure` does it in
one shot from a ``SessionConfig.obs`` section.

This package imports nothing from the rest of ``repro`` at module
level: every other layer imports *it*, and the capture fold/replay
helpers that need ``repro.plan`` import it lazily.
"""

from __future__ import annotations

from typing import Any, Optional

from .capture import (
    OpRecord,
    PhaseWindow,
    WorkloadRecorder,
    WorkloadTrace,
    declared_mix,
    fold,
    replay,
    synthetic_bursty_trace,
)
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import NULL_SPAN, Span, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_SPAN",
    "OpRecord",
    "PhaseWindow",
    "Span",
    "Tracer",
    "WorkloadRecorder",
    "WorkloadTrace",
    "configure",
    "declared_mix",
    "fold",
    "metrics",
    "recorder",
    "replay",
    "set_metrics",
    "set_recorder",
    "set_tracer",
    "synthetic_bursty_trace",
    "tracer",
]

_tracer = Tracer(enabled=False)
_metrics = MetricsRegistry(enabled=True)
_recorder = WorkloadRecorder(enabled=False)


def tracer() -> Tracer:
    """The process tracer (disabled unless configured on)."""
    return _tracer


def set_tracer(t: Tracer) -> Tracer:
    """Swap the process tracer; returns the previous one."""
    global _tracer
    prev, _tracer = _tracer, t
    return prev


def metrics() -> MetricsRegistry:
    """The process metrics registry."""
    return _metrics


def set_metrics(m: MetricsRegistry) -> MetricsRegistry:
    """Swap the process registry; returns the previous one."""
    global _metrics
    prev, _metrics = _metrics, m
    return prev


def recorder() -> WorkloadRecorder:
    """The process workload recorder (disabled unless configured on)."""
    return _recorder


def set_recorder(r: WorkloadRecorder) -> WorkloadRecorder:
    """Swap the process recorder; returns the previous one."""
    global _recorder
    prev, _recorder = _recorder, r
    return prev


def configure(obs_config: Optional[Any]) -> None:
    """Apply a ``SessionConfig.obs`` section to the process singletons.

    Duck-typed (``enabled`` / ``buffer`` / ``capture`` / ``metrics``
    attributes) so ``repro.obs`` stays import-independent of
    ``repro.session``.  A ``None`` config is a no-op.
    """
    if obs_config is None:
        return
    _tracer.set_enabled(bool(getattr(obs_config, "enabled", False)))
    buf = int(getattr(obs_config, "buffer", 0) or 0)
    if buf and buf != _tracer.buffer:
        _tracer.set_buffer(buf)
    _metrics.enabled = bool(getattr(obs_config, "metrics", True))
    _recorder.enabled = bool(getattr(obs_config, "capture", False))
