"""Expert-parallel MoE via shard_map + explicit all-to-all (§Perf).

The baseline einsum-dispatch MoE (GShard-style, ``moe_impl='dense'``)
leaves the big [G, E, C, D] buffers to XLA's SPMD partitioner, which —
with experts on the 'data' axis and tokens on the same axis — resolves
the conflict with replication + all-gathers (measured: the dominant
memory AND collective term of the dbrx/deepseek train cells; see
EXPERIMENTS.md §Perf-2).

This implementation takes manual control (``moe_impl='a2a'``), a
*weight-gathered* EP design suited to fine-grained experts:

1. tokens stay where they are: batch over the DP axes, sequence over the
   'model' axis (SP preserved); routing is computed locally per column;
2. each (token, k) choice is packed into a capacity-bounded
   ``[n_ep, C, D]`` buffer and ``jax.lax.all_to_all``'d over the EP
   ('data') axis — the exact communication pattern the paper's
   ``AllToAllCost`` prices, so the solved rank order of the data axis
   directly speeds this collective;
3. expert weights (small for fine-grained experts: deepseek d_ff 1536,
   ~0.5 GB/layer/row) are all-gathered over 'model', so every received
   token runs the FULL expert FFN locally — no cross-column psum, no
   second all-to-all detour;
4. results all-to-all back; weighted combine at the source.

Wire bytes per device per layer: 2 * n_ep * C * D (the a2a pair) + the
expert-weight gather; FLOPs: zero dispatch einsums (integer sorts only).

Gradients flow through shard_map / all_to_all / scatter natively.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = ["arm_ep", "clear_ep", "ep_armed", "moe_a2a"]

_EP_STATE: Dict[str, Any] = {"mesh": None, "ep": None, "tp": None, "dp": (),
                             "a2a_order": None}


def arm_ep(mesh: Mesh, ep_axis: str = "data", tp_axis: Optional[str] = "model",
           plan=None, session=None):
    """Arm expert parallelism; ``plan`` (a :class:`repro.plan.Plan`) may
    supply the shift-ring order for the EP all-to-all.

    When the plan carries an ``all-to-all`` entry whose group size
    equals the EP degree, its solved rank order becomes the order in
    which the shift schedule walks peers (see :func:`_shift_perms`) —
    the runtime consumption of the compiler's ``AllToAllCost`` solve.

    ``session`` (a :class:`repro.session.Session`) supplies its compiled
    plan when no explicit ``plan`` is passed — the Session-facade way of
    arming EP without hand-threading the plan object.
    """
    if plan is None and session is not None:
        plan = session.planned
    dp = tuple(a for a in ("pod",) if a in mesh.axis_names)
    ep = ep_axis if ep_axis in mesh.axis_names else None
    order = None
    if plan is not None and ep is not None:
        n_ep = dict(zip(mesh.axis_names, mesh.devices.shape))[ep]
        # among matching a2a entries take the largest payload bucket: the
        # multi-MB EP shuffle is the one worth ordering for (a tiny
        # latency-bound bucket may carry a very different solved ring)
        cands = [e for (op, _b, grp), e in plan.entries.items()
                 if op == "all-to-all" and len(grp) == n_ep]
        entry = max(cands, key=lambda e: e.size_bytes) if cands else None
        if entry is not None:
            # The shift ring pairs EP *axis indices*; the entry's
            # Program speaks node-id space.  On a planned mesh, axis
            # index i holds node mesh_plan.flat[i], so compose with its
            # inverse; on an identity mesh the lowered ring order (the
            # Program's local permutation) is already the axis order.
            if plan.mesh_plan is not None:
                flat = plan.mesh_plan.flat
                if flat.size == n_ep and set(map(int, flat)) == set(entry.group):
                    pos = {int(node): i for i, node in enumerate(flat)}
                    order = tuple(pos[int(node)] for node in entry.perm)
                # else: axis indices don't map 1:1 onto plan nodes
                # (multi-axis mesh) — leave the identity shift ring
            else:
                # == JaxExecutor().lower(entry.program()).order, without
                # recompiling the Program here: _a2a_shift obtains the
                # actual lowered schedule from the (cached) _lowered_a2a
                # for this order
                order = tuple(int(i) for i in entry.local_perm)
    _EP_STATE.update(
        mesh=mesh,
        ep=ep,
        tp=tp_axis if tp_axis and tp_axis in mesh.axis_names else None,
        dp=dp,
        a2a_order=order,
    )


def clear_ep():
    _EP_STATE.update(mesh=None, ep=None, tp=None, dp=(), a2a_order=None)


def ep_armed(cfg: ModelConfig) -> bool:
    m = _EP_STATE["mesh"]
    if m is None or _EP_STATE["ep"] is None:
        return False
    n_ep = dict(zip(m.axis_names, m.devices.shape))[_EP_STATE["ep"]]
    return cfg.n_experts % n_ep == 0


@functools.lru_cache(maxsize=64)
def _lowered_a2a(n: int, order: Optional[Tuple[int, ...]]):
    """The typed-IR lowering of the shift-scheduled a2a over ``order``.

    Compiles an ``all_to_all`` :class:`~repro.collective.Program`,
    applies ``order`` as the permutation pass, and lowers it through
    :class:`repro.collective.JaxExecutor` — the same Program/Executor
    pipeline the plan compiler priced, so the runtime walks exactly the
    per-round flows the plan was scored on.
    """
    from repro.collective import (
        CollectiveOp, JaxExecutor, apply_permutation, compile_op)

    if order is None:
        order = tuple(range(n))
    assert sorted(order) == list(range(n)), f"bad shift order {order}"
    prog = compile_op(CollectiveOp("all_to_all", float(n), range(n)),
                      "all_to_all")
    return JaxExecutor().lower(apply_permutation(prog, order))


def _shift_perms(n: int, order: Optional[Tuple[int, ...]] = None):
    """Static per-round (src, dst) pairs of the shift-scheduled a2a.

    ``order`` is a ring order of the n shards (``order[pos] = shard``):
    round k pairs every shard with the peer k steps ahead *along that
    ring*, so a solved rank order from the plan compiler changes which
    physical links each round crosses — the identity order reproduces
    the classic i -> i+k shift exactly.  Every round is a bijection and
    every ordered pair appears exactly once across the n-1 rounds
    (property-tested).  The schedule itself comes from the typed IR
    (:func:`_lowered_a2a`); this wrapper is the legacy list-of-pairs
    view of that lowering.
    """
    low = _lowered_a2a(n, None if order is None else tuple(order))
    return [list(rnd) for rnd in low.shift_rounds]


def _a2a_shift(x: jnp.ndarray, axis: str, n: int,
               order: Optional[Tuple[int, ...]] = None) -> jnp.ndarray:
    """All-to-all as N-1 shift rounds of ``ppermute``.

    x: [n, ...] — piece j is addressed to shard j; returns [n, ...] with
    piece s received from shard s.  This is the shift-scheduled a2a the
    paper's ``AllToAllCost`` models (round k: shard i -> shard i+k along
    the ``order`` ring), it lowers to native collective-permutes on every
    backend (XLA:CPU has no native all-to-all and would decompose into
    all-gathers, inflating both real traffic and accounting), and its
    wire bytes are exactly (n-1)/n of the buffer.
    """
    me = jax.lax.axis_index(axis)
    sigma = jnp.asarray(order if order is not None else range(n),
                        dtype=jnp.int32)
    pos_of = jnp.zeros((n,), jnp.int32).at[sigma].set(
        jnp.arange(n, dtype=jnp.int32))
    out = jnp.zeros_like(x)
    out = jax.lax.dynamic_update_index_in_dim(
        out, jnp.take(x, me, axis=0), me, 0)
    for k, perm in enumerate(_shift_perms(n, order), start=1):
        dst = sigma[(pos_of[me] + k) % n]
        sent = jnp.take(x, dst, axis=0)
        recv = jax.lax.ppermute(sent, axis, perm)
        src = sigma[(pos_of[me] - k) % n]
        out = jax.lax.dynamic_update_index_in_dim(out, recv, src, 0)
    return out


def moe_a2a(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for ``layers.moe_dense`` under an armed EP mesh."""
    from repro.models.layers import mlp  # shared-expert fused MLP

    mesh: Mesh = _EP_STATE["mesh"]
    ep_axis: str = _EP_STATE["ep"]
    tp_axis = _EP_STATE["tp"]
    dp = _EP_STATE["dp"]
    a2a_order = _EP_STATE["a2a_order"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_ep = sizes[ep_axis]
    E, K = cfg.n_experts, cfg.moe_top_k
    E_loc = E // n_ep
    B, S, D = x.shape

    batch_axes = (*dp, ep_axis)
    b_ok = B % math.prod(sizes[a] for a in batch_axes) == 0
    s_ok = tp_axis is not None and S % sizes[tp_axis] == 0
    x_spec = P(batch_axes if b_ok else dp or None,
               tp_axis if s_ok else None, None)

    w_spec: Dict[str, Any] = {
        "router": P(None, None),
        "w1": P(ep_axis, None, tp_axis),
        "w3": P(ep_axis, None, tp_axis),
        "w2": P(ep_axis, tp_axis, None),
    }
    if "shared" in p:
        w_spec["shared"] = {
            "w1": P(None, tp_axis), "w3": P(None, tp_axis),
            "w2": P(tp_axis, None),
        }

    def gather_w(w, dim):
        if tp_axis is None:
            return w
        return jax.lax.all_gather(w, tp_axis, axis=dim, tiled=True)

    def body(pp, xl):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, D)

        # --- routing (local) --------------------------------------------
        logits = (xf.astype(jnp.float32) @ pp["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, K)
        w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean((jax.nn.one_hot(idx, E).sum(1) > 0), axis=0)
        aux = jax.lax.pmean(E * jnp.sum(me * ce), ep_axis)

        dest = (idx // E_loc).reshape(-1)                     # [T*K]
        local_e = (idx % E_loc).reshape(-1).astype(jnp.int32)
        wk = w.reshape(-1).astype(xl.dtype)
        tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)

        # --- pack per-destination (argsort + scatter; capacity C) -------
        C = max(int(math.ceil(T * K / n_ep * cfg.capacity_factor)), K)
        TK = T * K
        order = jnp.argsort(dest, stable=True)
        sorted_dest = dest[order]
        seg = jnp.searchsorted(sorted_dest, jnp.arange(n_ep))
        pos = jnp.arange(TK) - seg[sorted_dest]
        keep = pos < C
        slot = sorted_dest * C + jnp.where(keep, pos, 0)

        send_x = jnp.zeros((n_ep * C, D), xl.dtype)
        send_x = send_x.at[slot].add(
            jnp.where(keep[:, None], xf[tok[order]], 0))
        send_e = jnp.zeros((n_ep * C,), jnp.int32)
        send_e = send_e.at[slot].add(jnp.where(keep, local_e[order], 0))
        slot_of = jnp.full((TK,), -1, jnp.int32)
        slot_of = slot_of.at[order].set(
            jnp.where(keep, slot, -1).astype(jnp.int32))

        # --- all-to-all over the EP axis (shift-scheduled ppermutes) -----
        recv_x = _a2a_shift(
            send_x.reshape(n_ep, C, D), ep_axis, n_ep,
            order=a2a_order).reshape(n_ep * C, D)
        recv_e = _a2a_shift(
            send_e.reshape(n_ep, C), ep_axis, n_ep,
            order=a2a_order).reshape(n_ep * C)

        # --- local expert FFNs (full weights via TP gather) --------------
        w1 = gather_w(pp["w1"], 2)
        w3 = gather_w(pp["w3"], 2)
        w2 = gather_w(pp["w2"], 1)
        T2 = n_ep * C
        C2 = max(int(math.ceil(T2 / E_loc * cfg.capacity_factor)), 1)
        order2 = jnp.argsort(recv_e, stable=True)
        sorted_e2 = recv_e[order2]
        seg2 = jnp.searchsorted(sorted_e2, jnp.arange(E_loc))
        pos2 = jnp.arange(T2) - seg2[sorted_e2]
        keep2 = pos2 < C2
        slot2 = sorted_e2 * C2 + jnp.where(keep2, pos2, 0)
        xin = jnp.zeros((E_loc * C2, D), xl.dtype)
        xin = xin.at[slot2].add(jnp.where(keep2[:, None], recv_x[order2], 0))
        xin = xin.reshape(E_loc, C2, D)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, w1))
        h = h * jnp.einsum("ecd,edf->ecf", xin, w3)
        xout = jnp.einsum("ecf,efd->ecd", h, w2).reshape(E_loc * C2, D)

        back = jnp.zeros((T2, D), xl.dtype)
        back = back.at[order2].add(jnp.where(keep2[:, None], xout[slot2], 0))

        # --- return trip + combine ---------------------------------------
        ret = _a2a_shift(
            back.reshape(n_ep, C, D), ep_axis, n_ep,
            order=a2a_order).reshape(n_ep * C, D)
        ok = slot_of >= 0
        contrib = jnp.where(ok[:, None], ret[jnp.maximum(slot_of, 0)], 0)
        y = jnp.zeros((T, D), xl.dtype).at[tok].add(contrib * wk[:, None])

        if "shared" in pp:
            shared_full = {
                "w1": gather_w(pp["shared"]["w1"], 1),
                "w3": gather_w(pp["shared"]["w3"], 1),
                "w2": gather_w(pp["shared"]["w2"], 0),
            }
            y = y + mlp(shared_full, xf)
        return y.reshape(Bl, Sl, D), aux

    # two EP all-to-alls per layer call (dispatch + return trip), each
    # moving the packed capacity buffer; recorded at trace time since
    # the in-jit body cannot call back into python
    from repro import obs

    rec = obs.recorder()
    if rec.enabled:
        C = max(int(math.ceil(
            B * S // max(math.prod(sizes[a] for a in batch_axes), 1)
            * K / n_ep * cfg.capacity_factor)), K)
        a2a_bytes = float(n_ep * C * D * x.dtype.itemsize)
        rec.record("all-to-all", a2a_bytes)
        rec.record("all-to-all", a2a_bytes)

    f = jax.shard_map(
        body, mesh=mesh,
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return f(p, x)
