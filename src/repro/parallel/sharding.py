"""Sharding rules: DP / TP / EP / SP over the production mesh.

Axis roles (DESIGN.md §5):

* ``pod``   — outer data parallelism across pods (DCN).
* ``data``  — data parallelism within a pod; also hosts MoE expert
  parallelism (experts live on the data axis — the standard EP-over-DP
  trick) and ZeRO-1 optimizer-state sharding.
* ``model`` — Megatron tensor parallelism: attention heads, FFN hidden,
  vocab.

Rules are name-based over the parameter tree; anything un-matched is
replicated.  Dims only get an axis when divisible by the axis size —
e.g. whisper's 12 heads stay replicated on a 16-way model axis while its
MLP still shards.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "mesh_axis_sizes", "dp_axes", "batch_spec", "param_pspecs",
    "named_shardings", "cache_pspecs", "zero1_spec",
]


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that carry the batch (pod + data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh))


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _model_ok(mesh: Mesh) -> int:
    return mesh_axis_sizes(mesh).get("model", 1)


def _param_rule(
    path: Tuple[str, ...], shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh
) -> P:
    """PartitionSpec for the *logical* (unstacked) parameter shape."""
    m = _model_ok(mesh)
    d_axes = dp_axes(mesh)
    name = path[-1]
    in_moe = "moe" in path
    in_attn = any(k in path for k in ("attn", "self_attn", "cross_attn", "time_mix"))

    def mdl(dim: int) -> Optional[str]:
        return "model" if _div(dim, m) else None

    # ---- embeddings / unembeddings -----------------------------------
    if name == "embed":
        return P(mdl(shape[0]), None)
    if name == "lm_head":
        return P(None, mdl(shape[1]))
    if name == "dec_pos":
        return P(None, None)

    # ---- MoE ----------------------------------------------------------
    if in_moe:
        E = cfg.n_experts
        edp = "data" if ("data" in mesh.axis_names and _div(E, mesh_axis_sizes(mesh)["data"])) else None
        if name == "router":
            return P(None, None)
        if name in ("w1", "w3") and len(shape) == 3:
            return P(edp, None, mdl(shape[2]))
        if name == "w2" and len(shape) == 3:
            return P(edp, mdl(shape[1]), None)
        # shared expert mlp (w1/w3/w2, rank 2) falls through to MLP rules

    # ---- attention projections ----------------------------------------
    if in_attn or name in ("wq_a", "wq_b", "wkv_a", "wkv_b", "wk_rope"):
        heads_ok = _div(cfg.n_heads, m)
        kv_ok = _div(cfg.n_kv_heads, m)
        if name == "wq":
            return P(None, "model" if heads_ok else None)
        if name in ("wk", "wv"):
            # rwkv time_mix wk/wv are [D, D] head-sharded like wq
            if "time_mix" in path:
                return P(None, "model" if heads_ok else None)
            return P(None, "model" if kv_ok else None)
        if name == "wo":
            return P("model" if heads_ok else None, None)
        if name == "bq":
            return P("model" if heads_ok else None)
        if name in ("bk", "bv"):
            return P("model" if kv_ok else None)
        # MLA: low-rank downs replicated, ups column-parallel, wo row-par.
        if name in ("wq_a", "wkv_a", "wk_rope"):
            return P(None, None)
        if name in ("wq_b", "wkv_b"):
            return P(None, "model" if heads_ok else None)
        # rwkv extras
        if name in ("wr", "wg"):
            return P(None, "model" if heads_ok else None)
        if name == "u" or name == "ln_x_w" or name == "ln_x_b":
            return P("model" if heads_ok else None, None)

    # ---- dense MLP ------------------------------------------------------
    if name in ("w1", "w3", "wk"):
        return P(None, mdl(shape[-1]))
    if name in ("w2", "wv"):
        return P(mdl(shape[0]), None)
    if name == "b1":
        return P(mdl(shape[0]))

    # ---- RG-LRU recurrent block -----------------------------------------
    if name in ("w_gate", "w_in", "w_a", "w_x"):
        return P(None, mdl(shape[-1]))
    if name == "w_out":
        return P(mdl(shape[0]), None)
    if name in ("b_a", "b_x", "lam"):
        return P(mdl(shape[0]))
    if name == "conv_w":
        return P(None, mdl(shape[-1]))
    if name == "conv_b":
        return P(mdl(shape[0]))

    return P(*([None] * len(shape)))


_STACK_KEYS = ("blocks", "groups", "enc_blocks", "dec_blocks")


def param_pspecs(params_shape: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (a shape pytree or
    real params)."""

    def visit(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path
        )
        shape = tuple(leaf.shape)
        stacked = any(k in _STACK_KEYS for k in keys)
        logical = shape[1:] if stacked else shape
        spec = _param_rule(keys, logical, cfg, mesh)
        if stacked:
            spec = P(None, *spec)
        if len(spec) < len(shape):
            spec = P(*spec, *([None] * (len(shape) - len(spec))))
        return spec

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: additionally shard optimizer state over the DP axes.

    Adds the *unused* dp axes to the first dim that is unsharded and
    divisible; leaves the spec unchanged when nothing divides.  Axes
    already occupied by the parameter spec (e.g. MoE experts on 'data')
    are never repeated — a PartitionSpec may use each axis once.
    """
    dp = dp_axes(mesh)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for part in parts:
        if part is None:
            continue
        for a in (part if isinstance(part, tuple) else (part,)):
            used.add(a)
    dp = tuple(a for a in dp if a not in used)
    if not dp:
        return spec
    sizes = mesh_axis_sizes(mesh)
    dp_total = int(np.prod([sizes[a] for a in dp]))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and _div(dim, dp_total):
            parts[i] = dp if len(dp) > 1 else dp[0]
            return P(*parts)
    return spec


def named_shardings(pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def cache_pspecs(cache: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """KV/state cache shardings: batch over dp axes, heads over model.

    Batch-dim position is determined by the cache key (see the model
    ``init_cache`` layouts):

    * ``k/v/xk/xv``      [L, B, KV, S, hd]     (rglru: [G, n_att, B, KV, W, hd])
    * ``ckv/k_rope``     [L, B, S, r]
    * ``wkv``            [L, B, H, K, K]
    * ``att_sx/ffn_sx``  [L, B, D]
    * ``h/conv``         rglru groups: [G, n_rec, B, ...]; tail: [n, B, ...]
    """
    dp = dp_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    m = sizes.get("model", 1)
    dp_total = int(np.prod([sizes[a] for a in dp])) if dp else 1
    dp_spec = (dp if len(dp) > 1 else dp[0]) if dp else None

    def visit(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        shape = tuple(leaf.shape)
        name = keys[-1]
        if name == "pos" or len(shape) == 0:
            return P()
        in_groups = "groups" in keys
        if name in ("k", "v", "xk", "xv"):
            b_dim = 2 if in_groups else 1
            kv_dim = b_dim + 1
        elif name in ("ckv", "k_rope", "wkv", "att_sx", "ffn_sx"):
            b_dim = 1
            kv_dim = 2 if name == "wkv" else None  # wkv heads dim
        elif name in ("h", "conv"):
            b_dim = 2 if in_groups else 1
            kv_dim = None
        elif name in ("tail_h", "tail_conv"):
            b_dim = 1
            kv_dim = None
        else:
            b_dim = 1 if len(shape) > 1 else None
            kv_dim = None
        parts: list = [None] * len(shape)
        if dp and b_dim is not None and _div(shape[b_dim], dp_total):
            parts[b_dim] = dp_spec
        if kv_dim is not None and kv_dim < len(shape) and _div(shape[kv_dim], m):
            parts[kv_dim] = "model"
        elif name in ("k", "v", "ckv", "k_rope") and len(shape) >= 2:
            # GQA/MLA: too few KV heads for the model axis -> shard the
            # cache *sequence* dim instead (sequence-sharded decode: the
            # softmax reduction over S becomes a model-axis collective).
            s_dim = len(shape) - 2
            if (s_dim != b_dim and parts[s_dim] is None
                    and _div(shape[s_dim], m) and shape[s_dim] >= m):
                parts[s_dim] = "model"
        # RG-LRU states pair with column-parallel w_in: channel dim is
        # model-sharded.  (rwkv sx states feed full-width matmuls ->
        # replicated channels.)
        if name in ("h", "conv", "tail_h", "tail_conv") and _div(shape[-1], m):
            parts[-1] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(visit, cache)
