"""Pipeline parallelism: GPipe-style microbatching over a mesh axis.

The assigned 256-chip pod holds every assigned model with DP x TP, so the
40-cell dry-run does not *need* PP — but 1000+-node deployments of larger
models do, so the substrate provides it (system prompt: "support the
parallelism features needed at that scale").

Implementation: ``shard_map`` over a ``stage`` mesh axis.  Stage ``i``
holds the stacked params of its layer slice; activations flow stage to
stage with ``jax.lax.ppermute`` in a scanned schedule of
``n_micro + n_stages - 1`` ticks (fill + steady state + drain).  The whole
schedule is differentiable (scan + ppermute transpose = reverse ppermute),
giving GPipe-equivalent backward without bespoke code.

Rank reordering applies to the stage ring exactly like any other axis —
the inter-stage hop cost is C_ring on the stage axis (one more place the
paper's objective shows up; see ``reorder.default_axis_weights``).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_forward", "pipeline_loss"]


def pipeline_forward(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,          # leaves [n_stages, ...] (stage-sharded)
    x: jnp.ndarray,             # [n_micro, mb, ...] microbatched input
    mesh: Mesh,
    axis: str = "stage",
) -> jnp.ndarray:
    """Run ``stage_fn`` as an ``n_stages``-deep pipeline over microbatches.

    Returns [n_micro, mb, ...]: every microbatch after all stages.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xs):
        params = jax.tree.map(lambda a: a[0], params)   # my stage's slice
        xs = xs[0]                                      # [n_micro, mb, ...]
        stage_id = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        state0 = jnp.zeros(mb_shape, xs.dtype)          # wire register
        outs0 = jnp.zeros((n_micro,) + mb_shape, xs.dtype)

        def tick(carry, t):
            state, outs = carry
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), keepdims=False)
            inp = jnp.where((stage_id == 0) & (t < n_micro), feed, state)
            out = stage_fn(params, inp)
            # last stage finishes microbatch t-(n_stages-1) at tick t
            done = t - (n_stages - 1)
            record = (stage_id == n_stages - 1) & (done >= 0)
            idx = jnp.clip(done, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, idx, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, out, cur), idx, 0)
            state = jax.lax.ppermute(out, axis, fwd_perm)
            return (state, outs), None

        (_, outs), _ = jax.lax.scan(
            tick, (state0, outs0), jnp.arange(n_ticks))
        # everyone returns; only the last stage holds real data -> psum
        # over a one-hot mask broadcasts it to all stages.
        mask = (stage_id == n_stages - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * mask, axis)
        return outs[None]

    f = jax.shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    )
    xs = jnp.broadcast_to(x[None], (n_stages,) + x.shape)
    out = f(stage_params, xs)
    # every stage slice is identical after the in-shard psum broadcast
    return out[0]


def pipeline_loss(
    stage_fn: Callable,
    head_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    stage_params: Any,
    x: jnp.ndarray,
    labels: jnp.ndarray,
    mesh: Mesh,
    axis: str = "stage",
) -> jnp.ndarray:
    """Mean loss over microbatches through the pipeline."""
    y = pipeline_forward(stage_fn, stage_params, x, mesh, axis)
    return head_fn(y, labels)
