from .pipeline import pipeline_forward, pipeline_loss  # noqa: F401
from .sharding import (  # noqa: F401
    batch_spec,
    cache_pspecs,
    dp_axes,
    mesh_axis_sizes,
    named_shardings,
    param_pspecs,
    zero1_spec,
)
