"""Deterministic synthetic LM data pipeline.

A hash-based token stream: reproducible across restarts (critical for the
fault-tolerance story — after an elastic restart the pipeline resumes at
the exact step), cheap to generate on every host, and shardable: each host
materializes only its addressable shard of the global batch via
``jax.make_array_from_callback``.

The stream has learnable structure (token t+1 depends on token t) so a
few hundred training steps show a falling loss in the e2e example.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["SyntheticLM", "host_batch", "make_global_batch"]


def _hash2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Deterministic 32-bit mix of two uint32 arrays."""
    x = (a.astype(np.uint64) * np.uint64(2654435761)
         + b.astype(np.uint64) * np.uint64(40503)) & np.uint64(0xFFFFFFFF)
    x ^= x >> np.uint64(16)
    x = (x * np.uint64(2246822519)) & np.uint64(0xFFFFFFFF)
    x ^= x >> np.uint64(13)
    return x.astype(np.uint32)


class SyntheticLM:
    """Markov-ish synthetic stream: next token = f(current, position)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed

    def sequence(self, step: int, row: int) -> np.ndarray:
        """One [seq_len + 1] token row, deterministic in (step, row)."""
        rid = np.uint32(step * self.batch + row + self.seed * 1_000_003)
        toks = np.empty(self.seq + 1, dtype=np.int32)
        toks[0] = int(_hash2(np.asarray(rid), np.asarray(np.uint32(0)))) % self.vocab
        # learnable structure: t+1 = (a * t + hash(pos)) % V with small noise
        pos_noise = _hash2(np.full(self.seq, rid), np.arange(self.seq, dtype=np.uint32))
        for i in range(self.seq):
            nxt = (toks[i] * 31 + 7 + int(pos_noise[i] % 13 == 0)) % self.vocab
            toks[i + 1] = nxt
        return toks

    def batch_rows(self, step: int, rows: np.ndarray) -> Dict[str, np.ndarray]:
        seqs = np.stack([self.sequence(step, int(r)) for r in rows])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}


def host_batch(ds: SyntheticLM, step: int) -> Dict[str, np.ndarray]:
    """Full global batch on one host (single-process testing path)."""
    return ds.batch_rows(step, np.arange(ds.batch))


def make_global_batch(
    ds: SyntheticLM, step: int, mesh: Mesh, spec: P
) -> Dict[str, jax.Array]:
    """Sharded global batch: every process materializes only its shard."""
    shape = (ds.batch, ds.seq)

    def build(name):
        sharding = NamedSharding(mesh, spec)

        def cb(index):
            rows = np.arange(ds.batch)[index[0]]
            data = ds.batch_rows(step, rows)[name]
            return data[:, index[1] if len(index) > 1 else slice(None)]

        return jax.make_array_from_callback(shape, sharding, cb)

    return {"tokens": build("tokens"), "labels": build("labels")}


def batches(ds: SyntheticLM, mesh: Optional[Mesh] = None,
            spec: Optional[P] = None, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        if mesh is None:
            yield host_batch(ds, step)
        else:
            yield make_global_batch(ds, step, mesh, spec or P())
        step += 1
