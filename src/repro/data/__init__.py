from .synthetic import SyntheticLM, batches, host_batch, make_global_batch  # noqa: F401
