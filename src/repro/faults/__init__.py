"""repro.faults — deterministic fault injection and session resilience.

Cloud fabrics are volatile by construction: probes time out under
noisy-neighbor interference, links degrade for minutes at a time,
preemptible VMs vanish mid-job.  This package makes that volatility a
first-class, *seeded* test dimension and gives sessions the machinery
to survive it:

* :mod:`repro.faults.inject` — :class:`FaultSchedule` (a deterministic
  timeline of fault events) and :class:`FaultyFabric` (a duck-typed
  fabric wrapper that applies the schedule to any probe path without
  touching callers);
* :mod:`repro.faults.retry` — :class:`RetryPolicy` capped exponential
  backoff with seeded jitter, shared by the probe, re-plan, and monitor
  paths;
* :mod:`repro.faults.health` — the ``healthy → degraded → halted``
  session health state machine;
* :mod:`repro.faults.ladder` — the graceful-degradation ladder
  (warm-start re-solve → bottleneck hot-patch → stale plan → identity
  order) and elastic-membership plan recovery.
"""

from repro.faults.health import HEALTH_STATES, HealthTracker
from repro.faults.inject import (
    FAULT_KINDS,
    FaultEvent,
    FaultSchedule,
    FaultyFabric,
    ProbeTimeout,
)
from repro.faults.ladder import (
    LADDER_RUNGS,
    identity_fallback,
    recover_entry,
    recover_plan,
    restrict_perm,
    warm_refine,
)
from repro.faults.retry import RetryError, RetryPolicy, call_with_retries

__all__ = [
    "FAULT_KINDS",
    "HEALTH_STATES",
    "LADDER_RUNGS",
    "FaultEvent",
    "FaultSchedule",
    "FaultyFabric",
    "HealthTracker",
    "ProbeTimeout",
    "RetryError",
    "RetryPolicy",
    "call_with_retries",
    "identity_fallback",
    "recover_entry",
    "recover_plan",
    "restrict_perm",
    "warm_refine",
]
