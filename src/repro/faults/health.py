"""Session health: the ``healthy → degraded → halted`` state machine.

The lifecycle states of :class:`repro.session.Session` (created /
attached / planned / applied / closed) say where the session is in its
*workflow*; health says how much the runtime should currently trust it:

* ``healthy`` — plans are fresh, the monitor is observing normally;
* ``degraded`` — consecutive failures crossed the retry policy's
  ``failure_threshold``, or a re-plan fell down the degradation ladder:
  the session still serves a plan (stale, hot-patched, or identity) but
  consumers were told via the ``degraded`` hook;
* ``halted`` — failures crossed ``halt_threshold``: the monitor stops
  burning probes, the session pins the identity-safe plan, and only an
  explicit :meth:`HealthTracker.reset` (a human or an orchestrator
  deciding the fabric is sane again) returns it to service.

Transitions are monotone between resets (healthy can degrade, degraded
can halt, nothing silently un-halts) and every transition is reported to
the owner via the return value so the session can fire hooks exactly
once per edge.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro import obs

__all__ = ["HEALTH_STATES", "HealthTracker"]

HEALTH_STATES = ("healthy", "degraded", "halted")


@dataclasses.dataclass
class HealthTracker:
    """Consecutive-failure counting with two thresholds (see module doc)."""

    failure_threshold: int = 3
    halt_threshold: int = 10
    state: str = "healthy"
    consecutive_failures: int = 0
    #: (state entered, reason) transition log, newest last
    transitions: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)

    def __post_init__(self) -> None:
        if self.state not in HEALTH_STATES:
            raise ValueError(f"unknown health state {self.state!r}; "
                             f"expected one of {HEALTH_STATES}")
        if self.halt_threshold < self.failure_threshold:
            raise ValueError(
                f"halt_threshold ({self.halt_threshold}) must be >= "
                f"failure_threshold ({self.failure_threshold})")

    # -- events ------------------------------------------------------------
    def record_failure(self, reason: str = "") -> Optional[str]:
        """Count one failure; returns the state newly entered, if any."""
        self.consecutive_failures += 1
        if self.state != "halted" and \
                self.consecutive_failures >= self.halt_threshold:
            return self._enter("halted", reason)
        if self.state == "healthy" and \
                self.consecutive_failures >= self.failure_threshold:
            return self._enter("degraded", reason)
        return None

    def record_success(self) -> Optional[str]:
        """A clean tick; degraded sessions recover, halted ones do not."""
        self.consecutive_failures = 0
        if self.state == "degraded":
            return self._enter("healthy", "recovered")
        return None

    def force_degraded(self, reason: str) -> Optional[str]:
        """Degrade regardless of counters (a ladder rung was taken)."""
        if self.state == "healthy":
            return self._enter("degraded", reason)
        return None

    def reset(self) -> None:
        """Explicit operator reset: back to healthy, counters cleared."""
        self.consecutive_failures = 0
        if self.state != "healthy":
            self._enter("healthy", "reset")

    # -- internals ---------------------------------------------------------
    def _enter(self, state: str, reason: str) -> str:
        self.state = state
        self.transitions.append((state, reason))
        # the single transition point: every health edge is one obs
        # event + the numeric gauge dashboards alert on
        obs.tracer().event("faults.health", state=state, reason=reason)
        m = obs.metrics()
        m.counter(f"faults.health.{state}").inc()
        m.gauge("faults.health.state").set(HEALTH_STATES.index(state))
        return state
