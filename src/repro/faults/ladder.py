"""The graceful-degradation ladder: every rung beats crashing.

When the fabric changes under a session — a preemption takes 25% of the
nodes, a congestion episode invalidates the plan, a re-plan compile
itself fails — the session must keep serving *some* valid order.  The
ladder tries progressively cheaper (and progressively less optimal)
recoveries, and its bottom rung can never fail:

1. **warm-start re-solve** (:func:`recover_entry`) — restrict the
   previous permutation to the surviving ranks (``Fabric.subset`` /
   ``HierarchyModel.restrict`` semantics: drop the dead, keep the
   order) and refine it with the PR-1 budgeted local search (2-opt +
   Or-opt for ring objectives, batched swap hill-climb otherwise).  No
   simulated annealing, no candidate sweep — milliseconds, not seconds.
2. **bottleneck-swap hot-patch** — the paper §VI repair: fix only the
   critical edge (:func:`repro.core.dynamic.bottleneck_swap`).
3. **stale** — serve the restricted previous order unrefined.
4. **identity** — fall back to identity order, which by construction
   cannot be worse than identity.

Every rung is guarded by the entry's own cost model: a recovered order
that prices worse than identity is replaced by identity, so the ladder
invariant — *the served order is never worse than identity order* —
holds at every rung (the chaos suite referees this on the simulator).

:func:`recover_plan` applies the ladder to a whole plan after an
elastic membership change, remapping every cached
:class:`~repro.plan.compiler.PlanEntry` to the new numbering; entries
whose algorithm is infeasible at the new group size (a power-of-two
builder after losing a node) are re-selected among the feasible
candidates, scored at the warm-started order.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.collective import candidates as builder_candidates
from repro.collective import get_builder
from repro.core.cost_models import RingCost, make_cost_model
from repro.core.dynamic import bottleneck_swap
from repro.core.solver import or_opt, swap_hill_climb, two_opt

__all__ = [
    "LADDER_RUNGS",
    "restrict_perm",
    "warm_refine",
    "recover_entry",
    "recover_plan",
    "identity_fallback",
]

#: rung names, best first (see module doc)
LADDER_RUNGS = ("warm_resolve", "hot_patch", "stale", "identity")


def restrict_perm(perm: Sequence[int], keep: Sequence[int]) -> List[int]:
    """Drop the dead from a permutation, preserving the survivors' order.

    ``perm`` lists node ids in rank order; ``keep`` is the surviving id
    set.  This is the warm-start seed: locality the solver already paid
    for survives the membership change.
    """
    keep_set = set(int(x) for x in keep)
    return [int(x) for x in perm if int(x) in keep_set]


def _ring_edge_matrix(model) -> np.ndarray:
    """Symmetric edge-cost matrix of a ring objective (for 2-opt/Or-opt)."""
    if model.c is not None:
        return model.c
    return model.lat + model.size_bytes * model.invbw


def warm_refine(model, start_local: np.ndarray,
                sweeps: int = 4) -> np.ndarray:
    """Budgeted local refinement from a warm start (no SA).

    Ring objectives get alternating 2-opt / Or-opt sweeps on the edge
    matrix; everything else gets the batched swap hill-climb.  The
    budget (``sweeps``) keeps recovery at milliseconds — the whole
    point of warm-starting is skipping the cold SA search.
    """
    start_local = np.asarray(start_local, dtype=np.int64)
    if isinstance(model, RingCost):
        c = _ring_edge_matrix(model)
        refined = or_opt(c, two_opt(c, start_local, max_sweeps=sweeps),
                         max_sweeps=sweeps)
        # the tour refiners optimize the symmetric edge matrix; keep the
        # warm start if the model objective says they regressed
        if model.cost(refined) <= model.cost(start_local):
            return np.asarray(refined, dtype=np.int64)
        return start_local
    return np.asarray(swap_hill_climb(model, start_local,
                                      max_sweeps=sweeps), dtype=np.int64)


def _choose_algorithm(entry, n_new: int, model_for, start_local: np.ndarray,
                      ) -> Tuple[str, Dict[str, int], object]:
    """Keep the entry's algorithm when feasible at ``n_new``; otherwise
    re-select among feasible candidates, scored at the warm order."""
    cands = builder_candidates(entry.op, n_new)
    if not cands:
        raise ValueError(
            f"no feasible algorithm for {entry.op!r} over {n_new} nodes")
    if get_builder(entry.algo).feasible(n_new):
        for name, akw in cands:
            if name == entry.algo:
                # candidate kwargs win over the stored ones: bcube's
                # base-4 variant may be infeasible at the new size
                return name, akw, model_for(name, akw)
    best = None
    for name, akw in cands:
        m = model_for(name, akw)
        t = float(m.cost(start_local))
        if best is None or t < best[0]:
            best = (t, name, akw, m)
    return best[1], best[2], best[3]


def recover_entry(entry, old_to_new: Dict[int, int],
                  lat: np.ndarray, bw: Optional[np.ndarray],
                  append_new: Sequence[int] = (),
                  hierarchy=None, sweeps: int = 4, seed: int = 0,
                  ):
    """Remap one plan entry onto the new membership; returns
    ``(new_entry, rung)`` or ``(None, "dropped")`` when fewer than two
    of the entry's nodes survive.

    ``old_to_new`` maps surviving old node ids to their ids in the new
    numbering; ``lat``/``bw`` are matrices over the new numbering.
    ``append_new`` lists new-numbering ids to add to the group (nodes
    that joined); they are appended to the warm-start order and placed
    by the refinement sweeps.  ``hierarchy`` — a
    :class:`~repro.fabric.HierarchyModel` over the new numbering (e.g.
    the previous tree put through ``restrict``) — contributes a
    locality-nested candidate order that competes with the refined
    warm start.
    """
    from repro.plan.compiler import PlanEntry  # local: faults <-> plan cycle

    members = [old_to_new[x] for x in entry.group if x in old_to_new]
    members = sorted(set(members) | set(int(x) for x in append_new))
    n_g = len(members)
    if n_g < 2:
        return None, "dropped"
    g = np.asarray(members, dtype=np.int64)
    sub_lat = lat[np.ix_(g, g)]
    sub_bw = bw[np.ix_(g, g)] if bw is not None else None
    pos = {node: i for i, node in enumerate(members)}

    # warm start: previous rank order restricted to survivors (+ joiners
    # appended; refinement finds their slots)
    warm_nodes = [old_to_new[x] for x in entry.perm if x in old_to_new]
    warm_local = [pos[x] for x in warm_nodes if x in pos]
    warm_local += [pos[int(x)] for x in append_new if int(x) in pos
                   and pos[int(x)] not in set(warm_local)]
    if len(warm_local) != n_g:   # stale perm missing members: fall back
        warm_local = list(range(n_g))
    warm_local = np.asarray(warm_local, dtype=np.int64)
    identity_local = np.arange(n_g)

    def model_for(name: str, akw: Dict[str, int]):
        m_algo = get_builder(name).cost_model
        kwargs = {"base": akw["base"]} if "base" in akw else {}
        if sub_bw is not None:
            return make_cost_model(m_algo, size_bytes=entry.size_bytes,
                                   lat=sub_lat, bw=sub_bw, **kwargs)
        return make_cost_model(m_algo, cost_matrix=sub_lat,
                               size_bytes=entry.size_bytes, **kwargs)

    algo, akw, model = _choose_algorithm(entry, n_g, model_for, warm_local)

    rung = None
    chosen = None
    try:                                           # rung 1: warm re-solve
        chosen = warm_refine(model, warm_local, sweeps=sweeps)
        rung = "warm_resolve"
    except Exception:
        try:                                       # rung 2: hot-patch
            chosen, _, _ = bottleneck_swap(model, warm_local, max_rounds=4)
            chosen = np.asarray(chosen, dtype=np.int64)
            rung = "hot_patch"
        except Exception:                          # rung 3: stale
            chosen = warm_local
            rung = "stale"

    if hierarchy is not None and not getattr(hierarchy, "flat", True):
        # locality-nested candidate from the restricted tree; it wins
        # only when it prices better than the refined warm start
        try:
            from repro.core.reorder import hierarchical_perm
            from repro.fabric import combine_cost

            sub_h = hierarchy.restrict(members)
            if not sub_h.flat:
                hl = hierarchical_perm(
                    combine_cost(sub_lat, sub_bw, entry.size_bytes),
                    sub_h, seed=seed)
                if model.cost(hl) < model.cost(chosen):
                    chosen = np.asarray(hl, dtype=np.int64)
        except Exception:
            pass                                   # candidate only; optional

    # rung 4 guard (always on): never worse than identity
    ident_t = float(model.cost(identity_local))
    chosen_t = float(model.cost(chosen))
    if not np.isfinite(chosen_t) or chosen_t > ident_t:
        chosen, chosen_t, rung = identity_local, ident_t, "identity"

    obs.metrics().counter(f"faults.ladder.{rung}").inc()
    new = PlanEntry(
        op=entry.op, bucket=entry.bucket, size_bytes=entry.size_bytes,
        group=tuple(members), algo=algo, algo_kwargs=dict(akw),
        chunks=entry.chunks if algo == entry.algo else 1,
        perm=tuple(int(x) for x in g[chosen]),
        expected_time=chosen_t,
        identity_times={algo: ident_t},
        solver_cost=chosen_t, oracle="cost_model",
        program_fingerprint="",
    )
    return new, rung


def recover_plan(plan, old_to_new: Dict[int, int],
                 lat: np.ndarray, bw: Optional[np.ndarray],
                 hierarchy=None, joiners: Sequence[int] = (),
                 sweeps: int = 4, seed: int = 0):
    """Warm-recover a whole plan onto the new membership.

    Returns ``(new_plan, rungs)`` where ``rungs`` maps each old entry
    key to the ladder rung its recovery used.  Entries that spanned the
    whole old fabric absorb ``joiners`` (new-numbering ids); sub-group
    entries only shrink.  The mesh plan is dropped — an N-D assignment
    cannot survive a node-count change; re-plan for a new mesh shape.
    """
    from repro.plan.cache import fabric_fingerprint
    from repro.plan.compiler import Plan

    # the obs timer replaces the ad-hoc perf_counter pair: recovery
    # latency is a product number (compile_seconds of the recovered
    # plan) and a trace span whenever tracing is on
    timer = obs.tracer().timer("faults.recover_plan",
                               entries=len(plan.entries))
    with timer:
        n_new = lat.shape[0]
        entries = {}
        rungs: Dict[Tuple, str] = {}
        for key, entry in plan.entries.items():
            was_full = len(entry.group) == plan.n
            new_entry, rung = recover_entry(
                entry, old_to_new, lat, bw,
                append_new=tuple(joiners) if was_full else (),
                hierarchy=hierarchy, sweeps=sweeps, seed=seed)
            rungs[key] = rung
            if new_entry is not None:
                entries[(new_entry.op, new_entry.bucket, new_entry.group)] = \
                    new_entry
        fp = fabric_fingerprint(lat, bw, hierarchy=hierarchy)
    obs.metrics().histogram("faults.recover.seconds", scale=1e-3).observe(
        timer.elapsed)
    new_plan = Plan(
        fingerprint=fp, n=n_new, entries=entries, mesh_plan=None,
        compile_seconds=timer.elapsed, mix_key=plan.mix_key,
        meta=dict(plan.meta,
                  recovered_from=plan.fingerprint.digest,
                  rungs={str(k): v for k, v in rungs.items()},
                  hierarchy=hierarchy.to_dict() if hierarchy is not None
                  and not getattr(hierarchy, "flat", True) else None),
    )
    return new_plan, rungs


def identity_fallback(plan) -> int:
    """Bottom of the ladder: pin every entry to identity order in place.

    Returns the number of entries changed.  Identity order is the
    no-reordering baseline — by definition it cannot be worse than
    itself, so a halted session serving this plan is always valid.
    """
    changed = 0
    for entry in plan.entries.values():
        ident = tuple(entry.group)
        if entry.perm != ident:
            entry.perm = ident
            changed += 1
    plan.meta["fallback"] = "identity"
    obs.tracer().event("faults.identity_fallback", changed=changed)
    obs.metrics().counter("faults.identity_fallbacks").inc()
    return changed
