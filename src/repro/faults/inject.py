"""Deterministic fault injection for cloud fabrics.

The source paper's premise is that cloud fabrics are multi-tenant and
volatile; the ROADMAP's elastic-fabrics item names the concrete
scenarios: preemptible-VM churn, nodes joining mid-job, time-varying
tenant interference.  This module makes those scenarios *first-class
and reproducible*:

* :class:`FaultEvent` — one scheduled fault: a probe timeout, dropped /
  NaN probe samples, a link-degradation episode, a node preemption or
  join, or a straggler onset.  Events carry a start ``tick``, a
  ``duration`` in ticks (episodes), target ``nodes``, and a magnitude.
* :class:`FaultSchedule` — an explicit event list, or a seeded
  generator (:meth:`FaultSchedule.generate`) drawing a deterministic
  chaos timeline from per-kind rates.  Same seed, same timeline — the
  chaos suite and the churn benchmark replay identical storms.
* :class:`FaultyFabric` — duck-types :class:`repro.fabric.Fabric`, so
  ``probe_fabric`` / ``sparse_probe_fabric`` / ``refresh_sparse`` apply
  the active faults **without touching callers**: reading ``.lat`` at a
  tick with an active ``probe_timeout`` raises :class:`ProbeTimeout`
  (the probe call fails exactly like a wedged fping sweep), link
  degradations and stragglers inflate the matrices the probe samples,
  and ``probe_drop`` / ``probe_nan`` corrupt a seeded subset of
  entries.  Membership events (preempt / join) do not mutate matrix
  shapes — they are surfaced by :meth:`FaultyFabric.advance` for the
  session's ``on_node_leave`` / ``on_node_join`` elastic path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fabric import Fabric

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultSchedule",
    "FaultyFabric",
    "ProbeTimeout",
]

#: every fault kind a schedule may carry
FAULT_KINDS = (
    "probe_timeout",   # the whole probe sweep times out (raises ProbeTimeout)
    "probe_drop",      # a fraction of probe samples are lost (entries -> +inf)
    "probe_nan",       # a fraction of probe samples are corrupted (-> NaN)
    "link_degrade",    # pairwise costs touching `nodes` inflate by `factor`
    "node_preempt",    # `nodes` leave the job (membership event)
    "node_join",       # `nodes` (re)join the job (membership event)
    "straggler",       # `nodes` slow down: all their links scale by `factor`
)

#: kinds that change membership rather than the probed matrices
MEMBERSHIP_KINDS = ("node_preempt", "node_join")


class ProbeTimeout(TimeoutError):
    """A probe sweep exceeded its deadline (injected or real)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault (see :data:`FAULT_KINDS`)."""

    kind: str
    tick: int                          # first tick the fault is active
    duration: int = 1                  # ticks the fault stays active
    nodes: Tuple[int, ...] = ()        # targets (membership / degrade / straggler)
    factor: float = 1.0                # cost multiplier (degrade / straggler)
    frac: float = 0.0                  # affected entry fraction (drop / nan)

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if self.tick < 0 or self.duration < 1:
            raise ValueError(
                f"FaultEvent needs tick >= 0 and duration >= 1; got "
                f"tick={self.tick}, duration={self.duration}")
        object.__setattr__(self, "nodes",
                           tuple(int(x) for x in self.nodes))

    def active_at(self, tick: int) -> bool:
        return self.tick <= tick < self.tick + self.duration

    def to_dict(self) -> dict:
        return {"kind": self.kind, "tick": self.tick,
                "duration": self.duration, "nodes": list(self.nodes),
                "factor": self.factor, "frac": self.frac}

    @staticmethod
    def from_dict(d: dict) -> "FaultEvent":
        return FaultEvent(
            kind=str(d["kind"]), tick=int(d["tick"]),
            duration=int(d.get("duration", 1)),
            nodes=tuple(int(x) for x in d.get("nodes", ())),
            factor=float(d.get("factor", 1.0)),
            frac=float(d.get("frac", 0.0)))


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A deterministic timeline of :class:`FaultEvent`\\ s."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    horizon: int = 0                   # ticks the generator covered

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.tick, e.kind))))

    def at(self, tick: int) -> List[FaultEvent]:
        """Events active at ``tick`` (episodes included mid-flight)."""
        return [e for e in self.events if e.active_at(tick)]

    def starting_at(self, tick: int) -> List[FaultEvent]:
        """Events whose first active tick is ``tick`` (membership firing)."""
        return [e for e in self.events if e.tick == tick]

    def membership_at(self, tick: int) -> List[FaultEvent]:
        """Preempt/join events firing exactly at ``tick``."""
        return [e for e in self.starting_at(tick)
                if e.kind in MEMBERSHIP_KINDS]

    @staticmethod
    def generate(
        n: int,
        ticks: int = 32,
        seed: int = 0,
        timeout_rate: float = 0.05,
        drop_rate: float = 0.05,
        nan_rate: float = 0.05,
        degrade_rate: float = 0.1,
        preempt_frac: float = 0.0,
        preempt_tick: Optional[int] = None,
        straggler_rate: float = 0.05,
        max_degrade_factor: float = 8.0,
    ) -> "FaultSchedule":
        """Draw a deterministic chaos timeline.

        Per tick, each transient kind fires with its rate; link
        degradations and stragglers get a 2-6 tick episode over a random
        node subset with a log-uniform factor.  ``preempt_frac`` > 0
        schedules ONE preemption of that node fraction (at
        ``preempt_tick``, default mid-horizon) followed by a rejoin of
        the same nodes three quarters in — the preemptible-VM churn
        scenario the acceptance gate replays.
        """
        if n < 2 or ticks < 1:
            raise ValueError(
                f"FaultSchedule.generate needs n >= 2 and ticks >= 1; "
                f"got n={n}, ticks={ticks}")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for t in range(ticks):
            if rng.random() < timeout_rate:
                events.append(FaultEvent("probe_timeout", t))
            if rng.random() < drop_rate:
                events.append(FaultEvent(
                    "probe_drop", t, frac=float(rng.uniform(0.01, 0.1))))
            if rng.random() < nan_rate:
                events.append(FaultEvent(
                    "probe_nan", t, frac=float(rng.uniform(0.01, 0.1))))
            if rng.random() < degrade_rate:
                k = int(rng.integers(1, max(2, n // 8) + 1))
                nodes = tuple(int(x) for x in
                              rng.choice(n, size=k, replace=False))
                events.append(FaultEvent(
                    "link_degrade", t,
                    duration=int(rng.integers(2, 7)), nodes=nodes,
                    factor=float(np.exp(rng.uniform(
                        np.log(2.0), np.log(max_degrade_factor))))))
            if rng.random() < straggler_rate:
                node = int(rng.integers(0, n))
                events.append(FaultEvent(
                    "straggler", t, duration=int(rng.integers(2, 7)),
                    nodes=(node,),
                    factor=float(rng.uniform(1.5, 4.0))))
        if preempt_frac > 0.0:
            k = max(1, int(round(preempt_frac * n)))
            dead = tuple(int(x) for x in
                         rng.choice(n, size=k, replace=False))
            pt = ticks // 2 if preempt_tick is None else int(preempt_tick)
            events.append(FaultEvent("node_preempt", pt, nodes=dead))
            rejoin = pt + max(1, ticks // 4)
            if rejoin < ticks:
                events.append(FaultEvent("node_join", rejoin, nodes=dead))
        return FaultSchedule(events=tuple(events), seed=seed, horizon=ticks)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        return {"seed": self.seed, "horizon": self.horizon,
                "events": [e.to_dict() for e in self.events]}

    @staticmethod
    def from_dict(d: dict) -> "FaultSchedule":
        return FaultSchedule(
            events=tuple(FaultEvent.from_dict(e) for e in d["events"]),
            seed=int(d.get("seed", 0)), horizon=int(d.get("horizon", 0)))


class FaultyFabric:
    """A :class:`Fabric` view with the schedule's faults applied per tick.

    Duck-types everything the probe layer reads (``n``, ``lat``, ``bw``,
    ``paths``, ``link_bw``, ``meta``, ``cost_matrix``, ``subset``), so
    it drops into ``probe_fabric(...)`` / ``sparse_probe_fabric(...)``
    / ``refresh_sparse(...)`` unchanged.  The *view* is what a probe
    would measure right now:

    * active ``link_degrade`` / ``straggler`` events inflate the latency
      rows/columns of their nodes (and deflate bandwidth);
    * active ``probe_drop`` events blank a seeded fraction of entries to
      ``+inf`` (a lost probe looks infinitely slow);
    * active ``probe_nan`` events corrupt a seeded fraction to NaN;
    * an active ``probe_timeout`` makes any matrix access raise
      :class:`ProbeTimeout` — the sweep never returns.

    Call :meth:`advance` once per monitor tick; it returns the
    membership events firing at the new tick so the harness can drive
    ``Session.on_node_leave`` / ``on_node_join``.
    """

    def __init__(self, fabric: Fabric, schedule: FaultSchedule,
                 tick: int = 0):
        self.base = fabric
        self.schedule = schedule
        self.tick = int(tick)

    # -- clock -------------------------------------------------------------
    def advance(self, ticks: int = 1) -> List[FaultEvent]:
        """Move the clock forward; returns membership events now firing."""
        if ticks < 1:
            raise ValueError(f"advance needs ticks >= 1; got {ticks}")
        fired: List[FaultEvent] = []
        for _ in range(ticks):
            self.tick += 1
            fired.extend(self.schedule.membership_at(self.tick))
        return fired

    def active(self) -> List[FaultEvent]:
        return self.schedule.at(self.tick)

    # -- Fabric duck-typing ------------------------------------------------
    @property
    def n(self) -> int:
        return self.base.n

    @property
    def paths(self):
        return self.base.paths

    @property
    def link_bw(self) -> np.ndarray:
        return self.base.link_bw

    @property
    def meta(self) -> Dict[str, object]:
        return dict(self.base.meta, faulty=True, tick=self.tick)

    def _check_timeout(self) -> None:
        for e in self.active():
            if e.kind == "probe_timeout":
                raise ProbeTimeout(
                    f"probe sweep timed out at tick {self.tick} "
                    f"(injected by FaultSchedule seed={self.schedule.seed})")

    def _node_factors(self) -> np.ndarray:
        """Per-node cost multiplier from active degrade/straggler events."""
        f = np.ones(self.base.n)
        for e in self.active():
            if e.kind in ("link_degrade", "straggler"):
                idx = [x for x in e.nodes if 0 <= x < self.base.n]
                f[idx] *= max(e.factor, 1.0)
        return f

    def _corrupt(self, mat: np.ndarray, fill: float) -> np.ndarray:
        """Apply active drop/nan corruption for ``fill`` to ``mat``."""
        n = self.base.n
        for e in self.active():
            want = "probe_drop" if np.isinf(fill) else "probe_nan"
            if e.kind != want or e.frac <= 0.0:
                continue
            # seeded per (schedule, event, tick): the same storm corrupts
            # the same entries on every replay
            rng = np.random.default_rng(
                (self.schedule.seed, e.tick, self.tick,
                 0 if np.isinf(fill) else 1))
            k = int(e.frac * n * (n - 1))
            if k < 1:
                k = 1
            i = rng.integers(0, n, size=k)
            j = rng.integers(0, n, size=k)
            ok = i != j
            mat[i[ok], j[ok]] = fill
        return mat

    @property
    def lat(self) -> np.ndarray:
        self._check_timeout()
        f = self._node_factors()
        lat = self.base.lat * np.maximum(f[:, None], f[None, :])
        np.fill_diagonal(lat, 0.0)
        lat = self._corrupt(lat, np.inf)
        return self._corrupt(lat, np.nan)

    @property
    def bw(self) -> np.ndarray:
        self._check_timeout()
        f = self._node_factors()
        return self.base.bw / np.maximum(f[:, None], f[None, :])

    def cost_matrix(self, size_bytes: float = 0.0) -> np.ndarray:
        from repro.fabric import combine_cost

        return combine_cost(self.lat, self.bw, size_bytes)

    def subset(self, nodes: Sequence[int]) -> Fabric:
        """Restriction of the *base* fabric (membership, not faults)."""
        return self.base.subset(nodes)

    def alive(self) -> List[int]:
        """Node ids alive at the current tick per the membership events."""
        alive = set(range(self.base.n))
        for e in self.schedule.events:
            if e.tick > self.tick:
                break
            if e.kind == "node_preempt":
                alive -= set(e.nodes)
            elif e.kind == "node_join":
                alive |= {x for x in e.nodes if 0 <= x < self.base.n}
        return sorted(alive)

    def __repr__(self) -> str:
        return (f"FaultyFabric(n={self.base.n}, tick={self.tick}, "
                f"active={[e.kind for e in self.active()]})")
