"""Capped exponential backoff with deterministic jitter.

The monitor loop, the probe path, and the planning service all face the
same failure shape: a transient fault (probe timeout, noisy-neighbor
congestion episode, a racing re-attach) that resolves itself within a
few seconds — and the occasional persistent one that does not.  Before
this module each caller either crashed or spun hot on a bare
``warnings.warn``.  A :class:`RetryPolicy` gives them one shared
contract:

* **retries** — :func:`call_with_retries` re-invokes the callable up to
  ``max_retries`` times with capped exponential backoff between
  attempts, then raises :class:`RetryError` wrapping the last failure;
* **jitter** — each delay is scaled by a seeded uniform factor so a
  fleet of sessions probing the same fabric does not synchronize its
  retry storms (and tests stay deterministic);
* **health thresholds** — ``failure_threshold`` / ``halt_threshold``
  are consumed by the session health state machine
  (:mod:`repro.faults.health`): consecutive monitor-tick failures past
  the first threshold degrade the session, past the second halt it.

The policy is a frozen all-scalar dataclass so it slots into
:class:`repro.session.SessionConfig` as the ``retry`` section and
round-trips through dict / JSON / ``REPRO_RETRY_*`` env overrides like
every other section.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, TypeVar

import numpy as np

from repro import obs

__all__ = ["RetryPolicy", "RetryError", "call_with_retries"]

T = TypeVar("T")


class RetryError(RuntimeError):
    """Every attempt failed; ``last`` is the final underlying exception."""

    def __init__(self, attempts: int, last: BaseException):
        super().__init__(
            f"gave up after {attempts} attempt(s); last error: "
            f"{type(last).__name__}: {last}")
        self.attempts = attempts
        self.last = last


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Backoff + health-threshold knobs shared by probe/plan/monitor paths.

    ``delay(attempt)`` for attempt = 1, 2, ... is
    ``min(max_delay_s, base_delay_s * multiplier**(attempt-1))`` scaled
    by ``1 ± jitter`` (seeded uniform).  All fields are scalars so the
    policy doubles as the ``retry`` section of a session config.
    """

    #: re-invocations after the first failure (0 = fail immediately)
    max_retries: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    #: relative jitter amplitude in [0, 1); 0.1 = delays vary by ±10%
    jitter: float = 0.1
    #: consecutive monitor-tick failures before the session degrades
    failure_threshold: int = 3
    #: consecutive monitor-tick failures before the session halts
    halt_threshold: int = 10
    #: seed for the jitter stream (deterministic chaos tests)
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(
                f"RetryPolicy.max_retries must be >= 0; got {self.max_retries}")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError(
                f"RetryPolicy delays must be >= 0; got base_delay_s="
                f"{self.base_delay_s}, max_delay_s={self.max_delay_s}")
        if self.multiplier < 1.0:
            raise ValueError(
                f"RetryPolicy.multiplier must be >= 1 (backoff never "
                f"shrinks); got {self.multiplier}")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(
                f"RetryPolicy.jitter must be in [0, 1); got {self.jitter}")
        if self.failure_threshold < 1 or self.halt_threshold < 1:
            raise ValueError(
                f"RetryPolicy thresholds must be >= 1; got "
                f"failure_threshold={self.failure_threshold}, "
                f"halt_threshold={self.halt_threshold}")
        if self.halt_threshold < self.failure_threshold:
            raise ValueError(
                f"RetryPolicy.halt_threshold ({self.halt_threshold}) must "
                f"be >= failure_threshold ({self.failure_threshold}): a "
                f"session degrades before it halts")

    def delay(self, attempt: int,
              rng: Optional[np.random.Generator] = None) -> float:
        """Backoff before retry ``attempt`` (1-based), jittered."""
        if attempt < 1:
            return 0.0
        base = min(self.max_delay_s,
                   self.base_delay_s * self.multiplier ** (attempt - 1))
        if self.jitter <= 0.0:
            return float(base)
        if rng is None:
            rng = np.random.default_rng(self.seed + attempt)
        return float(base * (1.0 + self.jitter * rng.uniform(-1.0, 1.0)))


def call_with_retries(
    fn: Callable[[], T],
    policy: RetryPolicy,
    sleep: Callable[[float], Any] = None,
    rng: Optional[np.random.Generator] = None,
    on_retry: Optional[Callable[[int, BaseException, float], None]] = None,
) -> T:
    """Invoke ``fn`` under ``policy``; raise :class:`RetryError` at the cap.

    ``sleep(delay_s)`` defaults to :func:`time.sleep`; the session
    monitor passes its stop-event ``wait`` so a close() interrupts a
    backoff immediately.  ``on_retry(attempt, error, delay_s)`` fires
    before each backoff — the hook the session uses for telemetry.
    """
    if sleep is None:
        import time

        sleep = time.sleep
    if rng is None:
        rng = np.random.default_rng(policy.seed)
    last: Optional[BaseException] = None
    for attempt in range(policy.max_retries + 1):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 — the whole point is containment
            last = e
            if attempt >= policy.max_retries:
                break
            d = policy.delay(attempt + 1, rng)
            obs.metrics().counter("faults.retry.attempts").inc()
            obs.tracer().event("faults.retry", attempt=attempt + 1,
                               delay_s=d, error=repr(e))
            if on_retry is not None:
                on_retry(attempt + 1, e, d)
            sleep(d)
    obs.metrics().counter("faults.retry.exhausted").inc()
    raise RetryError(policy.max_retries + 1, last)
