"""Bucketed gradient all-reduce fused with compute via certified schedules.

The data-parallel gradient all-reduce is the train step's one fleet-wide
collective; the paper's reordering wins only move *step* time if the
reordered schedule overlaps the step's compute (exposed communication is
the real cost).  This module is the train-side consumer of
:mod:`repro.kernels.overlap`:

* the grad pytree is partitioned into size-targeted **buckets**
  (:func:`partition_tree`) — bucket size is a *planned* dimension: the
  plan compiler scores candidate bucket payloads per octave and stores
  the winner on :attr:`PlanEntry.bucket_bytes`, which
  :func:`reducer_from_plan` picks up through ordinary ``Plan.lookup``;
* each bucket's payload runs the **certified** all-reduce schedule —
  certification happens before fusion (``require_certified`` /
  ``Session.lower``), and fusion never edits rounds;
* buckets are **pipelined**: bucket ``b``'s transfer goes on the wire
  while bucket ``b - 1``'s finishing math (un-flatten, mean) and any
  caller-supplied resident compute run, at bucket granularity
  (``mode="bucketed"``) or spread shard-by-shard across the schedule's
  rounds (``mode="fused"``).

Every mode computes the same reduction element-for-element — the modes
differ only in *when* compute is traced relative to the certified
rounds — so the overlapped step's loss and grads match the sequential
baseline to float tolerance (exactly, between explicit modes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import require_certified
from repro.collective import CollectiveOp, JaxExecutor, compile_op
from repro.collective.executors import LoweredSchedule
from repro.collective.passes import apply_permutation, chunk as chunk_pass
from repro.kernels.overlap import run_overlapped
from repro.kernels.schedule_runner import _shard_map
from repro.optim import apply_opt

from .train_step import TrainState

__all__ = [
    "GradBucket",
    "partition_tree",
    "certified_allreduce",
    "OverlapGradReducer",
    "reducer_from_plan",
    "make_overlap_train_step",
    "jit_overlap_train_step",
    "OVERLAP_MODES",
]

OVERLAP_MODES = ("sequential", "bucketed", "fused")


@dataclasses.dataclass(frozen=True)
class GradBucket:
    """One size-targeted slice of the (flattened) grad pytree."""

    index: int
    leaf_ids: Tuple[int, ...]        # indices into jax.tree.flatten order
    sizes: Tuple[int, ...]           # per-leaf element counts
    n_elems: int
    n_bytes: int


def partition_tree(tree, bucket_bytes: float,
                   leading_axis: bool = False) -> List[GradBucket]:
    """Greedy size-targeted partition of a pytree, in flatten order.

    ``bucket_bytes <= 0`` yields a single bucket.  With
    ``leading_axis=True`` leaves carry a stacked per-rank axis 0 that
    does not count toward the payload.  Works on arrays and on shape
    structs (anything with ``.shape``/``.dtype``), so the partition can
    be computed once from a template and reused across steps.
    """
    leaves = jax.tree.leaves(tree)
    buckets: List[GradBucket] = []
    cur_ids: List[int] = []
    cur_sizes: List[int] = []
    cur_bytes = 0
    for i, leaf in enumerate(leaves):
        shape = tuple(leaf.shape)[1:] if leading_axis else tuple(leaf.shape)
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nbytes = size * np.dtype(leaf.dtype).itemsize
        if cur_ids and bucket_bytes > 0 and cur_bytes + nbytes > bucket_bytes:
            buckets.append(GradBucket(
                index=len(buckets), leaf_ids=tuple(cur_ids),
                sizes=tuple(cur_sizes), n_elems=sum(cur_sizes),
                n_bytes=cur_bytes))
            cur_ids, cur_sizes, cur_bytes = [], [], 0
        cur_ids.append(i)
        cur_sizes.append(size)
        cur_bytes += nbytes
    if cur_ids:
        buckets.append(GradBucket(
            index=len(buckets), leaf_ids=tuple(cur_ids),
            sizes=tuple(cur_sizes), n_elems=sum(cur_sizes),
            n_bytes=cur_bytes))
    return buckets


def certified_allreduce(n: int, size_bytes: float, algo: str = "ring",
                        perm: Optional[Sequence[int]] = None,
                        chunk_factor: int = 1,
                        **algo_kwargs) -> LoweredSchedule:
    """Compile, lower and certify an all-reduce schedule for ``n`` ranks.

    The session-less convenience path (tests, benchmarks): planned
    deployments go through ``Session.lower`` / :func:`reducer_from_plan`
    instead, where the plan supplies algorithm, permutation and bucket
    size.  The returned schedule is certified against its program by
    :func:`repro.analysis.require_certified` before anything runs it.
    """
    op = CollectiveOp(kind="allreduce", size_bytes=float(size_bytes),
                      group=tuple(range(n)))
    prog = compile_op(op, algo, **algo_kwargs)
    if perm is not None:
        prog = apply_permutation(prog, [int(p) for p in perm])
    if chunk_factor > 1:
        prog = chunk_pass(prog, chunk_factor)
    sched = JaxExecutor().lower_schedule(prog)
    require_certified(prog, sched)
    return sched


class OverlapGradReducer:
    """Bucketed, certified DP gradient mean over one mesh axis.

    Callable on a *stacked* grad pytree (leaves ``[n, ...]``, sharded
    over ``axis``): returns the mean tree plus any resident-compute
    results.  The same certified schedule runs every bucket — the
    lowering is payload-agnostic, so the runner's memoised SEND/RECV
    tables hit across buckets and steps.
    """

    def __init__(self, mesh: Mesh, axis: str, schedule: LoweredSchedule,
                 bucket_bytes: float = 0.0, mode: str = "bucketed",
                 use_pallas_add: bool = False, interpret: bool = True):
        if mode not in OVERLAP_MODES:
            raise ValueError(f"mode must be one of {OVERLAP_MODES}, "
                             f"got {mode!r}")
        if schedule.postcondition != "allreduce":
            raise ValueError("OverlapGradReducer needs an all-reduce "
                             f"schedule, got {schedule.postcondition!r}")
        if mesh.shape[axis] != schedule.n:
            raise ValueError(f"mesh axis {axis!r} has {mesh.shape[axis]} "
                             f"devices, schedule wants {schedule.n}")
        self.mesh = mesh
        self.axis = axis
        self.schedule = schedule
        self.bucket_bytes = float(bucket_bytes)
        self.mode = mode
        self.use_pallas_add = use_pallas_add
        self.interpret = interpret
        self.n = schedule.n

    # -- bucketing ---------------------------------------------------------
    def buckets_for(self, stacked_tree) -> List[GradBucket]:
        return partition_tree(stacked_tree, self.bucket_bytes,
                              leading_axis=True)

    def record_buckets(self, stacked_tree) -> List[GradBucket]:
        """Report the per-bucket all-reduce payloads to ``repro.obs``.

        Python-level (never inside a traced function): call once per
        step, or once per (re)mesh if only the totals matter.
        """
        from repro import obs

        buckets = self.buckets_for(stacked_tree)
        rec = obs.recorder()
        for b in buckets:
            rec.record("all-reduce", float(b.n_bytes))
        obs.metrics().gauge("train.overlap.buckets").set(len(buckets))
        return buckets

    # -- the reduction -----------------------------------------------------
    def __call__(self, stacked_tree,
                 compute: Sequence[Callable[[], Any]] = ()
                 ) -> Tuple[Any, List[Any]]:
        leaves, tdef = jax.tree.flatten(stacked_tree)
        buckets = self.buckets_for(stacked_tree)
        n = self.n
        quantum = self.schedule.n_chunks * max(1, self.schedule.chunk_factor)

        payloads = []
        for bkt in buckets:
            flat = [leaves[i].reshape(n, -1) for i in bkt.leaf_ids]
            vec = flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=1)
            pad = (-vec.shape[1]) % quantum
            if pad:
                vec = jnp.pad(vec, ((0, 0), (0, pad)))
            payloads.append(vec)

        outs: List[Any] = [None] * len(buckets)
        finished: Dict[int, Any] = {}
        results: List[Any] = [None] * len(compute)
        shapes = [tuple(l.shape)[1:] for l in leaves]

        def finisher_shards(b: int):
            """Thunks turning bucket ``b``'s raw output into mean leaves.

            ``bucketed``: one shard per bucket; ``fused``: one per leaf,
            so the plan spreads them across the next bucket's rounds.
            """
            bkt = buckets[b]

            def vec():
                return outs[b].reshape(n, -1)[0, :bkt.n_elems] / n

            if self.mode == "fused":
                shards = []
                off = 0
                for i, sz in zip(bkt.leaf_ids, bkt.sizes):
                    def one(i=i, off=off, sz=sz):
                        return vec()[off:off + sz].reshape(shapes[i])
                    shards.append((i, one))
                    off += sz
                return shards

            def whole(bkt=bkt):
                v, off, out = vec(), 0, []
                for i, sz in zip(bkt.leaf_ids, bkt.sizes):
                    out.append(v[off:off + sz].reshape(shapes[i]))
                    off += sz
                return out
            return [(("bucket", b), whole)]

        def land(tag, value):
            if isinstance(tag, tuple) and tag[0] == "bucket":
                bkt = buckets[tag[1]]
                for i, leaf in zip(bkt.leaf_ids, value):
                    finished[i] = leaf
            elif isinstance(tag, tuple) and tag[0] == "user":
                results[tag[1]] = value
            else:
                finished[tag] = value

        user_split = np.array_split(np.arange(len(compute)),
                                    max(1, len(buckets)))
        pipelined = self.mode != "sequential"
        for b, payload in enumerate(payloads):
            shards = []
            if pipelined and b > 0:
                shards.extend(finisher_shards(b - 1))
            shards.extend(
                (("user", int(u)), compute[int(u)]) for u in user_split[b])
            tags = [t for t, _ in shards]
            out_b, res = run_overlapped(
                payload, self.mesh, self.axis, self.schedule,
                compute=[fn for _, fn in shards],
                use_pallas_add=self.use_pallas_add,
                interpret=self.interpret)
            outs[b] = out_b
            for tag, value in zip(tags, res):
                land(tag, value)
        # drain: the last bucket (every bucket, in sequential mode)
        for b in range(len(buckets)):
            if buckets[b].leaf_ids[0] in finished:
                continue
            for tag, fn in finisher_shards(b):
                land(tag, fn())

        mean_tree = tdef.unflatten([finished[i] for i in range(len(leaves))])
        return mean_tree, results


def reducer_from_plan(plan, mesh: Mesh, axis: str, total_bytes: float,
                      group: Optional[Sequence[int]] = None,
                      mode: str = "bucketed",
                      bucket_bytes: Optional[float] = None,
                      use_pallas_add: bool = False,
                      interpret: bool = True) -> OverlapGradReducer:
    """Reducer from a compiled :class:`~repro.plan.Plan`.

    Two ``PlanEntry`` lookups: the octave of the *full* grad payload
    supplies the planned ``bucket_bytes``, then the octave of the bucket
    payload supplies the algorithm/permutation/chunking actually run —
    so both the bucket size and the schedule are planned dimensions.
    The schedule is lowered and certified here, before any fusion.
    """
    entry = plan.lookup("all-reduce", total_bytes, group)
    bb = float(bucket_bytes if bucket_bytes is not None
               else (entry.bucket_bytes or total_bytes))
    entry_b = plan.lookup("all-reduce", bb, group)
    prog = entry_b.program()
    sched = JaxExecutor().lower_schedule(prog)
    require_certified(prog, sched)
    if sched.postcondition != "allreduce":
        # some algorithms (e.g. bcube) lower their all-reduce to a
        # schedule that ends reduce-scattered; the reducer needs every
        # rank to finish with the full sum, so fall back to a ring at
        # the planned rank order (the reordering win is kept, the
        # algorithm choice is not)
        local = [entry_b.group.index(p) for p in entry_b.perm]
        sched = certified_allreduce(len(entry_b.group), bb, algo="ring",
                                    perm=local,
                                    chunk_factor=max(1, entry_b.chunks))
    return OverlapGradReducer(mesh, axis, sched, bucket_bytes=bb, mode=mode,
                              use_pallas_add=use_pallas_add,
                              interpret=interpret)


def make_overlap_train_step(model, opt_cfg, mesh: Mesh, axis: str,
                            reducer: OverlapGradReducer):
    """Train step whose grad all-reduce is the reducer's certified path.

    Pure data parallelism over ``axis``: params replicated, batch
    sharded on its leading dim.  Per-device grads come out of a
    ``shard_map`` stacked ``[n, ...]``; the reducer pipelines the
    bucketed certified schedules (with the previous bucket's finishing
    math as resident compute) and AdamW applies to the mean — the same
    ``apply_opt`` as the baseline step, on grads that match it to float
    tolerance.
    """
    n = mesh.shape[axis]

    def local(params, b):
        loss, g = jax.value_and_grad(model.loss)(params, b)
        return loss[None], jax.tree.map(lambda t: t[None], g)

    sm = _shard_map(local, mesh, (P(), P(axis)), (P(axis), P(axis)))

    def step(state: TrainState, batch):
        losses, gstack = sm(state.params, batch)
        loss = jnp.mean(losses)
        grads, _ = reducer(gstack)
        new_params, new_opt, metrics = apply_opt(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


def jit_overlap_train_step(model, opt_cfg, mesh: Mesh, axis: str,
                           reducer: OverlapGradReducer, donate: bool = True):
    """jit of :func:`make_overlap_train_step` with explicit shardings."""
    step_fn = make_overlap_train_step(model, opt_cfg, mesh, axis, reducer)
    rep = NamedSharding(mesh, P())            # pytree-prefix: whole state
    batch_ns = NamedSharding(mesh, P(axis))   # prefix: every batch leaf
    return jax.jit(
        step_fn,
        in_shardings=(rep, batch_ns),
        out_shardings=None,
        donate_argnums=(0,) if donate else (),
    )
