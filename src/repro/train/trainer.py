"""Fault-tolerant trainer with cloud-aware rank reordering built in.

The trainer composes everything the paper's end-to-end experiments need
(§V-D) plus the large-scale runnability substrate:

* **rank-reordered mesh** — the cluster view probes its fabric, solves the
  N-D mesh plan (:mod:`repro.core.reorder`) and the trainer trains on the
  reordered mesh: the paper's technique as a first-class launcher feature;
* **checkpoint/restart** — async atomic checkpoints every N steps;
* **node-failure handling (elastic)** — on a :class:`NodeFailure`, the
  cluster view drops the dead nodes, re-probes the surviving fabric,
  *re-solves the rank order* (paper §VI dynamic adaptation), rebuilds the
  (smaller) mesh plan and resumes from the last checkpoint;
* **straggler mitigation** — per-step times feed a
  :class:`~repro.core.dynamic.StragglerDetector`; when a straggler
  degrades the current order beyond threshold the
  :class:`~repro.core.dynamic.AdaptiveReranker` performs the paper's
  bottleneck-edge replacement and the trainer adopts the new order.

On this CPU container the *cluster view* (node ids, fabric, rank order)
is simulated while the JAX execution mesh is whatever devices exist; on a
real fleet both are the same device set.  The state-machine, checkpoint,
and re-planning logic is identical either way and is what the tests
exercise.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.core import (
    AdaptiveReranker,
    Fabric,
    StragglerDetector,
    make_cost_model,
    optimize_mesh_assignment,
    probe_fabric,
)
from repro.fabric import probe as probe_mod
from repro.core.reorder import MeshPlan

__all__ = ["NodeFailure", "ClusterView", "TrainerConfig", "Trainer"]


class NodeFailure(RuntimeError):
    def __init__(self, nodes: List[int]):
        super().__init__(f"nodes failed: {nodes}")
        self.nodes = nodes


@dataclasses.dataclass
class ClusterView:
    """The trainer's model of the fleet: fabric + current rank order.

    ``session`` (a :class:`repro.session.Session`) makes the view a
    Session consumer: :meth:`solve_plan` attaches the survivor fabric to
    the session and adopts the compiled plan's mesh assignment (cached
    under the fabric fingerprint, so elastic restarts on an unchanged
    fabric skip the solve), and the trainer's drift observations flow
    through :meth:`Session.observe` instead of a hand-wired reranker.
    """

    fabric: Fabric
    mesh_shape: tuple
    axis_names: tuple
    plan: Optional[MeshPlan] = None
    alive: Optional[List[int]] = None
    payload_bytes: float = 4e6
    session: Optional[Any] = None          # repro.session.Session

    def __post_init__(self):
        if self.alive is None:
            self.alive = list(range(self.fabric.n))

    #: nodes actually occupying mesh slots (== alive unless the mesh is
    #: smaller than the survivor set after an elastic shrink)
    active: Optional[List[int]] = None

    def cost_matrix(self, nodes: Optional[List[int]] = None) -> np.ndarray:
        probed = probe_fabric(self.fabric.subset(nodes or self.alive))
        return probe_mod.cost_matrix(probed, self.payload_bytes)

    def solve_plan(self) -> MeshPlan:
        """Select + order nodes for the mesh (both are cloud-aware).

        When more nodes survive than the (power-of-two) mesh needs, keep
        the most *central* ones — lowest total cost to the rest — before
        solving the rank order.  Node selection is the zeroth-order form
        of the paper's locality exploitation.
        """
        need = int(np.prod(self.mesh_shape))
        c_all = None
        sel = None
        if len(self.alive) > need:
            c_all = self.cost_matrix()
            order = np.argsort(c_all.sum(axis=1))
            sel = sorted(int(i) for i in order[:need])
            self.active = [self.alive[i] for i in sel]
        else:
            self.active = list(self.alive)
        if self.session is not None:
            # Session consumer path: attach the survivor fabric, let the
            # planning service compile/cache the full plan, adopt its
            # N-D mesh assignment (same id space: subset-local indices).
            # The session probes the attached fabric itself, so the full
            # c_all probe above only runs when node selection needs it.
            if self.session.config.payload_bytes != self.payload_bytes:
                # one payload knob: drift observations are fed at the
                # cluster payload and must match the session reference
                self.session.config = self.session.config.replace(
                    payload_bytes=self.payload_bytes)
            self.session.attach(fabric=self.fabric.subset(self.active))
            compiled = self.session.plan(
                mesh_shape=self.mesh_shape, axis_names=self.axis_names)
            self.plan = compiled.mesh_plan
        else:
            if c_all is None:
                c = self.cost_matrix()
            else:
                c = c_all[np.ix_(sel, sel)]
            self.plan = optimize_mesh_assignment(
                c, self.mesh_shape, self.axis_names)
        return self.plan

    def fail(self, nodes: List[int]) -> None:
        self.alive = [n for n in self.alive if n not in nodes]

    def shrink_mesh(self) -> tuple:
        """Largest mesh of the same arity fitting the surviving nodes.

        Shrinks the outermost data-parallel axis first (stepwise halving)
        — the standard elastic-DP policy.
        """
        shape = list(self.mesh_shape)
        while int(np.prod(shape)) > len(self.alive):
            # halve the largest shrinkable axis (prefer axis 0 = pod/data)
            for i in range(len(shape)):
                if shape[i] > 1 and shape[i] % 2 == 0:
                    shape[i] //= 2
                    break
            else:
                raise RuntimeError("cannot shrink mesh further")
        self.mesh_shape = tuple(shape)
        return self.mesh_shape


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    rerank_threshold: float = 1.2
    max_restarts: int = 3
    #: grad-bucket payload for obs accounting (0 = one unbucketed
    #: all-reduce per step); use the planned PlanEntry.bucket_bytes
    bucket_bytes: float = 0.0


class Trainer:
    def __init__(
        self,
        step_fn: Callable,          # jitted (state, batch) -> (state, metrics)
        state: Any,
        batches: Iterator[Dict[str, Any]],
        cfg: TrainerConfig,
        cluster: Optional[ClusterView] = None,
        failure_injector: Optional[Callable[[int], Optional[List[int]]]] = None,
        rebuild: Optional[Callable[["Trainer"], None]] = None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.batches = batches
        self.cfg = cfg
        self.cluster = cluster
        self.failure_injector = failure_injector
        self.rebuild = rebuild
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir)
        self.history: List[Dict[str, float]] = []
        self.restarts = 0
        self._cached_param_bytes: Optional[float] = None
        #: per-bucket all-reduce payloads, computed once per (re)mesh
        self._cached_bucket_bytes: Optional[List[float]] = None
        self.rerank_events: List[int] = []
        if cluster is not None:
            if cluster.session is not None:
                # one sensitivity knob: the trainer's threshold governs
                # the session's drift monitor too
                cluster.session.set_drift_threshold(cfg.rerank_threshold)
            if cluster.plan is None:
                cluster.solve_plan()
            self._init_adaptation()
        else:
            self.straggler = None
            self.reranker = None

    def _init_adaptation(self) -> None:
        """(Re)build straggler detector + reranker over the ACTIVE nodes."""
        active = self.cluster.active or self.cluster.alive
        self.straggler = StragglerDetector(len(active))
        self.reranker = AdaptiveReranker(
            model_factory=lambda cm: make_cost_model("ring", cm, 0.0),
            perm=np.asarray(self.cluster.plan.flat),
            threshold=self.cfg.rerank_threshold,
        )

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        step = int(self.state.step)
        while step < self.cfg.total_steps:
            try:
                step = self._run_until_failure(step)
            except NodeFailure as failure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self._elastic_restart(failure)
                step = int(self.state.step)
        self.ckpt.wait()
        return {
            "final_step": step,
            "restarts": self.restarts,
            "rerank_events": self.rerank_events,
            "history": self.history,
        }

    # ------------------------------------------------------------------
    def _run_until_failure(self, step: int) -> int:
        while step < self.cfg.total_steps:
            if self.failure_injector is not None:
                failed = self.failure_injector(step)
                if failed:
                    raise NodeFailure(failed)
            batch = next(self.batches)
            timer = obs.tracer().timer("train.step", step=step + 1)
            with timer:
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = timer.elapsed
            step += 1
            obs.metrics().counter("train.steps").inc()
            # the data-parallel gradient all-reduce is the step's one
            # fleet-wide collective; record it at bucket granularity so
            # the captured workload prices what the overlap path issues
            rec = obs.recorder()
            for payload in self._bucket_bytes():
                rec.record("all-reduce", payload)
            self._observe_step(step, dt, metrics)
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                self.ckpt.save(step, self.state)
        return step

    def _param_bytes(self) -> float:
        """Total parameter bytes (the per-step all-reduce payload)."""
        if self._cached_param_bytes is None:
            params = getattr(self.state, "params", None)
            self._cached_param_bytes = float(sum(
                x.size * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(params)
                if hasattr(x, "size") and hasattr(x, "dtype")))
        return self._cached_param_bytes

    def _bucket_bytes(self) -> List[float]:
        """Per-bucket all-reduce payloads (one entry when unbucketed).

        Cached alongside ``_param_bytes`` and likewise invalidated on
        elastic restart — bucket boundaries only move when the params
        (or ``cfg.bucket_bytes``) do.
        """
        if self._cached_bucket_bytes is None:
            if self.cfg.bucket_bytes > 0:
                from .overlap_grads import partition_tree

                params = getattr(self.state, "params", None)
                buckets = partition_tree(params, self.cfg.bucket_bytes)
                self._cached_bucket_bytes = [float(b.n_bytes)
                                             for b in buckets]
                obs.metrics().gauge("train.overlap.buckets").set(
                    len(buckets))
            else:
                self._cached_bucket_bytes = [self._param_bytes()]
        return self._cached_bucket_bytes

    def _observe_step(self, step: int, dt: float, metrics: Dict) -> None:
        if step % self.cfg.log_every == 0 or step <= 2:
            self.history.append(
                {"step": step, "loss": float(metrics["loss"]), "sec": dt})
        if self.straggler is not None:
            # On a real fleet this is per-host step time collected via
            # heartbeats; simulated here by observing node 0.
            self.straggler.observe(0, dt)
            if self.cluster is not None and step % 10 == 0:
                active = self.cluster.active or self.cluster.alive
                c = self.straggler.inflate(self.cluster.cost_matrix(active))
                if self.cluster.session is not None \
                        and self.cluster.session.planned is not None:
                    # a preset cluster.plan means the session never
                    # compiled: fall to the reranker branch below
                    report = self.cluster.session.observe(c)
                    changed = report.stale
                    replanned = self.cluster.session.planned
                    if changed and replanned is not None \
                            and replanned.mesh_plan is not None:
                        self.cluster.plan = replanned.mesh_plan
                else:
                    _, changed = self.reranker.update(c)
                if changed:
                    self.rerank_events.append(step)

    # ------------------------------------------------------------------
    def _elastic_restart(self, failure: NodeFailure) -> None:
        """Drop dead nodes, re-plan the mesh (paper §VI), restore, go on."""
        assert self.cluster is not None, "elastic restart needs a ClusterView"
        self.cluster.fail(failure.nodes)
        self.cluster.shrink_mesh()
        self.cluster.solve_plan()           # re-probe + re-solve rank order
        if self.rebuild is not None:
            self.rebuild(self)              # caller re-jits step_fn / data
        # restore from the last durable checkpoint
        self.ckpt.wait()
        step = latest_step(self.cfg.ckpt_dir)
        if step is not None:
            template = jax.tree.map(np.asarray, self.state)
            restored, _, _ = restore(self.cfg.ckpt_dir, template, step)
            self.state = jax.tree.map(jax.numpy.asarray, restored)
        # the rebuilt step may carry differently-shaped params (elastic
        # remesh): recompute payloads on next use instead of reporting
        # the dead mesh's numbers
        self._cached_param_bytes = None
        self._cached_bucket_bytes = None
        self._init_adaptation()
