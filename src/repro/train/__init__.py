from .overlap_grads import (  # noqa: F401
    GradBucket,
    OverlapGradReducer,
    certified_allreduce,
    jit_overlap_train_step,
    make_overlap_train_step,
    partition_tree,
    reducer_from_plan,
)
from .train_step import (  # noqa: F401
    TrainState,
    batch_pspecs,
    init_state,
    jit_train_step,
    make_train_step,
    state_pspecs,
)
from .trainer import ClusterView, NodeFailure, Trainer, TrainerConfig  # noqa: F401
