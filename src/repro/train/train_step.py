"""Train-step factory: loss -> grads -> AdamW, with sharding plumbing.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
``in_shardings/out_shardings`` derived from :mod:`repro.parallel.sharding`
(params TP specs; optimizer moments additionally ZeRO-1 sharded over the
DP axes; batch over DP axes).  The same function is what the multi-pod
dry-run lowers for every (arch x train shape) cell.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.optim import AdamWConfig, OptState, apply_opt, init_opt
from repro.parallel import sharding as shd

__all__ = ["TrainState", "make_train_step", "state_pspecs", "batch_pspecs",
           "init_state"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jnp.ndarray


def init_state(model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(
    model, opt_cfg: AdamWConfig
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        new_params, new_opt, metrics = apply_opt(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def state_pspecs(state_shapes: TrainState, cfg: ModelConfig, mesh: Mesh
                 ) -> TrainState:
    """PartitionSpecs for a TrainState: TP params + ZeRO-1 moments."""
    pspecs = shd.param_pspecs(state_shapes.params, cfg, mesh)

    def z1(spec, leaf):
        return shd.zero1_spec(spec, tuple(leaf.shape), mesh)

    m_specs = jax.tree.map(z1, pspecs, state_shapes.params)
    return TrainState(
        params=pspecs,
        opt=OptState(m=m_specs, v=m_specs, count=P()),
        step=P(),
    )


def batch_pspecs(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, P]:
    bs = shd.batch_spec(mesh)

    def spec(leaf):
        return P(*bs, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch)


def jit_train_step(model, opt_cfg, cfg: ModelConfig, mesh: Mesh,
                   state_shapes: TrainState, batch_shapes: Dict[str, Any],
                   donate: bool = True, overlap: str = "off",
                   reducer: Any = None, axis: str = "data"):
    """jit with explicit shardings (ready to .lower() for the dry-run).

    ``overlap`` selects the gradient all-reduce path:

    * ``"off"`` (default) — the baseline below, preserved bit-for-bit:
      grads reduce through the compiler-inserted psum of the sharded
      ``value_and_grad``;
    * ``"bucketed"`` / ``"fused"`` — the certified bucketed overlap
      path (:mod:`repro.train.overlap_grads`): pass a ``reducer``
      (see :func:`~repro.train.overlap_grads.reducer_from_plan` or
      ``Session.overlap_step``) whose mode decides the interleave
      granularity; ``axis`` names the 1-D data-parallel mesh axis.
    """
    if overlap != "off":
        from .overlap_grads import OVERLAP_MODES, jit_overlap_train_step
        if overlap not in OVERLAP_MODES:
            raise ValueError(
                f"overlap must be 'off' or one of {OVERLAP_MODES}, "
                f"got {overlap!r}")
        if reducer is None:
            raise ValueError(
                "overlap != 'off' needs a reducer (Session.overlap_step "
                "or overlap_grads.reducer_from_plan)")
        if reducer.mode != overlap:
            reducer = type(reducer)(
                reducer.mesh, reducer.axis, reducer.schedule,
                bucket_bytes=reducer.bucket_bytes, mode=overlap,
                use_pallas_add=reducer.use_pallas_add,
                interpret=reducer.interpret)
        return jit_overlap_train_step(model, opt_cfg, mesh, axis, reducer,
                                      donate=donate)
    step_fn = make_train_step(model, opt_cfg)
    s_specs = state_pspecs(state_shapes, cfg, mesh)
    b_specs = batch_pspecs(batch_shapes, mesh)
    to_ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    metrics_ns = None  # replicated
    return jax.jit(
        step_fn,
        in_shardings=(to_ns(s_specs), to_ns(b_specs)),
        out_shardings=(to_ns(s_specs), metrics_ns),
        donate_argnums=(0,) if donate else (),
    )
