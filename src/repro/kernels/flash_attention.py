"""Flash attention Pallas TPU kernel (causal / full / sliding-window, GQA).

TPU adaptation notes (DESIGN.md §6): the GPU flash algorithm maps to TPU
as a *sequential* accumulation over the K grid dimension — TPU grids
execute minor-most-first in order on each core, so the online-softmax
running stats (m, l, acc) live in VMEM scratch across K iterations
instead of GPU shared memory within one block.  BlockSpecs tile
``[block_q, head_dim]`` / ``[block_k, head_dim]`` windows into VMEM and
the per-tile ``q @ k^T`` / ``p @ v`` contractions are MXU-shaped
(block sizes default to 128 = MXU width).

GQA is handled in the index maps: the K/V BlockSpecs map query head ``h``
to kv head ``h // group_size`` — no head-replication in HBM.

Validated in interpret mode against :mod:`repro.kernels.ref` over a
shape/dtype sweep (tests/test_kernels_flash.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scr, l_scr, acc_scr,
    *, sm_scale: float, causal: bool, window: int,
    block_q: int, block_k: int, n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)           # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)           # [bk, hd]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale  # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    rel = q_pos - k_pos
    if causal or window:
        mask = rel >= 0 if causal else jnp.ones_like(rel, dtype=jnp.bool_)
        if window:
            mask = jnp.logical_and(mask, rel < window)
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # [bq]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)         # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jnp.ndarray,            # [B, H, S, hd]
    k: jnp.ndarray,            # [B, KV, S, hd]
    v: jnp.ndarray,            # [B, KV, S, hd]
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, S, hd = q.shape
    KV = k.shape[1]
    assert H % KV == 0, (H, KV)
    group = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q = S // block_q
    n_k = S // block_k

    grid = (B, H, n_q, n_k)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max m
            pltpu.VMEM((block_q,), jnp.float32),       # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
