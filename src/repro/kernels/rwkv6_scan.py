"""RWKV6 WKV chunk-scan Pallas TPU kernel.

The WKV recurrence is sequential in time; running it token-by-token from
HBM is memory-bound (state [K, V] re-read per token).  TPU adaptation:
process the sequence in VMEM-resident **chunks** — the grid iterates
(batch*head, n_chunks); the chunk dimension is TPU-sequential so the
running state [K, V] persists in VMEM scratch across chunk iterations,
touching HBM once per chunk instead of once per token.  Within a chunk a
``fori_loop`` applies the exact per-token update (data-dependent decay
prevents a pure matmul form without approximation; the intra-chunk
matmul variant used by production RWKV kernels is noted as follow-up in
EXPERIMENTS.md §Perf).

Validated in interpret mode against :func:`repro.kernels.ref.wkv_chunk_ref`.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_scan"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0].astype(jnp.float32)                  # [K]

    def body(t, state):
        r_t = r_ref[0, t].astype(jnp.float32)         # [K]
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)         # [V]
        w_t = w_ref[0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]              # [K, V]
        y = jnp.sum((state + u[:, None] * kv) * r_t[:, None], axis=0)
        o_ref[0, t] = y.astype(o_ref.dtype)
        return w_t[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, body, state_scr[...])
    state_scr[...] = state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_scan(
    r: jnp.ndarray,   # [B, S, H, K]
    k: jnp.ndarray,
    v: jnp.ndarray,   # [B, S, H, V]
    w: jnp.ndarray,
    u: jnp.ndarray,   # [H, K]
    chunk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns y [B, S, H, V] (fresh zero initial state)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    # layout: fold (B, H) into one grid dim; time-major inside
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, x.shape[-1])

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)

    grid = (B * H, n_chunks)
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, K), lambda bh, c: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, V), v.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, V).transpose(0, 2, 1, 3)
