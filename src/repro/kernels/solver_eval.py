"""Jitted batched solver evaluators (optional ``backend="jax"`` path).

The numpy SA engine in ``repro.core.solver`` evaluates a [P, N] batch of
ring permutations with one gather; at very large chain counts (hundreds
of chains, N >= 1024) XLA fuses the gather + reduction and keeps the cost
matrix resident on the accelerator, so a ``jax.jit`` evaluator wins.
``solve_sa(..., backend="jax")`` routes its full evaluations here; the
O(K) delta path stays in numpy (the arrays are tiny and dispatch would
dominate).

The module is import-gated: constructing an evaluator raises only if jax
is genuinely unavailable, so the numpy default never pays the import.

Precision note: jax defaults to float32 (x64 is not enabled anywhere in
this repo), so costs computed here carry ~1e-7 relative rounding vs the
float64 numpy path.  That can flip Metropolis decisions on near-tied
orderings mid-run; it never affects the *reported* solver cost, which
``solve_sa`` recomputes exactly in float64 at the end.  Use the default
numpy backend when bit-stable trajectories matter.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

__all__ = ["make_ring_evaluator", "ring_cost_batch"]

_JIT_CACHE: dict = {}


def _get_jitted():
    fn = _JIT_CACHE.get("ring")
    if fn is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def _ring_cost(c, perms):
            # cost = sum_i c[perm[i], perm[i-1]] — one gather per batch
            return jnp.sum(c[perms, jnp.roll(perms, 1, axis=1)], axis=1)

        fn = _JIT_CACHE["ring"] = _ring_cost
    return fn


def ring_cost_batch(cmat: np.ndarray, perms: np.ndarray) -> np.ndarray:
    """Ring tour costs for a [P, N] permutation batch via jax.jit."""
    import jax.numpy as jnp

    perms = np.asarray(perms)
    if perms.ndim == 1:
        perms = perms[None, :]
    out = _get_jitted()(jnp.asarray(cmat), jnp.asarray(perms))
    return np.asarray(out, dtype=np.float64)


def make_ring_evaluator(cmat: np.ndarray) -> Callable[[np.ndarray], np.ndarray]:
    """Bind ``cmat`` once; returns ``perms -> [P] costs``.

    The matrix is transferred to the default device a single time so the
    per-iteration call ships only the small permutation batch.
    """
    import jax.numpy as jnp

    dev_c = jnp.asarray(cmat)
    fn = _get_jitted()

    def evaluate(perms: np.ndarray) -> np.ndarray:
        perms = np.asarray(perms)
        if perms.ndim == 1:
            perms = perms[None, :]
        return np.asarray(fn(dev_c, jnp.asarray(perms)), dtype=np.float64)

    return evaluate
