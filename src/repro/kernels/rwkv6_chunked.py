"""RWKV6 WKV kernel, chunked MATMUL form (the MXU fast path).

The token-loop kernel (:mod:`rwkv6_scan`) is VPU-bound: per token it does
rank-1 state updates.  This kernel restates the recurrence per chunk of T
tokens as three matmuls (the standard chunked linear-attention identity,
extended with RWKV6's data-dependent per-channel decay):

with A_t = prod_{s<=t} w_s (cumulative decay within the chunk),
r~_t = r_t * A_{t-1}, k~_s = k_s / A_s:

    y_t   = r~_t @ S_0  +  sum_{s<t} (r~_t . k~_s) v_s  +  (r_t.(u*k_t)) v_t
    S_T   = diag(A_T) @ (S_0 + k~^T V)      # next chunk's initial state

i.e. Y = R~ S_0 + ((R~ K~^T) * M_strict) V + rowscale(R.(u*K)) V — all
MXU-shaped [T,K]x[K,V] / [T,K]x[K,T] contractions instead of T rank-1
updates.

Numerics: k~ = k / A_s grows like w_min^-T within a chunk; the products
consumed downstream are bounded (A_{t-1}/A_s <= 1 for s <= t-1), so only
the intermediate k~ must stay in f32 range: with the default T=16 this is
safe for per-channel decays w >= 1e-2 (k~ <= 1e32 < f32 max); the wrapper
asserts the chunk bound.  Validated against :func:`ref.wkv_chunk_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_chunked_matmul"]


def _wkv_chunk_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr,
                      *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)         # [T, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)         # [T, V]
    w = w_ref[0].astype(jnp.float32)         # [T, K] decays in (0, 1)
    u = u_ref[0].astype(jnp.float32)         # [K]
    S0 = state_scr[...]                      # [K, V]

    log_w = jnp.log(w)
    la = jnp.cumsum(log_w, axis=0)           # log A_t
    A = jnp.exp(la)                          # [T, K]
    A_prev = jnp.exp(la - log_w)             # A_{t-1} (A_0 = 1)
    r_t = r * A_prev                         # r~
    k_t = k * jnp.exp(-la)                   # k~

    T = r.shape[0]
    inter = jax.lax.dot_general(
        r_t, S0, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [T, V]
    qk = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [T, T]
    row = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    qk = jnp.where(row > col, qk, 0.0)                       # strict lower
    intra = jax.lax.dot_general(
        qk, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [T, V]
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    o_ref[0] = (inter + intra + bonus).astype(o_ref.dtype)

    A_T = A[-1]                                              # [K]
    kv = jax.lax.dot_general(
        k_t * A_T[None, :], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # [K, V]
    state_scr[...] = A_T[:, None] * S0 + kv


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked_matmul(
    r: jnp.ndarray,   # [B, S, H, K]
    k: jnp.ndarray,
    v: jnp.ndarray,   # [B, S, H, V]
    w: jnp.ndarray,   # [B, S, H, K], decays in (0, 1)
    u: jnp.ndarray,   # [H, K]
    chunk: int = 16,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    assert chunk <= 32, "k~ range bound: keep chunks short (see docstring)"
    n_chunks = S // chunk

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, x.shape[-1])

    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)
    uf = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)

    out = pl.pallas_call(
        functools.partial(_wkv_chunk_kernel, chunk=chunk),
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, K), lambda bh, c: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, V), v.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    return out.reshape(B, H, S, V).transpose(0, 2, 1, 3)
