from .ops import (  # noqa: F401
    attention_op,
    fused_add,
    on_tpu,
    ring_all_reduce,
    ring_reduce_scatter,
    wkv_chunked_op,
    wkv_op,
)
from .solver_eval import make_ring_evaluator, ring_cost_batch  # noqa: F401
