"""Rank-reordered ring reduce-scatter / all-reduce — the paper's object.

Two implementations:

* :func:`ring_reduce_scatter` — ``shard_map`` ring: N-1 steps of
  ``ppermute`` (neighbor order = **the solved rank permutation**) with the
  local accumulation fused by a small Pallas add kernel
  (:func:`_fused_add`).  This is the portable path: it runs (and is
  tested) on CPU in interpret mode and on TPU as-is.  The ``perm``
  argument is where Cloud-Collectives plugs in: the neighbor list is the
  ring order produced by :mod:`repro.core.solver`.

* :func:`remote_ring_reduce_scatter_tpu` — all-Pallas RDMA version using
  ``pltpu.make_async_remote_copy`` between neighbor devices, following the
  JAX distributed-Pallas recipe (double-buffered, semaphore-synchronized).
  TPU-only: Mosaic remote DMAs do not exist on the CPU backend, so this
  path is exercised only on real hardware; its semantics oracle is
  :func:`repro.kernels.ref.ring_reduce_scatter_ref` like the portable one.

Note the equivalence: XLA's own reduce-scatter follows mesh-axis order,
so on the *reordered mesh* the plain ``jax.lax.psum_scatter`` already
benefits from the paper's technique; these kernels exist to (a) prove the
schedule explicitly and (b) fuse the accumulation.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["fused_add", "ring_reduce_scatter", "ring_all_reduce",
           "remote_ring_reduce_scatter_tpu"]


# ---------------------------------------------------------------------------
# local fused accumulate (Pallas)
# ---------------------------------------------------------------------------

def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = (a_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_add(a: jnp.ndarray, b: jnp.ndarray, block: int = 1024,
              interpret: bool = False) -> jnp.ndarray:
    """Tiled elementwise accumulate — the ring step's reduction op."""
    assert a.shape == b.shape
    flat = a.reshape(-1)
    n = flat.shape[0]
    block = min(block, n)
    pad = (-n) % block
    af = jnp.pad(flat, (0, pad))
    bf = jnp.pad(b.reshape(-1), (0, pad))
    out = pl.pallas_call(
        _add_kernel,
        grid=(af.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(af.shape, a.dtype),
        interpret=interpret,
    )(af, bf)
    return out[:n].reshape(a.shape)


# ---------------------------------------------------------------------------
# portable ring (shard_map + ppermute), neighbor order = solved perm
# ---------------------------------------------------------------------------

def _ring_links(perm: Sequence[int]) -> list:
    """ppermute links following the solved ring order: perm[i] -> perm[i+1].

    This closed form equals ``JaxExecutor().lower(ring_program).links``
    for a ring Program permuted by ``perm`` (pinned by
    ``tests/test_collective_ir.py``); the direct computation is kept
    because kernels re-derive links per trace and compiling a full
    O(n^2) Program for n neighbor pairs would dominate trace time at
    large n.
    """
    n = len(perm)
    return [(int(perm[i]), int(perm[(i + 1) % n])) for i in range(n)]


def ring_reduce_scatter(
    x: jnp.ndarray,
    mesh: Mesh,
    axis: str,
    perm: Optional[Sequence[int]] = None,
    use_pallas_add: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Reduce-scatter over ``axis`` with an explicit reordered ring.

    ``x``: [n, L] (L % n == 0), dim 0 sharded over ``axis`` — row d is
    device d's full local contribution.  Returns [n, L//n] sharded the
    same way: row d is the fully-reduced chunk d.

    Schedule (ring-position space; position i = pos_of[device]):
    at step s, position i forwards the partial sum of chunk
    ``perm[(i - s - 1) mod n]`` to position i+1, receives the partial of
    ``perm[(i - s - 2) mod n]`` and adds its own contribution.  After
    n-1 steps position i holds exactly chunk ``perm[i]`` = its own device
    id — i.e. reduce-scatter output lands in device-id order regardless
    of the ring order used for transport.
    """
    n = mesh.shape[axis]
    L = x.shape[1]
    assert x.shape[0] == n and L % n == 0, (x.shape, n)
    if perm is None:
        perm = list(range(n))
    links = _ring_links(perm)
    pos_of = np.zeros(n, dtype=np.int64)
    for i, d in enumerate(perm):
        pos_of[d] = i
    pos_arr = jnp.asarray(pos_of)
    perm_arr = jnp.asarray(np.asarray(perm, dtype=np.int64))

    def per_device(xs):
        chunks = xs[0].reshape(n, L // n)            # my n chunk contributions
        me = jax.lax.axis_index(axis)
        i = pos_arr[me]
        buf = jnp.take(chunks, perm_arr[(i - 1) % n], axis=0)

        def body(s, buf):
            received = jax.lax.ppermute(buf, axis, links)
            idx = perm_arr[(i - s - 2) % n]
            mine = jnp.take(chunks, idx, axis=0)
            if use_pallas_add:
                return fused_add(received, mine, interpret=interpret)
            return received + mine

        buf = jax.lax.fori_loop(0, n - 1, body, buf)
        return buf[None]

    f = jax.shard_map(
        per_device, mesh=mesh,
        in_specs=(P(axis),), out_specs=P(axis), check_vma=False)
    return f(x)


def ring_all_reduce(x, mesh, axis, perm=None, **kw):
    """reduce-scatter + all-gather (bandwidth-optimal ring all-reduce).

    Returns [n, L]: every row holds the full reduced vector.
    """
    n = mesh.shape[axis]
    rs = ring_reduce_scatter(x, mesh, axis, perm=perm, **kw)

    def ag(c):
        # chunks arrive in device-id order (see ring_reduce_scatter)
        return jax.lax.all_gather(c[0], axis).reshape(1, -1)

    return jax.shard_map(ag, mesh=mesh, in_specs=(P(axis),),
                         out_specs=P(axis), check_vma=False)(rs)


# ---------------------------------------------------------------------------
# TPU-only RDMA ring (make_async_remote_copy) — production fast path
# ---------------------------------------------------------------------------

def _rdma_ring_kernel(chunk_ref, out_ref, comm_buf, send_sem, recv_sem,
                      *, n: int, links):
    """One reduce-scatter pass: N-1 rounds of neighbor RDMA + accumulate.

    Follows the jax.dev distributed-Pallas recipe: double-buffered
    ``comm_buf`` (slot alternation), remote copy to the ring successor,
    semaphore wait, accumulate into ``out_ref``.
    """
    my_id = jax.lax.axis_index("x")
    out_ref[...] = chunk_ref[...]

    def round_body(s, _):
        slot = s % 2
        rdma = pltpu.make_async_remote_copy(
            src_ref=out_ref,
            dst_ref=comm_buf.at[1 - slot],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=(my_id + 1) % n,
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()
        rdma.wait()
        out_ref[...] = out_ref[...] + comm_buf[1 - slot]
        return ()

    jax.lax.fori_loop(0, n - 1, round_body, ())


def remote_ring_reduce_scatter_tpu(x: jnp.ndarray, mesh: Mesh, axis: str,
                                   perm: Optional[Sequence[int]] = None):
    """All-Pallas RDMA ring reduce-scatter.  TPU only (Mosaic remote DMA);
    semantics oracle: ref.ring_reduce_scatter_ref.  The reordered ring is
    realized by constructing ``mesh`` from the solved device permutation —
    the kernel always talks to its mesh neighbor, which *is* the paper's
    insertion point (neighbor identity comes from the mesh order)."""
    if jax.default_backend() != "tpu":  # pragma: no cover
        raise NotImplementedError("remote DMA ring requires a TPU backend")
    n = mesh.shape[axis]

    def per_device(chunk):
        return pl.pallas_call(
            functools.partial(_rdma_ring_kernel, n=n, links=None),
            out_shape=jax.ShapeDtypeStruct(chunk.shape[1:], chunk.dtype),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
            scratch_shapes=[
                pltpu.VMEM((2,) + tuple(chunk.shape[1:]), chunk.dtype),
                pltpu.SemaphoreType.DMA,
                pltpu.SemaphoreType.DMA,
            ],
        )(chunk[0])[None]

    f = jax.shard_map(per_device, mesh=mesh, in_specs=(P(axis),),
                      out_specs=P(axis), check_vma=False)
    return f(x)
