"""Execute a certified :class:`LoweredSchedule` on a jax mesh.

The generalized counterpart of :mod:`repro.kernels.ring_collective`:
instead of a hand-derived ring, this runner interprets the per-round
``PermuteStep``\\ s the :class:`~repro.collective.JaxExecutor` lowering
produced — so *any* registered algorithm (trees, halving-doubling,
bcube, recursive-doubling...) runs on real devices through
``jax.lax.ppermute``, with the reduce accumulation fused by the same
Pallas :func:`~repro.kernels.ring_collective.fused_add` kernel.

Execution semantics mirror the translation validator exactly
(:mod:`repro.analysis.equiv`):

* the mesh-axis index IS the schedule's position space; device p holds
  logical rank ``schedule.rank_of[p]``'s buffer;
* rounds are barriers: every step's payload is gathered from the
  round-entry buffer, all receives are staged, and applied together at
  the round boundary;
* a link ``(s, d)`` fires iff ``send_mask[s] and recv_mask[d]``;
* ``reduce`` accumulates into the destination chunk row, ``copy``
  overwrites it;
* ``chunk_factor`` k pipelines the body serially over k payload
  slices.

The local buffer is ``[n_chunks + 1, chunk_len]`` per device — row
``n_chunks`` is a zero scratch row that absorbs the gather/scatter of
non-participating positions, keeping every step a static dense
``ppermute`` (no per-device control flow, so the whole schedule jits
to one XLA program).

This module never certifies anything itself: callers obtain schedules
through ``Session.lower`` / ``JaxExecutor.lower`` where
:func:`repro.analysis.equiv.require_certified` has already proven the
artifact, which is the whole point of translation validation — the
runner can be this trusting *because* the schedule carries a proof.
"""

from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.collective.executors import LoweredSchedule

from .ring_collective import fused_add

__all__ = ["run_schedule", "check_postcondition", "schedule_tables"]


def _shard_map(f, mesh: Mesh, in_specs, out_specs):
    """shard_map across jax versions: the top-level ``jax.shard_map``
    (``check_vma``) when present, else the 0.4.x
    ``jax.experimental.shard_map`` (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _step_tables(step, n: int, n_chunks: int):
    """Static gather/scatter tables of one PermuteStep.

    Returns ``(eff_links, SEND, RECV)``: the mask-filtered ppermute
    link list and ``[n, m]`` int32 chunk-row tables (pad entries point
    at the zero scratch row ``n_chunks``).
    """
    m = max((len(c) for c in step.chunks), default=0)
    m = max(m, 1)
    send = np.full((n, m), n_chunks, dtype=np.int32)
    recv = np.full((n, m), n_chunks, dtype=np.int32)
    eff_links: List[Tuple[int, int]] = []
    for (s, d), chunks in zip(step.links, step.chunks):
        if not (step.send_mask[s] and step.recv_mask[d]):
            continue
        eff_links.append((int(s), int(d)))
        send[s, :len(chunks)] = chunks
        recv[d, :len(chunks)] = chunks
    return eff_links, send, recv


@functools.lru_cache(maxsize=256)
def schedule_tables(schedule: LoweredSchedule):
    """Static per-round ``(eff_links, SEND, RECV)`` tables + op tags.

    Schedules are hot-path constants: a train step re-runs the same
    certified artifact every call, so the tables are memoised on the
    schedule *value* (frozen dataclasses hash by content — two lowerings
    of the same program share one entry).  Returns
    ``(tables, ops)`` where ``tables[r][s]`` is :func:`_step_tables` of
    round ``r``'s step ``s`` and ``ops[r][s]`` its reduce/copy tag.
    The cached arrays are read-only by convention — every consumer
    gathers from them without mutation.
    """
    tables = tuple(
        tuple(_step_tables(step, schedule.n, schedule.n_chunks)
              for step in rnd)
        for rnd in schedule.rounds)
    ops = tuple(tuple(step.op for step in rnd) for rnd in schedule.rounds)
    return tables, ops


def _initial_buffers(schedule: LoweredSchedule,
                     x: np.ndarray) -> Tuple[np.ndarray, int]:
    """Rank-space ``[n, n_chunks + 1, chunk_len]`` buffers from inputs.

    ``x`` is rank-major: row r is logical rank r's contribution, shaped
    by the schedule's declared init (``replicated``: the full local
    vector; ``sharded``: rank r's own chunk; ``addressed``: the n
    outgoing pieces).
    """
    n, n_chunks = schedule.n, schedule.n_chunks
    x = np.asarray(x)
    assert x.ndim == 2 and x.shape[0] == n, x.shape
    if schedule.init == "replicated":
        assert x.shape[1] % n_chunks == 0, (x.shape, n_chunks)
        chunk_len = x.shape[1] // n_chunks
        buf = np.zeros((n, n_chunks + 1, chunk_len), dtype=x.dtype)
        buf[:, :n_chunks] = x.reshape(n, n_chunks, chunk_len)
    elif schedule.init == "sharded":
        chunk_len = x.shape[1]
        buf = np.zeros((n, n_chunks + 1, chunk_len), dtype=x.dtype)
        for r in range(n):
            buf[r, r] = x[r]
    elif schedule.init == "addressed":
        assert n_chunks == n * n and x.shape[1] % n == 0, (x.shape, n_chunks)
        chunk_len = x.shape[1] // n
        buf = np.zeros((n, n_chunks + 1, chunk_len), dtype=x.dtype)
        for s in range(n):
            buf[s, s * n:(s + 1) * n] = x[s].reshape(n, chunk_len)
    else:
        raise ValueError(f"unknown init {schedule.init!r}")
    return buf, chunk_len


def run_schedule(
    x,
    mesh: Mesh,
    axis: str,
    schedule: LoweredSchedule,
    use_pallas_add: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Run ``schedule`` over ``mesh[axis]``; returns final rank buffers.

    ``x``: ``[n, D]`` rank-major inputs (see :func:`_initial_buffers`
    for D per init).  Returns ``[n, n_chunks, chunk_len]`` rank-major —
    row r is logical rank r's final chunk buffer, against which the
    declared postcondition can be checked
    (:func:`check_postcondition`).
    """
    n = schedule.n
    assert mesh.shape[axis] == n, (mesh.shape, n)
    buf0, chunk_len = _initial_buffers(schedule, x)
    k = schedule.chunk_factor
    if chunk_len % k:
        raise ValueError(
            f"chunk_len {chunk_len} not divisible by chunk_factor {k}")
    piece_len = chunk_len // k

    # device at axis position p plays logical rank rank_of[p]
    rank_of = np.asarray(schedule.rank_of, dtype=np.int64)
    buf_pos = buf0[rank_of]                       # position-major

    # static per-step tables, resolved once per schedule (memoised —
    # repeated calls on the same certified artifact skip the rebuild)
    tables, ops = schedule_tables(schedule)
    cols0 = np.arange(piece_len)

    def per_device(rows):
        buf = rows[0]                              # [n_chunks+1, chunk_len]
        me = jax.lax.axis_index(axis)
        for piece in range(k):
            cols = jnp.asarray(cols0 + piece * piece_len)
            for rnd_tables, rnd_ops in zip(tables, ops):
                entry = buf                        # round-entry snapshot
                staged = []
                for eff_links, send, recv in rnd_tables:
                    if not eff_links:
                        staged.append(None)
                        continue
                    my_send = jnp.asarray(send)[me]          # [m]
                    payload = entry[my_send[:, None], cols[None, :]]
                    staged.append(
                        jax.lax.ppermute(payload, axis, eff_links))
                for (eff_links, send, recv), op, received in zip(
                        rnd_tables, rnd_ops, staged):
                    if received is None:
                        continue
                    my_recv = jnp.asarray(recv)[me]          # [m]
                    rows_idx = my_recv[:, None]
                    if op == "reduce":
                        tgt = buf[rows_idx, cols[None, :]]
                        if use_pallas_add:
                            new = fused_add(tgt, received,
                                            interpret=interpret)
                        else:
                            new = tgt + received
                    else:
                        new = received
                    buf = buf.at[rows_idx, cols[None, :]].set(new)
                    # the scratch row absorbed non-receiving positions'
                    # zero payloads; re-zero it so later gathers stay 0
                    buf = buf.at[schedule.n_chunks].set(
                        jnp.zeros_like(buf[schedule.n_chunks]))
        return buf[None]

    f = _shard_map(per_device, mesh, (P(axis),), P(axis))
    out_pos = f(jnp.asarray(buf_pos))
    # back to rank space, scratch row dropped
    order = np.asarray(schedule.order, dtype=np.int64)
    return jnp.asarray(out_pos)[order][:, :schedule.n_chunks]


def check_postcondition(schedule: LoweredSchedule, x,
                        out, atol: float = 1e-5) -> List[str]:
    """Numerically verify ``out`` satisfies the declared postcondition.

    ``x``/``out`` as in :func:`run_schedule`.  Returns human-readable
    mismatch descriptions (empty list = postcondition holds) — the
    end-to-end complement of the symbolic bisimulation proof.
    """
    n, n_chunks = schedule.n, schedule.n_chunks
    x = np.asarray(x, dtype=np.float64)
    out = np.asarray(out, dtype=np.float64)
    post = schedule.postcondition
    bad: List[str] = []

    def close(a, b) -> bool:
        return bool(np.allclose(a, b, atol=atol, rtol=1e-5))

    if post in ("allreduce", "reduce"):
        want = x.sum(axis=0).reshape(n_chunks, -1)   # replicated init
        if post == "allreduce":
            for r in range(n):
                if not close(out[r], want):
                    bad.append(f"rank {r}: allreduce result diverges")
        else:
            if not any(close(out[r], want) for r in range(n)):
                bad.append("no rank holds the fully-reduced vector")
    elif post == "reduce_scatter":
        want = x.sum(axis=0).reshape(n_chunks, -1)
        for r in range(n):
            if not close(out[r, r], want[r]):
                bad.append(f"rank {r}: chunk {r} not fully reduced")
    elif post == "all_gather":
        for r in range(n):
            for c in range(n_chunks):
                if not close(out[r, c], x[c]):
                    bad.append(f"rank {r}: chunk {c} not gathered")
    elif post == "all_to_all":
        piece = x.reshape(n, n, -1)                  # [src, dst, len]
        for s in range(n):
            for d in range(n):
                if not close(out[d, s * n + d], piece[s, d]):
                    bad.append(f"piece {s}→{d} undelivered")
    elif post != "none":
        bad.append(f"unknown postcondition {post!r}")
    return bad
