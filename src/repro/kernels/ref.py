"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "wkv_chunk_ref", "ring_reduce_scatter_ref"]


def attention_ref(
    q: jnp.ndarray,            # [B, H, S, hd]
    k: jnp.ndarray,            # [B, KV, S, hd]
    v: jnp.ndarray,            # [B, KV, S, hd]
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    qh = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qh, k.astype(jnp.float32)) * sm_scale
    pos = jnp.arange(S)
    rel = pos[:, None] - pos[None, :]
    mask = rel >= 0 if causal else jnp.ones_like(rel, dtype=bool)
    if window:
        mask = mask & (rel < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, S, hd).astype(q.dtype)


def wkv_chunk_ref(
    r: jnp.ndarray,   # [B, S, H, K]
    k: jnp.ndarray,   # [B, S, H, K]
    v: jnp.ndarray,   # [B, S, H, V]
    w: jnp.ndarray,   # [B, S, H, K]  decay in (0,1)
    u: jnp.ndarray,   # [H, K]
    state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token WKV recurrence (identical to models.rwkv6)."""
    from repro.models.rwkv6 import wkv_recurrence

    return wkv_recurrence(r, k, v, w, u, state)


def ring_reduce_scatter_ref(x: jnp.ndarray, n_shards: int, axis: int = 0
                            ) -> jnp.ndarray:
    """Reduce-scatter semantics oracle: sum over shards, split along axis.

    x: [n_shards, ...] stacked per-device contributions; returns the
    stacked per-device results [n_shards, chunk, ...].
    """
    total = jnp.sum(x, axis=0)                       # the all-reduced value
    chunks = jnp.split(total, n_shards, axis=axis)
    return jnp.stack(chunks)
