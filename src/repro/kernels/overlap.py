"""Compute–communication overlap for certified collective schedules.

:mod:`repro.kernels.schedule_runner` executes a certified
:class:`~repro.collective.executors.LoweredSchedule` standalone; this
module fuses one into a surrounding step.  The schedule becomes a
round-pipelined state machine:

* **issue** — gather each step's payload from round-entry state and put
  it on the wire (``jax.lax.ppermute``);
* **apply** — land the staged receives at the round barrier (``reduce``
  accumulates through the Pallas
  :func:`~repro.kernels.ring_collective.fused_add` kernel, ``copy``
  overwrites);
* **overlap** — between issue and apply, run resident compute shards
  and the *next* transfer.  ``chunk_factor`` pieces of one round are
  column-disjoint slices of the chunk buffers, so piece ``p + 1``'s
  transfer is issued while piece ``p``'s reduce and the resident
  compute run — the generalized form of the hand-overlapped ring in
  :mod:`repro.kernels.ring_collective`.

The interleaving is explicit: an :class:`OverlapPlan` lists, per
``(round, piece)`` slot, which caller-supplied compute shards (Pallas
matmul / flash-attention thunks, optimizer sub-steps...) run while that
slot's transfer is in flight.  In the traced program the shards have no
data dependency on the staged transfer, which is exactly the freedom
the XLA scheduler needs to hide the collective-permute.

Certification boundary: schedules are certified *before* fusion
(``Session.lower`` / ``require_certified``), and fusion never edits a
round — partial execution goes through
:meth:`LoweredSchedule.slice_rounds`, which only windows the certified
round sequence.  Interleaving therefore cannot change what the
collective computes: :func:`run_overlapped` is element-for-element the
same reduction order as :func:`~repro.kernels.schedule_runner.run_schedule`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.collective.executors import LoweredSchedule

from .ring_collective import fused_add
from .schedule_runner import _shard_map, schedule_tables

__all__ = [
    "OverlapSlot",
    "OverlapPlan",
    "build_overlap_plan",
    "run_overlapped",
    "seed_state",
    "finish_state",
]


@dataclasses.dataclass(frozen=True)
class OverlapSlot:
    """One pipeline slot: a ``(round, piece)`` transfer + resident compute.

    ``round_index`` indexes the (possibly sliced) schedule's rounds; a
    negative value marks a drain slot that only runs compute.
    ``compute`` holds indices into the caller's compute-shard list —
    those shards run while this slot's transfer is in flight.
    """

    round_index: int
    piece: int
    compute: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    """Explicit interleaving of schedule rounds with compute shards.

    Slots are executed in order; every ``(round, piece)`` of the
    schedule appears exactly once, rounds grouped and ascending (round
    barriers are data dependencies — pieces of one round commute, rounds
    do not).  The plan never rewrites the schedule: it only decides
    *when*, relative to the certified rounds, each compute shard runs.
    """

    schedule: LoweredSchedule
    n_compute: int
    slots: Tuple[OverlapSlot, ...]

    def validate(self) -> None:
        k = max(1, self.schedule.chunk_factor)
        want = [(r, p) for r in range(len(self.schedule.rounds))
                for p in range(k)]
        got = [(s.round_index, s.piece) for s in self.slots
               if s.round_index >= 0]
        if sorted(got) != want:
            raise ValueError(
                f"plan must cover every (round, piece) exactly once: "
                f"want {len(want)} slots, got {sorted(got)!r}")
        rounds_seen = [r for r, _ in got]
        if rounds_seen != sorted(rounds_seen):
            raise ValueError("slots must keep rounds in ascending order")
        cids = [c for s in self.slots for c in s.compute]
        if len(set(cids)) != len(cids) or any(
                not (0 <= c < self.n_compute) for c in cids):
            raise ValueError(
                f"compute ids must each appear once and lie in "
                f"[0, {self.n_compute}): got {cids!r}")


def build_overlap_plan(schedule: LoweredSchedule,
                       n_compute: int = 0) -> OverlapPlan:
    """Default plan: compute shards spread evenly over the slot grid.

    Slots run round-major (pieces of a round adjacent, so the
    double-buffered issue of piece ``p + 1`` overlaps piece ``p``'s
    apply).  Leftover compute — or all of it, for a round-less
    schedule — lands in a trailing drain slot.
    """
    k = max(1, schedule.chunk_factor)
    grid = [(r, p) for r in range(len(schedule.rounds)) for p in range(k)]
    if not grid:
        slots = ((OverlapSlot(-1, 0, tuple(range(n_compute))),)
                 if n_compute else ())
        return OverlapPlan(schedule, n_compute, slots)
    splits = np.array_split(np.arange(n_compute), len(grid))
    slots = tuple(
        OverlapSlot(r, p, tuple(int(c) for c in cids))
        for (r, p), cids in zip(grid, splits))
    return OverlapPlan(schedule, n_compute, slots)


def seed_state(schedule: LoweredSchedule, x) -> jnp.ndarray:
    """Position-major ``[n, n_chunks + 1, chunk_len]`` state from inputs.

    The traceable (jnp) counterpart of the runner's initial-buffer
    construction: ``x`` is rank-major per the schedule's declared init,
    and row ``n_chunks`` is the zero scratch row that absorbs
    non-participating positions.
    """
    n, n_chunks = schedule.n, schedule.n_chunks
    x = jnp.asarray(x)
    if x.ndim != 2 or x.shape[0] != n:
        raise ValueError(f"want [n={n}, D] rank-major inputs, got {x.shape}")
    if schedule.init == "replicated":
        if x.shape[1] % n_chunks:
            raise ValueError(f"D={x.shape[1]} not divisible by "
                             f"n_chunks={n_chunks}")
        chunk_len = x.shape[1] // n_chunks
        body = x.reshape(n, n_chunks, chunk_len)
    elif schedule.init == "sharded":
        chunk_len = x.shape[1]
        body = jnp.zeros((n, n_chunks, chunk_len), x.dtype)
        for r in range(n):
            body = body.at[r, r].set(x[r])
    elif schedule.init == "addressed":
        if n_chunks != n * n or x.shape[1] % n:
            raise ValueError(f"addressed init wants n_chunks=n^2 and "
                             f"D divisible by n, got {x.shape}")
        chunk_len = x.shape[1] // n
        body = jnp.zeros((n, n_chunks, chunk_len), x.dtype)
        for s in range(n):
            body = body.at[s, s * n:(s + 1) * n].set(
                x[s].reshape(n, chunk_len))
    else:
        raise ValueError(f"unknown init {schedule.init!r}")
    buf = jnp.concatenate(
        [body, jnp.zeros((n, 1, chunk_len), x.dtype)], axis=1)
    rank_of = np.asarray(schedule.rank_of, dtype=np.int64)
    return buf[rank_of]


def finish_state(schedule: LoweredSchedule, state) -> jnp.ndarray:
    """Back to rank space, scratch row dropped (run_schedule's output)."""
    order = np.asarray(schedule.order, dtype=np.int64)
    return jnp.asarray(state)[order][:, :schedule.n_chunks]


def _make_issue(mesh: Mesh, axis: str, rnd_tables, cols: np.ndarray):
    """shard_map'd transfer of one (round, piece): gather + ppermute.

    Returns ``None`` when the round has no effective links.  Output is
    one staged ``[n, m, piece_len]`` array per effective step — a value
    with no dependency on anything but round-entry state, so resident
    compute traced between issue and apply is free to overlap it.
    """
    live = [(eff, send) for eff, send, _ in rnd_tables if eff]
    if not live:
        return None

    def per_device(rows):
        buf = rows[0]
        me = jax.lax.axis_index(axis)
        c = jnp.asarray(cols)
        outs = []
        for eff_links, send in live:
            my_send = jnp.asarray(send)[me]               # [m]
            payload = buf[my_send[:, None], c[None, :]]
            outs.append(jax.lax.ppermute(payload, axis, eff_links)[None])
        return tuple(outs)

    return _shard_map(per_device, mesh, (P(axis),),
                      tuple(P(axis) for _ in live))


def _make_apply(mesh: Mesh, axis: str, rnd_tables, rnd_ops,
                cols: np.ndarray, n_chunks: int,
                use_pallas_add: bool, interpret: bool):
    """shard_map'd round barrier: land staged receives, re-zero scratch."""
    live = [((eff, recv), op)
            for (eff, _, recv), op in zip(rnd_tables, rnd_ops) if eff]
    if not live:
        return None

    def per_device(rows, *staged):
        buf = rows[0]
        me = jax.lax.axis_index(axis)
        c = jnp.asarray(cols)
        for ((eff_links, recv), op), rx in zip(live, staged):
            received = rx[0]                              # [m, piece_len]
            my_recv = jnp.asarray(recv)[me]               # [m]
            rows_idx = my_recv[:, None]
            if op == "reduce":
                tgt = buf[rows_idx, c[None, :]]
                if use_pallas_add:
                    new = fused_add(tgt, received, interpret=interpret)
                else:
                    new = tgt + received
            else:
                new = received
            buf = buf.at[rows_idx, c[None, :]].set(new)
            # non-receiving positions landed in the scratch row; re-zero
            # it so every later gather still reads zeros
            buf = buf.at[n_chunks].set(jnp.zeros_like(buf[n_chunks]))
        return buf[None]

    in_specs = (P(axis),) + tuple(P(axis) for _ in live)
    return _shard_map(per_device, mesh, in_specs, P(axis))


def run_overlapped(
    x,
    mesh: Mesh,
    axis: str,
    plan: Union[OverlapPlan, LoweredSchedule],
    compute: Sequence[Callable[[], Any]] = (),
    *,
    use_pallas_add: bool = True,
    interpret: bool = True,
    state: Optional[jnp.ndarray] = None,
    rounds: Optional[Tuple[int, Optional[int]]] = None,
    return_state: bool = False,
) -> Tuple[jnp.ndarray, List[Any]]:
    """Execute ``plan`` with compute shards fused into the round pipeline.

    ``plan`` is an :class:`OverlapPlan` or a bare certified
    :class:`LoweredSchedule` (a default plan is built over it).  With a
    bare schedule, ``rounds=(start, stop)`` executes only that window
    (via :meth:`LoweredSchedule.slice_rounds`); pass ``state`` to resume
    mid-stream and ``return_state=True`` to keep pipelining later.

    Returns ``(out, results)``: ``out`` matches
    :func:`~repro.kernels.schedule_runner.run_schedule` element for
    element (or the raw position-major state when ``return_state``),
    and ``results[i]`` is compute shard ``i``'s value.
    """
    if isinstance(plan, LoweredSchedule):
        schedule = plan if rounds is None else plan.slice_rounds(*rounds)
        plan = build_overlap_plan(schedule, len(compute))
    else:
        if rounds is not None:
            raise ValueError("pass rounds= only with a bare schedule; "
                             "an OverlapPlan already fixes its window")
        schedule = plan.schedule
        if plan.n_compute != len(compute):
            raise ValueError(f"plan expects {plan.n_compute} compute "
                             f"shards, got {len(compute)}")
    plan.validate()

    n, n_chunks = schedule.n, schedule.n_chunks
    if mesh.shape[axis] != n:
        raise ValueError(f"mesh axis {axis!r} has {mesh.shape[axis]} "
                         f"devices, schedule wants {n}")
    if state is None:
        state = seed_state(schedule, x)
    state = jnp.asarray(state)
    chunk_len = state.shape[-1]
    k = max(1, schedule.chunk_factor)
    if chunk_len % k:
        raise ValueError(
            f"chunk_len {chunk_len} not divisible by chunk_factor {k}")
    piece_len = chunk_len // k

    tables, ops = schedule_tables(schedule)
    piece_cols = [np.arange(piece_len) + p * piece_len for p in range(k)]

    def stage_fns(slot):
        if slot.round_index < 0:
            return None, None
        cols = piece_cols[slot.piece]
        issue = _make_issue(mesh, axis, tables[slot.round_index], cols)
        apply_ = _make_apply(mesh, axis, tables[slot.round_index],
                             ops[slot.round_index], cols, n_chunks,
                             use_pallas_add, interpret)
        return issue, apply_

    results: List[Any] = [None] * len(compute)
    slots = plan.slots
    staged_next: Any = None
    fns = [stage_fns(s) for s in slots]
    if slots and fns[0][0] is not None:
        staged_next = fns[0][0](state)
    for i, slot in enumerate(slots):
        staged, staged_next = staged_next, None
        issue_next, same_round = None, False
        if i + 1 < len(slots):
            issue_next = fns[i + 1][0]
            same_round = slots[i + 1].round_index == slot.round_index
        # double buffer: the next piece of this round reads the same
        # round-entry columns, so its transfer goes on the wire before
        # this slot's reduce lands
        if issue_next is not None and same_round:
            staged_next = issue_next(state)
        # resident compute — traced with no dependency on the transfer
        for cid in slot.compute:
            results[cid] = compute[cid]()
        apply_ = fns[i][1]
        if apply_ is not None:
            state = apply_(state, *staged)
        if issue_next is not None and not same_round:
            staged_next = issue_next(state)

    if return_state:
        return state, results
    return finish_state(schedule, state), results
