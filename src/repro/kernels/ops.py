"""Jit'd public wrappers for the Pallas kernels.

``attention_op`` / ``wkv_op`` auto-select interpret mode off-TPU so the
same call sites work in tests (CPU, interpret=True) and production
(TPU, compiled Mosaic).  The model configs choose the implementation via
``attention_impl`` ('xla' | 'flash').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ring_collective import fused_add, ring_all_reduce, ring_reduce_scatter
from .rwkv6_chunked import wkv_chunked_matmul
from .rwkv6_scan import wkv_scan

__all__ = ["attention_op", "wkv_op", "wkv_chunked_op", "fused_add",
           "ring_reduce_scatter", "ring_all_reduce", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def wkv_chunked_op(r, k, v, w, u, chunk=16):
    """MXU matmul-form WKV (auto interpret fallback off-TPU)."""
    return wkv_chunked_matmul(r, k, v, w, u, chunk=chunk,
                              interpret=not on_tpu())


def attention_op(q, k, v, causal=True, window=0, block_q=128, block_k=128):
    """Flash attention with automatic interpret fallback off-TPU."""
    return flash_attention(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=not on_tpu())


def wkv_op(r, k, v, w, u, chunk=64):
    return wkv_scan(r, k, v, w, u, chunk=chunk, interpret=not on_tpu())
