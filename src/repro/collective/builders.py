"""Algorithm builders: ``CollectiveOp`` → typed ``Program``.

Each seed algorithm from :data:`repro.core.schedule.SCHEDULES` is a
registered :class:`AlgorithmBuilder` that compiles a
:class:`~repro.collective.ir.CollectiveOp` into a
:class:`~repro.collective.ir.Program` in identity rank order — the rank
permutation is applied afterwards by the
:func:`repro.collective.passes.apply_permutation` rewrite pass, so no
builder threads ``perm`` through its schedule construction.

The emitted per-round ``(src, dst, size)`` structure matches the legacy
free builders in :mod:`repro.core.schedule` flow-for-flow (the
cross-backend equivalence suite pins this), while additionally carrying
reduce/copy semantics and chunk ids that let
:func:`repro.collective.ir.validate` prove each program's
postcondition.

Registry contract: :func:`get_builder` raises an actionable
``ValueError`` naming every registered builder on unknown names (no
bare ``KeyError``), and :func:`candidates` reproduces the plan
compiler's feasibility gating (power-of-two algorithms only on
power-of-two groups; bcube prefers base 4 when the group is a power of
4).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.schedule import _require_power_of_base, _require_power_of_two

from .ir import KINDS, CollectiveOp, FlowInstr, Program, kind_from_op

__all__ = [
    "AlgorithmBuilder",
    "register_builder",
    "get_builder",
    "registered_builders",
    "candidates",
    "compile_op",
]

Round = Tuple[FlowInstr, ...]


@dataclasses.dataclass(frozen=True)
class AlgorithmBuilder:
    """One registered collective algorithm.

    ``build(op, **kwargs)`` returns the identity-order :class:`Program`;
    ``feasible(n)`` gates group sizes (mirrors the ValueError contracts
    of the legacy builders); ``candidate_kwargs(n)`` enumerates the
    kwargs variants the plan compiler should consider (e.g. the bcube
    base).
    """

    name: str
    kinds: Tuple[str, ...]              # CollectiveOp kinds it compiles
    cost_model: str                     # analytic CostModel name
    build_fn: Callable[..., Tuple]      # (op, **kw) -> round/semantic data
    #: n=1 is a legal degenerate group (single-device meshes plan empty
    #: programs), matching the legacy builders' behavior
    feasible_fn: Callable[[int], bool] = lambda n: n >= 1
    kwargs_fn: Callable[[int], List[Dict[str, int]]] = lambda n: [{}]

    def feasible(self, n: int) -> bool:
        return bool(self.feasible_fn(n))

    def candidate_kwargs(self, n: int) -> List[Dict[str, int]]:
        return self.kwargs_fn(n)

    def build(self, op: CollectiveOp, **kwargs) -> Program:
        if op.kind not in self.kinds:
            raise ValueError(
                f"builder {self.name!r} compiles {self.kinds}, "
                f"not {op.kind!r}")
        rounds, n_chunks, chunk_bytes, init, post = self.build_fn(
            op, **kwargs)
        return Program(
            op=op,
            algorithm=self.name,
            algo_kwargs=tuple(sorted((k, int(v)) for k, v in kwargs.items())),
            rounds=tuple(tuple(r) for r in rounds),
            perm=op.group,                       # identity rank order
            n_chunks=n_chunks,
            chunk_bytes=chunk_bytes,
            init=init,
            postcondition=post,
            cost_model=self.cost_model,
        )


_REGISTRY: Dict[str, AlgorithmBuilder] = {}


def register_builder(builder: AlgorithmBuilder) -> AlgorithmBuilder:
    """Register (or replace) a builder under ``builder.name``."""
    _REGISTRY[builder.name] = builder
    return builder


def registered_builders() -> Tuple[str, ...]:
    """Registered builder names, in registration order."""
    return tuple(_REGISTRY)


def get_builder(name: str) -> AlgorithmBuilder:
    """Builder by name; unknown names raise an actionable ValueError."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown collective algorithm {name!r}; registered builders: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def candidates(kind: str, n: int) -> List[Tuple[str, Dict[str, int]]]:
    """Feasible ``(builder name, kwargs)`` pairs for ``kind`` at size n.

    Accepts either an IR kind (``allreduce``) or a plan-compiler op
    string (``all-reduce``).
    """
    if kind not in KINDS:
        kind = kind_from_op(kind)
    out: List[Tuple[str, Dict[str, int]]] = []
    for name, b in _REGISTRY.items():
        if kind in b.kinds and b.feasible(n):
            out.extend((name, kw) for kw in b.candidate_kwargs(n))
    return out


def compile_op(op: CollectiveOp, algorithm: str, **kwargs) -> Program:
    """Compile ``op`` with the named registered builder."""
    return get_builder(algorithm).build(op, **kwargs)


# ---------------------------------------------------------------------------
# schedule constructions (identity rank space, chunk-annotated)
# ---------------------------------------------------------------------------

def _is_pow2(n: int) -> bool:
    return n >= 2 and n & (n - 1) == 0


def _is_pow(n: int, base: int) -> bool:
    m = 1
    while m < n:
        m *= base
    return m == n and n >= base


def _ring_chunked_allreduce(op: CollectiveOp):
    """Bandwidth-optimal ring: RS lap then AG lap, n chunks of S/n.

    RS step s: rank i forwards partial chunk (i - s) mod n; AG step s:
    rank i forwards complete chunk (i + 1 - s) mod n.  Same 2(n-1)
    rounds of n S/n flows as the legacy ``ring_allreduce_chunked``.
    """
    n = op.n
    cb = op.size_bytes / n
    rounds: List[Round] = []
    for s in range(n - 1):                       # reduce-scatter lap
        rounds.append(tuple(
            FlowInstr(i, (i + 1) % n, cb, "reduce", ((i - s) % n,))
            for i in range(n)))
    for s in range(n - 1):                       # all-gather lap
        rounds.append(tuple(
            FlowInstr(i, (i + 1) % n, cb, "copy", ((i + 1 - s) % n,))
            for i in range(n)))
    return rounds, n, cb, "replicated", "allreduce"


def _ring_sequential_allreduce(op: CollectiveOp):
    """Naive ring: the full buffer walks 0→n-1 twice, one hop per round.

    This is the paper's C_r = Σ c_{i,i-1}(S) *regime model*: the second
    lap re-walks the same hop sequence (as the legacy builder does)
    carrying the circulating partial sums — both laps are ``reduce``
    flows, which keeps the contributor-set semantics monotone — so the
    provable postcondition is a rooted ``reduce`` (rank n-1 holds the
    full result), not a full allreduce.
    """
    n = op.n
    rounds: List[Round] = []
    for _lap in range(2):
        for r in range(n - 1):
            rounds.append(
                (FlowInstr(r, r + 1, op.size_bytes, "reduce", (0,)),))
    return rounds, 1, op.size_bytes, "replicated", "reduce"


def _hd_chunks(j: int, bit: int, n: int, toward: int) -> Tuple[int, ...]:
    """Chunk ids rank j exchanges at ``bit``: low bits match j, bit
    ``bit`` equals ``toward``'s, higher bits free."""
    low_mask = (1 << bit) - 1
    out = []
    for c in range(n):
        if (c & low_mask) == (j & low_mask) and \
                ((c >> bit) & 1) == ((toward >> bit) & 1):
            out.append(c)
    return tuple(out)


def _halving_doubling_allreduce(op: CollectiveOp):
    """Recursive vector-halving distance-doubling RS + mirrored AG."""
    n = op.n
    _require_power_of_two(n, "halving_doubling")
    log_n = int(np.log2(n))
    cb = op.size_bytes / n
    rounds: List[Round] = []
    for i in range(log_n):                       # reduce-scatter
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            sent = _hd_chunks(j, i, n, partner)
            flows.append(FlowInstr(j, partner, cb * len(sent), "reduce", sent))
        rounds.append(tuple(flows))
    for i in reversed(range(log_n)):             # all-gather mirror
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            # j's complete chunks agree with j on bits 0..i
            mask = (1 << (i + 1)) - 1
            sent = tuple(c for c in range(n) if (c & mask) == (j & mask))
            flows.append(FlowInstr(j, partner, cb * len(sent), "copy", sent))
        rounds.append(tuple(flows))
    return rounds, n, cb, "replicated", "allreduce"


def _balanced_tree_edges(n: int) -> List[Tuple[int, int, int]]:
    """(parent, child, depth) of the balanced tree over [0, n-1]."""
    out: List[Tuple[int, int, int]] = []

    def rec(lo: int, hi: int, depth: int) -> int:
        mid = (lo + hi) // 2
        if lo <= mid - 1:
            c = rec(lo, mid - 1, depth + 1)
            out.append((mid, c, depth))
        if mid + 1 <= hi:
            c = rec(mid + 1, hi, depth + 1)
            out.append((mid, c, depth))
        return mid

    rec(0, n - 1, 0)
    return out


def _double_binary_tree_allreduce(op: CollectiveOp):
    """Two complementary trees, each reducing+broadcasting one S/2 chunk."""
    n = op.n
    half = op.size_bytes / 2.0
    edges = _balanced_tree_edges(n)
    max_depth = max((d for _, _, d in edges), default=0)
    trees = [
        [((p - shift) % n, (c - shift) % n, d) for p, c, d in edges]
        for shift in (0, 1)
    ]
    rounds: List[Round] = []
    for d in range(max_depth, -1, -1):           # reduce: deepest first
        flows = [FlowInstr(c, p, half, "reduce", (t,))
                 for t, tree in enumerate(trees)
                 for p, c, dd in tree if dd == d]
        if flows:
            rounds.append(tuple(flows))
    for d in range(0, max_depth + 1):            # broadcast: root out
        flows = [FlowInstr(p, c, half, "copy", (t,))
                 for t, tree in enumerate(trees)
                 for p, c, dd in tree if dd == d]
        if flows:
            rounds.append(tuple(flows))
    return rounds, 2, half, "replicated", "allreduce"


def _bcube_allreduce(op: CollectiveOp, base: int = 4):
    """BCube digit rounds: k = log_b(n) rounds of (b-1)-peer exchanges.

    Like the legacy builder (and Gloo's cost model here), this is the
    recursive reduce-scatter phase — after round k-1 every rank holds
    its own S/n chunk fully reduced — so the provable postcondition is
    ``reduce_scatter``.
    """
    n = op.n
    n_rounds = _require_power_of_base(n, base, "bcube")
    cb = op.size_bytes / n
    rounds: List[Round] = []
    for i in range(n_rounds):
        stride = base ** i
        flows = []
        for j in range(n):
            digit = (j // stride) % base
            for k in range(1, base):
                p = j + (((digit + k) % base) - digit) * stride
                # chunks: digits 0..i-1 match j, digit i matches peer p
                sent = tuple(
                    c for c in range(n)
                    if all((c // base ** d) % base == (j // base ** d) % base
                           for d in range(i))
                    and (c // stride) % base == (p // stride) % base)
                flows.append(FlowInstr(j, p, cb * len(sent), "reduce", sent))
        rounds.append(tuple(flows))
    return rounds, n, cb, "replicated", "reduce_scatter"


def _ring_gather_family(op: CollectiveOp):
    """One-lap chunked ring: AG forwards complete chunks; RS is the
    mirrored reduce lap (identical flow structure, so both price the
    same — the legacy compiler's convention)."""
    n = op.n
    cb = op.size_bytes / n
    rounds: List[Round] = []
    if op.kind == "reduce_scatter":
        for s in range(n - 1):
            rounds.append(tuple(
                FlowInstr(i, (i + 1) % n, cb, "reduce", ((i - s - 1) % n,))
                for i in range(n)))
        return rounds, n, cb, "replicated", "reduce_scatter"
    for s in range(n - 1):
        rounds.append(tuple(
            FlowInstr(i, (i + 1) % n, cb, "copy", ((i - s) % n,))
            for i in range(n)))
    return rounds, n, cb, "sharded", "all_gather"


def _recursive_doubling_family(op: CollectiveOp):
    """Recursive doubling AG (payload doubles) / recursive halving RS
    (payload halves): mirrored round orders, identical (pairs, size)
    multisets, so simulated cost matches the legacy AG schedule."""
    n = op.n
    _require_power_of_two(n, "recursive_doubling")
    log_n = int(np.log2(n))
    cb = op.size_bytes / n
    rounds: List[Round] = []
    if op.kind == "reduce_scatter":
        for r in range(log_n):
            bit = log_n - 1 - r
            flows = []
            for j in range(n):
                partner = j ^ (1 << bit)
                high_mask = ~((1 << (bit + 1)) - 1)
                sent = tuple(
                    c for c in range(n)
                    if (c & high_mask) == (j & high_mask)
                    and ((c >> bit) & 1) == ((partner >> bit) & 1))
                flows.append(
                    FlowInstr(j, partner, cb * len(sent), "reduce", sent))
            rounds.append(tuple(flows))
        return rounds, n, cb, "replicated", "reduce_scatter"
    for i in range(log_n):
        flows = []
        for j in range(n):
            partner = j ^ (1 << i)
            # j holds chunks agreeing with it on bits i..log-1
            mask = ~((1 << i) - 1)
            sent = tuple(c for c in range(n) if (c & mask) == (j & mask))
            flows.append(FlowInstr(j, partner, cb * len(sent), "copy", sent))
        rounds.append(tuple(flows))
    return rounds, n, cb, "sharded", "all_gather"


def _all_to_all(op: CollectiveOp):
    """Shift-scheduled all-to-all: round k sends piece (j → j+k)."""
    n = op.n
    cb = op.size_bytes / n
    rounds: List[Round] = []
    for k in range(1, n):
        rounds.append(tuple(
            FlowInstr(j, (j + k) % n, cb, "copy", (j * n + (j + k) % n,))
            for j in range(n)))
    return rounds, n * n, cb, "addressed", "all_to_all"


# ---------------------------------------------------------------------------
# registration (order = the plan compiler's candidate preference order)
# ---------------------------------------------------------------------------

register_builder(AlgorithmBuilder(
    name="ring", kinds=("allreduce",), cost_model="ring",
    build_fn=_ring_chunked_allreduce))
register_builder(AlgorithmBuilder(
    name="ring_sequential", kinds=("allreduce",), cost_model="ring",
    build_fn=_ring_sequential_allreduce))
register_builder(AlgorithmBuilder(
    name="double_binary_tree", kinds=("allreduce",),
    cost_model="double_binary_tree",
    build_fn=_double_binary_tree_allreduce))
register_builder(AlgorithmBuilder(
    name="halving_doubling", kinds=("allreduce",),
    cost_model="halving_doubling",
    build_fn=_halving_doubling_allreduce, feasible_fn=_is_pow2))
register_builder(AlgorithmBuilder(
    name="bcube", kinds=("allreduce",), cost_model="bcube",
    build_fn=_bcube_allreduce, feasible_fn=_is_pow2,
    kwargs_fn=lambda n: [{"base": 4 if _is_pow(n, 4) else 2}]))
register_builder(AlgorithmBuilder(
    name="ring_all_gather", kinds=("all_gather", "reduce_scatter"),
    cost_model="ring", build_fn=_ring_gather_family))
register_builder(AlgorithmBuilder(
    name="recursive_doubling", kinds=("all_gather", "reduce_scatter"),
    cost_model="halving_doubling",
    build_fn=_recursive_doubling_family, feasible_fn=_is_pow2))
register_builder(AlgorithmBuilder(
    name="all_to_all", kinds=("all_to_all",), cost_model="all_to_all",
    build_fn=_all_to_all))
