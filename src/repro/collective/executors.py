"""Pluggable executors for collective :class:`Program`\\ s.

The :class:`Executor` protocol decouples *what a collective does* (the
IR) from *how it is priced or run*:

* :class:`AnalyticExecutor` — wraps the closed-form cost-model math of
  :mod:`repro.core.cost_models` (each builder declares which analytic
  model describes it);
* :class:`SimExecutor` — wraps the contention-aware max-min-fair
  simulator (:func:`repro.core.simulator.simulate_rounds`), the
  offline "real cloud" oracle;
* :class:`JaxExecutor` — lowers ring / all-to-all programs to the
  static ``ppermute`` shift schedules the jax runtime consumes
  (:mod:`repro.parallel.moe_a2a`, :mod:`repro.kernels.ring_collective`)
  instead of each call site hand-rolling them.

``estimate`` returns seconds for one execution of the program
(pipelining included); ``lower`` returns a :class:`Lowered` artifact.
Executors raise ``NotImplementedError`` for the direction they don't
support, so a caller holding any ``Executor`` can feature-test.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.cost_models import CostModel, make_cost_model
from repro.core.simulator import simulate_rounds
from repro.fabric.topology import Fabric

from .ir import Program

__all__ = [
    "Executor",
    "Lowered",
    "PermuteStep",
    "LoweredSchedule",
    "AnalyticExecutor",
    "SimExecutor",
    "JaxExecutor",
]


@runtime_checkable
class Executor(Protocol):
    """Anything that can price and/or lower a collective Program."""

    name: str

    def estimate(self, program: Program) -> float:
        """Seconds for one execution of ``program``."""
        ...

    def lower(self, program: Program) -> "Lowered":
        """Backend artifact for ``program`` (shift schedule, links...)."""
        ...


@dataclasses.dataclass(frozen=True)
class PermuteStep:
    """One ``collective-permute`` call in axis-index (position) space.

    ``links`` is a *partial permutation*: every position appears at most
    once as a source and at most once as a destination, which is
    exactly the contract of ``jax.lax.ppermute`` / XLA
    ``collective-permute``.  ``chunks[k]`` are the logical chunk ids
    link ``k`` carries; ``op`` tags whether the receiver accumulates
    (``reduce``) or overwrites (``copy``).  ``send_mask`` /
    ``recv_mask`` are per-position participation bits — a transfer on
    link ``(s, d)`` executes only when ``send_mask[s] and
    recv_mask[d]`` (the translation validator honors exactly this
    semantics, so a mask bug is an observable lost transfer, not dead
    metadata).
    """

    links: Tuple[Tuple[int, int], ...]       # (src_pos, dst_pos) pairs
    op: str                                  # "reduce" | "copy"
    chunks: Tuple[Tuple[int, ...], ...]      # per-link chunk ids
    send_mask: Tuple[bool, ...]              # send_mask[pos]
    recv_mask: Tuple[bool, ...]              # recv_mask[pos]
    round_index: int                         # source Program round

    @property
    def n_transfers(self) -> int:
        return sum(len(c) for c in self.chunks)


@dataclasses.dataclass(frozen=True)
class LoweredSchedule:
    """The generalized lowering: per-round collective-permute steps.

    Any round-based :class:`~repro.collective.ir.Program` lowers to
    this form: each IR round (a barrier of concurrent flows) becomes a
    tuple of :class:`PermuteStep`\\ s — a deterministic decomposition of
    the round's flow multigraph into partial permutations, one per
    ``(op tag, matching)`` — executed against *round-entry* state (the
    runtime stages every step's receives and applies them at the round
    barrier, mirroring the IR's semantics; see
    ``repro.kernels.schedule_runner``).

    Everything speaks axis-index space: ``order[rank] = position`` is
    the program's ``local_perm`` (the solved placement), and step links
    pair positions, directly consumable by ``ppermute`` over the mesh
    axis.  ``source_fingerprint`` names the exact Program this was
    lowered from; :func:`repro.analysis.equiv.bisimulate` certifies the
    pair, and :meth:`fingerprint` identifies the artifact itself.

    Construction is reserved to ``collective/executors.py`` and
    ``repro.analysis`` (mutation screening) — the custom lint rule
    ``lowered-construction`` enforces it — so every schedule a runtime
    sees went through the one certified lowering path.
    """

    algorithm: str
    kind: str                                 # CollectiveOp kind
    n: int
    order: Tuple[int, ...]                    # order[rank] = position
    n_chunks: int
    chunk_bytes: float
    init: str                                 # one of ir.INITS
    postcondition: str                        # one of ir.POSTCONDITIONS
    rounds: Tuple[Tuple[PermuteStep, ...], ...]
    chunk_factor: int = 1
    source_fingerprint: str = ""

    @property
    def rank_of(self) -> Tuple[int, ...]:
        """Inverse of ``order``: rank_of[position] = logical rank."""
        inv = [0] * self.n
        for rank, pos in enumerate(self.order):
            inv[pos] = rank
        return tuple(inv)

    @property
    def n_steps(self) -> int:
        return sum(len(r) for r in self.rounds)

    @property
    def n_transfers(self) -> int:
        return sum(s.n_transfers for r in self.rounds for s in r)

    def slice_rounds(self, start: int = 0,
                     stop: Optional[int] = None) -> "LoweredSchedule":
        """Sub-schedule holding ``rounds[start:stop]``.

        The per-round execution window the overlap layer
        (:mod:`repro.kernels.overlap`) interleaves compute into.  Steps
        keep their original ``round_index`` for traceability, and
        ``source_fingerprint`` still names the full program.  A partial
        window carries ``postcondition="none"`` — only the complete
        round sequence satisfies the declared contract — and a window
        with ``start > 0`` is only meaningful against explicitly seeded
        mid-stream buffers (``init`` is kept for shape metadata only).
        Slicing never edits a round: the full-range slice is the
        schedule itself, so certification transfers.
        """
        stop = len(self.rounds) if stop is None else stop
        if not (0 <= start <= stop <= len(self.rounds)):
            raise ValueError(
                f"round window [{start}, {stop}) out of range for "
                f"{len(self.rounds)} rounds")
        if start == 0 and stop == len(self.rounds):
            return self
        return dataclasses.replace(self, rounds=self.rounds[start:stop],
                                   postcondition="none")

    def split_rounds(self) -> Tuple["LoweredSchedule", ...]:
        """One single-round sub-schedule per round, in order."""
        return tuple(self.slice_rounds(i, i + 1)
                     for i in range(len(self.rounds)))

    def fingerprint(self) -> str:
        """Stable content hash of the lowered artifact."""
        payload = {
            "algorithm": self.algorithm,
            "kind": self.kind,
            "order": list(self.order),
            "n_chunks": self.n_chunks,
            "chunk_bytes": float(self.chunk_bytes),
            "init": self.init,
            "post": self.postcondition,
            "chunk_factor": self.chunk_factor,
            "rounds": [
                [(list(map(list, s.links)), s.op,
                  [list(c) for c in s.chunks],
                  [int(b) for b in s.send_mask],
                  [int(b) for b in s.recv_mask])
                 for s in rnd]
                for rnd in self.rounds
            ],
        }
        blob = json.dumps(payload, separators=(",", ":"), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class Lowered:
    """A jax-lowerable schedule in *axis-index* (local position) space.

    ``order[pos] = shard`` is the ring order the program's permutation
    induces over the group; ``links`` are the ppermute neighbor pairs of
    that ring; ``shift_rounds`` are the per-round ``(src, dst)`` pairs
    (all-to-all programs only; each round is a bijection).  ``schedule``
    is the generalized per-round :class:`LoweredSchedule` — populated
    for *every* algorithm, including the ring/a2a special cases whose
    closed-form ``links``/``shift_rounds`` views are kept for the
    legacy runtime consumers.
    """

    kind: str                                    # "ring" | "shift_a2a" | "general"
    order: Tuple[int, ...]
    links: Tuple[Tuple[int, int], ...]
    shift_rounds: Tuple[Tuple[Tuple[int, int], ...], ...] = ()
    fingerprint: str = ""
    schedule: Optional[LoweredSchedule] = None


class AnalyticExecutor:
    """Prices programs with the paper's closed-form cost models.

    Construct with full-fabric node-indexed matrices: either one
    pairwise ``cost_matrix`` (paper mode — rounds rescale linearly) or
    ``lat``/``bw`` (alpha-beta mode).  Group extraction and the
    rank→local-index mapping happen here, so callers hand over programs
    whose ``perm`` speaks global node ids.
    """

    name = "analytic"

    def __init__(self, cost_matrix: Optional[np.ndarray] = None, *,
                 lat: Optional[np.ndarray] = None,
                 bw: Optional[np.ndarray] = None):
        if cost_matrix is None and lat is None:
            raise ValueError(
                "AnalyticExecutor needs a cost_matrix or lat (+ bw)")
        self.c = None if cost_matrix is None else np.asarray(
            cost_matrix, dtype=np.float64)
        self.lat = None if lat is None else np.asarray(lat, dtype=np.float64)
        self.bw = None if bw is None else np.asarray(bw, dtype=np.float64)
        self._models: Dict[tuple, CostModel] = {}

    def model_for(self, program: Program) -> CostModel:
        """The builder-declared CostModel at the program's piece size."""
        g = np.asarray(sorted(program.op.group), dtype=np.int64)
        size = program.op.size_bytes / program.chunk_factor
        kwargs = {k: v for k, v in program.kwargs.items() if k == "base"}
        key = (program.cost_model, tuple(g), float(size),
               tuple(sorted(kwargs.items())))
        model = self._models.get(key)
        if model is None:
            if self.c is not None:
                model = make_cost_model(
                    program.cost_model, cost_matrix=self.c[np.ix_(g, g)],
                    size_bytes=size, **kwargs)
            else:
                sub_bw = None if self.bw is None else self.bw[np.ix_(g, g)]
                if sub_bw is None:
                    model = make_cost_model(
                        program.cost_model,
                        cost_matrix=self.lat[np.ix_(g, g)],
                        size_bytes=size, **kwargs)
                else:
                    model = make_cost_model(
                        program.cost_model, size_bytes=size,
                        lat=self.lat[np.ix_(g, g)], bw=sub_bw, **kwargs)
            self._models[key] = model
        return model

    def estimate(self, program: Program) -> float:
        model = self.model_for(program)
        return program.chunk_factor * float(model.cost(program.local_perm))

    def lower(self, program: Program) -> Lowered:
        raise NotImplementedError(
            "AnalyticExecutor prices programs; use JaxExecutor to lower")


class SimExecutor:
    """Prices programs on the contention-aware flow-level simulator."""

    name = "sim"

    def __init__(self, fabric: Fabric, jitter: float = 0.0,
                 seed: Optional[int] = None):
        self.fabric = fabric
        self.jitter = jitter
        self.seed = seed

    def estimate(self, program: Program) -> float:
        if self.jitter == 0.0 and program.chunk_factor > 1:
            # deterministic pipelining: the k pieces are identical, so
            # simulate one and scale instead of re-water-filling k times
            return program.chunk_factor * simulate_rounds(
                self.fabric, program.piece_flows())
        rng = np.random.default_rng(self.seed) if self.seed is not None \
            else None
        return simulate_rounds(self.fabric, program.to_flows(),
                               rng=rng, jitter=self.jitter)

    def lower(self, program: Program) -> Lowered:
        raise NotImplementedError(
            "SimExecutor prices programs; use JaxExecutor to lower")


#: builder names with a closed-form legacy artifact, by shape.  These
#: keep their historical ``kind`` (and ``links``/``shift_rounds``
#: views) because :mod:`repro.parallel.moe_a2a` and
#: :mod:`repro.serve.engine` consume them; everything else lowers as
#: ``kind="general"`` through the same :class:`LoweredSchedule` path.
_RING_ALGOS = ("ring", "ring_sequential", "ring_all_gather")
_SHIFT_ALGOS = ("all_to_all",)


def _decompose_round(
    flows, lp: Tuple[int, ...], n: int, round_index: int,
) -> Tuple[PermuteStep, ...]:
    """Decompose one IR round into position-space partial permutations.

    Greedy and deterministic: flows are visited in program order and
    packed into the first open step with the same reduce/copy tag whose
    source and destination positions are both still free (the ppermute
    contract).  Builders with per-round fan-out > 1 (bcube's b-1 peer
    exchanges, the double binary tree's two-child reduces) therefore
    split into several sequential collective-permute calls; single-
    matching rounds (rings, hypercube exchanges) stay one step.  All
    steps of a round still read *round-entry* state — the runtime
    applies receives at the round barrier — so the decomposition never
    reorders a data dependency.
    """
    # each open step: (op, links, chunks, used_src, used_dst)
    open_steps: List[Tuple[str, List[Tuple[int, int]],
                           List[Tuple[int, ...]], set, set]] = []
    for f in flows:
        s, d = lp[f.src], lp[f.dst]
        for op, links, chunks, used_s, used_d in open_steps:
            if op == f.op and s not in used_s and d not in used_d:
                links.append((s, d))
                chunks.append(tuple(int(c) for c in f.chunks))
                used_s.add(s)
                used_d.add(d)
                break
        else:
            open_steps.append(
                (f.op, [(s, d)], [tuple(int(c) for c in f.chunks)],
                 {s}, {d}))
    steps = []
    for op, links, chunks, used_s, used_d in open_steps:
        steps.append(PermuteStep(
            links=tuple(links), op=op, chunks=tuple(chunks),
            send_mask=tuple(i in used_s for i in range(n)),
            recv_mask=tuple(i in used_d for i in range(n)),
            round_index=round_index))
    return tuple(steps)


class JaxExecutor:
    """Lowers round-based programs to static ppermute schedules.

    The artifact speaks *axis-index* space: position i within the
    (sorted) group.  ``order`` is the program's local permutation — the
    ring order the solved rank placement induces — and the schedules
    are derived from the program's rounds, so a runtime consuming a
    :class:`Lowered` executes exactly the flows the plan was priced on.

    Every registered algorithm lowers: rings and the shift all-to-all
    keep their closed-form ``links``/``shift_rounds`` views for the
    legacy consumers, and *all* programs additionally get the
    generalized per-round :class:`LoweredSchedule` that
    :func:`repro.analysis.equiv.bisimulate` certifies against the IR.
    """

    name = "jax"

    def can_lower(self, program: Program) -> bool:
        """Total for round-based programs: every flow round decomposes
        into partial permutations, so any structurally valid Program
        lowers (certification is equiv's job, not a shape test)."""
        return bool(program.rounds) or program.n == 1

    def lowerable_algorithms(self) -> Tuple[str, ...]:
        """Registered builder names this executor can lower (all)."""
        from .builders import registered_builders
        return registered_builders()

    def lower_schedule(self, program: Program) -> LoweredSchedule:
        """Generalized lowering: Program rounds → per-round ppermute
        steps.  Pure structure translation — no certification; callers
        that execute the result go through ``Session.lower`` /
        ``analysis.equiv`` for the bisimulation proof."""
        lp = tuple(int(i) for i in program.local_perm)
        n = program.n
        rounds = tuple(
            _decompose_round(rnd, lp, n, r_i)
            for r_i, rnd in enumerate(program.rounds))
        return LoweredSchedule(
            algorithm=program.algorithm,
            kind=program.op.kind,
            n=n,
            order=lp,
            n_chunks=program.n_chunks,
            chunk_bytes=float(program.chunk_bytes),
            init=program.init,
            postcondition=program.postcondition,
            rounds=rounds,
            chunk_factor=program.chunk_factor,
            source_fingerprint=program.fingerprint(),
        )

    def lower(self, program: Program) -> Lowered:
        from repro import obs

        with obs.tracer().span("collective.lower",
                               algo=program.algorithm, n=program.n):
            lp = tuple(int(i) for i in program.local_perm)
            n = program.n
            links = tuple((lp[i], lp[(i + 1) % n]) for i in range(n))
            schedule = self.lower_schedule(program)
            if program.algorithm in _RING_ALGOS:
                obs.metrics().counter("collective.lowered.ring").inc()
                return Lowered(kind="ring", order=lp, links=links,
                               fingerprint=program.fingerprint(),
                               schedule=schedule)
            if program.algorithm in _SHIFT_ALGOS:
                shift_rounds = tuple(
                    tuple(sorted((lp[f.src], lp[f.dst]) for f in rnd))
                    for rnd in program.rounds)
                obs.metrics().counter("collective.lowered.shift_a2a").inc()
                return Lowered(kind="shift_a2a", order=lp, links=links,
                               shift_rounds=shift_rounds,
                               fingerprint=program.fingerprint(),
                               schedule=schedule)
            obs.metrics().counter("collective.lowered.general").inc()
            return Lowered(kind="general", order=lp, links=(),
                           fingerprint=program.fingerprint(),
                           schedule=schedule)

    def estimate(self, program: Program) -> float:
        raise NotImplementedError(
            "JaxExecutor lowers programs; wall-clock timing belongs to "
            "the benchmark harness (use Analytic/SimExecutor to price)")
