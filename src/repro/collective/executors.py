"""Pluggable executors for collective :class:`Program`\\ s.

The :class:`Executor` protocol decouples *what a collective does* (the
IR) from *how it is priced or run*:

* :class:`AnalyticExecutor` — wraps the closed-form cost-model math of
  :mod:`repro.core.cost_models` (each builder declares which analytic
  model describes it);
* :class:`SimExecutor` — wraps the contention-aware max-min-fair
  simulator (:func:`repro.core.simulator.simulate_rounds`), the
  offline "real cloud" oracle;
* :class:`JaxExecutor` — lowers ring / all-to-all programs to the
  static ``ppermute`` shift schedules the jax runtime consumes
  (:mod:`repro.parallel.moe_a2a`, :mod:`repro.kernels.ring_collective`)
  instead of each call site hand-rolling them.

``estimate`` returns seconds for one execution of the program
(pipelining included); ``lower`` returns a :class:`Lowered` artifact.
Executors raise ``NotImplementedError`` for the direction they don't
support, so a caller holding any ``Executor`` can feature-test.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.cost_models import CostModel, make_cost_model
from repro.core.simulator import simulate_rounds
from repro.fabric.topology import Fabric

from .ir import Program

__all__ = [
    "Executor",
    "Lowered",
    "AnalyticExecutor",
    "SimExecutor",
    "JaxExecutor",
]


@runtime_checkable
class Executor(Protocol):
    """Anything that can price and/or lower a collective Program."""

    name: str

    def estimate(self, program: Program) -> float:
        """Seconds for one execution of ``program``."""
        ...

    def lower(self, program: Program) -> "Lowered":
        """Backend artifact for ``program`` (shift schedule, links...)."""
        ...


@dataclasses.dataclass(frozen=True)
class Lowered:
    """A jax-lowerable schedule in *axis-index* (local position) space.

    ``order[pos] = shard`` is the ring order the program's permutation
    induces over the group; ``links`` are the ppermute neighbor pairs of
    that ring; ``shift_rounds`` are the per-round ``(src, dst)`` pairs
    (all-to-all programs only; each round is a bijection).
    """

    kind: str                                    # "ring" | "shift_a2a"
    order: Tuple[int, ...]
    links: Tuple[Tuple[int, int], ...]
    shift_rounds: Tuple[Tuple[Tuple[int, int], ...], ...] = ()
    fingerprint: str = ""


class AnalyticExecutor:
    """Prices programs with the paper's closed-form cost models.

    Construct with full-fabric node-indexed matrices: either one
    pairwise ``cost_matrix`` (paper mode — rounds rescale linearly) or
    ``lat``/``bw`` (alpha-beta mode).  Group extraction and the
    rank→local-index mapping happen here, so callers hand over programs
    whose ``perm`` speaks global node ids.
    """

    name = "analytic"

    def __init__(self, cost_matrix: Optional[np.ndarray] = None, *,
                 lat: Optional[np.ndarray] = None,
                 bw: Optional[np.ndarray] = None):
        if cost_matrix is None and lat is None:
            raise ValueError(
                "AnalyticExecutor needs a cost_matrix or lat (+ bw)")
        self.c = None if cost_matrix is None else np.asarray(
            cost_matrix, dtype=np.float64)
        self.lat = None if lat is None else np.asarray(lat, dtype=np.float64)
        self.bw = None if bw is None else np.asarray(bw, dtype=np.float64)
        self._models: Dict[tuple, CostModel] = {}

    def model_for(self, program: Program) -> CostModel:
        """The builder-declared CostModel at the program's piece size."""
        g = np.asarray(sorted(program.op.group), dtype=np.int64)
        size = program.op.size_bytes / program.chunk_factor
        kwargs = {k: v for k, v in program.kwargs.items() if k == "base"}
        key = (program.cost_model, tuple(g), float(size),
               tuple(sorted(kwargs.items())))
        model = self._models.get(key)
        if model is None:
            if self.c is not None:
                model = make_cost_model(
                    program.cost_model, cost_matrix=self.c[np.ix_(g, g)],
                    size_bytes=size, **kwargs)
            else:
                sub_bw = None if self.bw is None else self.bw[np.ix_(g, g)]
                if sub_bw is None:
                    model = make_cost_model(
                        program.cost_model,
                        cost_matrix=self.lat[np.ix_(g, g)],
                        size_bytes=size, **kwargs)
                else:
                    model = make_cost_model(
                        program.cost_model, size_bytes=size,
                        lat=self.lat[np.ix_(g, g)], bw=sub_bw, **kwargs)
            self._models[key] = model
        return model

    def estimate(self, program: Program) -> float:
        model = self.model_for(program)
        return program.chunk_factor * float(model.cost(program.local_perm))

    def lower(self, program: Program) -> Lowered:
        raise NotImplementedError(
            "AnalyticExecutor prices programs; use JaxExecutor to lower")


class SimExecutor:
    """Prices programs on the contention-aware flow-level simulator."""

    name = "sim"

    def __init__(self, fabric: Fabric, jitter: float = 0.0,
                 seed: Optional[int] = None):
        self.fabric = fabric
        self.jitter = jitter
        self.seed = seed

    def estimate(self, program: Program) -> float:
        if self.jitter == 0.0 and program.chunk_factor > 1:
            # deterministic pipelining: the k pieces are identical, so
            # simulate one and scale instead of re-water-filling k times
            return program.chunk_factor * simulate_rounds(
                self.fabric, program.piece_flows())
        rng = np.random.default_rng(self.seed) if self.seed is not None \
            else None
        return simulate_rounds(self.fabric, program.to_flows(),
                               rng=rng, jitter=self.jitter)

    def lower(self, program: Program) -> Lowered:
        raise NotImplementedError(
            "SimExecutor prices programs; use JaxExecutor to lower")


#: builder names JaxExecutor can lower, by shape
_RING_ALGOS = ("ring", "ring_sequential", "ring_all_gather")
_SHIFT_ALGOS = ("all_to_all",)


class JaxExecutor:
    """Lowers ring / all-to-all programs to static ppermute schedules.

    The artifact speaks *axis-index* space: position i within the
    (sorted) group.  ``order`` is the program's local permutation — the
    ring order the solved rank placement induces — and the schedules
    are derived from the program's rounds, so a runtime consuming a
    :class:`Lowered` executes exactly the flows the plan was priced on.
    """

    name = "jax"

    def can_lower(self, program: Program) -> bool:
        return program.algorithm in _RING_ALGOS + _SHIFT_ALGOS

    def lower(self, program: Program) -> Lowered:
        from repro import obs

        with obs.tracer().span("collective.lower",
                               algo=program.algorithm, n=program.n):
            lp = tuple(int(i) for i in program.local_perm)
            n = program.n
            links = tuple((lp[i], lp[(i + 1) % n]) for i in range(n))
            if program.algorithm in _RING_ALGOS:
                obs.metrics().counter("collective.lowered.ring").inc()
                return Lowered(kind="ring", order=lp, links=links,
                               fingerprint=program.fingerprint())
            if program.algorithm in _SHIFT_ALGOS:
                shift_rounds = tuple(
                    tuple(sorted((lp[f.src], lp[f.dst]) for f in rnd))
                    for rnd in program.rounds)
                obs.metrics().counter("collective.lowered.shift_a2a").inc()
                return Lowered(kind="shift_a2a", order=lp, links=links,
                               shift_rounds=shift_rounds,
                               fingerprint=program.fingerprint())
        raise NotImplementedError(
            f"JaxExecutor cannot lower {program.algorithm!r} programs; "
            f"lowerable algorithms: {_RING_ALGOS + _SHIFT_ALGOS}")

    def estimate(self, program: Program) -> float:
        raise NotImplementedError(
            "JaxExecutor lowers programs; wall-clock timing belongs to "
            "the benchmark harness (use Analytic/SimExecutor to price)")
