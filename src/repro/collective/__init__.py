"""repro.collective — the typed collective IR (DESIGN.md §7).

One representation for "a collective", shared by the analytic cost
models, the contention simulator, the plan compiler, and the jax
runtime::

    from repro.collective import (
        CollectiveOp, compile_op, apply_permutation, chunk,
        SimExecutor, AnalyticExecutor, JaxExecutor,
    )

    op   = CollectiveOp("allreduce", size_bytes=64e6, group=range(16))
    prog = compile_op(op, "ring")                  # typed Program
    prog = apply_permutation(prog, solved_perm)    # rank order = IR pass
    prog = chunk(prog, 4)                          # pipelining = IR pass
    secs = SimExecutor(fabric).estimate(prog)      # oracle seconds
    low  = JaxExecutor().lower(prog)               # ppermute schedule

The legacy surfaces remain as shims: ``repro.core.schedule.SCHEDULES``
delegates here (with a DeprecationWarning), and the plan compiler's
``(algo, chunks, perm)`` string tuples are now derived views of the
Program each entry carries.
"""

from .builders import (  # noqa: F401
    AlgorithmBuilder,
    candidates,
    compile_op,
    get_builder,
    register_builder,
    registered_builders,
)
from .executors import (  # noqa: F401
    AnalyticExecutor,
    Executor,
    JaxExecutor,
    Lowered,
    LoweredSchedule,
    PermuteStep,
    SimExecutor,
)
from .ir import (  # noqa: F401
    INITS,
    KINDS,
    POSTCONDITIONS,
    CollectiveOp,
    FlowInstr,
    Program,
    ProgramInvariantError,
    kind_from_op,
    op_from_kind,
    validate,
)
from .passes import apply_permutation, chunk, fuse_rounds  # noqa: F401

__all__ = [
    "AlgorithmBuilder",
    "AnalyticExecutor",
    "CollectiveOp",
    "Executor",
    "FlowInstr",
    "INITS",
    "JaxExecutor",
    "KINDS",
    "Lowered",
    "LoweredSchedule",
    "POSTCONDITIONS",
    "PermuteStep",
    "Program",
    "ProgramInvariantError",
    "SimExecutor",
    "apply_permutation",
    "candidates",
    "chunk",
    "compile_op",
    "fuse_rounds",
    "get_builder",
    "kind_from_op",
    "op_from_kind",
    "register_builder",
    "registered_builders",
    "validate",
]
