"""The typed collective IR (DESIGN.md §7).

A collective is specified as a :class:`CollectiveOp` (what to compute)
and compiled by a registered builder (:mod:`repro.collective.builders`)
into a :class:`Program` (how to compute it): rounds of
:class:`FlowInstr`\\ s carrying explicit reduce/copy semantics and chunk
metadata, plus the rank→node mapping as *data* — the permutation is a
rewrite pass (:func:`repro.collective.passes.apply_permutation`), not a
parameter threaded through every builder.

Design rules:

* **Rank space.** ``FlowInstr`` endpoints are logical ranks
  ``0..n-1``; ``Program.perm[rank]`` is the global node id placed at
  that rank.  ``to_flows()`` materializes node-space legacy
  :class:`repro.core.schedule.Flow` rounds for the simulator.
* **Chunk metadata.** Each program declares its logical data chunks
  (``n_chunks`` pieces of ``chunk_bytes`` each, initial placement
  ``init``) and every flow names the chunk ids it carries — enough for
  :func:`validate` to *interpret* the program and prove the
  postcondition (every rank ends holding the reduced/gathered result).
* **Programs are immutable.** Passes return new programs; the builder
  output is shared and never mutated.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, FrozenSet, List, Tuple

import numpy as np

from repro.core.schedule import Flow

__all__ = [
    "KINDS",
    "INITS",
    "POSTCONDITIONS",
    "CollectiveOp",
    "FlowInstr",
    "Program",
    "ProgramInvariantError",
    "kind_from_op",
    "op_from_kind",
    "validate",
]

#: collective kinds the IR can express.  ``reduce_scatter`` is a
#: first-class kind (the plan compiler prices it with the all-gather
#: builders, which emit the mirrored reduce program for it).
KINDS = ("allreduce", "all_gather", "reduce_scatter", "all_to_all")

#: initial chunk placement models understood by :func:`validate`:
#: ``replicated`` — every rank holds every chunk (its own contribution);
#: ``sharded`` — rank r holds chunk r (complete);
#: ``addressed`` — rank s holds chunks s*n+d addressed to each rank d.
INITS = ("replicated", "sharded", "addressed")

#: program postconditions :func:`validate` can prove:
#: ``allreduce`` — every rank holds every chunk reduced over all ranks;
#: ``all_gather`` — every rank holds every chunk;
#: ``reduce_scatter`` — rank r holds chunk r reduced over all ranks;
#: ``all_to_all`` — rank d holds chunk s*n+d from every source s;
#: ``reduce`` — some rank holds every chunk reduced over all ranks
#: (rooted reduce; the naive sequential ring's broadcast lap reuses the
#: same hop sequence as its reduce lap by design — see the builder);
#: ``none`` — structural checks only.
POSTCONDITIONS = ("allreduce", "all_gather", "reduce_scatter",
                  "all_to_all", "reduce", "none")

#: plan-compiler op string <-> IR kind
_OP_TO_KIND = {
    "all-reduce": "allreduce",
    "all-gather": "all_gather",
    "reduce-scatter": "reduce_scatter",
    "all-to-all": "all_to_all",
}
_KIND_TO_OP = {v: k for k, v in _OP_TO_KIND.items()}


def kind_from_op(op: str) -> str:
    """Map a plan-compiler op string (``all-reduce``) to an IR kind."""
    try:
        return _OP_TO_KIND[op]
    except KeyError:
        raise ValueError(
            f"unknown collective op {op!r}; expected one of "
            f"{tuple(_OP_TO_KIND)}") from None


def op_from_kind(kind: str) -> str:
    """Map an IR kind (``allreduce``) back to the plan op string."""
    try:
        return _KIND_TO_OP[kind]
    except KeyError:
        raise ValueError(
            f"unknown collective kind {kind!r}; expected one of {KINDS}"
        ) from None


class ProgramInvariantError(AssertionError):
    """A :class:`Program` violated a structural or semantic invariant."""


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """What to compute: the backend-agnostic collective specification."""

    kind: str                     # one of KINDS
    size_bytes: float             # total payload (gathered size for AG)
    group: Tuple[int, ...]        # participating global node ids
    chunks: int = 1               # requested pipelining factor

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        object.__setattr__(self, "group", tuple(int(g) for g in self.group))
        if len(set(self.group)) != len(self.group) or not self.group:
            raise ValueError(f"group must be non-empty unique node ids, "
                             f"got {self.group}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")

    @property
    def n(self) -> int:
        return len(self.group)


@dataclasses.dataclass(frozen=True)
class FlowInstr:
    """One typed point-to-point transfer (rank space)."""

    src: int                      # logical rank
    dst: int
    size: float                   # bytes
    op: str = "copy"              # "reduce" | "copy"
    chunks: Tuple[int, ...] = ()  # logical chunk ids carried


@dataclasses.dataclass(frozen=True)
class Program:
    """How to compute it: rounds of typed flows + chunk semantics.

    Rounds are barriers (flows within a round are concurrent and read
    the round-entry state), matching the simulator's and the cost
    models' conservative execution model.
    """

    op: CollectiveOp
    algorithm: str                          # registered builder name
    algo_kwargs: Tuple[Tuple[str, int], ...]  # sorted builder kwargs
    rounds: Tuple[Tuple[FlowInstr, ...], ...]
    perm: Tuple[int, ...]                   # perm[rank] = global node id
    n_chunks: int                           # logical data chunks
    chunk_bytes: float                      # bytes per logical chunk
    init: str                               # one of INITS
    postcondition: str                      # one of POSTCONDITIONS
    cost_model: str                         # analytic CostModel name
    chunk_factor: int = 1                   # serialized pipeline pieces

    # -- basic views ------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.perm)

    @property
    def n_rounds(self) -> int:
        """Rounds actually executed (pipelining repeats the base body)."""
        return len(self.rounds) * self.chunk_factor

    @property
    def total_bytes(self) -> float:
        """Wire bytes for one full execution (pipelining-invariant)."""
        return sum(f.size for rnd in self.rounds for f in rnd)

    @property
    def kwargs(self) -> Dict[str, int]:
        return dict(self.algo_kwargs)

    @property
    def local_perm(self) -> np.ndarray:
        """perm as positions within sorted(group) (rank -> index)."""
        pos = {node: i for i, node in enumerate(sorted(self.op.group))}
        return np.asarray([pos[node] for node in self.perm], dtype=np.int64)

    def replace(self, **kw) -> "Program":
        return dataclasses.replace(self, **kw)

    # -- lowering to the legacy flow representation -----------------------
    def piece_flows(self) -> List[List[Flow]]:
        """Node-space flow rounds for ONE pipeline piece (payload/k)."""
        scale = 1.0 / self.chunk_factor
        return [
            [Flow(self.perm[f.src], self.perm[f.dst], f.size * scale)
             for f in rnd]
            for rnd in self.rounds
        ]

    def to_flows(self) -> List[List[Flow]]:
        """Node-space ``List[List[Flow]]`` rounds for the simulator.

        A ``chunk_factor`` of k repeats the body k times at 1/k payload
        — the serialized-pipelining model the plan compiler scores.
        """
        body = self.piece_flows()
        if self.chunk_factor == 1:
            return body
        return [list(rnd) for _ in range(self.chunk_factor) for rnd in body]

    # -- identity ---------------------------------------------------------
    def fingerprint(self) -> str:
        """Stable content hash of the program (schedule + placement)."""
        payload = {
            "kind": self.op.kind,
            "size_bytes": float(self.op.size_bytes),
            "group": list(self.op.group),
            "algorithm": self.algorithm,
            "algo_kwargs": [list(kv) for kv in self.algo_kwargs],
            "perm": list(self.perm),
            "chunk_factor": self.chunk_factor,
            "rounds": [[(f.src, f.dst, f.size, f.op) for f in rnd]
                       for rnd in self.rounds],
        }
        blob = json.dumps(payload, separators=(",", ":"), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# validation: structural invariants + abstract interpretation
# ---------------------------------------------------------------------------

def _initial_state(
    program: Program,
) -> Dict[int, Dict[int, FrozenSet[int]]]:
    n = program.n
    full = frozenset(range(n))
    if program.init == "replicated":
        return {r: {c: frozenset((r,)) for c in range(program.n_chunks)}
                for r in range(n)}
    if program.init == "sharded":
        return {r: {r: full} for r in range(n)}
    if program.init == "addressed":
        return {s: {s * n + d: frozenset((s,)) for d in range(n)}
                for s in range(n)}
    raise ValueError(f"unknown init {program.init!r}; "
                     f"expected one of {INITS}")


def _check_postcondition(program: Program,
                         state: Dict[int, Dict[int, FrozenSet[int]]]) -> None:
    n = program.n
    full = frozenset(range(n))
    post = program.postcondition

    def held_full(rank: int, chunk: int) -> bool:
        return state[rank].get(chunk) == full

    if post == "none":
        return
    if post == "allreduce":
        bad = [(r, c) for r in range(n) for c in range(program.n_chunks)
               if not held_full(r, c)]
        if bad:
            raise ProgramInvariantError(
                f"{program.algorithm}: allreduce incomplete — rank/chunk "
                f"pairs missing full reduction: {bad[:4]}...")
    elif post == "reduce_scatter":
        bad = [r for r in range(n) if not held_full(r, r)]
        if bad:
            raise ProgramInvariantError(
                f"{program.algorithm}: reduce-scatter incomplete — ranks "
                f"{bad} do not hold their own chunk fully reduced")
    elif post == "all_gather":
        bad = [(r, c) for r in range(n) for c in range(program.n_chunks)
               if c not in state[r]]
        if bad:
            raise ProgramInvariantError(
                f"{program.algorithm}: all-gather incomplete — missing "
                f"rank/chunk pairs: {bad[:4]}...")
    elif post == "all_to_all":
        bad = [(s, d) for s in range(n) for d in range(n)
               if s * n + d not in state[d]]
        if bad:
            raise ProgramInvariantError(
                f"{program.algorithm}: all-to-all incomplete — undelivered "
                f"(src, dst) pairs: {bad[:4]}...")
    elif post == "reduce":
        if not any(all(held_full(r, c) for c in range(program.n_chunks))
                   for r in range(n)):
            raise ProgramInvariantError(
                f"{program.algorithm}: rooted reduce incomplete — no rank "
                f"holds every chunk fully reduced")
    else:
        raise ValueError(f"unknown postcondition {post!r}; "
                         f"expected one of {POSTCONDITIONS}")


def validate(program: Program, semantics: bool = True) -> None:
    """Check structural invariants and (optionally) the postcondition.

    Structural: endpoints are in-range ranks, no self-flows, payloads
    positive and finite, and every flow's bytes equal its chunk count
    times the program's declared ``chunk_bytes`` (byte conservation —
    no flow moves data its chunk metadata doesn't account for).

    Semantic: abstract interpretation over per-rank chunk→contributor
    sets; rounds are barriers (senders read round-entry state); the
    declared postcondition must hold at program end.

    Raises :class:`ProgramInvariantError` on violation.
    """
    n = program.n
    if sorted(program.perm) != sorted(program.op.group):
        raise ProgramInvariantError(
            f"{program.algorithm}: perm {program.perm} is not a "
            f"permutation of group {program.op.group}")
    if program.n_chunks < 1 or program.chunk_bytes < 0:
        raise ProgramInvariantError(
            f"{program.algorithm}: bad chunk metadata "
            f"(n_chunks={program.n_chunks}, chunk_bytes={program.chunk_bytes})")
    for r_i, rnd in enumerate(program.rounds):
        for f in rnd:
            if not (0 <= f.src < n and 0 <= f.dst < n):
                raise ProgramInvariantError(
                    f"{program.algorithm} round {r_i}: endpoint out of "
                    f"range in {f}")
            if f.src == f.dst and n > 1:
                raise ProgramInvariantError(
                    f"{program.algorithm} round {r_i}: self-flow {f}")
            if not np.isfinite(f.size) or f.size <= 0:
                raise ProgramInvariantError(
                    f"{program.algorithm} round {r_i}: non-positive "
                    f"payload in {f}")
            if f.op not in ("reduce", "copy"):
                raise ProgramInvariantError(
                    f"{program.algorithm} round {r_i}: unknown flow op "
                    f"{f.op!r}")
            if not f.chunks:
                raise ProgramInvariantError(
                    f"{program.algorithm} round {r_i}: flow {f} carries "
                    f"no chunks")
            expect = len(f.chunks) * program.chunk_bytes
            if program.chunk_bytes and abs(f.size - expect) > 1e-9 * max(
                    expect, 1.0):
                raise ProgramInvariantError(
                    f"{program.algorithm} round {r_i}: flow bytes "
                    f"{f.size} != {len(f.chunks)} chunks x "
                    f"{program.chunk_bytes} bytes")

    if not semantics:
        return
    state = _initial_state(program)
    for rnd in program.rounds:
        # barrier semantics: all sends in a round read round-entry state
        updates: List[Tuple[str, int, int, FrozenSet[int]]] = []
        for f in rnd:
            src_chunks = state[f.src]
            for c in f.chunks:
                if c not in src_chunks:
                    raise ProgramInvariantError(
                        f"{program.algorithm}: rank {f.src} sends chunk "
                        f"{c} it does not hold")
                updates.append((f.op, f.dst, c, src_chunks[c]))
        for fop, dst, c, contrib in updates:
            if fop == "reduce":
                # accumulate into the destination's partial
                state[dst][c] = state[dst].get(c, frozenset()) | contrib
            else:
                # a copy OVERWRITES the destination buffer: the receiver
                # keeps exactly the sender's contributions, so a builder
                # that emits "copy" where a reduction is required cannot
                # validate complete (the typing exists to catch that)
                state[dst][c] = contrib
    _check_postcondition(program, state)
