"""Composable rewrite passes over :class:`~repro.collective.ir.Program`.

Passes are pure ``Program -> Program`` functions; they compose freely
and never mutate their input.  The three seed passes:

* :func:`apply_permutation` — rank reordering (the paper's object) as a
  rewrite instead of a ``perm`` argument threaded through every builder;
* :func:`chunk` — serialized pipelining: k pieces of 1/k payload (the
  chunking dimension the plan compiler scores);
* :func:`fuse_rounds` — merge adjacent rounds with disjoint
  participants (barrier elimination that cannot reorder a data
  dependency).
"""

from __future__ import annotations

from typing import Sequence, Tuple

from .ir import Program

__all__ = ["apply_permutation", "chunk", "fuse_rounds"]


def apply_permutation(program: Program, perm: Sequence[int]) -> Program:
    """Place rank r on node ``perm[r]``.

    ``perm`` may be given in node-id space (a rearrangement of
    ``program.op.group`` — the plan compiler's convention) or in local
    index space (a permutation of ``range(n)``, composed through the
    group).  Because flows live in rank space, the pass only rewrites
    the rank→node mapping; the schedule structure is untouched — which
    is exactly the permutation-independence invariant the legacy
    builders maintained implicitly.
    """
    n = program.n
    perm = tuple(int(p) for p in perm)
    if len(perm) != n:
        raise ValueError(
            f"perm has {len(perm)} entries for a {n}-rank program")
    group = program.op.group
    if sorted(perm) == sorted(group):
        node_perm = perm
    elif sorted(perm) == list(range(n)):
        ordered = tuple(sorted(group))
        node_perm = tuple(ordered[i] for i in perm)
    else:
        raise ValueError(
            f"perm {perm} is neither a rearrangement of group {group} "
            f"nor of range({n})")
    return program.replace(perm=node_perm)


def chunk(program: Program, k: int) -> Program:
    """Split the payload into ``k`` serialized pipeline pieces.

    Execution model (shared with the plan compiler's scoring): the full
    schedule runs k times back-to-back at 1/k payload — captured as
    ``chunk_factor`` so the base rounds stay shared;
    ``Program.to_flows()`` materializes the repetition.
    """
    if k < 1:
        raise ValueError(f"chunk factor must be >= 1, got {k}")
    if k == 1:
        return program
    return program.replace(chunk_factor=program.chunk_factor * k)


def _participants(rnd) -> frozenset:
    return frozenset(e for f in rnd for e in (f.src, f.dst))


def fuse_rounds(program: Program, verify: bool = True) -> Tuple[Program, int]:
    """Merge adjacent rounds whose participant sets are disjoint.

    A rank absent from round i can neither produce data round i+1
    forwards nor observe its barrier, so dropping the barrier between
    two participant-disjoint rounds preserves program semantics (the
    flows now contend for links, which the executors price faithfully).
    Disjointness is over *ranks*: two instructions that share only a
    chunk id carry unrelated per-rank state entries and fuse safely
    (see ``tests/test_analysis.py::test_fuse_rounds_chunk_id_overlap``).

    With ``verify`` (the default) the fused program is re-checked with
    the static dependency analysis; a fusion that manufactured an
    intra-round race or missing-data error raises
    :class:`repro.analysis.VerificationError` instead of shipping.
    Returns ``(program, n_fused)``.
    """
    fused = []
    n_fused = 0
    for rnd in program.rounds:
        if fused and _participants(fused[-1]).isdisjoint(_participants(rnd)):
            fused[-1] = fused[-1] + tuple(rnd)
            n_fused += 1
        else:
            fused.append(tuple(rnd))
    if not n_fused:
        return program, 0
    out = program.replace(rounds=tuple(fused))
    if verify:
        # lazy: repro.analysis imports this package's IR at module scope
        from repro.analysis import require_valid
        require_valid(out, passes=("deps",))
    return out, n_fused
