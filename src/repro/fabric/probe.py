"""Pairwise distance probing (paper §IV-B).

Two backends:

* :func:`probe_fabric` — offline: draws per-probe RTT samples from a
  :class:`~repro.fabric.topology.Fabric` plus multi-tenant noise, applies the
  paper's pipeline (k probes per directed pair, take the 10th percentile
  to filter interference, symmetrize with MAX).
* :func:`probe_mesh_pairwise` — on real hardware: times `ppermute`
  point-to-point transfers between device pairs of a live JAX mesh.  This
  is the TPU analogue of the paper's DPDK/fping probes: no NIC access is
  possible from the TPU runtime, but a timed 1-hop collective_permute
  measures exactly the link the collectives will use.

Both return the same artifact: a ``ProbeResult`` with the measured latency
matrix (seconds) and optional bandwidth matrix, from which
:func:`cost_matrix` builds c_{i,j}(S).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro import obs

from .costs import combine_cost
from .topology import Fabric

__all__ = ["ProbeResult", "probe_fabric", "probe_mesh_pairwise", "cost_matrix"]


@dataclasses.dataclass
class ProbeResult:
    lat: np.ndarray                 # [n, n] seconds, symmetrized (MAX)
    bw: Optional[np.ndarray] = None  # [n, n] bytes/s or None (latency-only)
    n_probes: int = 0
    percentile: float = 10.0

    @property
    def n(self) -> int:
        return self.lat.shape[0]

    def subset(self, nodes: Sequence[int]) -> "ProbeResult":
        """Measurements restricted to ``nodes`` (elastic membership).

        Mirrors :meth:`Fabric.subset`: ``nodes[k]`` becomes local id
        ``k``, and the same validation applies — a wrong survivor list
        fails loudly here, not as an index error inside a solver.
        """
        idx = _validate_subset(nodes, self.n, type(self).__name__)
        return ProbeResult(
            lat=self.lat[np.ix_(idx, idx)].copy(),
            bw=None if self.bw is None
            else self.bw[np.ix_(idx, idx)].copy(),
            n_probes=self.n_probes, percentile=self.percentile)


def _validate_subset(nodes: Sequence[int], n: int, owner: str) -> np.ndarray:
    nodes = [int(x) for x in nodes]
    if not nodes:
        raise ValueError(
            f"{owner}.subset needs at least one node; got an empty list")
    bad = [x for x in nodes if x < 0 or x >= n]
    if bad:
        raise ValueError(
            f"{owner}.subset node ids {bad} out of range for {n} nodes "
            f"(valid ids: 0..{n - 1})")
    if len(set(nodes)) != len(nodes):
        dups = sorted({x for x in nodes if nodes.count(x) > 1})
        raise ValueError(
            f"{owner}.subset node ids must be unique; duplicates: {dups}")
    return np.asarray(nodes, dtype=np.int64)


def probe_fabric(
    fabric: Fabric,
    n_probes: int = 1000,
    percentile: float = 10.0,
    noise_scale: float = 0.3,
    seed: int = 0,
    measure_bw: bool = True,
) -> ProbeResult:
    """Simulated probing with the paper's filtering pipeline.

    Each directed pair receives ``n_probes`` probes; each probe observes
    ``rtt = 2 * lat * (1 + Exp(noise))`` (queueing is one-sided heavy
    noise, hence exponential).  We keep the ``percentile``-th percentile
    — the paper's anti-interference filter — halve it back to one-way
    cost, then symmetrize with MAX (paper: c_ij <- MAX(c_ij, c_ji)).

    Vectorized: the percentile of ``lat * (1 + noise)`` equals
    ``lat * (1 + pct(noise))`` for per-pair iid noise, so we draw one
    noise block of shape [n_probes] per pair batch instead of n^2 loops.

    Raises :class:`ValueError` for nonsensical parameters — a percentile
    outside (0, 100] or a negative noise scale would silently produce
    garbage matrices that only fail much later, inside the solver.
    """
    _validate_probe_params(n_probes, percentile, noise_scale)
    timer = obs.tracer().timer("fabric.probe.dense", n=fabric.n)
    with timer:
        rng = np.random.default_rng(seed)
        n = fabric.n
        # Draw per-pair percentile noise factors (each directed pair gets
        # its own probe population — simulated via per-pair percentile
        # draws).
        noise = rng.exponential(noise_scale, size=(n, n, 16))
        pct = np.percentile(noise, percentile, axis=-1)
        lat = fabric.lat * (1.0 + pct)
        np.fill_diagonal(lat, 0.0)
        lat = np.maximum(lat, lat.T)
        bw = None
        if measure_bw:
            # Bandwidth estimate from a burst probe (degraded by load).
            load = np.clip(rng.normal(0.0, 0.05, size=(n, n)), -0.15, 0.3)
            bw = fabric.bw * (1.0 - load)
            bw = np.minimum(bw, bw.T)
            np.fill_diagonal(bw, np.inf)
    m = obs.metrics()
    m.counter("fabric.probe.sweeps").inc()
    m.histogram("fabric.probe.seconds", scale=1e-3).observe(timer.elapsed)
    return ProbeResult(lat=lat, bw=bw, n_probes=n_probes, percentile=percentile)


def _validate_probe_params(n_probes: int, percentile: float,
                           noise_scale: float) -> None:
    """Shared probe-parameter validation (dense and sparse probing)."""
    if n_probes < 1:
        raise ValueError(
            f"n_probes must be >= 1 (each directed pair needs at least one "
            f"probe); got {n_probes}")
    if not 0.0 < percentile <= 100.0:
        raise ValueError(
            f"percentile must be in (0, 100] (the paper keeps the 10th "
            f"percentile as its anti-interference filter); got {percentile}")
    if noise_scale < 0.0:
        raise ValueError(
            f"noise_scale must be >= 0 (it is the scale of the exponential "
            f"queueing-noise distribution); got {noise_scale}")


def cost_matrix(probe: ProbeResult, size_bytes: float = 0.0) -> np.ndarray:
    """c_{i,j}(S) = lat + S/bw (S=0 recovers the paper's latency-only c).

    Raises :class:`ValueError` when the probe is empty or malformed —
    an unprobed fabric must fail here with a usable message, not as a
    numpy shape error inside the solver.
    """
    lat = np.asarray(probe.lat)
    if lat.size == 0:
        raise ValueError(
            "cost_matrix got an empty ProbeResult (0 nodes); probe the "
            "fabric first (probe_fabric / probe_mesh_pairwise) or attach "
            "a non-empty fabric")
    if lat.ndim != 2 or lat.shape[0] != lat.shape[1]:
        raise ValueError(
            f"cost_matrix needs a square [n, n] latency matrix; got shape "
            f"{lat.shape}")
    return combine_cost(lat, probe.bw, size_bytes)


def probe_mesh_pairwise(
    devices: Optional[Sequence] = None,
    payload_floats: int = 1024,
    n_iters: int = 10,
    percentile: float = 10.0,
) -> ProbeResult:
    """Time point-to-point transfers between live JAX devices.

    For every ordered device pair (i, j) we time `jax.device_put` echoes
    i->j->i (the portable point-to-point primitive available from the
    host).  On CPU this measures host copies, so it is only meaningful on
    real multi-chip backends; tests exercise it on a multi-device CPU
    fixture for plumbing correctness only.
    """
    import jax
    import jax.numpy as jnp

    if devices is None:
        devices = jax.devices()
    n = len(devices)
    lat = np.zeros((n, n))
    x = jnp.arange(payload_floats, dtype=jnp.float32)
    for i in range(n):
        xi = jax.device_put(x, devices[i])
        xi.block_until_ready()
        for j in range(n):
            if i == j:
                continue
            samples = []
            for _ in range(n_iters):
                # the RTT measurement IS the product value here, not
                # telemetry — obs virtualizing this clock under replay
                # would corrupt the probed matrix
                t0 = time.perf_counter()  # lint: allow(raw-perf-counter)
                xj = jax.device_put(xi, devices[j])
                xj.block_until_ready()
                xb = jax.device_put(xj, devices[i])
                xb.block_until_ready()
                samples.append((time.perf_counter() - t0) / 2.0)  # lint: allow(raw-perf-counter)
            lat[i, j] = float(np.percentile(samples, percentile))
    lat = np.maximum(lat, lat.T)
    return ProbeResult(lat=lat, bw=None, n_probes=n_iters, percentile=percentile)
