"""Budgeted sparse probing: plan-grade cost matrices from O(n·log n) probes.

Dense probing (paper §IV-B) measures every directed pair — n(n-1)
probes, the scalability wall the paper names as future work (§VI).  The
hierarchy makes most of those probes redundant: within a recovered
block, costs are statistically exchangeable, and between two blocks
every pair crosses the same bottleneck tier.  So:

1. **Landmark sweep** — probe every node against L = O(log n) landmark
   nodes (n·L probes).  Each node's landmark cost vector is a locality
   embedding: same-rack nodes have near-identical vectors.
2. **Cluster** — agglomerate the embeddings
   (:func:`repro.fabric.hierarchy.infer_hierarchy` on the embedding
   distance matrix) into locality clusters.
3. **Refine** — probe all intra-cluster pairs (clusters are small) plus
   a few representative pairs per cluster pair (medoid-to-medoid and
   random cross members), trimming to the probe budget.
4. **Complete** — unprobed (i, j) entries take the **median** of the
   probed entries between cluster(i) and cluster(j).

The result is a :class:`SparseProbeResult` — a drop-in
:class:`~repro.fabric.probe.ProbeResult` carrying the completed
matrices, the probe count actually spent, and the inferred
:class:`~repro.fabric.hierarchy.HierarchyModel` (re-derived from the
completed matrix, so downstream consumers see one consistent tree).

:func:`refresh_sparse` is the drift path: re-probe each cluster's
representative against the landmarks, and only clusters whose median
cost moved get their pairs re-probed — monitoring cost scales with the
number of *changed* clusters, not with n².
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from .hierarchy import HierarchyModel, infer_hierarchy
from .probe import ProbeResult, _validate_probe_params
from .topology import Fabric

__all__ = ["SparseProbeResult", "sparse_probe_fabric", "refresh_sparse"]

#: simulated probe-sample population per pair (matches probe_fabric)
_SAMPLES = 16


@dataclasses.dataclass
class SparseProbeResult(ProbeResult):
    """A :class:`ProbeResult` reconstructed from a probe subsample.

    ``lat``/``bw`` are *completed* matrices (cluster-median filled), so
    every dense consumer — cost models, solvers, the plan compiler —
    works unchanged.  The sparse-only artifacts ride along:
    """

    #: locality tree inferred from the completed matrix
    hierarchy: Optional[HierarchyModel] = None
    #: directed probes actually spent (2 per measured undirected pair)
    probes_used: int = 0
    #: the budget the probe was asked to respect (fraction of n(n-1))
    probe_budget: float = 0.25
    #: [n, n] bool — True where the entry was measured, not completed
    observed: Optional[np.ndarray] = None
    #: landmark node ids of the seed sweep (refresh re-uses them)
    landmarks: Tuple[int, ...] = ()

    @property
    def probe_fraction(self) -> float:
        """Directed probes spent / the dense probe's n(n-1)."""
        n = self.n
        return self.probes_used / max(n * (n - 1), 1)

    def subset(self, nodes: Sequence[int]) -> "SparseProbeResult":
        """Restriction to ``nodes``, sparse artifacts included.

        The hierarchy is put through
        :meth:`~repro.fabric.hierarchy.HierarchyModel.restrict` (same
        local re-indexing), the observed mask is sliced, and landmarks
        keep only surviving nodes (remapped) — so
        :func:`refresh_sparse` keeps tracking clusters across an
        elastic membership change instead of restarting from scratch.
        """
        from .probe import _validate_subset

        idx = _validate_subset(nodes, self.n, type(self).__name__)
        members = [int(x) for x in idx]
        local = {node: k for k, node in enumerate(members)}
        return SparseProbeResult(
            lat=self.lat[np.ix_(idx, idx)].copy(),
            bw=None if self.bw is None
            else self.bw[np.ix_(idx, idx)].copy(),
            n_probes=self.n_probes, percentile=self.percentile,
            hierarchy=None if self.hierarchy is None
            else self.hierarchy.restrict(members),
            probes_used=self.probes_used, probe_budget=self.probe_budget,
            observed=None if self.observed is None
            else self.observed[np.ix_(idx, idx)].copy(),
            landmarks=tuple(local[x] for x in self.landmarks
                            if x in local))


# ---------------------------------------------------------------------------
# pair measurement (shared noise model with probe_fabric)
# ---------------------------------------------------------------------------

def _measure_pairs(fabric: Fabric, pairs: np.ndarray, rng: np.random.Generator,
                   percentile: float, noise_scale: float, measure_bw: bool,
                   ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Measured (lat, bw) per undirected pair, MAX/MIN symmetrized.

    Same per-pair pipeline as :func:`repro.fabric.probe.probe_fabric`:
    percentile of exponential queueing noise on each direction, then
    symmetrize (lat with MAX, bw with MIN).
    """
    i, j = pairs[:, 0], pairs[:, 1]
    noise = rng.exponential(noise_scale, size=(len(pairs), 2, _SAMPLES)) \
        if noise_scale > 0 else np.zeros((len(pairs), 2, _SAMPLES))
    pct = np.percentile(noise, percentile, axis=-1)
    lat = np.maximum(fabric.lat[i, j] * (1.0 + pct[:, 0]),
                     fabric.lat[j, i] * (1.0 + pct[:, 1]))
    bw = None
    if measure_bw:
        load = np.clip(rng.normal(0.0, 0.05, size=(len(pairs), 2)),
                       -0.15, 0.3)
        bw = np.minimum(fabric.bw[i, j] * (1.0 - load[:, 0]),
                        fabric.bw[j, i] * (1.0 - load[:, 1]))
    return lat, bw


def _fill_pairs(mat: np.ndarray, pairs: np.ndarray, vals: np.ndarray) -> None:
    mat[pairs[:, 0], pairs[:, 1]] = vals
    mat[pairs[:, 1], pairs[:, 0]] = vals


def _pair_set(pairs: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Dedup + canonicalize (i < j) an undirected pair list."""
    canon = {(min(a, b), max(a, b)) for a, b in pairs if a != b}
    if not canon:
        return np.zeros((0, 2), dtype=np.int64)
    return np.asarray(sorted(canon), dtype=np.int64)


# ---------------------------------------------------------------------------
# cluster selection
# ---------------------------------------------------------------------------

def _embedding_clusters(emb: np.ndarray, landmarks: np.ndarray,
                        max_cluster: int) -> List[List[int]]:
    """Locality clusters from the landmark embedding.

    Agglomerate the embedding distance matrix with the same tier-cut
    machinery as the full hierarchy inference; when no structure
    separates (uniform fabric), fall back to nearest-landmark buckets
    so the refinement stage still has bounded clusters to work with.
    """
    n = emb.shape[0]
    d = np.sqrt(((emb[:, None, :] - emb[None, :, :]) ** 2).mean(axis=-1))
    h = infer_hierarchy(d)
    clusters = [c for c in h.blocks(0)] if not h.flat else []
    if not clusters or max(len(c) for c in clusters) > max_cluster \
            or np.mean([len(c) for c in clusters]) < 2:
        lab = np.argmin(np.abs(emb), axis=1) if len(landmarks) else \
            np.zeros(n, dtype=np.int64)
        buckets: Dict[int, List[int]] = {}
        for node, b in enumerate(lab):
            buckets.setdefault(int(b), []).append(node)
        clusters = list(buckets.values())
    # split any oversized cluster into contiguous halves until bounded
    out: List[List[int]] = []
    stack = [sorted(c) for c in clusters]
    while stack:
        c = stack.pop()
        if len(c) <= max_cluster:
            out.append(c)
        else:
            mid = len(c) // 2
            stack.append(c[:mid])
            stack.append(c[mid:])
    return sorted(out, key=lambda c: c[0])


def _medoid(emb: np.ndarray, members: List[int]) -> int:
    sub = emb[members]
    d = np.abs(sub[:, None, :] - sub[None, :, :]).sum(axis=(1, 2))
    return members[int(np.argmin(d))]


# ---------------------------------------------------------------------------
# completion
# ---------------------------------------------------------------------------

def _complete(mat: np.ndarray, observed: np.ndarray, labels: np.ndarray,
              kind: str) -> np.ndarray:
    """Fill unobserved entries with their cluster-pair median.

    ``kind="lat"``: diagonal 0, symmetrize with MAX (the paper's
    convention); ``kind="bw"``: diagonal inf, symmetrize with MIN.
    Cluster-pair medians are computed in one sorted pass over the
    observed entries (no per-pair python re-slicing).
    """
    n = mat.shape[0]
    k = int(labels.max()) + 1
    pid = labels[:, None] * k + labels[None, :]
    obs = observed & ~np.eye(n, dtype=bool) & np.isfinite(mat)
    vals = mat[obs]
    pids = pid[obs]
    med = np.full(k * k, np.nan)
    g = float(np.median(vals)) if vals.size else 0.0
    if vals.size:
        order = np.argsort(pids, kind="stable")
        sp, sv = pids[order], vals[order]
        uniq, starts = np.unique(sp, return_index=True)
        bounds = np.append(starts, len(sv))
        for u, a, b in zip(uniq, bounds[:-1], bounds[1:]):
            med[u] = np.median(sv[a:b])
    med = np.where(np.isnan(med), g, med)
    out = np.where(obs, mat, med[pid])
    if kind == "lat":
        np.fill_diagonal(out, 0.0)
        return np.maximum(out, out.T)
    np.fill_diagonal(out, np.inf)
    return np.minimum(out, out.T)


# ---------------------------------------------------------------------------
# the sparse probe
# ---------------------------------------------------------------------------

def sparse_probe_fabric(
    fabric: Fabric,
    budget: float = 0.25,
    **kwargs,
) -> SparseProbeResult:
    """Instrumented front-end of :func:`_sparse_probe_fabric` (same
    signature): the sweep runs under an obs timer, feeding the
    ``fabric.probe.seconds`` latency histogram and the probes-used
    gauge that make the sparse budget observable in ``repro status``."""
    timer = obs.tracer().timer("fabric.probe.sparse", n=fabric.n)
    with timer:
        result = _sparse_probe_fabric(fabric, budget=budget, **kwargs)
    m = obs.metrics()
    m.counter("fabric.probe.sweeps").inc()
    m.histogram("fabric.probe.seconds", scale=1e-3).observe(timer.elapsed)
    m.gauge("fabric.probe.sparse.probes_used").set(result.probes_used)
    return result


def _sparse_probe_fabric(
    fabric: Fabric,
    budget: float = 0.25,
    n_probes: int = 1000,
    percentile: float = 10.0,
    noise_scale: float = 0.3,
    seed: int = 0,
    measure_bw: bool = True,
    n_landmarks: Optional[int] = None,
    inter_reps: int = 3,
    fill_budget: bool = True,
) -> SparseProbeResult:
    """Probe ``fabric`` with at most ``budget`` of the dense n(n-1) probes.

    See the module docstring for the four stages.  ``budget`` is a hard
    cap: if full intra-cluster refinement would exceed it, intra pairs
    are subsampled — cluster rings and medoid-medoid anchors are
    trimmed last, so in-block ordering and every cluster-pair median
    stay grounded in real measurements for as long as the budget
    permits.  When the structural stages
    leave budget over, ``fill_budget`` (default) spends it on random
    unobserved pairs — real measurements beat completed ones;
    ``fill_budget=False`` stops at the O(n·log n + K²) structural
    probes, the minimal spend at which completion is still plan-grade.
    Raises :class:`ValueError` on a budget outside (0, 1] or the
    shared probe-parameter violations.
    """
    _validate_probe_params(n_probes, percentile, noise_scale)
    if not 0.0 < budget <= 1.0:
        raise ValueError(
            f"sparse probe budget must be in (0, 1] (fraction of the dense "
            f"n(n-1) directed probes); got {budget}")
    rng = np.random.default_rng(seed)
    n = fabric.n
    max_pairs = int(budget * n * (n - 1)) // 2     # undirected budget
    if n <= 2:
        # nothing to subsample; fall back to measuring the only pair(s)
        max_pairs = max(max_pairs, n - 1)
    elif max_pairs < n - 1:
        raise ValueError(
            f"sparse probe budget {budget} allows only {max_pairs} "
            f"undirected pairs, below the {n - 1} needed to touch every "
            f"node once; raise the budget to at least "
            f"{2 * (n - 1) / (n * (n - 1)):.4f} for n={n}")

    # 1. landmark sweep -----------------------------------------------------
    L = n_landmarks if n_landmarks is not None else \
        max(4, int(np.ceil(2 * np.log2(max(n, 2)))))
    # the sweep may spend at most half the budget; refinement needs the rest
    L = min(L, n - 1, max(1, (max_pairs // 2) // max(n, 1)))
    # the L cap above bounds the sweep at max_pairs // 2 pairs (or at the
    # n-1 spanning star when the budget is that tight, which the
    # validation guaranteed fits), so the sweep never overshoots
    landmarks = np.sort(rng.choice(n, size=max(L, 1), replace=False))
    seed_pairs = _pair_set([(i, int(l)) for l in landmarks for i in range(n)])
    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf) if measure_bw else None
    observed = np.eye(n, dtype=bool)
    lat_v, bw_v = _measure_pairs(fabric, seed_pairs, rng, percentile,
                                 noise_scale, measure_bw)
    _fill_pairs(lat, seed_pairs, lat_v)
    if bw is not None:
        _fill_pairs(bw, seed_pairs, bw_v)
    observed[seed_pairs[:, 0], seed_pairs[:, 1]] = True
    observed[seed_pairs[:, 1], seed_pairs[:, 0]] = True

    # 2. cluster the landmark embedding ------------------------------------
    emb = lat[:, landmarks]
    max_cluster = max(4, int(np.ceil(np.sqrt(max_pairs))))
    clusters = _embedding_clusters(emb, landmarks, max_cluster)
    labels = np.zeros(n, dtype=np.int64)
    for cid, members in enumerate(clusters):
        labels[members] = cid

    # 3. refinement pairs: intra-cluster + representative inter ------------
    budget_left = max_pairs - len(seed_pairs)
    intra: List[Tuple[int, int]] = []
    for members in clusters:
        m = len(members)
        full = [(members[a], members[b])
                for a in range(m) for b in range(a + 1, m)]
        intra.append(full)
    intra_pairs = [p for block in intra for p in block]
    ring_pairs: List[Tuple[int, int]] = []       # one ring per cluster
    for members in clusters:
        ring_pairs.extend(p for p in zip(members, members[1:] + members[:1])
                          if p[0] != p[1])
    ring_set = {(min(q), max(q)) for q in ring_pairs}
    if len(intra_pairs) > budget_left and budget_left > 0:
        # keep a ring through each cluster, spend the rest on random chords
        keep = list(ring_pairs)
        chords = [p for p in intra_pairs
                  if (min(p), max(p)) not in ring_set]
        extra = max(0, budget_left - len(keep))
        if chords and extra:
            picks = rng.choice(len(chords), size=min(extra, len(chords)),
                               replace=False)
            keep.extend(chords[int(x)] for x in picks)
        if len(keep) > budget_left:      # even the rings exceed budget
            picks = rng.choice(len(keep), size=max(budget_left, 0),
                               replace=False)
            keep = [keep[int(i)] for i in sorted(picks)]
        intra_pairs = keep
    medoids = [_medoid(emb, members) for members in clusters]
    medoid_set = set()
    inter: List[Tuple[int, int]] = []
    for a in range(len(clusters)):
        for b in range(a + 1, len(clusters)):
            m = (medoids[a], medoids[b])
            medoid_set.add((min(m), max(m)))
            inter.append(m)
            for _ in range(max(inter_reps - 1, 0)):
                inter.append((int(rng.choice(clusters[a])),
                              int(rng.choice(clusters[b]))))
    refine = _pair_set(intra_pairs + inter)
    if refine.size:
        new = ~observed[refine[:, 0], refine[:, 1]]
        refine = refine[new]
    if len(refine) > budget_left:
        # load-bearing pairs go last: cluster rings (in-block ordering)
        # and medoid-medoid anchors (every cluster-pair median) survive
        # while random chords and extra inter reps are trimmed
        prio_set = ring_set | medoid_set
        is_prio = np.asarray([(min(p), max(p)) in prio_set
                              for p in map(tuple, refine)])
        prio_idx = np.nonzero(is_prio)[0]
        rest_idx = np.nonzero(~is_prio)[0]
        room = max(budget_left, 0) - len(prio_idx)
        if room >= 0:
            picks = rng.choice(rest_idx.size,
                               size=min(room, int(rest_idx.size)),
                               replace=False) if rest_idx.size and room \
                else np.zeros(0, dtype=np.int64)
            keep_idx = np.concatenate([prio_idx, rest_idx[picks]])
        else:
            sub = rng.choice(prio_idx.size, size=max(budget_left, 0),
                             replace=False)
            keep_idx = prio_idx[sub]
        refine = refine[np.sort(keep_idx.astype(np.int64))]
    if refine.size:
        lat_v, bw_v = _measure_pairs(fabric, refine, rng, percentile,
                                     noise_scale, measure_bw)
        _fill_pairs(lat, refine, lat_v)
        if bw is not None:
            _fill_pairs(bw, refine, bw_v)
        observed[refine[:, 0], refine[:, 1]] = True
        observed[refine[:, 1], refine[:, 0]] = True

    # residual fill: the budget is paid for either way, so spend any
    # remainder on random unobserved (inter-cluster) pairs — at small n
    # the landmark sweep is a big budget fraction and every extra real
    # measurement sharpens the completion medians
    leftover = (max_pairs - len(seed_pairs) - len(refine)) if fill_budget \
        else 0
    if leftover > 0:
        ui, uj = np.nonzero(np.triu(~observed, 1))
        if ui.size:
            picks = rng.choice(ui.size, size=min(leftover, ui.size),
                               replace=False)
            extra = np.stack([ui[picks], uj[picks]], axis=1)
            lat_v, bw_v = _measure_pairs(fabric, extra, rng, percentile,
                                         noise_scale, measure_bw)
            _fill_pairs(lat, extra, lat_v)
            if bw is not None:
                _fill_pairs(bw, extra, bw_v)
            observed[extra[:, 0], extra[:, 1]] = True
            observed[extra[:, 1], extra[:, 0]] = True
        else:
            extra = np.zeros((0, 2), dtype=np.int64)
    else:
        extra = np.zeros((0, 2), dtype=np.int64)

    # 4. complete from cluster medians -------------------------------------
    lat_full = _complete(lat, observed, labels, "lat")
    bw_full = _complete(bw, observed, labels, "bw") if bw is not None else None
    hierarchy = infer_hierarchy(lat_full)
    probes_used = 2 * (len(seed_pairs) + len(refine) + len(extra))
    return SparseProbeResult(
        lat=lat_full, bw=bw_full, n_probes=n_probes, percentile=percentile,
        hierarchy=hierarchy, probes_used=probes_used, probe_budget=budget,
        observed=observed, landmarks=tuple(int(x) for x in landmarks))


# ---------------------------------------------------------------------------
# cluster-scoped refresh (the drift monitor's probe path)
# ---------------------------------------------------------------------------

def refresh_sparse(
    fabric: Fabric,
    prev: SparseProbeResult,
    seed: int = 0,
    moved_tol_octaves: float = 0.5,
    percentile: float = 10.0,
    noise_scale: float = 0.3,
    measure_bw: bool = True,
) -> Tuple[SparseProbeResult, List[int]]:
    """Re-probe only the clusters that moved since ``prev``.

    Each cluster's medoid is re-probed against the stored landmarks
    (O(K·L) probes); a cluster whose median landmark cost moved by more
    than ``moved_tol_octaves`` gets all of its previously observed
    pairs re-measured.  Returns the refreshed result (``probes_used``
    counts only this refresh) and the moved cluster ids.
    """
    if getattr(prev, "hierarchy", None) is None \
            or getattr(prev, "observed", None) is None \
            or not getattr(prev, "landmarks", ()):
        raise ValueError(
            "refresh_sparse needs a SparseProbeResult from "
            "sparse_probe_fabric (with hierarchy, observed mask, and "
            "landmarks); re-probe from scratch instead")
    rng = np.random.default_rng(seed)
    n = fabric.n
    landmarks = np.asarray(prev.landmarks, dtype=np.int64)
    clusters = prev.hierarchy.blocks(0)
    labels = prev.hierarchy.labels(0)
    emb_prev = prev.lat[:, landmarks]
    medoids = [_medoid(emb_prev, list(members)) for members in clusters]

    # 1. cheap sentinel sweep: medoid -> landmarks
    sentinel = _pair_set([(m, int(l)) for m in medoids for l in landmarks])
    lat_s, _ = _measure_pairs(fabric, sentinel, rng, percentile,
                              noise_scale, False)
    probe_count = len(sentinel)
    fresh = np.full((n, n), np.nan)
    _fill_pairs(fresh, sentinel, lat_s)

    moved: List[int] = []
    for cid, medoid in enumerate(medoids):
        now = np.asarray([fresh[medoid, l] for l in landmarks if l != medoid])
        ref = np.asarray([prev.lat[medoid, l] for l in landmarks
                          if l != medoid])
        ok = np.isfinite(now) & (now > 0) & (ref > 0)
        if not ok.any():
            continue
        shift = abs(float(np.log2(np.median(now[ok]) /
                                  np.median(ref[ok]))))
        if shift > moved_tol_octaves:
            moved.append(cid)

    lat = prev.lat.copy()
    bw = prev.bw.copy() if prev.bw is not None else None
    observed = prev.observed.copy()
    if moved:
        moved_mask = np.isin(labels, moved)
        touch = observed & (moved_mask[:, None] | moved_mask[None, :]) \
            & ~np.eye(n, dtype=bool)
        ii, jj = np.nonzero(np.triu(touch, 1))
        pairs = np.stack([ii, jj], axis=1)
        lat_v, bw_v = _measure_pairs(fabric, pairs, rng, percentile,
                                     noise_scale, measure_bw and bw is not None)
        _fill_pairs(lat, pairs, lat_v)
        if bw is not None and bw_v is not None:
            _fill_pairs(bw, pairs, bw_v)
        probe_count += len(pairs)
        # re-complete the moved rows/cols from the refreshed medians
        lat = _complete(np.where(observed, lat, 0.0), observed, labels, "lat")
        if bw is not None:
            bw = _complete(np.where(observed, bw, np.inf), observed,
                           labels, "bw")
    hierarchy = infer_hierarchy(lat) if moved else prev.hierarchy
    m = obs.metrics()
    m.counter("fabric.refresh.ticks").inc()
    if moved:
        m.counter("fabric.refresh.moved_clusters").inc(len(moved))
        obs.tracer().event("fabric.refresh.moved", clusters=list(moved),
                           probes=2 * probe_count)
    return SparseProbeResult(
        lat=lat, bw=bw, n_probes=prev.n_probes, percentile=percentile,
        hierarchy=hierarchy, probes_used=2 * probe_count,
        probe_budget=prev.probe_budget, observed=observed,
        landmarks=prev.landmarks), moved
