"""The one pairwise-cost formula every fabric artifact shares.

The paper's cost is c_{i,j}(S) = latency + S / bandwidth, symmetrized
with MAX (§IV-B).  Before ``repro.fabric`` existed that formula lived
twice — :meth:`Fabric.cost_matrix` and :func:`repro.fabric.probe.cost_matrix`
each re-implemented it — and the copies had already drifted in how they
handled a missing bandwidth matrix.  Both now call :func:`combine_cost`;
their public signatures are unchanged.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["combine_cost"]


def combine_cost(lat: np.ndarray, bw: Optional[np.ndarray] = None,
                 size_bytes: float = 0.0) -> np.ndarray:
    """c_{i,j}(S) = lat + S/bw, zero diagonal, symmetrized with MAX.

    ``size_bytes=0`` (or ``bw=None``) recovers the paper's latency-only
    cost; TPU callers pass the real payload so multi-MB transfers are
    bandwidth-dominated.  Always returns a fresh array.
    """
    lat = np.asarray(lat, dtype=np.float64)
    if lat.ndim != 2 or lat.shape[0] != lat.shape[1]:
        raise ValueError(
            f"combine_cost needs a square [n, n] latency matrix; got shape "
            f"{lat.shape}")
    c = lat.copy()
    if size_bytes and bw is not None:
        with np.errstate(divide="ignore"):
            c = c + float(size_bytes) / np.asarray(bw, dtype=np.float64)
    np.fill_diagonal(c, 0.0)
    return np.maximum(c, c.T)
