"""Locality-tree inference: recover the hidden datacenter hierarchy.

The paper's setting hides the placement hierarchy from the tenant — all
it can see is the probed pairwise cost matrix (§IV-B).  But the
hierarchy is *in* that matrix: a 3-tier Clos quantizes pairwise costs
into a few well-separated bands (intra-rack ~µs, cross-rack ~tens of
µs, cross-agg ~hundreds), and a TPU fleet separates ICI from DCN by two
orders of magnitude.  This module recovers that structure explicitly:

* :func:`infer_hierarchy` — average-linkage agglomerative clustering
  over the cost matrix with an **automatic tier cut**: merge heights
  inside one physical tier are tightly banded, so tier boundaries show
  up as large gaps (in octaves) between consecutive merge heights.  One
  cut per significant gap yields the recovered tiers, finest first.
* :class:`HierarchyModel` — the recovered locality tree: nested
  partitions per tier, the cut heights, ultrametric
  :meth:`~HierarchyModel.distance_ranks`, and a JSON round-trip so plan
  caches can persist the tree.

Downstream consumers: hierarchy-decomposed solving
(:func:`repro.core.reorder.optimize_rank_order_hierarchical`), sparse
probe completion (:mod:`repro.fabric.sparse`), and tree-sketch plan
fingerprints (:func:`repro.plan.cache.fabric_fingerprint`).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["HierarchyModel", "infer_hierarchy"]


Blocks = Tuple[Tuple[int, ...], ...]


@dataclasses.dataclass(frozen=True)
class HierarchyModel:
    """A recovered locality tree over ``n`` endpoints.

    ``tiers[t]`` is the node partition at tier ``t`` — finest first
    (racks before aggregation domains before the fabric root).  The
    partitions are nested: every block of tier ``t`` is contained in
    exactly one block of tier ``t+1``.  ``heights[t]`` is the cost
    threshold (seconds) the tier was cut at.  An empty ``tiers`` means
    the matrix showed no separable structure (a flat/uniform fabric).
    """

    n: int
    tiers: Tuple[Blocks, ...]
    heights: Tuple[float, ...]

    def __post_init__(self) -> None:
        assert len(self.tiers) == len(self.heights)

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def flat(self) -> bool:
        """True when no hierarchy was recovered (no exploitable tiers)."""
        return not self.tiers

    def blocks(self, tier: int = 0) -> List[List[int]]:
        """The node blocks at ``tier`` (0 = finest).  Flat model: one
        block per node at any tier."""
        if self.flat:
            return [[i] for i in range(self.n)]
        return [list(b) for b in self.tiers[tier]]

    def labels(self, tier: int = 0) -> np.ndarray:
        """[n] block id per node at ``tier`` (0 = finest)."""
        out = np.zeros(self.n, dtype=np.int64)
        if self.flat:
            return np.arange(self.n, dtype=np.int64)
        for b_id, block in enumerate(self.tiers[tier]):
            out[list(block)] = b_id
        return out

    def distance_ranks(self) -> np.ndarray:
        """Ultrametric tier distance: ``rank[i, j]`` = number of tiers
        whose partition separates i from j (0 = same finest block).

        This is the tree's own cost matrix — integer, noise-free, and
        exactly what rank-distance-structured schedules care about.
        """
        r = np.zeros((self.n, self.n), dtype=np.int64)
        for t in range(self.n_tiers):
            lab = self.labels(t)
            r += (lab[:, None] != lab[None, :]).astype(np.int64)
        return r

    def restrict(self, nodes: Sequence[int]) -> "HierarchyModel":
        """The tree over a node subset, re-indexed to local ids.

        ``nodes[k]`` becomes local id ``k`` (the plan compiler's group →
        local-rank convention).  Blocks that lose all members vanish;
        tiers whose partition collapses to a single block (or to all
        singletons) are dropped — they carry no structure over the
        subset.
        """
        nodes = [int(x) for x in nodes]
        local = {node: k for k, node in enumerate(nodes)}
        if len(local) != len(nodes):
            raise ValueError("HierarchyModel.restrict needs unique node ids")
        tiers: List[Blocks] = []
        heights: List[float] = []
        for tier, h in zip(self.tiers, self.heights):
            part = tuple(
                tuple(sorted(local[x] for x in block if x in local))
                for block in tier)
            part = tuple(b for b in part if b)
            if len(part) <= 1 or all(len(b) == 1 for b in part):
                continue
            if tiers and part == tiers[-1]:
                continue
            tiers.append(part)
            heights.append(h)
        return HierarchyModel(n=len(nodes), tiers=tuple(tiers),
                              heights=tuple(heights))

    # -- presentation ------------------------------------------------------
    def describe(self) -> str:
        """One line per tier, finest first — for CLI probe/plan dumps."""
        if self.flat:
            return f"hierarchy: flat ({self.n} nodes, no separable tiers)"
        lines = [f"hierarchy: {self.n} nodes, {self.n_tiers} tiers"]
        for t in range(self.n_tiers):
            sizes = [len(b) for b in self.tiers[t]]
            lines.append(
                f"  tier {t}: {len(sizes)} blocks "
                f"(size {min(sizes)}..{max(sizes)}, "
                f"mean {sum(sizes) / len(sizes):.1f}) "
                f"cut @ {self.heights[t] * 1e6:.1f}us")
        return "\n".join(lines)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "n": self.n,
            "tiers": [[list(b) for b in tier] for tier in self.tiers],
            "heights": list(self.heights),
        }

    @staticmethod
    def from_dict(d: dict) -> "HierarchyModel":
        return HierarchyModel(
            n=int(d["n"]),
            tiers=tuple(
                tuple(tuple(int(x) for x in b) for b in tier)
                for tier in d["tiers"]),
            heights=tuple(float(h) for h in d["heights"]),
        )


# ---------------------------------------------------------------------------
# agglomerative inference
# ---------------------------------------------------------------------------

def _average_linkage(c: np.ndarray) -> List[Tuple[int, int, float]]:
    """UPGMA merges over the full matrix: [(rep_i, rep_j, height), ...].

    Lance–Williams update in place — each of the n-1 merges is one O(n)
    row recombination plus an O(n^2) argmin, so the whole dendrogram is
    a few numpy passes even at n=1024.  Average linkage is reducible,
    so merge heights are non-decreasing (no inversions) — the property
    the gap-based tier cut below relies on.
    """
    n = c.shape[0]
    D = np.asarray(c, dtype=np.float64).copy()
    np.fill_diagonal(D, np.inf)
    size = np.ones(n)
    merges: List[Tuple[int, int, float]] = []
    for _ in range(n - 1):
        k = int(np.argmin(D))
        i, j = divmod(k, n)
        if i > j:
            i, j = j, i
        h = float(D[i, j])
        merges.append((i, j, h))
        si, sj = size[i], size[j]
        row = (si * D[i] + sj * D[j]) / (si + sj)
        D[i, :] = row
        D[:, i] = row
        D[i, i] = np.inf
        D[j, :] = np.inf
        D[:, j] = np.inf
        size[i] = si + sj
    return merges


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def _partition_at(n: int, merges: Sequence[Tuple[int, int, float]],
                  threshold: float) -> Blocks:
    """Dendrogram cut: connected components of merges below threshold."""
    uf = _UnionFind(n)
    for i, j, h in merges:
        if h <= threshold:
            uf.union(i, j)
    groups: dict = {}
    for x in range(n):
        groups.setdefault(uf.find(x), []).append(x)
    return tuple(tuple(sorted(g)) for g in
                 sorted(groups.values(), key=lambda g: g[0]))


def infer_hierarchy(cost_matrix: np.ndarray,
                    max_tiers: int = 3,
                    gap_octaves: float = 0.75,
                    min_merges_below: int = 1) -> HierarchyModel:
    """Recover the locality tree from a probed pairwise cost matrix.

    Agglomerate with average linkage, then cut the dendrogram wherever
    consecutive sorted merge heights jump by more than ``gap_octaves``
    (log2): probe noise moves same-tier heights by fractions of an
    octave, while Clos/DCN tier boundaries are 1–7 octaves wide.  At
    most ``max_tiers`` cuts are kept (the largest gaps win), finest
    first.  A matrix with no significant gap yields a *flat* model
    (``HierarchyModel.flat``) — consumers then fall back to the dense
    paths.
    """
    c = np.asarray(cost_matrix, dtype=np.float64)
    if c.ndim != 2 or c.shape[0] != c.shape[1]:
        raise ValueError(
            f"infer_hierarchy needs a square [n, n] cost matrix; got "
            f"shape {c.shape}")
    n = c.shape[0]
    if n < 4:
        return HierarchyModel(n=n, tiers=(), heights=())
    c = np.maximum(c, c.T)
    merges = _average_linkage(c)
    hs = np.asarray([h for (_, _, h) in merges], dtype=np.float64)
    # Guard degenerate zero heights (identical rows) before the log.
    floor = max(float(hs.max()), 1e-30) * 1e-12
    log_h = np.log2(np.maximum(np.sort(hs), floor))
    gaps = np.diff(log_h)
    cut_idx = [int(k) for k in np.argsort(gaps)[::-1]
               if gaps[k] > gap_octaves][:max_tiers]
    cut_idx = sorted(cut_idx)
    tiers: List[Blocks] = []
    heights: List[float] = []
    sorted_h = np.sort(hs)
    seen: set = set()
    for k in cut_idx:
        if k + 1 < min_merges_below:
            continue
        # geometric midpoint of the straddling heights: maximally far
        # (in octaves) from both tiers' merge bands
        theta = float(np.sqrt(max(sorted_h[k], floor) * sorted_h[k + 1]))
        part = _partition_at(n, merges, theta)
        key = tuple(part)
        if len(part) <= 1 or key in seen:
            continue
        seen.add(key)
        tiers.append(part)
        heights.append(theta)
    return HierarchyModel(n=n, tiers=tuple(tiers), heights=tuple(heights))
