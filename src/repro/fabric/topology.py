"""Synthetic network fabrics: hierarchical datacenters and TPU fleets.

The paper's setting is a multi-tenant hierarchical datacenter whose
pairwise VM-to-VM cost is non-uniform and hidden from the tenant.  This
module generates such fabrics so every algorithmic layer (probing, cost
models, solvers, simulator) can be exercised without cloud access:

* :func:`make_datacenter` — classic 3-tier Clos (node -> ToR -> agg ->
  spine) with oversubscription and per-link multi-tenant congestion.
* :func:`make_tpu_fleet` — one or more TPU pods; intra-pod 2D torus ICI,
  inter-pod DCN through datacenter tiers.  This is the adaptation
  target: the ``pod`` mesh axis of a multi-pod JAX job rides on DCN.
* :func:`scramble` — random node relabeling: models the "randomly ordered
  IP list" a tenant gets from the provider (paper §I).

All links are **full duplex**: each physical link contributes separate
up/down directed link ids, so a chunked ring (every node sends and
receives concurrently) does not self-contend on NICs.

A :class:`Fabric` carries everything downstream layers need:

* ``lat[i, j]``   — base one-way latency seconds between endpoints,
* ``bw[i, j]``    — bottleneck bandwidth bytes/s of the path (no contention),
* ``paths[i][j]`` — tuple of directed link ids the path traverses (for the
  contention-aware simulator),
* ``link_bw[l]``  — capacity of each directed link id.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .costs import combine_cost

__all__ = [
    "Fabric",
    "make_datacenter",
    "make_tpu_fleet",
    "scramble",
]


@dataclasses.dataclass
class Fabric:
    """A network fabric between ``n`` endpoints (VMs or TPU chips)."""

    n: int
    lat: np.ndarray                       # [n, n] seconds, 0 on diagonal
    bw: np.ndarray                        # [n, n] bytes/s, inf on diagonal
    paths: List[List[Tuple[int, ...]]]    # paths[i][j] -> directed link ids
    link_bw: np.ndarray                   # [n_links] bytes/s
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        assert self.lat.shape == (self.n, self.n)
        assert self.bw.shape == (self.n, self.n)

    def cost_matrix(self, size_bytes: float = 0.0) -> np.ndarray:
        """Paper-style pairwise cost c_{i,j}(S) = latency + S / bandwidth.

        The paper uses a latency-centric cost (§IV-B, TCP throughput ~
        MSS / (RTT sqrt(p))): ``size_bytes=0`` (default) reproduces that.
        On TPU fabrics the bandwidth term matters for multi-MB payloads,
        so callers there pass the real payload.
        """
        return combine_cost(self.lat, self.bw, size_bytes)

    def subset(self, nodes: Sequence[int]) -> "Fabric":
        """Fabric restricted to ``nodes`` (elastic restart after failure).

        Raises :class:`ValueError` on empty, out-of-range, or duplicate
        node ids — a wrong survivor list must fail loudly here, not as a
        numpy index error deep inside a solver.
        """
        nodes = [int(x) for x in nodes]
        if not nodes:
            raise ValueError(
                "Fabric.subset needs at least one node; got an empty list")
        bad = [x for x in nodes if x < 0 or x >= self.n]
        if bad:
            raise ValueError(
                f"Fabric.subset node ids {bad} out of range for a fabric of "
                f"{self.n} nodes (valid ids: 0..{self.n - 1})")
        if len(set(nodes)) != len(nodes):
            dups = sorted({x for x in nodes if nodes.count(x) > 1})
            raise ValueError(
                f"Fabric.subset node ids must be unique; duplicates: {dups}")
        idx = np.asarray(nodes)
        paths = [[self.paths[i][j] for j in nodes] for i in nodes]
        return Fabric(
            n=len(nodes),
            lat=self.lat[np.ix_(idx, idx)].copy(),
            bw=self.bw[np.ix_(idx, idx)].copy(),
            paths=paths,
            link_bw=self.link_bw.copy(),
            meta=dict(self.meta, parent_nodes=nodes),
        )


class _LinkTable:
    def __init__(self) -> None:
        self.bw: List[float] = []
        self.lat: List[float] = []

    def add(self, bw_bytes: float, lat_s: float) -> int:
        self.bw.append(bw_bytes)
        self.lat.append(lat_s)
        return len(self.bw) - 1

    def add_duplex(self, bw_bytes: float, lat_s: float) -> Tuple[int, int]:
        return self.add(bw_bytes, lat_s), self.add(bw_bytes, lat_s)


def _assemble(
    n: int,
    chains: List[List[Tuple[int, int]]],  # per node: [(up_id, down_id), ...]
    links: _LinkTable,
    meta: Dict[str, object],
) -> Fabric:
    """Build a Fabric from per-node duplex uplink chains.

    The path i -> j walks i's *up* directions to the lowest common level,
    then j's *down* directions back out.
    """
    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf)
    link_bw = np.asarray(links.bw, dtype=np.float64)
    link_lat = np.asarray(links.lat, dtype=np.float64)
    paths: List[List[Tuple[int, ...]]] = [[() for _ in range(n)] for _ in range(n)]
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            ci, cj = chains[i], chains[j]
            k = 0
            while (
                k < min(len(ci), len(cj))
                and ci[len(ci) - 1 - k] == cj[len(cj) - 1 - k]
            ):
                k += 1
            ups = [u for (u, _) in ci[: len(ci) - k]]
            downs = [d for (_, d) in reversed(cj[: len(cj) - k])]
            path = tuple(ups + downs)
            paths[i][j] = path
            lat[i, j] = float(link_lat[list(path)].sum()) if path else 0.0
            bw[i, j] = float(link_bw[list(path)].min()) if path else np.inf
    return Fabric(n=n, lat=lat, bw=bw, paths=paths, link_bw=link_bw, meta=meta)


def make_datacenter(
    n_nodes: int,
    nodes_per_rack: int = 8,
    racks_per_agg: int = 4,
    oversub: float = 4.0,
    nic_gbps: float = 12.5,
    tenancy_load: float = 0.4,
    heavy_tail: float = 0.8,
    seed: int = 0,
) -> Fabric:
    """3-tier Clos datacenter with multi-tenant congestion (paper §II-A).

    * node -> ToR: dedicated full-duplex NIC (not shared; "VMs within the
      same rack have the best and stable performance").
    * ToR -> agg: oversubscribed by ``oversub``; multi-tenant load both
      cuts capacity and adds queueing latency.
    * agg -> spine: further oversubscribed, highest queueing.

    Latency ranges match the paper's Fig. 2 heatmap: intra-rack a few µs,
    cross-agg tens to hundreds of µs depending on load.
    """
    rng = np.random.default_rng(seed)
    n_racks = -(-n_nodes // nodes_per_rack)
    n_aggs = -(-n_racks // racks_per_agg)
    nic = nic_gbps * 1e9  # GB/s -> bytes/s

    links = _LinkTable()

    def congestion() -> Tuple[float, float]:
        """(capacity keep-fraction, latency multiplier) for a shared link.

        Multi-tenant queueing is heavy-tailed (noisy neighbors): a
        lognormal latency factor gives most links a mild penalty and a
        few links a 10-30x one — the regime behind the paper's Fig. 1
        wide performance distribution.
        """
        load = rng.beta(2.0, 2.0 / max(tenancy_load, 1e-3) - 2.0)
        tail = float(np.exp(rng.normal(0.0, heavy_tail)))
        return (1.0 - 0.8 * load) / (1.0 + 0.3 * (tail - 1.0)), (1.0 + 10.0 * load) * tail

    tor_up: List[Tuple[int, int]] = []
    for _ in range(n_racks):
        keep, lat_mult = congestion()
        cap = nic * nodes_per_rack / oversub * keep
        tor_up.append(links.add_duplex(cap, 5e-6 * lat_mult))
    agg_up: List[Tuple[int, int]] = []
    for _ in range(n_aggs):
        keep, lat_mult = congestion()
        cap = nic * nodes_per_rack * racks_per_agg / (oversub * 2.0) * keep
        agg_up.append(links.add_duplex(cap, 15e-6 * lat_mult))

    chains: List[List[Tuple[int, int]]] = []
    for i in range(n_nodes):
        rack = i // nodes_per_rack
        agg = rack // racks_per_agg
        l_nic = links.add_duplex(
            nic * (1.0 - 0.2 * rng.beta(2, 8)), 1.5e-6 * (1.0 + rng.random())
        )
        chains.append([l_nic, tor_up[rack], agg_up[agg]])

    return _assemble(
        n_nodes, chains, links,
        meta={
            "kind": "datacenter", "n_racks": n_racks, "n_aggs": n_aggs,
            "nodes_per_rack": nodes_per_rack, "seed": seed,
        },
    )


def make_tpu_fleet(
    n_pods: int = 2,
    pod_shape: Tuple[int, int] = (16, 16),
    ici_gbps: float = 50.0,
    ici_hop_lat: float = 1e-6,
    dcn_gbps_per_host: float = 25.0,
    dcn_lat: float = 25e-6,
    fragmentation: float = 0.0,
    seed: int = 0,
) -> Fabric:
    """TPU fleet: per-pod 2D torus ICI, DCN between pods.

    Intra-pod chip-to-chip cost follows torus hop distance (placement of a
    logical rank inside the pod matters — the intra-pod analogue of the
    paper's locality).  ``fragmentation`` > 0 randomly degrades a fraction
    of ICI links, modeling partial/fragmented slice allocations.

    Inter-pod traffic leaves through per-host DCN NICs (4 chips/host) into
    pod-edge routers and a shared spine; DCN links carry multi-tenant load.
    """
    rng = np.random.default_rng(seed)
    px, py = pod_shape
    chips_per_pod = px * py
    n = n_pods * chips_per_pod
    ici_bw = ici_gbps * 1e9
    dcn_bw = dcn_gbps_per_host * 1e9

    links = _LinkTable()

    # --- torus links: one duplex pair per (pod, x, y, axis) -------------
    torus_link: Dict[Tuple[int, int, int, int], Tuple[int, int]] = {}
    for p in range(n_pods):
        for x in range(px):
            for y in range(py):
                for axis in (0, 1):
                    degrade = 1.0
                    if fragmentation and rng.random() < fragmentation:
                        degrade = 0.25 + 0.5 * rng.random()
                    torus_link[(p, x, y, axis)] = links.add_duplex(
                        ici_bw * degrade, ici_hop_lat
                    )

    # --- DCN: host NIC -> pod edge -> spine ------------------------------
    spine = links.add_duplex(dcn_bw * n / 4 / 3.0, 10e-6)
    pod_edge = []
    for _ in range(n_pods):
        load = rng.beta(2, 6)
        pod_edge.append(
            links.add_duplex(dcn_bw * chips_per_pod / 4 / 2.0 * (1 - 0.6 * load), 8e-6)
        )
    host_nic = []
    for _ in range(n // 4):
        load = rng.beta(2, 8)
        host_nic.append(
            links.add_duplex(dcn_bw * (1 - 0.5 * load), dcn_lat * (0.8 + 0.4 * rng.random()))
        )

    def chip_id(p: int, x: int, y: int) -> int:
        return p * chips_per_pod + x * py + y

    def torus_path(p: int, xa: int, ya: int, xb: int, yb: int) -> Tuple[int, ...]:
        """X-then-Y dimension-ordered routing with wraparound; directed."""
        out: List[int] = []
        x = xa
        dx = (xb - xa) % px
        step = 1 if dx <= px // 2 else -1
        while x != xb:
            nx = (x + step) % px
            lo = min(x, nx) if abs(x - nx) == 1 else max(x, nx)
            duplex = torus_link[(p, lo, ya, 0)]
            out.append(duplex[0] if step == 1 else duplex[1])
            x = nx
        y = ya
        dy = (yb - ya) % py
        step = 1 if dy <= py // 2 else -1
        while y != yb:
            ny = (y + step) % py
            lo = min(y, ny) if abs(y - ny) == 1 else max(y, ny)
            duplex = torus_link[(p, xb, lo, 1)]
            out.append(duplex[0] if step == 1 else duplex[1])
            y = ny
        return tuple(out)

    lat = np.zeros((n, n))
    bw = np.full((n, n), np.inf)
    link_bw = np.asarray(links.bw)
    link_lat = np.asarray(links.lat)
    paths: List[List[Tuple[int, ...]]] = [[() for _ in range(n)] for _ in range(n)]

    for p in range(n_pods):
        for xa in range(px):
            for ya in range(py):
                a = chip_id(p, xa, ya)
                for xb in range(px):
                    for yb in range(py):
                        b = chip_id(p, xb, yb)
                        if a == b:
                            continue
                        path = torus_path(p, xa, ya, xb, yb)
                        paths[a][b] = path
                        lat[a, b] = float(link_lat[list(path)].sum())
                        bw[a, b] = float(link_bw[list(path)].min())

    for a in range(n):
        pa = a // chips_per_pod
        for b in range(n):
            pb = b // chips_per_pod
            if a == b or pa == pb:
                continue
            path = (
                host_nic[a // 4][0], pod_edge[pa][0], spine[0],
                pod_edge[pb][1], host_nic[b // 4][1],
            )
            paths[a][b] = path
            lat[a, b] = float(link_lat[list(path)].sum())
            bw[a, b] = float(link_bw[list(path)].min())

    return Fabric(
        n=n, lat=lat, bw=bw, paths=paths, link_bw=link_bw,
        meta={
            "kind": "tpu_fleet", "n_pods": n_pods, "pod_shape": pod_shape,
            "chips_per_pod": chips_per_pod, "seed": seed,
            "ici_gbps": ici_gbps, "dcn_gbps_per_host": dcn_gbps_per_host,
        },
    )


def scramble(fabric: Fabric, seed: int = 0) -> Tuple[Fabric, np.ndarray]:
    """Randomly relabel nodes: the tenant's 'random IP list' (paper §I).

    Returns ``(scrambled, hidden)`` where ``hidden[new_id] = old_id``.
    A solver working on the scrambled fabric should rediscover locality
    without ever seeing ``hidden``.
    """
    rng = np.random.default_rng(seed)
    hidden = rng.permutation(fabric.n)
    paths = [
        [fabric.paths[hidden[i]][hidden[j]] for j in range(fabric.n)]
        for i in range(fabric.n)
    ]
    return (
        Fabric(
            n=fabric.n,
            lat=fabric.lat[np.ix_(hidden, hidden)].copy(),
            bw=fabric.bw[np.ix_(hidden, hidden)].copy(),
            paths=paths,
            link_bw=fabric.link_bw.copy(),
            meta=dict(fabric.meta, scrambled=True),
        ),
        hidden,
    )
