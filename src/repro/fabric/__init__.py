"""repro.fabric — everything the system knows about the network fabric.

One subsystem owns the fabric lifecycle end to end:

* :mod:`~repro.fabric.topology` — synthetic fabrics (Clos datacenter,
  TPU fleet) and the :class:`Fabric` artifact (moved from
  ``repro.core.topology``, which remains as a deprecating shim);
* :mod:`~repro.fabric.probe` — dense pairwise probing, paper §IV-B
  (moved from ``repro.core.probe``, shim kept);
* :mod:`~repro.fabric.costs` — the one shared c_{i,j}(S) formula;
* :mod:`~repro.fabric.hierarchy` — locality-tree inference from a
  probed cost matrix (agglomerative, automatic tier cut);
* :mod:`~repro.fabric.sparse` — budgeted O(n·log n) probing that
  reconstructs a plan-grade matrix from ≤25% of the dense probes, plus
  the cluster-scoped drift refresh.

See DESIGN.md §8 for the subsystem architecture and the migration map.
"""

from .costs import combine_cost  # noqa: F401
from .hierarchy import HierarchyModel, infer_hierarchy  # noqa: F401
from .probe import (  # noqa: F401
    ProbeResult,
    cost_matrix,
    probe_fabric,
    probe_mesh_pairwise,
)
from .sparse import (  # noqa: F401
    SparseProbeResult,
    refresh_sparse,
    sparse_probe_fabric,
)
from .topology import (  # noqa: F401
    Fabric,
    make_datacenter,
    make_tpu_fleet,
    scramble,
)

__all__ = [
    "Fabric",
    "make_datacenter",
    "make_tpu_fleet",
    "scramble",
    "ProbeResult",
    "probe_fabric",
    "probe_mesh_pairwise",
    "cost_matrix",
    "combine_cost",
    "HierarchyModel",
    "infer_hierarchy",
    "SparseProbeResult",
    "sparse_probe_fabric",
    "refresh_sparse",
]
