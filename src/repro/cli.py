"""The single ``python -m repro`` command-line interface.

One argparse tree, five subcommands, all round-tripping
:class:`repro.session.SessionConfig`::

    python -m repro probe --fabric datacenter --nodes 64
    python -m repro plan  --mesh 8x8 --dry-run
    python -m repro train --arch qwen2-0.5b --mesh 1x1 --steps 20
    python -m repro serve --arch qwen2-0.5b --max-new 16
    python -m repro bench --smoke

Every subcommand accepts ``--config session.json`` plus ``REPRO_*``
environment overrides (see :meth:`SessionConfig.from_env`) plus explicit
flags, in that precedence order; ``--dump-config`` prints the resolved
config as JSON and exits, so a flag-built config can be saved and
re-fed via ``--config`` unchanged.

The old ``python -m repro.launch.train`` / ``repro.launch.serve`` entry
points remain as deprecation shims that delegate here.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro import obs

__all__ = ["main", "build_parser", "session_config_from_args",
           "run_obs_scenario"]


# ---------------------------------------------------------------------------
# shared session arguments
# ---------------------------------------------------------------------------

def _add_session_args(ap: argparse.ArgumentParser) -> None:
    g = ap.add_argument_group("session config")
    g.add_argument("--config", default=None, metavar="JSON",
                   help="SessionConfig JSON file to start from")
    g.add_argument("--fabric", default=None,
                   choices=["datacenter", "tpu-fleet", "live"])
    g.add_argument("--nodes", type=int, default=None,
                   help="datacenter fabric size")
    g.add_argument("--pods", type=int, default=None,
                   help="tpu-fleet pod count")
    g.add_argument("--pod-shape", default=None, metavar="AxB")
    g.add_argument("--scramble-seed", type=int, default=None,
                   help="relabel nodes (the cloud's random IP list)")
    g.add_argument("--fabric-seed", type=int, default=None)
    g.add_argument("--probe-seed", type=int, default=None)
    g.add_argument("--probe-mode", default=None, choices=["dense", "sparse"],
                   help="dense n^2 probing or budgeted sparse probing")
    g.add_argument("--sparse", action="store_true", default=None,
                   help="shorthand for --probe-mode sparse")
    g.add_argument("--probe-budget", type=float, default=None,
                   help="sparse probe budget as a fraction of n(n-1)")
    g.add_argument("--mesh", default=None, metavar="AxB[xC]",
                   help="N-D mesh shape, e.g. 8x8 or 2x16x16")
    g.add_argument("--axes", default=None, metavar="a,b",
                   help="mesh axis names, e.g. data,model")
    g.add_argument("--payload-bytes", type=float, default=None)
    g.add_argument("--moe", action="store_true", default=None,
                   help="add the EP all-to-all to the default mix")
    g.add_argument("--plan-cache-dir", default=None,
                   help="persist compiled plans across launches")
    g.add_argument("--iters", type=int, default=None,
                   help="solver SA iterations per entry")
    g.add_argument("--chains", type=int, default=None)
    g.add_argument("--solver-engine", default=None,
                   choices=["vectorized", "reference"])
    g.add_argument("--solver-backend", default=None,
                   choices=["numpy", "jax"])
    g.add_argument("--solver-seed", type=int, default=None)
    g.add_argument("--drift-threshold", type=float, default=None)
    g.add_argument("--dump-config", action="store_true",
                   help="print the resolved SessionConfig JSON and exit")


def session_config_from_args(args: argparse.Namespace,
                             workload: Optional[str] = None):
    """Resolve file -> environment -> explicit flags into a SessionConfig."""
    from repro.session import SessionConfig

    base = SessionConfig.load(args.config) if args.config else SessionConfig()
    cfg = SessionConfig.from_env(base=base)

    updates: Dict[str, Any] = {}
    fabric: Dict[str, Any] = {}
    if args.fabric is not None:
        fabric["kind"] = args.fabric
    if args.nodes is not None:
        fabric["nodes"] = args.nodes
    if args.pods is not None:
        fabric["n_pods"] = args.pods
    if getattr(args, "pod_shape", None) is not None:
        fabric["pod_shape"] = args.pod_shape
    if args.scramble_seed is not None:
        fabric["scramble_seed"] = args.scramble_seed
    if args.fabric_seed is not None:
        fabric["seed"] = args.fabric_seed
    if fabric:
        updates["fabric"] = fabric
    probe: Dict[str, Any] = {}
    if args.probe_seed is not None:
        probe["seed"] = args.probe_seed
    if getattr(args, "probe_mode", None) is not None:
        probe["mode"] = args.probe_mode
    if getattr(args, "sparse", None):
        probe["mode"] = "sparse"
    if getattr(args, "probe_budget", None) is not None:
        probe["budget"] = args.probe_budget
    if probe:
        updates["probe"] = probe
    mesh: Dict[str, Any] = {}
    if args.mesh is not None:
        mesh["shape"] = args.mesh
    if args.axes is not None:
        mesh["axis_names"] = args.axes
    if mesh:
        updates["mesh"] = mesh
    solver: Dict[str, Any] = {}
    budget: Dict[str, Any] = {}
    if args.iters is not None:
        budget["iters"] = args.iters
    if args.chains is not None:
        budget["chains"] = args.chains
    if args.solver_engine is not None:
        budget["engine"] = args.solver_engine
    if args.solver_backend is not None:
        budget["backend"] = args.solver_backend
    if budget:
        solver["budget"] = budget
    if args.solver_seed is not None:
        solver["seed"] = args.solver_seed
    if solver:
        updates["solver"] = solver
    if args.plan_cache_dir is not None:
        updates["cache"] = {"dir": args.plan_cache_dir}
    if args.drift_threshold is not None:
        updates["drift"] = {"threshold": args.drift_threshold}
    if args.payload_bytes is not None:
        updates["payload_bytes"] = args.payload_bytes
    if args.moe:
        updates["moe"] = True
    if workload is not None:
        updates["workload"] = workload
    return cfg.replace(**updates) if updates else cfg


def _maybe_dump(args: argparse.Namespace, cfg) -> bool:
    if getattr(args, "dump_config", False):
        print(cfg.to_json())
        return True
    return False


# ---------------------------------------------------------------------------
# probe
# ---------------------------------------------------------------------------

def cmd_probe(args: argparse.Namespace) -> int:
    import numpy as np

    from repro.session import Session

    cfg = session_config_from_args(args)
    if _maybe_dump(args, cfg):
        return 0
    with Session(cfg) as s:
        s.attach()
        probe = s.probe
        lat = probe.lat
        off = lat[~np.eye(lat.shape[0], dtype=bool)] if lat.shape[0] > 1 \
            else np.zeros(1)
        print(f"[probe] fabric={cfg.fabric.kind} n={probe.n} "
              f"lat p10={np.percentile(off, 10) * 1e6:.1f}us "
              f"p50={np.percentile(off, 50) * 1e6:.1f}us "
              f"p90={np.percentile(off, 90) * 1e6:.1f}us "
              f"bw={'probed' if probe.bw is not None else 'n/a'}")
        if getattr(probe, "probes_used", 0):
            print(f"[probe] sparse: {probe.probes_used} directed probes "
                  f"({probe.probe_fraction * 100:.1f}% of dense n(n-1), "
                  f"budget {probe.probe_budget * 100:.0f}%)")
        if s.hierarchy is not None:
            print(s.hierarchy.describe())
        if args.out:
            payload = {
                "n": probe.n,
                "lat": probe.lat.tolist(),
                "bw": None if probe.bw is None else
                      np.where(np.isfinite(probe.bw), probe.bw, -1.0).tolist(),
                "n_probes": probe.n_probes,
                "percentile": probe.percentile,
            }
            if s.hierarchy is not None:
                payload["hierarchy"] = s.hierarchy.to_dict()
                payload["probes_used"] = int(getattr(probe, "probes_used", 0))
            with open(args.out, "w") as f:
                json.dump(payload, f)
            print(f"[probe] wrote {args.out}")
    return 0


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

def cmd_plan(args: argparse.Namespace) -> int:
    from repro.session import Session

    cfg = session_config_from_args(args)
    if args.dry_run:
        # a dry run must leave no trace: no persistent cache writes
        cfg = cfg.replace(cache={"dir": None})
    if _maybe_dump(args, cfg):
        return 0
    with Session(cfg) as s:
        plan = s.plan()
        hit = "cache hit" if s.service.stats["cache_hits"] else \
            f"compiled in {plan.compile_seconds:.2f}s"
        mode = "dry-run: " if args.dry_run else ""
        print(f"[plan] {mode}{plan.fingerprint.digest} ({hit}) "
              f"mix={cfg.workload} n={plan.n}")
        for (op, bucket, group), e in sorted(plan.entries.items()):
            fp = f" prog={e.program_fingerprint}" if e.program_fingerprint \
                else ""
            print(f"  {op:<15} bucket=2^{bucket:<3} group={len(group):>4} "
                  f"-> {e.algo:<20} chunks={e.chunks} "
                  f"t={e.expected_time * 1e3:.3f}ms "
                  f"({e.best_identity_time / max(e.expected_time, 1e-30):.2f}x "
                  f"vs identity){fp}")
        if plan.mesh_plan is not None:
            mp = plan.mesh_plan
            print(f"  mesh {'x'.join(map(str, mp.assignment.shape))} "
                  f"cost {mp.baseline_cost:.5f} -> {mp.cost:.5f} "
                  f"({mp.baseline_cost / max(mp.cost, 1e-30):.2f}x)")
        if plan.meta.get("hierarchy"):
            from repro.fabric import HierarchyModel

            tree = HierarchyModel.from_dict(plan.meta["hierarchy"])
            for line in tree.describe().splitlines():
                print(f"  {line}")
        if args.out:
            # an explicit --out is a user-requested artifact, written
            # even under --dry-run (which only skips the plan *store*)
            with open(args.out, "w") as f:
                f.write(plan.to_json())
            print(f"[plan] wrote {args.out}")
    return 0


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def cmd_train(args: argparse.Namespace) -> int:
    import dataclasses as _dc

    import jax

    from repro.configs import get_config
    from repro.data import SyntheticLM, host_batch
    from repro.launch.mesh import mesh_context
    from repro.launch.specs import configure_sp
    from repro.launch.train import build_mesh
    from repro.models import get_model
    from repro.optim import AdamWConfig, cosine_schedule
    from repro.train import Trainer, TrainerConfig, init_state, make_train_step

    cfg = session_config_from_args(args, workload="train")
    if _maybe_dump(args, cfg):
        return 0

    arch = get_config(args.arch)
    if args.smoke:
        arch = _dc.replace(arch.smoke(), vocab_size=2048)
    model = get_model(arch)
    mesh, plan = build_mesh(args, len(jax.devices()),
                            moe=bool(arch.n_experts), session_config=cfg)
    configure_sp(arch, mesh, plan=plan)   # SP/EP contexts + planned a2a ring

    state = init_state(model, jax.random.PRNGKey(0))
    opt = AdamWConfig(schedule=cosine_schedule(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticLM(arch.vocab_size, args.seq, args.batch, seed=0)

    def batches():
        i = 0
        while True:
            yield host_batch(ds, i)
            i += 1

    with mesh_context(mesh):
        trainer = Trainer(
            step_fn=step_fn, state=state, batches=batches(),
            cfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                              ckpt_dir=args.ckpt_dir, log_every=20))
        report = trainer.run()
    h = report["history"]
    print(f"[train] arch={arch.name} steps={report['final_step']} "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")
    return 0


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def cmd_serve(args: argparse.Namespace) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import mesh_context
    from repro.launch.specs import configure_sp
    from repro.launch.train import build_mesh
    from repro.models import get_model
    from repro.serve import GenerationConfig, GenerationEngine
    from repro.session import serve_mix

    cfg = session_config_from_args(args, workload="serve")
    # decode payloads are smaller than gradient payloads: keep the old
    # serve launcher's 1e6 default unless the payload was set explicitly
    # (flag, config file, or environment)
    import os

    if args.payload_bytes is None and args.config is None \
            and "REPRO_PAYLOAD_BYTES" not in os.environ:
        cfg = cfg.replace(payload_bytes=1e6)
    if _maybe_dump(args, cfg):
        return 0

    arch = get_config(args.arch)
    if args.smoke:
        arch = arch.smoke()
    model = get_model(arch)
    mix = serve_mix(cfg.payload_bytes, moe=bool(arch.n_experts))
    mesh, plan = build_mesh(args, len(jax.devices()), mix=mix,
                            session_config=cfg)
    configure_sp(arch, mesh, plan=plan)

    params = model.init(jax.random.PRNGKey(0))
    fe = None
    if arch.family == "vlm":
        fe = jnp.ones((args.batch, arch.n_img_tokens, arch.d_model),
                      jnp.float32)
    if arch.family == "encdec":
        fe = jnp.ones((args.batch, arch.n_audio_ctx, arch.d_model),
                      jnp.float32)

    prompts = [
        [(11 * i + j) % arch.vocab_size for j in range(args.prompt_len)]
        for i in range(args.batch)
    ]
    with mesh_context(mesh):
        eng = GenerationEngine(
            model, params,
            GenerationConfig(max_new_tokens=args.max_new, eos_token=-1),
            plan=plan)
        if plan is not None:
            print(f"[serve] plan {plan.fingerprint.digest} hints: "
                  f"{eng.collective_hints(cfg.payload_bytes)}")
        timer = obs.tracer().timer("cli.serve.generate", batch=args.batch)
        with timer:
            outs = eng.generate(prompts, frontend_embeds=fe)
        dt = max(timer.elapsed, 1e-9)
    total = sum(len(o) for o in outs)
    print(f"[serve] arch={arch.name} {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    return 0


# ---------------------------------------------------------------------------
# bench
# ---------------------------------------------------------------------------

def cmd_bench_faults(args: argparse.Namespace) -> int:
    """Seeded churn scenario: preempt 25% of the nodes mid-session, let
    the degradation ladder recover, referee the recovered order against
    identity, and rejoin the preempted nodes.  Fails (exit 1) if any
    recovery raises, loses the plan, or serves an order the cost model
    scores worse than identity."""
    from repro.faults import FaultSchedule, FaultyFabric
    from repro.fabric import make_datacenter, scramble
    from repro.session import Session

    n = 16 if args.smoke else 32
    iters = 200 if args.smoke else 400
    fab, _ = scramble(make_datacenter(n, seed=0), seed=1)
    schedule = FaultSchedule.generate(
        n, ticks=8, seed=args.seed, preempt_frac=0.25,
        timeout_rate=0.0, drop_rate=0.0, nan_rate=0.0)
    faulty = FaultyFabric(fab, schedule)
    cfg = session_config_from_args(args).replace(
        mesh={"shape": ()}, cache={"dir": None},
        probe={"n_probes": 4},
        solver={"budget": {"iters": iters, "chains": 4}})
    events: List[Dict[str, Any]] = []
    with Session(cfg) as s:
        s.attach(fab)
        s.plan()
        for _ in range(8):
            for ev in faulty.advance():
                timer = obs.tracer().timer("bench.recovery", kind=ev.kind)
                with timer:
                    if ev.kind == "node_preempt":
                        alive = s.alive
                        plan = s.on_node_leave(
                            [alive.index(b) for b in ev.nodes if b in alive])
                    else:
                        plan = s.on_node_join(
                            [b for b in ev.nodes if b not in s.alive])
                ms = timer.elapsed * 1e3
                ok = plan is not None and all(
                    e.expected_time <= e.best_identity_time * (1 + 1e-9)
                    and sorted(e.perm) == list(e.group)
                    for e in plan.entries.values())
                events.append({
                    "kind": ev.kind, "survivors": len(s.alive),
                    "recovery_ms": round(ms, 2),
                    "rungs": sorted(set(
                        (plan.meta.get("rungs") or {}).values()))
                    if plan is not None else [],
                    "ok": ok,
                })
                print(f"bench_faults,{ev.kind},{ms * 1e3:.0f},"
                      f"survivors={len(s.alive)}")
        health = s.health
    payload = {"bench": "session_faults", "smoke": bool(args.smoke),
               "n": n, "seed": args.seed, "health": health,
               "events": events}
    print(json.dumps(payload, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {args.out}")
    if not events or not all(e["ok"] for e in events):
        print("[bench] FAIL: a churn recovery lost the plan or served "
              "an order worse than identity")
        return 1
    return 0


def run_obs_scenario(smoke: bool = True, seed: int = 0,
                     window_s: float = 1.0) -> Dict[str, Any]:
    """The obs benchmark scenario (CLI ``bench --scenario obs`` and
    ``benchmarks/obs_trace.py`` share this).

    Two measurements:

    * **tracing overhead** — median wall time of the same
      ``PlanCompiler.compile`` with the tracer disabled vs enabled
      (the disabled path must be a no-op: ``span()`` returns the
      shared null span);
    * **capture → replay** — price a synthetic bursty trace under the
      single declared-mix plan (one operator-declared payload size, see
      :func:`repro.obs.declared_mix`) vs per-phase-window plans
      compiled from :func:`repro.obs.fold` output.  Phase-aware
      planning must not lose to the stationary plan.
    """
    import statistics

    from repro.fabric import make_datacenter, probe_fabric, scramble
    from repro.obs import declared_mix, fold, replay, synthetic_bursty_trace
    from repro.plan import PlanCompiler, SolveBudget

    n = 16 if smoke else 32
    iters = 60 if smoke else 200
    reps = 5 if smoke else 9
    fab, _ = scramble(make_datacenter(n, seed=seed), seed=seed + 1)
    probe = probe_fabric(fab, seed=seed)
    compiler = PlanCompiler(budget=SolveBudget(iters=iters, chains=2))

    trace = synthetic_bursty_trace(n, seed=seed)
    stationary_mix = declared_mix(trace)

    tr = obs.tracer()
    was_enabled = tr.enabled
    timings: Dict[str, float] = {}
    try:
        for mode, enable in (("disabled", False), ("enabled", True)):
            tr.set_enabled(enable)
            samples = []
            for _ in range(reps):
                t = tr.timer("bench.obs.compile")   # measures even when off
                with t:
                    compiler.compile(probe, stationary_mix)
                samples.append(t.elapsed)
            timings[mode] = statistics.median(samples)
    finally:
        tr.set_enabled(was_enabled)
    overhead_pct = (timings["enabled"] / max(timings["disabled"], 1e-12)
                    - 1.0) * 100.0

    declared_plan = compiler.compile(probe, stationary_mix)
    windows = fold(trace, window_s=window_s)
    phased = [(w, compiler.compile(probe, w.mix)) for w in windows]
    base = replay(trace, declared_plan, probe.lat, probe.bw)
    ph = replay(trace, declared_plan, probe.lat, probe.bw, windows=phased)
    return {
        "bench": "obs",
        "smoke": bool(smoke),
        "n": n,
        "seed": seed,
        "compile": {
            "disabled_s": round(timings["disabled"], 6),
            "enabled_s": round(timings["enabled"], 6),
            "overhead_pct": round(overhead_pct, 3),
            "reps": reps,
        },
        "replay": {
            "trace": trace.name,
            "records": len(trace),
            "windows": len(windows),
            "declared_s": base["total_seconds"],
            "phased_s": ph["total_seconds"],
            "phased_beats_declared":
                ph["total_seconds"] <= base["total_seconds"],
            "unplanned": base["unplanned"] + ph["unplanned"],
        },
    }


def cmd_bench_obs(args: argparse.Namespace) -> int:
    """Observability scenario: tracing-overhead gate + capture→replay.

    Fails (exit 1) if enabled-tracer overhead exceeds 10% (CI noise
    headroom over the 2% budget recorded in BENCH_obs.json) or if the
    phase-windowed plans lose to the single declared-mix plan."""
    payload = run_obs_scenario(smoke=bool(args.smoke), seed=args.seed)
    print(json.dumps(payload, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {args.out}")
    if payload["compile"]["overhead_pct"] >= 10.0:
        print("[bench] FAIL: enabled-tracer overhead "
              f"{payload['compile']['overhead_pct']:.1f}% >= 10%")
        return 1
    if not payload["replay"]["phased_beats_declared"]:
        print("[bench] FAIL: phase-windowed plans lost to the single "
              "declared-mix plan on the bursty trace")
        return 1
    return 0


def cmd_bench_overlap(args: argparse.Namespace) -> int:
    """Overlap scenario: planned+bucketed vs planned-sequential step.

    Thin CLI front for :mod:`benchmarks.overlap_step` (modeled-fabric
    pipeline gate + 8-device host-mesh numeric equivalence); fails
    (exit 1) when the bucketed step models under the 1.15x floor, the
    overlapped loss diverges from the baseline, or the certified
    schedule's postcondition breaks."""
    import importlib

    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    sys.path.insert(0, repo)
    try:
        mod = importlib.import_module("benchmarks.overlap_step")
    except ImportError as e:
        print(f"[bench] benchmarks/ not importable from {repo}: {e}")
        return 1
    try:
        mod.run(smoke=bool(args.smoke),
                out_path=args.out or "BENCH_overlap.json", seed=args.seed)
    except RuntimeError as e:
        print(f"[bench] FAIL: {e}")
        return 1
    return 0


def cmd_bench(args: argparse.Namespace) -> int:
    """Self-contained plan-pipeline benchmark (CI smoke + local sanity).

    Times, per fabric size: cold compile, warm cache hit, and the plan's
    expected speedup over the identity order — through the same Session
    facade applications use.

    ``--scenario faults`` switches to the churn/recovery scenario
    (:func:`cmd_bench_faults`); ``--scenario obs`` to the observability
    overhead + capture→replay scenario (:func:`cmd_bench_obs`);
    ``--scenario overlap`` to the overlapped-train-step gate
    (:func:`cmd_bench_overlap`).
    """
    from repro.session import Session

    if getattr(args, "scenario", "plan") == "faults":
        return cmd_bench_faults(args)
    if getattr(args, "scenario", "plan") == "obs":
        return cmd_bench_obs(args)
    if getattr(args, "scenario", "plan") == "overlap":
        return cmd_bench_overlap(args)
    sizes = [16] if args.smoke else [32, 64]
    iters = 200 if args.smoke else 800
    results: List[Dict[str, Any]] = []
    for n in sizes:
        cfg = session_config_from_args(args)
        cfg = cfg.replace(
            fabric={"kind": "datacenter", "nodes": n, "scramble_seed": 1},
            mesh={"shape": ()},
            cache={"dir": None},
            solver={"budget": {"iters": iters, "chains": 4}})
        with Session(cfg) as s:
            cold = obs.tracer().timer("bench.cold_compile", n=n)
            with cold:
                plan = s.plan()
            cold_s = cold.elapsed
            warm = obs.tracer().timer("bench.warm_hit", n=n)
            with warm:
                s.service.request(s.probe, s.mix)    # warm: LRU probe
            warm_s = warm.elapsed
            speedups = [
                e.best_identity_time / max(e.expected_time, 1e-30)
                for e in plan.entries.values()
            ]
            row = {
                "n": n,
                "entries": len(plan.entries),
                "cold_compile_s": round(cold_s, 4),
                "warm_hit_s": round(warm_s, 6),
                "warm_speedup_x": round(cold_s / max(warm_s, 1e-9), 1),
                "mean_speedup_vs_identity":
                    round(sum(speedups) / len(speedups), 3),
                "cache_hits": s.service.stats["cache_hits"],
            }
        results.append(row)
        print(f"bench,n={n},{row['cold_compile_s'] * 1e6:.0f},"
              f"warm_x={row['warm_speedup_x']}")
    payload = {"bench": "session_plan", "smoke": bool(args.smoke),
               "results": results}
    print(json.dumps(payload, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote {args.out}")
    for row in results:
        if row["cache_hits"] < 1:
            print("[bench] FAIL: warm request missed the plan cache")
            return 1
    return 0


# ---------------------------------------------------------------------------
# status / trace
# ---------------------------------------------------------------------------

def cmd_status(args: argparse.Namespace) -> int:
    """Print the process obs-metrics snapshot (JSON or Prometheus text).

    By default a small dry-run session (attach + plan, no cache writes)
    is driven first so the snapshot reflects a live pipeline; pass
    ``--no-run`` to dump whatever the process has already recorded.
    """
    cfg = session_config_from_args(args)
    if _maybe_dump(args, cfg):
        return 0
    if not args.no_run:
        from repro.session import Session

        run_cfg = cfg.replace(
            mesh={"shape": ()}, cache={"dir": None},
            **({} if args.iters is not None
               else {"solver": {"budget": {"iters": 60, "chains": 2}}}))
        with Session(run_cfg) as s:
            s.attach()
            s.plan()
    m = obs.metrics()
    if args.format == "prom":
        sys.stdout.write(m.to_prometheus())
    else:
        print(json.dumps(m.snapshot(), indent=1))
    return 0


def cmd_trace_export(args: argparse.Namespace) -> int:
    """Run the planning pipeline under the tracer, export Chrome JSON.

    The artifact loads in ``chrome://tracing`` and https://ui.perfetto.dev.
    """
    cfg = session_config_from_args(args)
    if _maybe_dump(args, cfg):
        return 0
    from repro.session import Session

    tr = obs.tracer()
    tr.set_enabled(True)
    run_cfg = cfg.replace(
        mesh={"shape": ()}, cache={"dir": None},
        **({} if args.iters is not None
           else {"solver": {"budget": {"iters": 60, "chains": 2}}}))
    with Session(run_cfg) as s:
        s.attach()
        s.plan()
    n_events = tr.export(args.out)
    print(f"[trace] wrote {n_events} events to {args.out} "
          f"(open in chrome://tracing or ui.perfetto.dev)")
    return 0


def cmd_trace_replay(args: argparse.Namespace) -> int:
    """Replay a captured (or synthetic bursty) workload trace.

    Compares the single declared-mix plan against per-phase-window
    plans compiled from the folded trace; prints both totals.
    """
    from repro.fabric import make_datacenter, probe_fabric, scramble
    from repro.obs import (WorkloadTrace, declared_mix, fold, replay,
                           synthetic_bursty_trace)
    from repro.plan import PlanCompiler, SolveBudget

    if args.trace:
        trace = WorkloadTrace.load(args.trace)
        n = int(trace.meta.get("n", args.nodes or 16))
    else:
        n = args.nodes or 16          # session-args --nodes, default 16
        trace = synthetic_bursty_trace(n, seed=args.seed)
    if not len(trace):
        print("[trace] empty trace: nothing to replay")
        return 1
    fab, _ = scramble(make_datacenter(n, seed=args.seed),
                      seed=args.seed + 1)
    probe = probe_fabric(fab, seed=args.seed)
    compiler = PlanCompiler(
        budget=SolveBudget(iters=args.iters or 200, chains=2))
    declared_plan = compiler.compile(probe, declared_mix(trace))
    windows = fold(trace, window_s=args.window)
    phased = [(w, compiler.compile(probe, w.mix)) for w in windows]
    base = replay(trace, declared_plan, probe.lat, probe.bw)
    ph = replay(trace, declared_plan, probe.lat, probe.bw, windows=phased)
    print(f"[trace] replay {trace.name}: {len(trace)} records, "
          f"{len(windows)} phase windows (window={args.window}s), n={n}")
    print(f"  declared-mix plan : {base['total_seconds'] * 1e3:.3f}ms "
          f"({base['unplanned']} unplanned)")
    print(f"  phase-window plans: {ph['total_seconds'] * 1e3:.3f}ms "
          f"({ph['unplanned']} unplanned)")
    win = base["total_seconds"] / max(ph["total_seconds"], 1e-30)
    print(f"  phased vs declared: {win:.4f}x")
    if args.out:
        payload = {"trace": trace.name, "n": n, "windows": len(windows),
                   "declared": base, "phased": ph}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[trace] wrote {args.out}")
    return 0


# ---------------------------------------------------------------------------
# analyze
# ---------------------------------------------------------------------------

def _analyze_sweep(n_list, fabric_nodes, seed):
    """Verify the full builder catalogue; returns (reports, n_bad)."""
    import random

    from repro.collective import (
        CollectiveOp, apply_permutation, chunk, compile_op, get_builder,
        registered_builders)
    from repro.collective.builders import candidates
    from repro.analysis import verify_program

    fab = None
    if fabric_nodes:
        from repro.fabric import make_datacenter
        fab = make_datacenter(fabric_nodes, seed=seed)
    reports = []
    n_bad = 0
    for algo in sorted(registered_builders()):
        b = get_builder(algo)
        for kind in b.kinds:
            for n in n_list:
                # candidates() supplies the feasible kwarg sets (e.g.
                # every valid bcube base at this n)
                akws = [akw for a, akw in candidates(kind, n) if a == algo]
                op = CollectiveOp(kind=kind, size_bytes=1 << 20,
                                  group=tuple(range(n)))
                for akw in akws:
                    base = compile_op(op, algo, **dict(akw))
                    rng = random.Random(seed + n)
                    perm = list(range(n))
                    rng.shuffle(perm)
                    variants = (("identity", base),
                                ("permuted", apply_permutation(base, perm)),
                                ("chunked", chunk(base, 4)))
                    for label, prog in variants:
                        use_fab = fab if fab is not None and \
                            fab.n == prog.n else None
                        rep = verify_program(prog, fabric=use_fab)
                        reports.append((label, rep))
                        if not rep.clean:
                            n_bad += 1
    return reports, n_bad


def _equiv_sweep(n_list, seed):
    """Differential translation validation over the builder catalogue.

    Every registered builder × kind × n is lowered and bisimulated at
    each rewrite stage (base → apply_permutation → chunk →
    fuse_rounds).  Returns (rows, n_bad) where each row is one
    program's stage-by-stage verdict list.
    """
    import random

    from repro.collective import CollectiveOp, compile_op, get_builder, \
        registered_builders
    from repro.collective.builders import candidates
    from repro.analysis import certify_stages

    rows = []
    n_bad = 0
    for algo in sorted(registered_builders()):
        b = get_builder(algo)
        for kind in b.kinds:
            for n in n_list:
                akws = [akw for a, akw in candidates(kind, n) if a == algo]
                op = CollectiveOp(kind=kind, size_bytes=1 << 20,
                                  group=tuple(range(n)))
                for akw in akws:
                    prog = compile_op(op, algo, **dict(akw))
                    rng = random.Random(seed + n)
                    perm = list(range(n))
                    rng.shuffle(perm)
                    stages = certify_stages(prog, perm=perm, chunk_k=4)
                    ok = all(s["ok"] for s in stages)
                    if not ok:
                        n_bad += 1
                    rows.append({
                        "algorithm": algo, "kind": kind, "n": n,
                        "algo_kwargs": dict(akw), "ok": ok,
                        "stages": stages,
                    })
    return rows, n_bad


def cmd_analyze(args: argparse.Namespace) -> int:
    """Static analysis: lint the repo, or verify collective Programs."""
    if args.lint:
        import os as _os

        from repro.analysis.lint import RULES, lint_repo

        root = args.root or _os.getcwd()
        findings, n_files = lint_repo(root)
        for f in findings:
            print(f)
        verdict = "clean" if not findings else f"{len(findings)} finding(s)"
        print(f"[lint] {n_files} files, {len(RULES)} rules: {verdict}")
        return 1 if findings else 0

    if args.equiv:
        n_list = [int(x) for x in args.n_list.split(",")]
        rows, n_bad = _equiv_sweep(n_list, args.seed)
        for row in rows:
            if row["ok"]:
                continue
            for st in row["stages"]:
                if st["ok"]:
                    continue
                print(f"  FAIL {row['algorithm']}/{row['kind']} "
                      f"n={row['n']} stage={st['stage']} "
                      f"codes={sorted(st['codes'])}")
        by_algo: Dict[str, int] = {}
        for row in rows:
            by_algo.setdefault(row["algorithm"], 0)
            if not row["ok"]:
                by_algo[row["algorithm"]] += 1
        for algo in sorted(by_algo):
            total = sum(1 for r in rows if r["algorithm"] == algo)
            state = "CERTIFIED" if not by_algo[algo] \
                else f"{by_algo[algo]} FAILING"
            print(f"  {algo:<22} {total:>3} programs  {state}")
        print(f"[analyze] equiv: {len(rows)} programs x "
              f"{len(rows[0]['stages']) if rows else 0} stages, "
              f"{n_bad} failing")
        if args.out:
            payload = {"n_programs": len(rows), "n_bad": n_bad,
                       "n_list": n_list, "rows": rows}
            with open(args.out, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"[analyze] wrote {args.out}")
        return 1 if n_bad else 0

    if args.program:
        from repro.collective import CollectiveOp, compile_op, get_builder
        from repro.analysis import verify_program

        algo = args.program
        b = get_builder(algo)
        n = args.nodes or 16
        if not b.feasible(n):
            print(f"[analyze] {algo} is infeasible at n={n}")
            return 1
        fab = None
        if args.fabric_nodes:
            from repro.fabric import make_datacenter
            fab = make_datacenter(n, seed=args.seed)
        bad = 0
        for kind in b.kinds:
            op = CollectiveOp(kind=kind, size_bytes=args.payload_bytes
                              or (1 << 20), group=tuple(range(n)))
            rep = verify_program(compile_op(op, algo), fabric=fab)
            print(rep.describe())
            bad += 0 if rep.ok else 1
        return 1 if bad else 0

    if args.plan:
        from repro.session import Session
        from repro.analysis import verify_program

        cfg = session_config_from_args(args)
        if _maybe_dump(args, cfg):
            return 0
        bad = 0
        with Session(cfg) as s:
            plan = s.plan()
            fab = s._oracle_fabric
            for (op, bucket, group), e in sorted(plan.entries.items()):
                prog = e.program()
                use_fab = fab if fab is not None and fab.n >= max(group) + 1 \
                    else None
                rep = verify_program(prog, fabric=use_fab)
                print(f"  {op:<15} bucket=2^{bucket:<3} "
                      f"group={len(group):>4} {rep.summary()}")
                bad += 0 if rep.ok else 1
        print(f"[analyze] plan: {bad} failing entr{'y' if bad == 1 else 'ies'}"
              if bad else "[analyze] plan: all entries verified")
        return 1 if bad else 0

    # default: full-catalogue sweep
    n_list = [int(x) for x in args.n_list.split(",")]
    reports, n_bad = _analyze_sweep(n_list, args.fabric_nodes, args.seed)
    by_algo: Dict[str, int] = {}
    for label, rep in reports:
        by_algo[rep.algorithm] = by_algo.get(rep.algorithm, 0)
        if not rep.clean:
            by_algo[rep.algorithm] += 1
            print(rep.describe())
    for algo in sorted(by_algo):
        n_variants = sum(1 for _, r in reports if r.algorithm == algo)
        state = "CLEAN" if not by_algo[algo] else f"{by_algo[algo]} DIRTY"
        print(f"  {algo:<22} {n_variants:>3} variants  {state}")
    print(f"[analyze] {len(reports)} programs verified, "
          f"{n_bad} with errors/warnings")
    if args.out:
        payload = {
            "n_programs": len(reports),
            "n_bad": n_bad,
            "n_list": n_list,
            "reports": [dict(variant=label, **rep.to_dict())
                        for label, rep in reports],
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[analyze] wrote {args.out}")
    return 1 if n_bad else 0


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="Cloud Collectives: probe, plan, train, serve, bench")
    from repro import __version__

    ap.add_argument("--version", action="version",
                    version=f"repro {__version__}")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("probe", help="probe a fabric, print/export the result")
    _add_session_args(p)
    p.add_argument("--out", default=None, help="write probe JSON here")
    p.set_defaults(fn=cmd_probe)

    p = sub.add_parser("plan", help="compile (or fetch) a collective plan")
    _add_session_args(p)
    p.add_argument("--dry-run", action="store_true",
                   help="compile + report without touching the plan store")
    p.add_argument("--out", default=None, help="write the plan JSON here")
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("train", help="train on a planned (reordered) mesh")
    _add_session_args(p)
    p.add_argument("--arch", default="qwen2-0.5b")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--reorder", choices=["none", "simulate", "probe"],
                   default="simulate")
    p.add_argument("--smoke", action="store_true", default=True,
                   help="reduced config (CPU); drop on a real fleet")
    p.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    p.add_argument("--lr", type=float, default=1e-3)
    p.set_defaults(fn=cmd_train, mesh_default="1x1")

    p = sub.add_parser("serve", help="batched generation on a planned mesh")
    _add_session_args(p)
    p.add_argument("--arch", default="qwen2-0.5b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--reorder", choices=["none", "simulate", "probe"],
                   default="simulate")
    p.add_argument("--smoke", action="store_true", default=True)
    p.set_defaults(fn=cmd_serve, mesh_default="1x1")

    p = sub.add_parser("bench", help="session/plan pipeline benchmark")
    _add_session_args(p)
    p.add_argument("--smoke", action="store_true",
                   help="one small fabric (CI)")
    p.add_argument("--scenario", default="plan",
                   choices=["plan", "faults", "obs", "overlap"],
                   help="plan: compile/cache pipeline; faults: seeded "
                        "churn with ladder recovery; obs: tracing "
                        "overhead + capture/replay; overlap: bucketed "
                        "overlapped train step vs sequential")
    p.add_argument("--seed", type=int, default=0,
                   help="scenario seed (faults schedule / obs trace)")
    p.add_argument("--out", default=None, help="write bench JSON here")
    p.set_defaults(fn=cmd_bench)

    p = sub.add_parser("analyze",
                       help="static analysis: verify Programs / lint repo")
    _add_session_args(p)
    p.add_argument("--lint", action="store_true",
                   help="run the repo's AST lint gate instead of the "
                        "program verifier")
    p.add_argument("--root", default=None,
                   help="repo root for --lint (default: cwd)")
    p.add_argument("--program", default=None, metavar="ALGO",
                   help="verify one registered builder's program")
    p.add_argument("--plan", action="store_true",
                   help="verify every entry of the session's plan")
    p.add_argument("--equiv", action="store_true",
                   help="differential translation validation: lower + "
                        "bisimulate every builder at each rewrite stage")
    p.add_argument("--n-list", default="4,8,16,64",
                   help="sweep group sizes (default: 4,8,16,64)")
    p.add_argument("--fabric-nodes", type=int, default=None,
                   help="attach a synthetic datacenter fabric of this "
                        "size for the contention pass")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None,
                   help="write the verification report JSON here")
    p.set_defaults(fn=cmd_analyze)

    p = sub.add_parser("status",
                       help="obs metrics snapshot (json or prometheus)")
    _add_session_args(p)
    p.add_argument("--format", default="json", choices=["json", "prom"])
    p.add_argument("--no-run", action="store_true",
                   help="skip the dry-run pipeline; dump current metrics")
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("trace", help="export or replay obs traces")
    tsub = p.add_subparsers(dest="trace_cmd", required=True)

    t = tsub.add_parser("export",
                        help="run the pipeline traced, write Chrome JSON")
    _add_session_args(t)
    t.add_argument("--out", default="trace.json",
                   help="Chrome trace-event JSON path")
    t.set_defaults(fn=cmd_trace_export)

    t = tsub.add_parser("replay",
                        help="replay a captured/synthetic workload trace")
    _add_session_args(t)
    t.add_argument("--trace", default=None,
                   help="WorkloadTrace JSON (default: synthetic bursty)")
    t.add_argument("--window", type=float, default=1.0,
                   help="fold window seconds")
    t.add_argument("--seed", type=int, default=0)
    t.add_argument("--out", default=None, help="write comparison JSON here")
    t.set_defaults(fn=cmd_trace_replay)

    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    # train/serve build meshes: give --mesh a launcher default of 1x1
    if getattr(args, "mesh", None) is None and hasattr(args, "mesh_default"):
        args.mesh = args.mesh_default
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
