"""``python -m repro`` — the single CLI entry point (see repro.cli)."""

import sys

from repro.cli import main

if __name__ == "__main__":
    sys.exit(main())
