"""Fault-tolerant checkpointing: atomic, manifested, optionally async.

Layout::

    <dir>/step_000123/
        arrays.npz          # flattened leaves (host-gathered)
        manifest.json       # tree structure, shapes, dtypes, step, extras
    <dir>/LATEST            # atomic pointer file (write-temp + rename)

Guarantees:
* a checkpoint is visible (pointed to by LATEST) only after all bytes are
  durably on disk (tmp-dir + ``os.replace`` rename);
* interrupted saves leave the previous LATEST intact — restart-safe;
* ``AsyncCheckpointer`` snapshots to host memory synchronously (cheap)
  and writes on a background thread so the train loop never blocks on IO.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _flatten_with_names(tree: Any):
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, jax.tree.structure(tree)


def save(directory: str, step: int, tree: Any,
         extras: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous checkpoint.  Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    host = [np.asarray(x) for x in leaves]

    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_save_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": int(step),
            "names": names,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extras": extras or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(directory: str, like: Any, step: Optional[int] = None
            ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a template pytree).

    Leaves are returned as numpy; callers re-device-put with their own
    shardings (which is what makes restore work across *different* mesh
    shapes after an elastic restart).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest["names"]))]
    flat_like, tdef = jax.tree.flatten(like)
    assert len(flat_like) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, template {len(flat_like)}")
    for a, b in zip(flat_like, leaves):
        assert tuple(a.shape) == tuple(b.shape), (a.shape, b.shape)
    return tdef.unflatten(leaves), manifest["step"], manifest["extras"]


class AsyncCheckpointer:
    """Snapshot-now, write-later checkpointing.

    ``save`` copies device arrays to host synchronously (the only part
    that must be consistent with training state) then spawns a writer
    thread.  ``wait()`` joins the in-flight write; a new save waits for
    the previous one (single-writer discipline).
    """

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any,
             extras: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save(self.directory, step, host_tree, extras)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
