"""RecurrentGemma / Griffin — RG-LRU recurrent blocks + local attention.

[arXiv:2402.19427]  Layer pattern cycles (R, R, A): two recurrent blocks
per local-attention block.  The recurrent block is::

    x -> GeLU(W_gate x)  *  RG-LRU(conv1d_4(W_in x))  -> W_out

with the RG-LRU diagonal recurrence (c = 8)::

    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          # input gate
    a_t = exp(-c * softplus(L) * r_t)     # data-dependent decay in (0,1)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The attention block is MQA (kv=1) with RoPE and a sliding window of 2048,
so decode state is O(window) + O(d) per recurrent layer — this arch runs
the ``long_500k`` cell (DESIGN.md §4).

Simplification noted in DESIGN.md: Griffin produces the RG-LRU gates with
block-diagonal projections; we use dense ``[d_rnn, d_rnn]`` ones.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L

Params = Dict[str, Any]
C_RGLRU = 8.0


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RG-LRU recurrence (oracle for any fused kernel; scan over time)
# ---------------------------------------------------------------------------

def rglru_recurrence(
    x: jnp.ndarray,        # [B, S, D] (post-conv)
    r_gate: jnp.ndarray,   # [B, S, D] sigmoid already applied
    i_gate: jnp.ndarray,   # [B, S, D]
    log_lambda: jnp.ndarray,  # [D] softplus'd decay parameter
    h0: Optional[jnp.ndarray] = None,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Diagonal linear recurrence, chunk-checkpointed over time.

    A flat scan saves f32 [S, B, D] step residuals for backward — the
    dominant memory of the recurrentgemma train cell (EXPERIMENTS
    §Perf-E).  Scanning over S/chunk checkpointed segments saves only the
    per-segment carry (f32 [S/chunk, B, D]) and recomputes each segment's
    steps during its own backward — a 1/chunk memory cut for one extra
    forward of elementwise work.
    """
    B, S, D = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)
    log_a = (-C_RGLRU * log_lambda[None, None] * r_gate).astype(jnp.float32)
    a = jnp.exp(log_a)
    # the gated input tolerates bf16 (it is added once, not compounded);
    # the decay `a` stays f32 — it multiplies across up to S steps.
    gated = ((i_gate * x).astype(jnp.float32) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))).astype(x.dtype)

    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t.astype(jnp.float32)
        return h, h

    def segment(h, inp):
        a_c, g_c = inp                        # [chunk, B, D] time-major
        return jax.lax.scan(step, h, (a_c, g_c))

    a_tm = a.transpose(1, 0, 2)
    g_tm = gated.transpose(1, 0, 2)
    if chunk and S % chunk == 0 and S > chunk:
        n = S // chunk
        a_ch = a_tm.reshape(n, chunk, B, D)
        g_ch = g_tm.reshape(n, chunk, B, D)
        h_last, ys = jax.lax.scan(
            lambda h, inp: jax.checkpoint(segment)(h, inp), h0, (a_ch, g_ch))
        ys = ys.reshape(S, B, D)
    else:
        h_last, ys = segment(h0, (a_tm, g_tm))
    return ys.transpose(1, 0, 2).astype(x.dtype), h_last


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width W.  x: [B,S,D], w: [W,D].

    Returns (y, new_state) with state = last W-1 inputs [B, W-1, D].
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : W - 1])
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # [B, S+W-1, D]
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W)) + b
    return y, xp[:, -(W - 1):]


class RecurrentGemmaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = cfg.layer_kinds()                   # ('R','R','A',...)
        self.pattern = cfg.block_pattern
        self.n_groups, self.n_tail = divmod(cfg.n_layers, len(self.pattern))

    # -- init -------------------------------------------------------------
    def _init_rec_block(self, rng) -> Params:
        cfg = self.cfg
        d, dt = cfg.d_model, _dtype(cfg)
        W = cfg.rglru_conv_width
        r = jax.random.split(rng, 8)
        return {
            "norm": jnp.ones((d,), dt),
            "w_gate": L.dense_init(r[0], (d, d), dtype=dt),
            "w_in": L.dense_init(r[1], (d, d), dtype=dt),
            "conv_w": L.dense_init(r[2], (W, d), scale=0.1, dtype=dt),
            "conv_b": jnp.zeros((d,), dt),
            "w_a": L.dense_init(r[3], (d, d), dtype=dt),
            "b_a": jnp.zeros((d,), dt),
            "w_x": L.dense_init(r[4], (d, d), dtype=dt),
            "b_x": jnp.zeros((d,), dt),
            "lam": jnp.full((d,), 0.7, dt),              # softplus -> decay
            "w_out": L.dense_init(r[5], (d, d), dtype=dt),
            "mlp_norm": jnp.ones((d,), dt),
            "mlp": L.init_mlp(r[6], d, cfg.d_ff, dt),
        }

    def _init_attn_block(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 2)
        return {
            "norm": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attention(r[0], cfg, dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
            "mlp": L.init_mlp(r[1], cfg.d_model, cfg.d_ff, dt),
        }

    def _init_group(self, rng) -> Params:
        """One (R, R, A) super-block (scanned unit)."""
        r = jax.random.split(rng, len(self.pattern))
        out: Params = {}
        for i, kind in enumerate(self.pattern):
            key = f"{kind}{i}"
            out[key] = (
                self._init_rec_block(r[i]) if kind == "R"
                else self._init_attn_block(r[i])
            )
        return out

    def init(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 3 + self.n_groups + self.n_tail)
        groups = [self._init_group(r[3 + i]) for i in range(self.n_groups)]
        params: Params = {
            "embed": L.dense_init(r[0], (cfg.vocab_size, cfg.d_model),
                                  scale=0.02, dtype=dt),
            "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": L.dense_init(r[1], (cfg.d_model, cfg.vocab_size),
                                    scale=0.02, dtype=dt),
        }
        if self.n_tail:
            params["tail"] = [
                self._init_rec_block(r[3 + self.n_groups + i])
                if self.pattern[i] == "R" else self._init_attn_block(
                    r[3 + self.n_groups + i])
                for i in range(self.n_tail)
            ]
        return params

    # -- forward blocks -----------------------------------------------------
    def _rec_block_fwd(self, p, x, h0=None, conv_state=None):
        cfg = self.cfg
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        gate = jax.nn.gelu(h @ p["w_gate"])
        u = h @ p["w_in"]
        u, new_conv = _causal_conv1d(u, p["conv_w"], p["conv_b"], conv_state)
        r_gate = jax.nn.sigmoid(h @ p["w_a"] + p["b_a"])
        i_gate = jax.nn.sigmoid(h @ p["w_x"] + p["b_x"])
        lam = jax.nn.softplus(p["lam"].astype(jnp.float32))
        y, new_h = rglru_recurrence(u, r_gate, i_gate, lam, h0)
        x = x + (gate * y) @ p["w_out"]
        m = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], m)
        return x, new_h, new_conv

    def _attn_block_fwd(self, p, x, positions):
        cfg = self.cfg
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        out, kv = L.attention(p["attn"], h, cfg, causal=True,
                              positions=positions, window=cfg.attn_window)
        x = x + out
        m = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], m)
        return x, kv

    def _group_fwd(self, gp, x, positions):
        if self.cfg.sequence_parallel:
            x = L.sp_constrain(x)
        for i, kind in enumerate(self.pattern):
            p = gp[f"{kind}{i}"]
            if kind == "R":
                x, _, _ = self._rec_block_fwd(p, x)
            else:
                x, _ = self._attn_block_fwd(p, x, positions)
        return x

    def forward(self, params, tokens, frontend_embeds=None,
                return_features=False):
        cfg = self.cfg
        x = params["embed"][tokens] * math.sqrt(cfg.d_model)
        positions = jnp.arange(tokens.shape[1])

        def body(x, gp):
            fn = self._group_fwd
            if cfg.remat == "block":
                fn = jax.checkpoint(fn, static_argnums=())
            return fn(gp, x, positions), None

        if cfg.use_scan:
            x, _ = jax.lax.scan(body, x, params["groups"])
        else:
            n = jax.tree.leaves(params["groups"])[0].shape[0]
            for i in range(n):
                gp = jax.tree.map(lambda a: a[i], params["groups"])
                x = self._group_fwd(gp, x, positions)
        for i, p in enumerate(params.get("tail", [])):
            if self.pattern[i] == "R":
                x, _, _ = self._rec_block_fwd(p, x)
            else:
                x, _ = self._attn_block_fwd(p, x, positions)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if return_features:
            return x, jnp.zeros((), jnp.float32)
        return x @ params["lm_head"], jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        from .transformer import lm_loss
        feats, _ = self.forward(params, batch["tokens"], return_features=True)
        return lm_loss(feats, params["lm_head"], batch["labels"],
                       self.cfg.loss_chunk_size)

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch: int, s_max: int, dtype=None) -> Params:
        """Recurrent state + ring-buffer window KV (O(window), not O(S))."""
        cfg = self.cfg
        dt = dtype or _dtype(cfg)
        W = cfg.attn_window
        d = cfg.d_model
        cw = cfg.rglru_conv_width - 1
        n_rec_per_group = sum(1 for k in self.pattern if k == "R")
        n_att_per_group = len(self.pattern) - n_rec_per_group

        def group_cache(n):
            return {
                "h": jnp.zeros((n, n_rec_per_group, batch, d), jnp.float32),
                "conv": jnp.zeros((n, n_rec_per_group, batch, cw, d), dt),
                "k": jnp.zeros((n, n_att_per_group, batch, cfg.n_kv_heads,
                                W, cfg.head_dim), dt),
                "v": jnp.zeros((n, n_att_per_group, batch, cfg.n_kv_heads,
                                W, cfg.head_dim), dt),
            }

        cache: Params = {
            "groups": group_cache(self.n_groups),
            "pos": jnp.zeros((), jnp.int32),
        }
        if self.n_tail:
            n_rec_tail = sum(1 for k in self.pattern[: self.n_tail] if k == "R")
            cache["tail_h"] = jnp.zeros((n_rec_tail, batch, d), jnp.float32)
            cache["tail_conv"] = jnp.zeros((n_rec_tail, batch, cw, d), dt)
        return cache

    def _attn_decode_window(self, p, x, k_cache, v_cache, pos):
        """MQA decode against a ring-buffer window cache.

        Slot = pos % W; each slot's absolute position is reconstructed to
        mask invalid (future/too-old/unwritten) entries.  K is stored
        with RoPE already applied at its absolute position.
        """
        cfg = self.cfg
        B = x.shape[0]
        W = cfg.attn_window
        h = L.rms_norm(x, p["norm"], cfg.norm_eps)
        q, k_new, v_new = L._qkv(p["attn"], h, cfg)
        cos, sin = L.make_rope(pos[None], cfg.head_dim, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
        slot = pos % W
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, 0, slot, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, 0, slot, 0))
        idx = jnp.arange(W)
        base = pos - slot
        abs_pos = jnp.where(idx <= slot, base + idx, base - W + idx)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
        qh = q.reshape(B, KV, G, 1, cfg.head_dim)
        scores = jnp.einsum("bkgqd,bksd->bkgqs", qh, k_cache).astype(jnp.float32)
        scores = scores / math.sqrt(cfg.head_dim)
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
        out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v_cache)
        out = out.reshape(B, cfg.n_heads, 1, cfg.head_dim).transpose(0, 2, 1, 3)
        out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
        x = x + out @ p["attn"]["wo"]
        m = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], m), k_cache, v_cache

    def _group_decode(self, gp, x, gc, pos):
        ri = ai = 0
        new_h, new_conv, new_k, new_v = [], [], [], []
        for i, kind in enumerate(self.pattern):
            p = gp[f"{kind}{i}"]
            if kind == "R":
                x, h, conv = self._rec_block_fwd(
                    p, x, h0=gc["h"][ri], conv_state=gc["conv"][ri])
                new_h.append(h)
                new_conv.append(conv)
                ri += 1
            else:
                x, k, v = self._attn_decode_window(
                    p, x, gc["k"][ai], gc["v"][ai], pos)
                new_k.append(k)
                new_v.append(v)
                ai += 1
        return x, {
            "h": jnp.stack(new_h) if new_h else gc["h"],
            "conv": jnp.stack(new_conv) if new_conv else gc["conv"],
            "k": jnp.stack(new_k) if new_k else gc["k"],
            "v": jnp.stack(new_v) if new_v else gc["v"],
        }

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"][tokens][:, None, :] * math.sqrt(cfg.d_model)

        def body(x, inp):
            gp, gc = inp
            x, nc = self._group_decode(gp, x, gc, pos)
            return x, nc

        if cfg.use_scan:
            x, new_groups = jax.lax.scan(
                body, x, (params["groups"], cache["groups"]))
        else:
            ncs = []
            for i in range(self.n_groups):
                inp = jax.tree.map(
                    lambda a: a[i], (params["groups"], cache["groups"]))
                x, nc = body(x, inp)
                ncs.append(nc)
            new_groups = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        new_cache: Params = {"groups": new_groups, "pos": pos + 1}

        if self.n_tail:
            hs, convs = [], []
            ri = 0
            for i, p in enumerate(params.get("tail", [])):
                if self.pattern[i] == "R":
                    x, h, conv = self._rec_block_fwd(
                        p, x, h0=cache["tail_h"][ri],
                        conv_state=cache["tail_conv"][ri])
                    hs.append(h)
                    convs.append(conv)
                    ri += 1
                else:  # pragma: no cover — pattern puts A last
                    raise NotImplementedError
            new_cache["tail_h"] = jnp.stack(hs)
            new_cache["tail_conv"] = jnp.stack(convs)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (x @ params["lm_head"])[:, 0], new_cache

    def prefill(self, params, tokens, frontend_embeds=None):
        """Prompt pass returning decode-ready state (window KV + h)."""
        cfg = self.cfg
        B, S = tokens.shape
        W = cfg.attn_window
        x = params["embed"][tokens] * math.sqrt(cfg.d_model)
        positions = jnp.arange(S)

        def run_group(gp, x):
            hs, convs, ks, vs = [], [], [], []
            for i, kind in enumerate(self.pattern):
                p = gp[f"{kind}{i}"]
                if kind == "R":
                    x, h, conv = self._rec_block_fwd(p, x)
                    hs.append(h)
                    convs.append(conv)
                else:
                    x, kv = self._attn_block_fwd(p, x, positions)
                    # keep the last W positions, laid out ring-buffer style
                    k, v = kv["k"], kv["v"]
                    ks.append(_to_ring(k, W, S))
                    vs.append(_to_ring(v, W, S))
            return x, (jnp.stack(hs), jnp.stack(convs),
                       jnp.stack(ks), jnp.stack(vs))

        def body(x, gp):
            x, out = run_group(gp, x)
            return x, out

        if cfg.use_scan:
            x, (h, conv, k, v) = jax.lax.scan(body, x, params["groups"])
        else:
            outs = []
            for i in range(self.n_groups):
                gp = jax.tree.map(lambda a: a[i], params["groups"])
                x, o = body(x, gp)
                outs.append(o)
            h, conv, k, v = (
                jnp.stack([o[j] for o in outs]) for j in range(4))
        cache: Params = {
            "groups": {"h": h, "conv": conv, "k": k, "v": v},
            "pos": jnp.asarray(S, jnp.int32),
        }
        if self.n_tail:
            hs, convs = [], []
            for i, p in enumerate(params.get("tail", [])):
                x, hh, conv1 = self._rec_block_fwd(p, x)
                hs.append(hh)
                convs.append(conv1)
            cache["tail_h"] = jnp.stack(hs)
            cache["tail_conv"] = jnp.stack(convs)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (x[:, -1] @ params["lm_head"]), cache


def _to_ring(k: jnp.ndarray, W: int, S: int) -> jnp.ndarray:
    """Last-W slice of [B,KV,S,hd], arranged so slot i holds abs pos
    with (abs % W) == i — matching the decode ring buffer layout."""
    if S <= W:
        pad = jnp.zeros(k.shape[:2] + (W - S,) + k.shape[3:], k.dtype)
        return jnp.concatenate([k, pad], axis=2)
    last = k[:, :, S - W :]                     # abs positions S-W .. S-1
    # slot of abs position p is p % W; roll so that index i holds abs
    # position with i == abs % W.
    shift = (S - W) % W
    return jnp.roll(last, shift, axis=2)
