"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

[arXiv:2404.05892]  Per block: TimeMix (the WKV linear-attention state
recurrence with LoRA-produced, *data-dependent* per-channel decay ``w_t``
— Finch's contribution over Eagle) and ChannelMix (squared-ReLU FFN with
token shift).

State per layer is O(1) in sequence length — ``long_500k`` decode is a
state update, which is why this arch (and the RG-LRU hybrid) are the two
assigned archs that run the 500k cell (DESIGN.md §4).

Training uses ``jax.lax.scan`` over time (XLA path); the blocked Pallas
chunk-scan kernel in :mod:`repro.kernels.rwkv6_scan` is the TPU fast path
validated against :func:`wkv_recurrence` as its oracle.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L

Params = Dict[str, Any]

LORA_R = 32  # decay / token-shift LoRA rank (Finch uses 32-64 by size)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _shift(x: jnp.ndarray, init: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token shift: x_{t-1} (zeros or carried state at t=0).  x: [B,S,D]."""
    pad = jnp.zeros_like(x[:, :1]) if init is None else init[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# WKV recurrence (the oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

def wkv_recurrence(
    r: jnp.ndarray,   # [B, S, H, K]
    k: jnp.ndarray,   # [B, S, H, K]
    v: jnp.ndarray,   # [B, S, H, V]
    w: jnp.ndarray,   # [B, S, H, K]   decay in (0, 1), data dependent
    u: jnp.ndarray,   # [H, K]         bonus for the current token
    state: Optional[jnp.ndarray] = None,  # [B, H, K, V]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """y_t = r_t . (S_{t-1} + (u*k_t) outer v_t);  S_t = diag(w_t) S_{t-1} + k_t outer v_t."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., None] * v_t[..., None, :]          # [B,H,K,V]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + u[None, :, :, None] * kv)
        S_ = w_t[..., None] * S_ + kv
        return S_, y

    xs = (
        r.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        w.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), state  # [B,S,H,V]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

class Rwkv6LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.n_heads = cfg.d_model // cfg.rwkv_head_dim
        self.head_dim = cfg.rwkv_head_dim

    # -- init -----------------------------------------------------------
    def _init_block(self, rng) -> Params:
        cfg = self.cfg
        d, dt = cfg.d_model, _dtype(cfg)
        H, K = self.n_heads, self.head_dim
        r = jax.random.split(rng, 12)
        tm = {
            # static token-shift mixes + data-dependent LoRA (5 targets)
            "maa_x": jnp.zeros((d,), dt),
            "maa": jnp.zeros((5, d), dt),       # w, k, v, r, g
            "maa_A": L.dense_init(r[0], (d, 5 * LORA_R), scale=0.01, dtype=dt),
            "maa_B": L.dense_init(r[1], (5, LORA_R, d), scale=0.01, dtype=dt),
            # decay: w = exp(-exp(w0 + tanh(xw @ A) @ B))
            "w0": jnp.full((d,), -6.0, dt),
            "wA": L.dense_init(r[2], (d, LORA_R * 2), scale=0.01, dtype=dt),
            "wB": L.dense_init(r[3], (LORA_R * 2, d), scale=0.01, dtype=dt),
            "u": jnp.zeros((H, K), dt),          # time_faaaa bonus
            "wr": L.dense_init(r[4], (d, d), dtype=dt),
            "wk": L.dense_init(r[5], (d, d), dtype=dt),
            "wv": L.dense_init(r[6], (d, d), dtype=dt),
            "wg": L.dense_init(r[7], (d, d), dtype=dt),
            "wo": L.dense_init(r[8], (d, d), dtype=dt),
            # per-head GroupNorm (faithful to RWKV's ln_x; also shard-local
            # when heads are sharded over the model axis)
            "ln_x_w": jnp.ones((H, K), dt),
            "ln_x_b": jnp.zeros((H, K), dt),
        }
        cm = {
            "maa_k": jnp.zeros((d,), dt),
            "maa_r": jnp.zeros((d,), dt),
            "wk": L.dense_init(r[9], (d, cfg.d_ff), dtype=dt),
            "wv": L.dense_init(r[10], (cfg.d_ff, d), dtype=dt),
            "wr": L.dense_init(r[11], (d, d), dtype=dt),
        }
        return {
            "ln1": jnp.ones((d,), dt),
            "ln2": jnp.ones((d,), dt),
            "time_mix": tm,
            "channel_mix": cm,
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 3 + cfg.n_layers)
        blocks = [self._init_block(r[3 + i]) for i in range(cfg.n_layers)]
        return {
            "embed": L.dense_init(r[0], (cfg.vocab_size, cfg.d_model),
                                  scale=0.02, dtype=dt),
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": L.dense_init(r[1], (cfg.d_model, cfg.vocab_size),
                                    scale=0.02, dtype=dt),
        }

    # -- time mix ---------------------------------------------------------
    def _time_mix_inputs(self, p: Params, x, sx):
        """Project token-shifted inputs to (r, k, v, w, g)."""
        dx = sx - x
        xxx = x + dx * p["maa_x"]
        dd = jnp.tanh(xxx @ p["maa_A"])                       # [B,S,5R]
        B_, S_, _ = dd.shape
        dd = dd.reshape(B_, S_, 5, LORA_R).transpose(2, 0, 1, 3)
        offsets = jnp.einsum("nbsr,nrd->nbsd", dd, p["maa_B"])  # [5,B,S,D]
        mixed = x[None] + dx[None] * (p["maa"][:, None, None, :] + offsets)
        x_w, x_k, x_v, x_r, x_g = mixed
        r = x_r @ p["wr"]
        k = x_k @ p["wk"]
        v = x_v @ p["wv"]
        g = jax.nn.silu(x_g @ p["wg"])
        w = jnp.exp(-jnp.exp(
            (p["w0"] + jnp.tanh(x_w @ p["wA"]) @ p["wB"]).astype(jnp.float32)
        ))
        return r, k, v, w, g

    def _heads(self, t: jnp.ndarray) -> jnp.ndarray:
        B, S, _ = t.shape
        return t.reshape(B, S, self.n_heads, self.head_dim)

    def _time_mix(self, p, x, sx_init=None, state=None):
        cfg = self.cfg
        B, S, d = x.shape
        sx = _shift(x, sx_init)
        r, k, v, w, g = self._time_mix_inputs(p, x, sx)
        if (cfg.wkv_impl == "kernel" and state is None and S > 1
                and S % 16 == 0):
            # Pallas chunked matmul kernel (fresh-state training path;
            # decode keeps the exact scan — it carries state)
            from repro.kernels import wkv_chunked_op

            y = wkv_chunked_op(
                self._heads(r), self._heads(k), self._heads(v),
                self._heads(w.astype(x.dtype)), p["u"])
            new_state = jnp.zeros(
                (B, self.n_heads, self.head_dim, self.head_dim), jnp.float32)
        else:
            y, new_state = wkv_recurrence(
                self._heads(r), self._heads(k), self._heads(v),
                self._heads(w.astype(x.dtype)), p["u"], state)
        # per-head GroupNorm over the head_dim channels
        yf = y.astype(jnp.float32)
        mu = yf.mean(-1, keepdims=True)
        var = yf.var(-1, keepdims=True)
        yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = (yf * p["ln_x_w"].astype(jnp.float32)
             + p["ln_x_b"].astype(jnp.float32)).astype(y.dtype)
        y = y.reshape(B, S, d)
        y = (y * g) @ p["wo"]
        return y, x[:, -1], new_state

    def _channel_mix(self, p, x, sx_init=None):
        sx = _shift(x, sx_init)
        dx = sx - x
        x_k = x + dx * p["maa_k"]
        x_r = x + dx * p["maa_r"]
        k = jnp.square(jax.nn.relu(x_k @ p["wk"]))
        out = jax.nn.sigmoid(x_r @ p["wr"]) * (k @ p["wv"])
        return out, x[:, -1]

    def _block(self, bp, x):
        cfg = self.cfg
        if cfg.sequence_parallel:
            x = L.sp_constrain(x)
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        att, _, _ = self._time_mix(bp["time_mix"], h)
        x = x + att
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        ffn, _ = self._channel_mix(bp["channel_mix"], h)
        return x + ffn

    # -- training ---------------------------------------------------------
    def forward(self, params, tokens, frontend_embeds=None,
                return_features=False):
        cfg = self.cfg
        x = params["embed"][tokens]

        def body(x, bp):
            fn = self._block
            if cfg.remat == "block":
                fn = jax.checkpoint(fn)
            return fn(bp, x), None

        if cfg.use_scan:
            x, _ = jax.lax.scan(body, x, params["blocks"])
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            for i in range(n):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                x, _ = body(x, bp)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if return_features:
            return x, jnp.zeros((), jnp.float32)
        return x @ params["lm_head"], jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        from .transformer import lm_loss
        feats, _ = self.forward(params, batch["tokens"], return_features=True)
        return lm_loss(feats, params["lm_head"], batch["labels"],
                       self.cfg.loss_chunk_size)

    # -- serving ----------------------------------------------------------
    def init_cache(self, batch: int, s_max: int, dtype=None) -> Params:
        """State cache: O(1) in context length (s_max unused — that is
        the point of an SSM: the 500k cell costs the same as 1k)."""
        cfg = self.cfg
        dt = dtype or _dtype(cfg)
        H, K = self.n_heads, self.head_dim
        n, d = cfg.n_layers, cfg.d_model
        return {
            "att_sx": jnp.zeros((n, batch, d), dt),
            "ffn_sx": jnp.zeros((n, batch, d), dt),
            "wkv": jnp.zeros((n, batch, H, K, K), jnp.float32),
            "pos": jnp.zeros((), jnp.int32),
        }

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        x = params["embed"][tokens][:, None, :]  # [B,1,D]

        def body(x, inp):
            bp, att_sx, ffn_sx, wkv = inp
            h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
            att, new_att_sx, new_wkv = self._time_mix(
                bp["time_mix"], h, sx_init=att_sx, state=wkv)
            x = x + att
            h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
            ffn, new_ffn_sx = self._channel_mix(
                bp["channel_mix"], h, sx_init=ffn_sx)
            return x + ffn, (new_att_sx, new_ffn_sx, new_wkv)

        xs = (params["blocks"], cache["att_sx"], cache["ffn_sx"], cache["wkv"])
        if cfg.use_scan:
            x, (att_sx, ffn_sx, wkv) = jax.lax.scan(body, x, xs)
        else:
            n = cfg.n_layers
            outs = []
            for i in range(n):
                x, o = body(x, jax.tree.map(lambda a: a[i], xs))
                outs.append(o)
            att_sx, ffn_sx, wkv = (
                jnp.stack([o[j] for o in outs]) for j in range(3))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["lm_head"])[:, 0]
        return logits, {
            "att_sx": att_sx, "ffn_sx": ffn_sx, "wkv": wkv,
            "pos": cache["pos"] + 1,
        }

    def prefill(self, params, tokens, frontend_embeds=None):
        """Run the recurrence over the prompt, return final state cache."""
        cfg = self.cfg
        x = params["embed"][tokens]

        def body(x, inp):
            bp = inp
            h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
            att, att_sx, wkv = self._time_mix(bp["time_mix"], h)
            x = x + att
            h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
            ffn, ffn_sx = self._channel_mix(bp["channel_mix"], h)
            return x + ffn, (att_sx, ffn_sx, wkv)

        if cfg.use_scan:
            x, (att_sx, ffn_sx, wkv) = jax.lax.scan(body, x, params["blocks"])
        else:
            outs = []
            for i in range(cfg.n_layers):
                x, o = body(x, jax.tree.map(lambda a: a[i], params["blocks"]))
                outs.append(o)
            att_sx, ffn_sx, wkv = (
                jnp.stack([o[j] for o in outs]) for j in range(3))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x[:, -1] @ params["lm_head"])
        return logits, {
            "att_sx": att_sx, "ffn_sx": ffn_sx, "wkv": wkv,
            "pos": jnp.asarray(tokens.shape[1], jnp.int32),
        }
