"""Model registry: config -> model instance with the uniform interface.

Every model exposes::

    init(rng) -> params
    forward(params, tokens, frontend_embeds=None) -> (logits, aux)
    loss(params, batch) -> scalar
    prefill(params, tokens, frontend_embeds=None) -> (logits[B,V], cache)
    decode_step(params, tokens[B], cache) -> (logits[B,V], cache')
    init_cache(batch, s_max) -> cache
"""

from __future__ import annotations

from repro.configs.base import ModelConfig

from .rglru import RecurrentGemmaLM
from .rwkv6 import Rwkv6LM
from .transformer import DecoderLM
from .whisper import WhisperLM

__all__ = ["get_model"]


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return Rwkv6LM(cfg)
    if cfg.family == "hybrid":
        return RecurrentGemmaLM(cfg)
    if cfg.family == "encdec":
        return WhisperLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
