"""Decoder-only transformer LM: dense (GQA+RoPE), MoE, MLA, VLM backbone.

Covers glm4-9b, qwen2-0.5b, granite-8b, minitron-8b, dbrx-132b,
deepseek-v2-236b and llava-next-mistral-7b (vision stub).

Layer-stacked parameters + ``jax.lax.scan`` keep the HLO size independent
of depth (compiling 60-layer deepseek on the CPU dry-run).  Leading
non-uniform layers (deepseek's first dense layer) are unrolled separately.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


class DecoderLM:
    """Functional decoder-only LM; all methods are jit/pjit friendly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _init_block(self, rng, moe: bool) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 4)
        p: Params = {
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
        }
        if cfg.use_mla:
            p["attn"] = L.init_mla(r[0], cfg, dt)
        else:
            p["attn"] = L.init_attention(r[0], cfg, dt)
        if moe:
            p["moe"] = L.init_moe(r[1], cfg, dt)
        else:
            p["mlp"] = L.init_mlp(r[1], cfg.d_model, cfg.d_ff, dt)
        return p

    def init(self, rng) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        r = jax.random.split(rng, 4 + cfg.n_layers)
        n_head_layers = cfg.n_dense_layers if cfg.n_experts else 0
        n_scan = cfg.n_layers - n_head_layers
        moe = cfg.n_experts > 0

        # Stacked uniform blocks: init each layer then stack leaves.
        blocks = [self._init_block(r[4 + i], moe) for i in range(n_scan)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

        params: Params = {
            "embed": L.dense_init(r[0], (cfg.vocab_size, cfg.d_model),
                                  scale=0.02, dtype=dt),
            "blocks": stacked,
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
        if n_head_layers:
            params["head_blocks"] = [
                self._init_block(r[1], False) for _ in range(n_head_layers)
            ]
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(
                r[2], (cfg.d_model, cfg.vocab_size), scale=0.02, dtype=dt)
        return params

    # ------------------------------------------------------------------
    # blocks
    # ------------------------------------------------------------------
    def _block_fwd(self, p: Params, x, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        if cfg.sequence_parallel:
            x = L.sp_constrain(x)
        h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        if cfg.use_mla:
            attn_out, _ = L.mla_attention(p["attn"], h, cfg, positions)
        else:
            attn_out, _ = L.attention(
                p["attn"], h, cfg, causal=True, positions=positions,
                window=cfg.attn_window)
        x = x + attn_out
        h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if "moe" in p:
            y, aux = L.moe_layer(p["moe"], h, cfg)
        else:
            y, aux = L.mlp(p["mlp"], h), jnp.zeros((), jnp.float32)
        return x + y, aux

    def _embed(self, params: Params, tokens,
               frontend_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.family == "vlm" and frontend_embeds is not None:
            # anyres stub: patch embeddings replace the first n_img slots
            n_img = frontend_embeds.shape[1]
            x = jnp.concatenate(
                [frontend_embeds.astype(x.dtype), x[:, n_img:]], axis=1)
        return x

    def forward(
        self,
        params: Params,
        tokens: jnp.ndarray,
        frontend_embeds: Optional[jnp.ndarray] = None,
        return_features: bool = False,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens [B, S] -> (logits [B, S, V], aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens, frontend_embeds)
        positions = jnp.arange(tokens.shape[1])
        aux_total = jnp.zeros((), jnp.float32)

        for hp in params.get("head_blocks", []):
            x, aux = self._block_fwd(hp, x, positions)
            aux_total = aux_total + aux

        def body(carry, bp):
            x, aux_acc = carry
            fn = self._block_fwd
            if cfg.remat == "block":
                fn = jax.checkpoint(fn)
            x, aux = fn(bp, x, positions)
            return (x, aux_acc + aux), None

        if cfg.use_scan:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["blocks"])
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            for i in range(n):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                (x, aux_total), _ = body((x, aux_total), bp)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if return_features:
            return x, aux_total
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ head
        return logits, aux_total

    # ------------------------------------------------------------------
    # losses
    # ------------------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        feats, aux = self.forward(
            params, batch["tokens"], batch.get("frontend_embeds"),
            return_features=True)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ce = lm_loss(feats, head, batch["labels"], cfg.loss_chunk_size)
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, s_max: int, dtype=None) -> Params:
        cfg = self.cfg
        dt = dtype or _dtype(cfg)
        n_head_layers = cfg.n_dense_layers if cfg.n_experts else 0
        n_scan = cfg.n_layers - n_head_layers

        def one(n):
            if cfg.use_mla:
                return {
                    "ckv": jnp.zeros((n, batch, s_max, cfg.kv_lora_rank), dt),
                    "k_rope": jnp.zeros((n, batch, s_max, cfg.qk_rope_head_dim), dt),
                }
            return {
                "k": jnp.zeros((n, batch, cfg.n_kv_heads, s_max, cfg.head_dim), dt),
                "v": jnp.zeros((n, batch, cfg.n_kv_heads, s_max, cfg.head_dim), dt),
            }

        cache: Params = {"scan": one(n_scan), "pos": jnp.zeros((), jnp.int32)}
        if n_head_layers:
            cache["head"] = one(n_head_layers)
        return cache

    def _block_decode(self, p: Params, x, layer_cache, pos):
        cfg = self.cfg
        h = L.rms_norm(x, p["attn_norm"], cfg.norm_eps)
        if cfg.use_mla:
            attn_out, new_cache = L.mla_attention_decode(
                p["attn"], h, layer_cache, pos, cfg)
        else:
            attn_out, new_cache = L.attention_decode(
                p["attn"], h, layer_cache, pos, cfg)
        x = x + attn_out
        h = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if "moe" in p:
            y, _ = L.moe_layer(p["moe"], h, cfg)
        else:
            y = L.mlp(p["mlp"], h)
        return x + y, new_cache

    def decode_step(
        self, params: Params, tokens: jnp.ndarray, cache: Params
    ) -> Tuple[jnp.ndarray, Params]:
        """tokens [B] -> (logits [B, V], cache').  Window caches use
        pos % window as the write slot (ring buffer)."""
        cfg = self.cfg
        assert not cfg.attn_window, "windowed decode lives in the hybrid model"
        pos = cache["pos"]
        x = params["embed"][tokens][:, None, :]
        new_cache: Params = {"pos": pos + 1}
        write_pos = pos

        if "head_blocks" in params:
            hc = []
            for i, hp in enumerate(params["head_blocks"]):
                lc = jax.tree.map(lambda a: a[i], cache["head"])
                x, nc = self._block_decode(hp, x, lc, write_pos)
                hc.append(nc)
            new_cache["head"] = jax.tree.map(lambda *xs: jnp.stack(xs), *hc)

        def body(x, inp):
            bp, lc = inp
            x, nc = self._block_decode(bp, x, lc, write_pos)
            return x, nc

        if cfg.use_scan:
            x, scan_cache = jax.lax.scan(
                body, x, (params["blocks"], cache["scan"]))
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            ncs = []
            for i in range(n):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                lc = jax.tree.map(lambda a: a[i], cache["scan"])
                x, nc = self._block_decode(bp, x, lc, write_pos)
                ncs.append(nc)
            scan_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        new_cache["scan"] = scan_cache

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return (x @ head)[:, 0], new_cache

    def prefill(
        self,
        params: Params,
        tokens: jnp.ndarray,
        frontend_embeds: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, Params]:
        """Full forward; returns (last-position logits [B, V], cache).

        The cache is sized to the prompt (serving engines re-allocate for
        generation headroom via ``init_cache``).
        """
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens, frontend_embeds)
        positions = jnp.arange(S)
        caches = []

        def run_block(bp, x):
            h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
            if cfg.use_mla:
                attn_out, kv = L.mla_attention(bp["attn"], h, cfg, positions)
            else:
                attn_out, kv = L.attention(
                    bp["attn"], h, cfg, causal=True, positions=positions,
                    window=cfg.attn_window)
            x = x + attn_out
            h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
            if "moe" in bp:
                y, _ = L.moe_layer(bp["moe"], h, cfg)
            else:
                y = L.mlp(bp["mlp"], h)
            return x + y, kv

        for hp in params.get("head_blocks", []):
            x, kv = run_block(hp, x)
            caches.append(("head", kv))

        def body(x, bp):
            x, kv = run_block(bp, x)
            return x, kv

        if cfg.use_scan:
            x, scan_kv = jax.lax.scan(body, x, params["blocks"])
        else:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            kvs = []
            for i in range(n):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                x, kv = run_block(bp, x)
                kvs.append(kv)
            scan_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x[:, -1] @ head

        cache: Params = {"pos": jnp.asarray(S, jnp.int32), "scan": scan_kv}
        head_kvs = [kv for tag, kv in caches if tag == "head"]
        if head_kvs:
            cache["head"] = jax.tree.map(lambda *xs: jnp.stack(xs), *head_kvs)
        return logits, cache


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def lm_loss(features: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray,
            chunk: int = 0) -> jnp.ndarray:
    """Cross entropy from final hidden states, never materializing the
    full [B, S, V] logits: sequence chunks are projected + reduced inside
    a rematerialized scan, so peak memory is [B, chunk, V] (forward AND
    backward).  Essential for the 150k-256k-vocab archs at 1M tokens."""
    from . import layers as L

    B, S, D = features.shape
    # pin the vocab sharding of the head so the chunk-scan's gradient
    # accumulator stays vocab-sharded (an unsharded f32 [D, 256k] grad
    # accumulator costs 4.2 GB/device on the 256k-vocab archs).
    if head.ndim == 2:
        head = L.sp_head_constrain(head)
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        return _xent(features @ head, labels)
    n = S // chunk
    xc = features.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def chunk_loss(xi, li):
        # bf16 operands, f32 accumulation (a post-matmul astype would be
        # hoisted into an f32 copy of the whole head)
        logits = jnp.einsum("bsd,dv->bsv", xi, head,
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    def body(acc, inp):
        xi, li = inp
        return acc + jax.checkpoint(chunk_loss)(xi, li), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)
