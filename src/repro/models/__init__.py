from .model_zoo import get_model  # noqa: F401
